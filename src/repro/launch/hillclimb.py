import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: per selected cell, run the paper-faithful
baseline and each candidate change through the identical dry-run probe,
printing before/after roofline terms for EXPERIMENTS.md §Perf.

Usage: PYTHONPATH=src python -m repro.launch.hillclimb [cell ...]
Cells: qwen3_sp qwen3_dots flux_gen_b1 phi_decode
"""

import dataclasses
import json
import sys

from repro.configs import get_config
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh


def emit(tag, report):
    row = report.row()
    row["tag"] = tag
    row["collectives"] = report.collective_breakdown
    with open("hillclimb.jsonl", "a") as f:
        f.write(json.dumps(row) + "\n")
    print(f"[{tag}] compute={report.compute_s*1e3:.1f}ms "
          f"memory={report.memory_s*1e3:.1f}ms "
          f"collective={report.collective_s*1e3:.1f}ms "
          f"dominant={report.dominant} useful={report.useful_ratio:.2f} "
          f"mem={report.peak_mem_bytes/1e9:.1f}GB")


def qwen3_variants(mesh, which):
    base = get_config("qwen3-32b")
    if which == "sp":
        # Hypothesis: sequence-parallel residual stream cuts the
        # memory-term (norm/elementwise bytes /16) and converts TP
        # all-reduce into RS+AG (same volume, but the duplicated
        # elementwise work disappears from bytes-accessed).
        v = dataclasses.replace(base, train=dataclasses.replace(
            base.train, seq_parallel=True))
        emit("qwen3.train_4k.seq_parallel",
             run_cell("qwen3-32b", "train_4k", mesh=mesh, arch=v,
                      verbose=False))
    elif which == "dots":
        # Hypothesis: saving matmul outputs in remat removes the
        # recomputed-forward matmul FLOPs (~25% of compute term),
        # trading activation memory (checked against the 16 GB budget).
        v = dataclasses.replace(base, train=dataclasses.replace(
            base.train, remat_policy="dots"))
        emit("qwen3.train_4k.remat_dots",
             run_cell("qwen3-32b", "train_4k", mesh=mesh, arch=v,
                      verbose=False))
    elif which == "sp_dots":
        v = dataclasses.replace(base, train=dataclasses.replace(
            base.train, seq_parallel=True, remat_policy="dots"))
        emit("qwen3.train_4k.sp+dots",
             run_cell("qwen3-32b", "train_4k", mesh=mesh, arch=v,
                      verbose=False))


def flux_gen_variants(mesh, which):
    base = get_config("flux-dev")
    if which == "batch_seq":
        # Hypothesis: gen_1024's 94 GB/dev all-gather comes from
        # sequence-sharded tokens being re-gathered for every joint
        # attention; replicating tokens and sharding only heads kills the
        # AG at the cost of replicated FFN token work. Predicted: large
        # collective-term drop, compute-term rise (batch is tiny).
        # Realized by treating the cell as batch-only parallel: override
        # shape batch so seqpar rules put everything on batch/model.
        sh = [dataclasses.replace(s, batch=16) if s.name == "gen_1024"
              else s for s in base.shapes]
        v = dataclasses.replace(base, shapes=tuple(sh))
        emit("flux.gen_1024.batch16",
             run_cell("flux-dev", "gen_1024", mesh=mesh, arch=v,
                      verbose=False))


def phi_decode_variants(mesh, which):
    base = get_config("phi3.5-moe-42b-a6.6b")
    if which == "nofsdp":
        # Hypothesis (iteration 2, after repheads was refuted): the
        # decode collective term is the FSDP weight all-gather — every
        # step re-gathers the data-sharded weights for one token's worth
        # of compute.  Plain TP weights (replicated over 'data') keep
        # 42B/16 = 5.3 GB bf16-class shards per chip and eliminate the
        # gather entirely.  Predicted: collective term collapses;
        # memory/compute unchanged.
        v = dataclasses.replace(base, decode_no_fsdp=True)
        emit("phi.decode_32k.no_fsdp",
             run_cell("phi3.5-moe-42b-a6.6b", "decode_32k", mesh=mesh,
                      arch=v, verbose=False))
        return
    if which == "repheads":
        # Hypothesis: decode_32k is collective-bound because q-heads and
        # the KV cache's sequence dim both want the model axis — GSPMD
        # ping-pongs activations between the two shardings every layer.
        # Replicating q-heads at decode (attention FLOPs are negligible
        # for one token) removes the resharding; FFN/expert TP unchanged.
        v = dataclasses.replace(base, decode_replicate_heads=True)
        emit("phi.decode_32k.replicate_heads",
             run_cell("phi3.5-moe-42b-a6.6b", "decode_32k", mesh=mesh,
                      arch=v, verbose=False))


def main():
    cells = sys.argv[1:] or ["qwen3_sp"]
    mesh = make_production_mesh(multi_pod=False)
    for c in cells:
        if c.startswith("qwen3_"):
            qwen3_variants(mesh, c.split("_", 1)[1])
        elif c == "flux_gen_b1":
            flux_gen_variants(mesh, "batch_seq")
        elif c == "phi_decode":
            phi_decode_variants(mesh, "repheads")
        elif c == "phi_nofsdp":
            phi_decode_variants(mesh, "nofsdp")
        else:
            raise SystemExit(f"unknown cell {c}")


if __name__ == "__main__":
    main()
