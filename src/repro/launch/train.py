"""Training launcher: ``python -m repro.launch.train --arch dit-b2
--shape train_256 --steps 200 [--smoke] [--ckpt-dir DIR] [overrides...]``

Wires: config -> model defs -> sharded train state -> synthetic data
pipeline -> jitted train step -> host loop with async checkpointing and
auto-resume (restart the same command after a crash and it continues
from the newest valid checkpoint).
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.config.base import ShapeSpec, apply_overrides
from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.data import synthetic
from repro.launch.mesh import make_host_mesh
from repro.launch.workloads import build_workload, model_fns
from repro.models.params import init_params
from repro.training import train_loop
from repro.utils.logging import get_logger

log = get_logger("launch.train")


def make_batch_fn(arch, shape: ShapeSpec):
    fam = arch.family
    m = arch.model
    if fam == "lm":
        return lambda spec, i: synthetic.token_batch(
            spec, i, shape.global_batch, shape.seq_len, m.vocab_size)
    if fam in ("dit", "mmdit", "unet", "vdit"):
        def diff_batch(spec, i):
            if fam == "dit":
                g = (1, m.latent_res(shape.img_res), m.latent_res(shape.img_res))
                b = synthetic.latent_video_batch(spec, i, shape.batch, g,
                                                 m.in_channels)
                lat = b["latents"][:, 0]
                key = jax.random.fold_in(jax.random.PRNGKey(spec.seed), i)
                return {"latents": lat,
                        "labels": jax.random.randint(
                            key, (shape.batch,), 0, m.num_classes)}
            if fam == "mmdit":
                lr = shape.img_res // 8
                b = synthetic.latent_video_batch(
                    spec, i, shape.batch, (1, lr, lr), m.in_channels,
                    txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)
                key = jax.random.fold_in(jax.random.PRNGKey(spec.seed + 3), i)
                return {"latents": b["latents"][:, 0], "txt": b["txt"],
                        "vec": 0.05 * jax.random.normal(key, (shape.batch, 768))}
            if fam == "unet":
                lr = shape.img_res // 8
                b = synthetic.latent_video_batch(
                    spec, i, shape.batch, (1, lr, lr), m.in_channels,
                    txt_tokens=m.ctx_tokens, txt_dim=m.ctx_dim)
                return {"latents": b["latents"][:, 0], "ctx": b["txt"]}
            g = m.grid(img_res=shape.img_res)
            b = synthetic.latent_video_batch(
                spec, i, shape.batch,
                (g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch),
                m.in_channels, txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)
            return b
        return diff_batch
    # vision
    return lambda spec, i: synthetic.image_batch(
        spec, i, shape.batch, shape.img_res,
        num_classes=m.num_classes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-override", type=int, default=0)
    ap.add_argument("overrides", nargs="*",
                    help="config overrides like train.learning_rate=1e-4")
    args = ap.parse_args(argv)

    arch = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    arch = apply_overrides(arch, args.overrides)
    shape = arch.shape(args.shape)
    if args.batch_override:
        field = ("global_batch" if arch.family == "lm" else "batch")
        shape = dataclasses.replace(shape, **{field: args.batch_override})
        arch = dataclasses.replace(
            arch, shapes=tuple(shape if s.name == shape.name else s
                               for s in arch.shapes))

    mesh = make_host_mesh() if len(jax.devices()) > 1 else None
    wl = build_workload(arch, args.shape, mesh)
    step_fn = wl.jitted()

    defs = model_fns(arch)
    params = init_params(defs, jax.random.PRNGKey(args.seed))
    state = train_loop.train_state_init(params, arch.train)

    ckpt = None
    start_step = 0
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir, keep=3,
                            async_save=arch.checkpoint.async_save)
        found, restored, extra = ckpt.restore_latest(state)
        if found is not None:
            state, start_step = restored, found
            log.info("resumed from checkpoint step %d", start_step)

    spec = synthetic.DataSpec(seed=args.seed)
    batch_fn = make_batch_fn(arch, shape)
    it = synthetic.batch_iterator(batch_fn, spec, start_index=start_step)

    def wrapped_step(state, batch, rng):
        return step_fn(state, batch, rng)

    state, history = train_loop.run_train_loop(
        wrapped_step, state, it, args.steps, rng=jax.random.PRNGKey(args.seed),
        checkpointer=ckpt, checkpoint_every=args.ckpt_every if ckpt else 0,
        start_step=start_step)
    if ckpt:
        ckpt.wait()
    final = history[-1] if history else {}
    log.info("training done: %s", final)
    return state, history


if __name__ == "__main__":
    main()
