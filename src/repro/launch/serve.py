"""Serving launcher: batched diffusion generation with TimeRipple on.

``python -m repro.launch.serve --arch dit-b2 --shape gen_fast --smoke
--requests 8`` spins up the DiffusionEngine, submits synthetic requests,
and reports latency + the reuse savings actually achieved per step.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.config.base import apply_overrides
from repro.diffusion.sampler import cfg_wrap, ddim_sample, euler_flow_sample
from repro.diffusion.schedule import DDPMSchedule
from repro.launch.workloads import (_denoise_call, attention_plan,
                                    model_fns)  # shared path
from repro.distributed.sharding import NULL_CTX
from repro.models.params import init_params
from repro.serving.engine import DiffusionEngine, GenRequest
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def build_sampler(arch, shape, params, *, use_ripple=True):
    """Returns sample_fn(noise, txt, rng) -> latents and the latent shape."""
    m = arch.model
    fam = arch.family
    steps = shape.steps or 50
    res = shape.img_res

    if fam == "dit":
        lat_shape = (m.latent_res(res), m.latent_res(res), m.in_channels)
    elif fam in ("mmdit", "unet"):
        lr = res // 8
        lat_shape = (lr, lr, m.in_channels)
    else:  # vdit
        g = m.grid(img_res=res)
        lat_shape = (g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch,
                     m.in_channels)

    ddpm = DDPMSchedule()

    def make_cond(txt, B, rng):
        if fam == "dit":
            return {"labels": jax.random.randint(rng, (B,), 0, m.num_classes)}
        if fam == "mmdit":
            return {"txt": txt, "vec": jnp.zeros((B, 768))}
        if fam == "unet":
            return {"ctx": txt}
        return {"txt": txt}

    @jax.jit
    def sample_fn(noise, txt, rng):
        B = noise.shape[0]
        cond = make_cond(txt, B, rng)

        def denoise(x, t, step):
            return _denoise_call(
                arch, params, x, t, cond, step, steps, NULL_CTX,
                use_ripple=use_ripple).astype(x.dtype)

        if fam == "mmdit":
            return euler_flow_sample(denoise, noise, steps)
        return ddim_sample(denoise, noise, ddpm, steps)

    return sample_fn, lat_shape


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--no-ripple", action="store_true")
    ap.add_argument("--attn-backend", default=None,
                    choices=("auto", "dense", "reference", "collapse",
                             "pallas"),
                    help="override RippleConfig.backend for the dispatch "
                         "layer (default: the arch config's setting)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)

    arch = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    arch = apply_overrides(arch, args.overrides)
    if args.attn_backend is not None:
        arch = dataclasses.replace(
            arch, ripple=dataclasses.replace(arch.ripple,
                                             backend=args.attn_backend))
    shape = arch.shape(args.shape)
    m = arch.model

    defs = model_fns(arch)
    params = init_params(defs, jax.random.PRNGKey(args.seed))
    sample_fn, lat_shape = build_sampler(arch, shape, params,
                                         use_ripple=not args.no_ripple)

    engine = DiffusionEngine(sample_fn, lat_shape,
                             max_batch=args.max_batch,
                             attn_plan=attention_plan(arch, shape))
    engine.start()
    txt_dim = getattr(m, "txt_dim", getattr(m, "ctx_dim", 64))
    txt_tokens = getattr(m, "txt_tokens", getattr(m, "ctx_tokens", 8))
    t0 = time.time()
    for i in range(args.requests):
        txt = 0.05 * np.random.default_rng(i).standard_normal(
            (txt_tokens, txt_dim)).astype(np.float32)
        engine.submit(GenRequest(request_id=i, txt=txt,
                                 steps=shape.steps, seed=i))
    for i in range(args.requests):
        r = engine.result(i)
        log.info("request %d done in %.2fs; latents %s",
                 i, r.walltime_s, r.latents.shape)
    engine.stop()
    log.info("served %d requests in %.2fs total", args.requests,
             time.time() - t0)


if __name__ == "__main__":
    main()
