"""Serving launcher: bucketed continuous-batching diffusion generation
with TimeRipple on, optionally sharded over a device mesh.

``python -m repro.launch.serve --smoke`` spins up the DiffusionEngine on
a mixed-shape request stream (several (resolution, steps) buckets),
logs the resolved attention-dispatch plan per bucket, and reports
latency.  ``--shape NAME`` pins single-shape traffic instead;
``--mesh DxMxS`` (e.g. ``--mesh 4x2`` or ``--mesh 1x1x2``) installs a
(data, model[, seq]) mesh so the ripple/reuse-mask pipeline runs under
shard_map (DESIGN.md §10); a third component shards the token axis for
context-parallel ring attention (DESIGN.md §14) — on CPU prefix with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import importlib
import json
import os
import signal
import sys
import time
import types

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.config.base import apply_overrides
from repro.core import dispatch as dispatch_lib
from repro.core.policy import list_policies
from repro.diffusion.sampler import ddim_sample, euler_flow_sample
from repro.diffusion.schedule import DDPMSchedule
from repro.launch.mesh import parse_mesh_spec
from repro.launch.workloads import (_denoise_call, attention_plan,
                                    latent_shape_for, mixed_gen_shapes,
                                    mixed_request_stream, model_fns,
                                    vdit_decision_state)
from repro.distributed.sharding import NULL_CTX
from repro.models.params import init_params
from repro.serving.engine import DiffusionEngine
from repro.serving.slo import ServiceEstimator, ShedError
from repro.utils.diskio import atomic_write_text
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def build_sampler(arch, shape, params, *, use_ripple=True, policy=None,
                  reuse_every=None, stream_every=None, sentinel=False):
    """Returns sample_fn(noise, txt, rngs) -> latents (or ``(latents,
    aux)`` with decision-cache telemetry) and the latent shape.
    ``rngs`` is the engine's (B, 2) per-request key batch: the initial
    noise is built outside from the same keys, and conditioning
    randomness (DiT labels) is drawn per request via vmap — no request
    in a batch ever shares sampler randomness.  ``policy`` overrides the
    arch config's reuse policy for this sampler (DESIGN.md §11);
    ``reuse_every`` its decision-cache cadence (DESIGN.md §13) — with a
    cadence > 1 (or the drift guard on) on a cache-capable vdit config,
    the per-layer decision state is threaded through the sampler's scan
    and the reuse decision is only recomputed on refresh steps.

    ``stream_every=K`` returns a *generator* sample_fn instead: the
    denoising scan runs in jitted K-step chunks (the samplers'
    ``step_offset``/``total_steps`` slicing, bitwise-identical math to
    the monolithic scan) and each chunk's latents are yielded as they
    land, so the engine can deliver intermediate frames and measure
    time-to-first-frame (DESIGN.md §15.3).  The decision-cache state
    crosses chunks through the generator's loop carry, so the cadence
    and drift guard behave exactly as in one scan.

    ``sentinel=True`` arms the in-graph quality sentinels (DESIGN.md
    §17): the samplers carry a running non-finite latent count
    (``aux["latent_nonfinite"]``) and, on cache-threading vdit configs,
    the dispatch layer accumulates per-call attention-output sentinels
    into the decision cache (``aux["sentinel_nonfinite"]`` /
    ``aux["sentinel_drift"]``) — the counters the engine's degradation
    ladder trips on."""
    if sentinel:
        arch = dataclasses.replace(
            arch, ripple=dataclasses.replace(arch.ripple, sentinel=True))
    if policy:
        arch = dataclasses.replace(
            arch, ripple=dataclasses.replace(arch.ripple, policy=policy))
    if reuse_every is not None:
        arch = dataclasses.replace(
            arch, ripple=dataclasses.replace(arch.ripple,
                                             reuse_every=int(reuse_every)))
    m = arch.model
    fam = arch.family
    steps = shape.steps or 50
    lat_shape = latent_shape_for(arch, shape)
    ddpm = DDPMSchedule()
    from repro.core import decision_cache

    rip = arch.ripple
    thread_cache = (
        use_ripple and fam == "vdit"
        and (rip.reuse_every > 1 or rip.drift_tol > 0)
        and decision_cache.supports_cache(rip))

    def make_cond(txt, rngs):
        if fam == "dit":
            labels = jax.vmap(
                lambda k: jax.random.randint(k, (), 0, m.num_classes))(rngs)
            return {"labels": labels}
        if fam == "mmdit":
            return {"txt": txt, "vec": jnp.zeros((txt.shape[0], 768))}
        if fam == "unet":
            return {"ctx": txt}
        return {"txt": txt}

    def cache_aux(dstate, aux):
        aux["cache_hits"] = dstate.hits.sum()
        aux["cache_refreshes"] = dstate.refreshes.sum()
        if dstate.elided is not None:
            # Ring-path telemetry (DESIGN.md §14): total ring hops
            # the block map let every seq shard skip this request.
            aux["ring_elided_hops"] = dstate.elided.sum()
        if dstate.nonfinite is not None:
            aux["sentinel_nonfinite"] = dstate.nonfinite.sum()
        if dstate.probe_err is not None:
            aux["sentinel_drift"] = dstate.probe_err.max()
        return aux

    if stream_every:
        K = max(int(stream_every), 1)

        @functools.partial(jax.jit, static_argnames=("count",))
        def chunk_fn(x, txt, rngs, step0, dstate, *, count):
            cond = make_cond(txt, rngs)
            if thread_cache:
                def denoise(x, t, step, ds):
                    out, ds = _denoise_call(
                        arch, params, x, t, cond, step, steps, NULL_CTX,
                        use_ripple=use_ripple, dstate=ds)
                    return out.astype(x.dtype), ds
                out = ddim_sample(denoise, x, ddpm, count,
                                  decision_state=dstate,
                                  step_offset=step0, total_steps=steps,
                                  sentinel=sentinel)
                return out if sentinel else out + (None,)

            def denoise(x, t, step):
                return _denoise_call(
                    arch, params, x, t, cond, step, steps, NULL_CTX,
                    use_ripple=use_ripple).astype(x.dtype)

            if fam == "mmdit":
                out = euler_flow_sample(denoise, x, count,
                                        step_offset=step0,
                                        total_steps=steps,
                                        sentinel=sentinel)
            else:
                out = ddim_sample(denoise, x, ddpm, count,
                                  step_offset=step0, total_steps=steps,
                                  sentinel=sentinel)
            if sentinel:
                return out[0], None, out[1]
            return out, None, None

        def sample_fn(noise, txt, rngs, resume=None):
            # Mid-flight resume (DESIGN.md §18): ``resume={"step": S,
            # "dstate": state}`` starts the chunk loop at offset S with
            # the checkpointed decision state; ``noise`` is then the
            # checkpointed x_t, not fresh noise.  Because checkpoints
            # land only at chunk boundaries, the resumed run replays
            # the exact chunk partitioning of the uninterrupted one —
            # the PR 7 chaining contract makes the result bitwise-equal.
            start = 0
            dstate = None
            if resume is not None:
                start = int(resume.get("step", 0))
                dstate = resume.get("dstate")
                if thread_cache and dstate is None and start > 0:
                    # A mid-flight start without the cached decision
                    # state would apply a zeroed plan at a non-refresh
                    # step; replaying from 0 is slower but exact.
                    start = 0
            if thread_cache and dstate is None:
                dstate = vdit_decision_state(arch, shape.img_res,
                                             noise.shape[0])
            x = noise
            nf_total = jnp.zeros((), jnp.int32)
            for s0 in range(start, steps, K):
                count = min(K, steps - s0)
                x, dstate, nf = chunk_fn(x, txt, rngs,
                                         jnp.asarray(s0, jnp.int32),
                                         dstate, count=count)
                aux = {}
                if dstate is not None:
                    cache_aux(dstate, aux)
                if nf is not None:
                    # Per-chunk counts accumulate so the final chunk's
                    # aux reports the whole trajectory.
                    nf_total = nf_total + nf
                    aux["latent_nonfinite"] = nf_total
                # Chunk-boundary checkpoint state for the engine's
                # store (§18): the step offset the *next* chunk would
                # start from, plus the decision state that step needs.
                aux["__ckpt__"] = {"step": s0 + count, "dstate": dstate}
                yield x, aux

        return sample_fn, lat_shape

    @jax.jit
    def sample_fn(noise, txt, rngs):
        cond = make_cond(txt, rngs)

        if thread_cache:
            def denoise(x, t, step, dstate):
                out, dstate = _denoise_call(
                    arch, params, x, t, cond, step, steps, NULL_CTX,
                    use_ripple=use_ripple, dstate=dstate)
                return out.astype(x.dtype), dstate

            dstate = vdit_decision_state(arch, shape.img_res,
                                         noise.shape[0])
            out = ddim_sample(denoise, noise, ddpm, steps,
                              decision_state=dstate, sentinel=sentinel)
            lat, final = out[0], out[1]
            aux = cache_aux(final, {})
            if sentinel:
                aux["latent_nonfinite"] = out[2]
            return lat, aux

        def denoise(x, t, step):
            return _denoise_call(
                arch, params, x, t, cond, step, steps, NULL_CTX,
                use_ripple=use_ripple).astype(x.dtype)

        if fam == "mmdit":
            out = euler_flow_sample(denoise, noise, steps,
                                    sentinel=sentinel)
        else:
            out = ddim_sample(denoise, noise, ddpm, steps,
                              sentinel=sentinel)
        if sentinel:
            return out[0], {"latent_nonfinite": out[1]}
        return out

    return sample_fn, lat_shape


def make_sampler_factory(arch, shapes, params, *, use_ripple=True,
                         mesh=None, sentinel=False):
    """(engine sampler_factory, plan_fn) over a set of generate cells,
    keyed by the engine's (latent_shape, steps, policy, reuse_every,
    stream_every) bucket identity.  The engine hands both callables the
    bucket's reuse-policy name (None = the arch config's
    ``ripple.policy``) and the factory additionally its decision-cache
    cadence (None = the config's ``ripple.reuse_every``) and streaming
    cadence (None = monolithic delivery, DESIGN.md §15.3)."""
    by_bucket = {}
    for sp in shapes:
        by_bucket[(tuple(latent_shape_for(arch, sp)), sp.steps)] = sp

    def factory(latent_shape, steps, policy=None, reuse_every=None,
                stream_every=None):
        sp = by_bucket[(tuple(latent_shape), steps)]
        fn, _ = build_sampler(arch, sp, params, use_ripple=use_ripple,
                              policy=policy, reuse_every=reuse_every,
                              stream_every=stream_every,
                              sentinel=sentinel)
        return fn

    def plan_fn(latent_shape, steps, policy=None):
        sp = by_bucket[(tuple(latent_shape), steps)]
        return attention_plan(arch, sp, mesh=mesh, policy=policy)

    return factory, plan_fn


def _maybe_kill_replica(front, fault, completed: int):
    """Fire a ``kill_replica`` fault (DESIGN.md §17.3) once ``completed``
    results have been consumed: fail the deepest router replica so its
    pending requests demonstrably requeue onto survivors."""
    from repro.serving.router import Router

    if fault is None or not isinstance(front, Router):
        return
    spec = fault.spec("kill_replica")
    if spec is None or completed < int(spec.param("after", 1)):
        return
    if fault.take("kill_replica") is None:
        return
    depths = front.depths()
    if not depths:
        return
    idx = max(depths, key=depths.get)
    log.warning("fault injection: killing replica %d (depth %d)",
                idx, depths[idx])
    front.fail_replica(idx)


def _maybe_crash(fault, completed: int, *, store=None):
    """Fire a ``crash`` fault (DESIGN.md §18): SIGKILL this process —
    no drain, no clean-shutdown marker — once ``completed`` results have
    been consumed.  With ``wait_ckpt=1`` (default) and a checkpoint
    store attached, first block until at least one in-flight request
    has a chunk checkpoint on disk (entries are discarded at finish, so
    an existing entry *is* in-flight work), making "killed
    mid-generation" deterministic instead of a race with the sampler."""
    if fault is None:
        return
    spec = fault.spec("crash")
    if spec is None or completed < int(spec.param("after", 1)):
        return
    if int(spec.param("wait_ckpt", 1)) and store is not None:
        deadline = time.time() + float(spec.param("wait_s", 60.0))
        while store.count() == 0 and time.time() < deadline:
            time.sleep(0.05)
        if store.count() == 0:
            log.warning("crash fault: no checkpoint landed within the "
                        "wait budget; killing anyway")
    if fault.take("crash") is None:
        return
    log.warning("fault injection: SIGKILL self (hard crash, no drain)")
    sys.stderr.flush()
    os.kill(os.getpid(), signal.SIGKILL)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vdit-paper", choices=ALL_ARCHS)
    ap.add_argument("--shape", default=None,
                    help="single-shape traffic from this named shape; "
                         "default: a mixed-shape request stream")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="DxMxS",
                    help="(data, model[, seq]) mesh, e.g. 8, 4x2 or "
                         "1x1x2; shards the attention dispatch under "
                         "shard_map.  A third component shards the token "
                         "axis for context-parallel ring attention "
                         "(DESIGN.md §14)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-compiled", type=int, default=8,
                    help="bounded LRU of per-bucket compiled samplers")
    ap.add_argument("--replicas", type=int, default=1, metavar="N",
                    help="serve through a Router over N in-process "
                         "engine replicas (DESIGN.md §15.4): least-"
                         "loaded balancing, failover requeue")
    ap.add_argument("--scheduler", default="edf",
                    choices=("edf", "hottest"),
                    help="bucket drain policy (DESIGN.md §15.1): "
                         "deadline-aware EDF (default) or the pre-SLO "
                         "hottest-bucket-first")
    ap.add_argument("--deadline-ms", type=float, default=None, metavar="MS",
                    help="stamp every request with a deadline of now+MS "
                         "at submit; infeasible requests are shed at "
                         "the door (DESIGN.md §15.2)")
    ap.add_argument("--stream-every", type=int, default=None, metavar="K",
                    help="chunked streaming delivery: yield decoded "
                         "latents every K denoising steps and report "
                         "time-to-first-frame (DESIGN.md §15.3)")
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed before touching "
                         "devices (multi-host fleet, DESIGN.md §15.4); "
                         "reads --coordinator/--num-processes/"
                         "--process-id")
    ap.add_argument("--coordinator", default=None, metavar="HOST:PORT")
    ap.add_argument("--num-processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--no-ripple", action="store_true")
    ap.add_argument("--policy", default=None,
                    help="reuse-policy name for every request (built-ins: "
                         "ripple, svg, equal_mse, dense; out-of-tree "
                         "policies register via --policy-module). "
                         "Default: the arch config's ripple.policy")
    ap.add_argument("--policy-module", default=None, metavar="MODULE",
                    help="import this python module before serving so it "
                         "can register_policy() an out-of-tree strategy")
    ap.add_argument("--reuse-every", type=int, default=None, metavar="R",
                    help="decision-cache cadence (DESIGN.md §13): "
                         "recompute the reuse decision every R denoising "
                         "steps and re-apply it in between; part of the "
                         "engine bucket key.  Default: the arch config's "
                         "ripple.reuse_every (1 = per-step decisions)")
    ap.add_argument("--drift-tol", type=float, default=None, metavar="TOL",
                    help="decision-cache drift guard: force an early "
                         "refresh when the sampled-channel Δ statistic "
                         "moves more than TOL (relative) from the cached "
                         "decision's reference.  0 disables (default: "
                         "the arch config's ripple.drift_tol)")
    ap.add_argument("--attn-backend", default=None,
                    choices=("auto", "dense", "reference", "collapse",
                             "pallas", "sparse"),
                    help="override RippleConfig.backend for the dispatch "
                         "layer (default: the arch config's setting)")
    ap.add_argument("--pattern-artifact", default=None, metavar="PATH",
                    help="install a searched pattern artifact "
                         "(launch/pattern_search.py) for the static / "
                         "rainfusion policies; errors if the file is "
                         "missing or corrupt.  Default: the "
                         "REPRO_PATTERN_ARTIFACT env var / user cache "
                         "(loaded lazily, missing file tolerated)")
    ap.add_argument("--no-guardrail", action="store_true",
                    help="disable the runtime quality guardrails "
                         "(DESIGN.md §17): in-graph NaN/drift sentinels "
                         "and the per-bucket degradation ladder.  On by "
                         "default")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="arm the deterministic chaos harness "
                         "(serving.faults, DESIGN.md §17.3), e.g. "
                         "'attn_nan:step=1;kill_replica:after=1'.  "
                         "Default: the REPRO_FAULTS env var")
    ap.add_argument("--batch-timeout", type=float, default=None,
                    metavar="S",
                    help="hang-watchdog floor per batch in seconds "
                         "(scaled by the service-time estimator once "
                         "observed); a hung batch marks the replica "
                         "unhealthy and its requests fail over.  "
                         "Default: no watchdog")
    ap.add_argument("--probe-interval", type=float, default=0.5,
                    metavar="S",
                    help="router health-probe cadence for re-admitting "
                         "recovered replicas (only with --replicas > 1)")
    ap.add_argument("--journal", default=None, metavar="DIR",
                    help="crash-safe serving (DESIGN.md §18): write the "
                         "request-lifecycle WAL, chunk-boundary "
                         "generation checkpoints, and the service-time "
                         "estimator snapshot under DIR.  SIGTERM drains "
                         "gracefully and leaves a clean-shutdown marker; "
                         "SIGKILL leaves a recoverable journal")
    ap.add_argument("--resume", action="store_true",
                    help="recover the journal directory's pending "
                         "requests (submitted, never finished/shed) and "
                         "resume any with a chunk checkpoint mid-flight "
                         "before serving new traffic; requires --journal")
    ap.add_argument("--journal-fsync", default="always",
                    choices=("always", "interval", "never"),
                    help="journal durability policy: fsync every append "
                         "(default), every few appends, or never (flush "
                         "only — survives SIGKILL but not power loss)")
    ap.add_argument("--checkpoint-max", type=int, default=64, metavar="N",
                    help="bound on distinct requests with an on-disk "
                         "generation checkpoint (least-recently-written "
                         "evicted first)")
    ap.add_argument("--summary-json", default=None, metavar="PATH",
                    help="write a machine-readable final summary "
                         "(completed/errors/recovered/resumed_from_step/"
                         "counters) to PATH — the crash-restart smoke's "
                         "assertion surface")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("overrides", nargs="*")
    args = ap.parse_args(argv)

    if args.distributed:
        from repro.launch.mesh import init_distributed

        init_distributed(coordinator_address=args.coordinator,
                         num_processes=args.num_processes,
                         process_id=args.process_id)

    if args.policy_module:
        importlib.import_module(args.policy_module)
    if args.pattern_artifact is not None:
        from repro.core import patterns

        art = patterns.install_artifact(args.pattern_artifact)
        log.info("pattern artifact %s: %d heads, %.0f%% static",
                 art.version, len(art.heads),
                 100.0 * art.static_fraction())
    if args.policy is not None and args.policy not in list_policies():
        ap.error(f"unknown policy {args.policy!r}; registered: "
                 f"{list_policies()} (use --policy-module to register "
                 f"an out-of-tree policy first)")

    mesh = parse_mesh_spec(args.mesh) if args.mesh else None
    if mesh is not None:
        dispatch_lib.set_dispatch_mesh(mesh)
        log.info("dispatch mesh: %s", dict(mesh.shape))

    arch = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    arch = apply_overrides(arch, args.overrides)
    if args.attn_backend is not None:
        arch = dataclasses.replace(
            arch, ripple=dataclasses.replace(arch.ripple,
                                             backend=args.attn_backend))
    if args.drift_tol is not None:
        arch = dataclasses.replace(
            arch, ripple=dataclasses.replace(arch.ripple,
                                             drift_tol=args.drift_tol))

    if args.shape is not None:
        shapes = (arch.shape(args.shape),)
    else:
        shapes = mixed_gen_shapes(arch, smoke=args.smoke)
    log.info("traffic buckets: %s",
             [(s.name, s.img_res, s.steps) for s in shapes])

    from repro.serving import faults as fault_lib

    if args.inject_faults:
        fault_lib.install_faults(args.inject_faults)
    else:
        fault_lib.install_from_env()
    fault = fault_lib.active_faults()

    guardrail = not args.no_guardrail
    ladder = None
    if guardrail:
        from repro.core.guardrail import DegradationLadder

        # One ladder shared across every replica: degraded-bucket state
        # survives a replica failover (DESIGN.md §17.2).
        ladder = DegradationLadder()

    # -- crash-safety state (DESIGN.md §18) ---------------------------------
    if args.resume and not args.journal:
        ap.error("--resume requires --journal DIR")
    journal = store = None
    estimator = None
    recovered = []
    rid_base = 0
    est_path = None
    if args.journal:
        from repro.serving import journal as journal_lib

        # Scan *before* opening: Journal() removes the clean marker.
        rec = journal_lib.recover(args.journal)
        if rec.events:
            log.info("journal %s: %d event(s), %d pending, clean=%s, "
                     "torn_tail=%s", args.journal, rec.events,
                     len(rec.pending), rec.clean, rec.torn)
        journal = journal_lib.Journal(args.journal,
                                      fsync=args.journal_fsync)
        store = journal_lib.CheckpointStore(
            args.journal, max_entries=args.checkpoint_max,
            fsync=args.journal_fsync != "never")
        est_path = os.path.join(args.journal, "estimator.json")
        if os.path.exists(est_path):
            try:
                with open(est_path, "r", encoding="utf-8") as f:
                    estimator = ServiceEstimator.from_json(f.read())
                log.info("restored service-time estimator from %s",
                         est_path)
            except (OSError, ValueError):
                log.warning("could not restore estimator from %s; "
                            "starting cold", est_path)
        # New request ids must never collide with journaled history —
        # a reused id would alias lifecycle records across requests.
        known = (set(rec.pending) | set(rec.finished) | set(rec.shed))
        rid_base = max(known, default=-1) + 1
        if args.resume and rec.pending:
            if not rec.clean:
                log.warning("crash detected (no matching clean-shutdown "
                            "marker): recovering %d pending request(s)",
                            len(rec.pending))
            for rid, reqd in sorted(rec.pending.items()):
                try:
                    req = journal_lib.request_from_dict(reqd)
                except (KeyError, ValueError, TypeError):
                    log.exception("journaled request %s is unreadable; "
                                  "skipping", rid)
                    continue
                # The absolute deadline has almost certainly expired
                # across the restart; shedding a journaled request at
                # the recovery door would break the every-journaled-
                # request-completes contract.
                req.deadline_s = None
                req.recovered = True
                ck = store.get(rid) if req.stream_every else None
                if ck and 0 < ck["step"] < req.steps \
                        and ck["step"] % req.stream_every == 0:
                    req.resume = {"step": ck["step"], "x": ck["x"],
                                  "dstate": ck.get("dstate")}
                    log.info("request %d resumes from step %d/%d",
                             rid, ck["step"], req.steps)
                recovered.append(req)
    if estimator is None and args.journal:
        estimator = ServiceEstimator()

    defs = model_fns(arch)
    params = init_params(defs, jax.random.PRNGKey(args.seed))
    factory, plan_fn = make_sampler_factory(
        arch, shapes, params, use_ripple=not args.no_ripple, mesh=mesh,
        sentinel=guardrail)

    def make_engine():
        return DiffusionEngine(sampler_factory=factory,
                               max_batch=args.max_batch,
                               max_compiled=args.max_compiled,
                               plan_fn=plan_fn,
                               default_policy=args.policy,
                               default_reuse_every=args.reuse_every,
                               scheduler=args.scheduler,
                               guardrail=ladder,
                               batch_timeout_s=args.batch_timeout,
                               estimator=estimator,
                               journal=journal,
                               checkpoint_store=store)

    if args.replicas > 1:
        from repro.serving.router import Router

        front = Router([make_engine() for _ in range(args.replicas)],
                       probe_interval_s=args.probe_interval,
                       checkpoint_store=store)
    else:
        front = make_engine()
    front.start()
    traffic = mixed_request_stream(arch, shapes, args.requests,
                                   seed=args.seed, policy=args.policy,
                                   reuse_every=args.reuse_every,
                                   stream_every=args.stream_every)
    terminating = {"sigterm": False}
    if args.journal:
        def _graceful(signum, frame):
            # Graceful drain (§18): queued requests stay journaled-
            # pending, in-flight chunks are already checkpointed; the
            # finally block below stops without drain and writes the
            # clean-shutdown marker.
            terminating["sigterm"] = True
            log.warning("SIGTERM: graceful drain — pending work stays "
                        "journaled for --resume")
            raise SystemExit(143)

        signal.signal(signal.SIGTERM, _graceful)
    t0 = time.time()
    shed = 0
    submitted = []
    completed = []
    errors = {}
    try:
        for req in recovered:
            sp = types.SimpleNamespace(name="recovered", steps=req.steps)
            try:
                front.submit(req)
            except ShedError as e:
                shed += 1
                log.warning("%s", e)
                continue
            submitted.append((sp, req))
        for sp, req in traffic:
            req.request_id += rid_base
            if args.deadline_ms is not None:
                req.deadline_s = time.time() + args.deadline_ms / 1e3
            try:
                front.submit(req)
            except ShedError as e:
                shed += 1
                log.warning("%s", e)
                continue
            submitted.append((sp, req))
        for done, (sp, req) in enumerate(submitted):
            _maybe_kill_replica(front, fault, done)
            _maybe_crash(fault, done, store=store)
            try:
                r = front.result(req.request_id)
            except (RuntimeError, TimeoutError) as e:
                errors[req.request_id] = str(e)
                log.error("request %d failed: %s", req.request_id, e)
                continue
            completed.append(req.request_id)
            log.info("request %d (%s, %d steps) done in %.2fs "
                     "(ttff %.3fs%s%s%s); latents %s",
                     req.request_id, sp.name, sp.steps, r.walltime_s,
                     r.ttff_s,
                     "" if r.deadline_met is None
                     else f", deadline "
                          f"{'met' if r.deadline_met else 'MISSED'}",
                     ", DEGRADED" if r.degraded else "",
                     ", RECOVERED" if req.recovered else "",
                     r.latents.shape)
    except SystemExit:
        if not terminating["sigterm"]:
            raise
    finally:
        front.stop(drain=not terminating["sigterm"])
        if journal is not None:
            journal.close(clean=True)
            if estimator is not None and est_path is not None:
                atomic_write_text(est_path, estimator.to_json())
    counters = dict(front.metrics()) if hasattr(front, "metrics") else {}
    if fault is not None:
        counters.update(fault.counters())
    if ladder is not None:
        counters.update(ladder.metrics())
    if counters:
        log.info("serving counters: %s", counters)
    resumed_from = max(
        [int(v) for k, v in counters.items()
         if k.endswith("last_resume_step")] or [0])
    log.info("served %d/%d requests (%d shed, %d recovered, deepest "
             "resume step %d) over %d bucket(s) in %.2fs total",
             len(completed), args.requests + len(recovered), shed,
             len(recovered), resumed_from, len(shapes),
             time.time() - t0)
    if args.summary_json:
        summary = {
            "submitted": [req.request_id for _, req in submitted],
            "completed": completed,
            "errors": {str(k): v for k, v in errors.items()},
            "shed": shed,
            "recovered": len(recovered),
            "resumed_from_step": resumed_from,
            "sigterm": terminating["sigterm"],
            "counters": {k: (float(v) if isinstance(v, float) else int(v))
                         for k, v in counters.items()},
        }
        with open(args.summary_json, "w", encoding="utf-8") as f:
            json.dump(summary, f, indent=1, sort_keys=True)
        log.info("wrote summary to %s", args.summary_json)
    if terminating["sigterm"]:
        sys.exit(143)


if __name__ == "__main__":
    main()
