"""Workload construction: (arch x shape x mesh) -> a jittable step
function + abstract inputs + shardings.

This is the single bridge the dry-run, the trainer, and the server all
go through, so the thing that compiles in the dry-run is exactly the
thing that runs.  ``build_workload`` returns a :class:`Workload` whose
``lower()`` produces the pjit-lowered artifact for roofline analysis.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ArchConfig, ShapeSpec, TrainConfig
from repro.core import dispatch as dispatch_lib
from repro.diffusion.schedule import DDPMSchedule, RectifiedFlowSchedule
from repro.distributed import sharding as shlib
from repro.distributed.sharding import ShardCtx
from repro.models import (dit as dit_lib, efficientnet as eff_lib,
                          mmdit as mmdit_lib, transformer_lm as lm_lib,
                          unet as unet_lib, vdit as vdit_lib, vit as vit_lib)
from repro.models.params import abstract_params, init_params, logical_axes
from repro.training import train_loop
from repro.training.train_loop import TrainState


@dataclasses.dataclass
class Workload:
    arch: ArchConfig
    shape: ShapeSpec
    mesh: Optional[Mesh]
    fn: Callable                      # jit-able step function
    args: Tuple[Any, ...]             # abstract (or concrete) args
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    # multiplier to turn one lowered step into the full workload
    # (e.g. sampler steps for 'generate' shapes)
    steps_multiplier: int = 1
    # cost-probe metadata (see dryrun.run_cell): trip count of the
    # primary scan-over-layers loop, and how to probe the exact cost.
    loop_trips: int = 0
    probe: str = "two_point"  # 'two_point' | 'unroll' | 'none'
    # resolved attention-dispatch plan for the cell's self-attention
    # shape (diffusion generate cells; None elsewhere) — what the
    # dry-run and the server report as the execution strategy.
    attn_plan: Optional[dispatch_lib.DispatchPlan] = None

    def jitted(self):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.args)


# --- family dispatch tables --------------------------------------------------


def model_fns(arch: ArchConfig):
    fam = arch.family
    if fam == "lm":
        return lm_lib.lm_defs(arch.model)
    if fam == "dit":
        return dit_lib.dit_defs(arch.model)
    if fam == "mmdit":
        return mmdit_lib.mmdit_defs(arch.model)
    if fam == "unet":
        return unet_lib.unet_defs(arch.model)
    if fam == "vit":
        return vit_lib.vit_defs(arch.model)
    if fam == "effnet":
        return eff_lib.effnet_defs(arch.model)
    if fam == "vdit":
        return vdit_lib.vdit_defs(arch.model)
    raise ValueError(fam)


def _named(mesh, spec):
    return NamedSharding(mesh, spec) if mesh is not None else None


def _leaf_is_axes(x):
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)


def _state_shardings(arch, defs, mesh, train_cfg: TrainConfig):
    axes = logical_axes(defs)
    state_axes = train_loop.train_state_logical_axes(axes, train_cfg)
    abstract = train_loop.abstract_train_state(abstract_params(defs), train_cfg)
    if mesh is None:
        return abstract, None
    rules = shlib.param_rules(mesh)
    shardings = jax.tree_util.tree_map(
        lambda ax, ab: NamedSharding(
            mesh, shlib.spec_from_axes(ax, rules, ab.shape, mesh)),
        state_axes, abstract, is_leaf=_leaf_is_axes)
    return abstract, shardings


def _param_shardings(defs, mesh, fsdp: bool = True, dtype=None):
    axes = logical_axes(defs)
    abstract = abstract_params(defs)
    if dtype is not None:
        # serving-precision weights (bf16 checkpoints at decode time)
        abstract = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, abstract)
    if mesh is None:
        return abstract, None
    rules = shlib.param_rules(mesh, fsdp=fsdp)
    shardings = jax.tree_util.tree_map(
        lambda ax, ab: NamedSharding(
            mesh, shlib.spec_from_axes(ax, rules, ab.shape, mesh)),
        axes, abstract, is_leaf=_leaf_is_axes)
    return abstract, shardings


def _effective_accum(accum: int, global_batch: int, mesh) -> int:
    """Clamp grad accumulation so each microbatch still divides the batch
    shards: on the 2x16x16 mesh the batch axis is 32-way, so accum must
    leave microbatches of >= 32 samples. Largest accum' <= accum with
    (B/accum') % shards == 0."""
    if mesh is None:
        return accum
    shards = shlib.axis_size(mesh, shlib.batch_axes(mesh)) or 1
    a = min(accum, max(global_batch // shards, 1))
    while a > 1 and (global_batch % a or (global_batch // a) % shards):
        a -= 1
    return max(a, 1)


def _batch_sharding(mesh, batch_dims: int, extra=(), size0: int = 0):
    """Shard dim0 over the largest prefix of (pod, data) dividing it;
    remaining dims replicated/extra."""
    bd = list(shlib.batch_axes(mesh))
    if mesh is not None and size0:
        while bd and size0 % shlib.axis_size(mesh, tuple(bd)) != 0:
            bd.pop()
    bd = tuple(bd)
    return _named(mesh, P(bd if bd else None, *extra,
                          *([None] * (batch_dims - 1 - len(extra)))))


# --- LM workloads -------------------------------------------------------------


def _lm_train(arch: ArchConfig, shape: ShapeSpec, mesh) -> Workload:
    cfg = arch.model
    tc = dataclasses.replace(
        arch.train, grad_accum=_effective_accum(
            arch.train.grad_accum, shape.global_batch, mesh))
    defs = lm_lib.lm_defs(cfg)
    ctx = ShardCtx(mesh, shlib.train_act_rules(mesh, tc.seq_parallel))

    def loss_fn(params, batch, rng):
        return lm_lib.lm_loss(params, batch["tokens"], batch["targets"], cfg,
                              ctx=ctx, remat=tc.remat,
                              remat_policy=tc.remat_policy)

    step = train_loop.make_train_step(loss_fn, tc)
    abstract_state, state_sh = _state_shardings(arch, defs, mesh, tc)
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    bsh = {k: _batch_sharding(mesh, 2, size0=B) for k in batch}
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return Workload(
        arch=arch, shape=shape, mesh=mesh, fn=step,
        args=(abstract_state, batch, rng),
        in_shardings=(state_sh, bsh, _named(mesh, P())),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
        loop_trips=cfg.num_layers)


def _lm_prefill(arch: ArchConfig, shape: ShapeSpec, mesh) -> Workload:
    cfg = arch.model
    defs = lm_lib.lm_defs(cfg)
    ctx = ShardCtx(mesh, shlib.decode_act_rules(mesh))
    max_len = shape.seq_len

    def fn(params, tokens):
        return lm_lib.lm_prefill(params, tokens, cfg, max_len, ctx=ctx)

    ap, psh = _param_shardings(defs, mesh)
    B, S = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    cache_ax = lm_lib.cache_logical_axes()
    rules = shlib.decode_act_rules(mesh)
    cache_abs = lm_lib.abstract_cache(cfg, B, max_len)
    cache_sh = tuple(
        _named(mesh, shlib.spec_from_axes(ax, rules, ab.shape, mesh))
        for ax, ab in zip(cache_ax, cache_abs))
    return Workload(
        arch=arch, shape=shape, mesh=mesh, fn=fn,
        args=(ap, tokens),
        in_shardings=(psh, _batch_sharding(mesh, 2, size0=B)),
        out_shardings=(None, cache_sh),
        loop_trips=cfg.num_layers)


def _lm_decode(arch: ArchConfig, shape: ShapeSpec, mesh) -> Workload:
    cfg = arch.model
    defs = lm_lib.lm_defs(cfg)
    long_ctx = shape.seq_len >= 262144
    rules = shlib.decode_act_rules(
        mesh, long_context=long_ctx,
        replicate_heads=arch.decode_replicate_heads)
    ctx = ShardCtx(mesh, rules)
    B, S = shape.global_batch, shape.seq_len

    def fn(params, token, cache, index):
        return lm_lib.lm_decode_step(params, token, cache, index, cfg,
                                     ctx=ctx)

    # NOTE(§Perf): dtype=jnp.bfloat16 here (serving-precision weights)
    # should halve the no-FSDP weight footprint, but the compiled module
    # reports *higher* temp bytes (23.9 vs 18.7 GB) — XLA materializes
    # f32 upcasts of the bf16 weights for the f32 logit path instead of
    # fusing them.  Kept at checkpoint precision pending a kernel-level
    # fix; see EXPERIMENTS.md §Perf cell 3 iteration 3.
    ap, psh = _param_shardings(defs, mesh, fsdp=not arch.decode_no_fsdp)
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cache_abs = lm_lib.abstract_cache(cfg, B, S)
    cache_ax = lm_lib.cache_logical_axes()
    cache_sh = tuple(
        _named(mesh, shlib.spec_from_axes(ax, rules, ab.shape, mesh))
        for ax, ab in zip(cache_ax, cache_abs))
    index = jax.ShapeDtypeStruct((), jnp.int32)
    return Workload(
        arch=arch, shape=shape, mesh=mesh, fn=fn,
        args=(ap, token, cache_abs, index),
        in_shardings=(psh, _batch_sharding(mesh, 2, size0=B), cache_sh,
                      _named(mesh, P())),
        out_shardings=(None, cache_sh),
        donate_argnums=(2,),
        loop_trips=cfg.num_layers)


# --- diffusion workloads -------------------------------------------------------


def _diffusion_batch_specs(arch: ArchConfig, shape: ShapeSpec, mesh,
                           train: bool):
    """Abstract latents/conditioning for one diffusion workload cell."""
    fam = arch.family
    m = arch.model
    res = shape.img_res
    B = shape.batch
    if fam == "dit":
        lat = (B, res // m.vae_factor, res // m.vae_factor, m.in_channels)
        cond = {"labels": jax.ShapeDtypeStruct((B,), jnp.int32)}
    elif fam == "mmdit":
        lr = res // 8
        lat = (B, lr, lr, m.in_channels)
        cond = {"txt": jax.ShapeDtypeStruct((B, m.txt_tokens, m.txt_dim),
                                            jnp.float32),
                "vec": jax.ShapeDtypeStruct((B, 768), jnp.float32)}
    elif fam == "unet":
        lr = res // 8
        lat = (B, lr, lr, m.in_channels)
        cond = {"ctx": jax.ShapeDtypeStruct((B, m.ctx_tokens, m.ctx_dim),
                                            jnp.float32)}
    elif fam == "vdit":
        g = m.grid(img_res=res)
        lat = (B, g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch,
               m.in_channels)
        cond = {"txt": jax.ShapeDtypeStruct((B, m.txt_tokens, m.txt_dim),
                                            jnp.float32)}
    else:
        raise ValueError(fam)
    return jax.ShapeDtypeStruct(lat, jnp.float32), cond


def _denoise_call(arch: ArchConfig, params, x, t, cond, step, total, ctx,
                  use_ripple: bool, dstate=None):
    """One denoiser forward.  ``dstate`` threads the per-layer decision
    cache (DESIGN.md §13) — vdit only; the call then returns
    ``(out, new_dstate)``."""
    fam = arch.family
    m = arch.model
    rip = arch.ripple if use_ripple else dataclasses.replace(
        arch.ripple, enabled=False)
    kw = dict(ripple=rip, step=step, total_steps=total, ctx=ctx)
    if dstate is not None and fam != "vdit":
        raise ValueError(f"decision-cache state is only threaded through "
                         f"the vdit family, not {fam!r}")
    if fam == "dit":
        out = dit_lib.dit_apply(params, x, t, cond["labels"], m, **kw)
        return out[..., : m.in_channels]  # drop sigma for the ODE path
    if fam == "mmdit":
        return mmdit_lib.mmdit_apply(params, x, t, cond["txt"], cond["vec"],
                                     m, **kw)
    if fam == "unet":
        return unet_lib.unet_apply(params, x, t, cond["ctx"], m, **kw)
    if fam == "vdit":
        return vdit_lib.vdit_apply(params, x, t, cond["txt"], m,
                                   decision_state=dstate, **kw)
    raise ValueError(fam)


def _attn_seq_fallback(arch, mesh, rules):
    """Archs whose head count doesn't divide the model axis (flux: 24
    heads on 16) shard attention over the query-sequence dim instead
    (context parallelism): logits (B, H, Nq/16, Nk), K/V gathered."""
    heads = getattr(arch.model, "num_heads", 0)
    if mesh is not None and "model" in mesh.axis_names and heads and             heads % mesh.shape["model"] != 0:
        rules = dict(rules)
        rules["attn_seq"] = "model"
    return rules


def _diffusion_train(arch: ArchConfig, shape: ShapeSpec, mesh) -> Workload:
    tc = dataclasses.replace(
        arch.train, grad_accum=_effective_accum(
            arch.train.grad_accum, shape.batch, mesh))
    defs = model_fns(arch)
    ctx = ShardCtx(mesh, _attn_seq_fallback(
        arch, mesh, shlib.train_act_rules(mesh)))
    ddpm = DDPMSchedule()
    rf = RectifiedFlowSchedule()
    fam = arch.family
    m = arch.model

    def loss_fn(params, batch, rng):
        x0 = batch["latents"]
        B = x0.shape[0]
        k1, k2 = jax.random.split(rng)
        noise = jax.random.normal(k1, x0.shape, x0.dtype)
        if fam == "mmdit":  # rectified flow
            t = rf.sample_t(k2, B)
            xt = rf.interpolate(x0, noise, t)
            target = rf.velocity_target(x0, noise)
            pred = _denoise_call(arch, params, xt, t, batch, None, None, ctx,
                                 use_ripple=False)
        else:
            t = jax.random.randint(k2, (B,), 0, ddpm.num_train_steps)
            xt = ddpm.add_noise(x0, noise, t)
            target = noise
            pred = _denoise_call(arch, params, xt, t.astype(jnp.float32),
                                 batch, None, None, ctx, use_ripple=False)
        loss = jnp.mean(jnp.square(pred.astype(jnp.float32)
                                   - target.astype(jnp.float32)))
        return loss, {"mse": loss}

    step = train_loop.make_train_step(loss_fn, tc)
    abstract_state, state_sh = _state_shardings(arch, defs, mesh, tc)
    lat, cond = _diffusion_batch_specs(arch, shape, mesh, train=True)
    batch = {"latents": lat, **cond}
    bsh = {k: _batch_sharding(mesh, v.ndim, size0=v.shape[0])
           for k, v in batch.items()}
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    trips, probe = _diffusion_probe_info(arch)
    return Workload(
        arch=arch, shape=shape, mesh=mesh, fn=step,
        args=(abstract_state, batch, rng),
        in_shardings=(state_sh, bsh, _named(mesh, P())),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
        loop_trips=trips, probe=probe)


def _diffusion_probe_info(arch: ArchConfig):
    fam = arch.family
    if fam in ("dit", "vdit"):
        return arch.model.num_layers, "two_point"
    if fam == "mmdit":
        # two scans with different trip counts (double/single blocks):
        # the two-point identity can't separate them -> full unroll.
        return 0, "unroll"
    return 0, "none"  # unet: python-level loops, HLO already explicit


def _diffusion_generate(arch: ArchConfig, shape: ShapeSpec, mesh) -> Workload:
    """One denoising step exactly as the sampler invokes it (with CFG
    batch doubling for the CFG families); steps_multiplier carries the
    sampler length for the roofline report."""
    defs = model_fns(arch)
    rules = shlib.seqpar_act_rules(mesh, shape.batch * _cfg_factor(arch)) \
        if mesh is not None else None
    if rules is not None:
        rules = _attn_seq_fallback(arch, mesh, rules)
    ctx = ShardCtx(mesh, rules)
    fam = arch.family
    total = shape.steps

    def fn(params, x, t, cond, step):
        return _denoise_call(arch, params, x, t, cond, step, total, ctx,
                             use_ripple=True)

    ap, psh = _param_shardings(defs, mesh)
    lat, cond = _diffusion_batch_specs(arch, shape, mesh, train=False)
    f = _cfg_factor(arch)
    lat = jax.ShapeDtypeStruct((lat.shape[0] * f, *lat.shape[1:]), lat.dtype)
    cond = {k: jax.ShapeDtypeStruct((v.shape[0] * f, *v.shape[1:]), v.dtype)
            for k, v in cond.items()}
    t = jax.ShapeDtypeStruct((lat.shape[0],), jnp.float32)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    bsh_lat = _named(mesh, _gen_spec(mesh, lat.shape, rules))
    bsh_cond = {k: _named(mesh, _gen_spec(mesh, v.shape, rules))
                for k, v in cond.items()}
    return Workload(
        arch=arch, shape=shape, mesh=mesh, fn=fn,
        args=(ap, lat, t, cond, step),
        in_shardings=(psh, bsh_lat, _named(mesh, P()), bsh_cond,
                      _named(mesh, P())),
        out_shardings=bsh_lat,
        steps_multiplier=shape.steps,
        loop_trips=_diffusion_probe_info(arch)[0],
        probe=_diffusion_probe_info(arch)[1],
        attn_plan=attention_plan(arch, shape, mesh=mesh))


def attention_plan(arch: ArchConfig, shape: ShapeSpec,
                   mesh: Optional[Mesh] = None,
                   policy: Optional[str] = None):
    """Resolved dispatch plan for the cell's joint self-attention shape.

    Metadata only (the models resolve their own plans at trace time via
    ``attention_dispatch``); UNet is skipped — its attention runs at
    several resolutions with level-dependent head dims.  ``mesh`` makes
    the recorded batch/head sharding match what the sharded serving path
    will execute (DESIGN.md §10); ``policy`` overrides the arch config's
    reuse policy (DESIGN.md §11).
    """
    m = arch.model
    fam = arch.family
    res = shape.img_res
    if fam == "dit":
        n = m.num_tokens(res)
    elif fam == "mmdit":
        n = (res // 8 // m.patch) ** 2 + m.txt_tokens
    elif fam == "vdit":
        g = m.grid(img_res=res)
        n = g[0] * g[1] * g[2] + m.txt_tokens
    else:
        return None
    heads = m.num_heads
    bh = max(shape.batch, 1) * _cfg_factor(arch) * heads
    return dispatch_lib.plan_for_shape(n, m.d_model // heads, arch.ripple,
                                       batch_heads=bh, heads=heads,
                                       mesh=mesh, policy=policy)


def vdit_decision_state(arch: ArchConfig, img_res: int, batch: int,
                        policy: Optional[str] = None,
                        compute_dtype=jnp.bfloat16):
    """Per-layer decision-cache state for one vdit sampler invocation
    (DESIGN.md §13): an all-zeros stacked CachedDecision matching the
    model's per-layer self-attention operands at this resolution and
    batch.  Safe to call inside the jitted sampler (the zeros become
    constants); step 0 always refreshes, so the zeros are never applied.
    Returns None when the config can't cache (inactive ripple, or a
    policy without the capability) — callers fall back to the plain
    per-step path."""
    from repro.core import decision_cache

    pol = policy or arch.ripple.policy
    if not decision_cache.supports_cache(arch.ripple, pol):
        return None
    m = arch.model
    g = m.grid(img_res=img_res)
    n_img = g[0] * g[1] * g[2]
    hd = m.d_model // m.num_heads
    q_shape = (batch, m.num_heads, m.txt_tokens + n_img, hd)
    return decision_cache.initial_state(
        q_shape, grid=g, cfg=dataclasses.replace(arch.ripple, policy=pol),
        grid_slice=(m.txt_tokens, n_img), num_layers=m.num_layers,
        dtype=compute_dtype)


# --- serving traffic helpers ----------------------------------------------------


def latent_shape_for(arch: ArchConfig, shape: ShapeSpec) -> Tuple[int, ...]:
    """Per-request latent shape (no batch dim) for one generate cell —
    the serving engine's bucket identity."""
    m = arch.model
    fam = arch.family
    res = shape.img_res
    if fam == "dit":
        lr = m.latent_res(res)
        return (lr, lr, m.in_channels)
    if fam in ("mmdit", "unet"):
        lr = res // 8
        return (lr, lr, m.in_channels)
    if fam == "vdit":
        g = m.grid(img_res=res)
        return (g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch,
                m.in_channels)
    raise ValueError(f"no latent shape for family {fam!r}")


def mixed_gen_shapes(arch: ArchConfig, *, smoke: bool = False,
                     base: Optional[ShapeSpec] = None):
    """Heterogeneous 'generate' cells for mixed-traffic serving: the base
    resolution/step count plus a half-resolution and a short-schedule
    variant (each its own engine bucket)."""
    if base is None:
        gens = [s for s in arch.shapes if s.kind == "generate"]
        base = gens[0] if gens else ShapeSpec(
            name="gen", kind="generate", img_res=64, batch=1, steps=4)
    if smoke:
        base = dataclasses.replace(base, img_res=64, steps=3)
    res_lo = max(base.img_res // 2, 32)
    steps_lo = max(base.steps // 2, 2)
    variants = [
        base,
        dataclasses.replace(base, name=f"{base.name}_r{res_lo}",
                            img_res=res_lo),
        dataclasses.replace(base, name=f"{base.name}_s{steps_lo}",
                            steps=steps_lo),
    ]
    seen, out = set(), []
    for s in variants:
        k = (s.img_res, s.steps)
        if k not in seen:
            seen.add(k)
            out.append(s)
    return tuple(out)


def mixed_request_stream(arch: ArchConfig, shapes, num_requests: int,
                         seed: int = 0, policy: Optional[str] = None,
                         reuse_every: Optional[int] = None,
                         stream_every: Optional[int] = None):
    """Round-robin (ShapeSpec, GenRequest) traffic over ``shapes`` with
    deterministic per-request text embeddings and seeds.  ``policy``
    stamps every request with that reuse-policy name, ``reuse_every``
    with that decision-cache cadence, ``stream_every`` with that
    chunked-streaming cadence (each its own engine bucket dimension).
    Deadlines are *not* stamped here — an SLO is relative to submit
    time, so callers stamp ``deadline_s`` when they actually submit
    (``launch.serve``, ``benchmarks.serve_mixed``)."""
    from repro.serving.engine import GenRequest

    m = arch.model
    txt_dim = getattr(m, "txt_dim", getattr(m, "ctx_dim", 64))
    txt_tokens = getattr(m, "txt_tokens", getattr(m, "ctx_tokens", 8))
    out = []
    for i in range(num_requests):
        sp = shapes[i % len(shapes)]
        txt = 0.05 * np.random.default_rng(seed + i).standard_normal(
            (txt_tokens, txt_dim)).astype(np.float32)
        out.append((sp, GenRequest(
            request_id=i, txt=txt, steps=sp.steps, seed=seed + i,
            latent_shape=latent_shape_for(arch, sp), policy=policy,
            reuse_every=reuse_every, stream_every=stream_every)))
    return out


def _cfg_factor(arch: ArchConfig) -> int:
    # flux-dev is guidance-distilled (guidance embedding, single pass);
    # DiT / UNet / vDiT sample with classifier-free guidance (x2 batch).
    return 1 if arch.family == "mmdit" else 2


def _gen_spec(mesh, shape, rules):
    """Batch dim over whatever 'batch' resolved to; spatial dims get the
    'seq' axes if they divide (sequence parallelism for small batches)."""
    if mesh is None:
        return P()
    b_axes = rules.get("batch", ())
    s_axes = rules.get("seq", ())
    entries = [b_axes if b_axes else None]
    placed = False
    for dim in shape[1:]:
        if not placed and s_axes and dim % shlib.axis_size(mesh, s_axes) == 0:
            entries.append(s_axes)
            placed = True
        else:
            entries.append(None)
    return P(*entries)


# --- vision workloads ----------------------------------------------------------


def _vision_train(arch: ArchConfig, shape: ShapeSpec, mesh) -> Workload:
    tc = arch.train
    defs = model_fns(arch)
    ctx = ShardCtx(mesh, shlib.train_act_rules(mesh))
    m = arch.model
    fam = arch.family

    def loss_fn(params, batch, rng):
        if fam == "vit":
            logits = vit_lib.vit_apply(params, batch["images"], m, ctx=ctx,
                                       remat=tc.remat)
        else:
            logits = eff_lib.effnet_apply(params, batch["images"], m, ctx=ctx,
                                          remat=tc.remat)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0]
        loss = jnp.mean(logz - gold)
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"])
                       .astype(jnp.float32))
        return loss, {"acc": acc}

    step = train_loop.make_train_step(loss_fn, tc)
    abstract_state, state_sh = _state_shardings(arch, defs, mesh, tc)
    B, res = shape.batch, shape.img_res
    batch = {"images": jax.ShapeDtypeStruct((B, res, res, 3), jnp.float32),
             "labels": jax.ShapeDtypeStruct((B,), jnp.int32)}
    bsh = {k: _batch_sharding(mesh, v.ndim, size0=v.shape[0])
           for k, v in batch.items()}
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    trips = m.num_layers if fam == "vit" else 0
    return Workload(
        arch=arch, shape=shape, mesh=mesh, fn=step,
        args=(abstract_state, batch, rng),
        in_shardings=(state_sh, bsh, _named(mesh, P())),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
        loop_trips=trips, probe="two_point" if trips else "none")


def _vision_serve(arch: ArchConfig, shape: ShapeSpec, mesh) -> Workload:
    defs = model_fns(arch)
    m = arch.model
    fam = arch.family
    B, res = shape.batch, shape.img_res
    bd_size = 1 if mesh is None else shlib.axis_size(
        mesh, shlib.batch_axes(mesh))
    rules = shlib.train_act_rules(mesh)
    if B % max(bd_size, 1) != 0:
        rules["batch"] = ()   # latency cell: model-parallel only
    ctx = ShardCtx(mesh, rules)

    def fn(params, images):
        if fam == "vit":
            return vit_lib.vit_apply(params, images, m, ctx=ctx)
        return eff_lib.effnet_apply(params, images, m, ctx=ctx)

    ap, psh = _param_shardings(defs, mesh)
    images = jax.ShapeDtypeStruct((B, res, res, 3), jnp.float32)
    img_spec = P(rules["batch"] if rules["batch"] else None)
    trips = m.num_layers if fam == "vit" else 0
    return Workload(
        arch=arch, shape=shape, mesh=mesh, fn=fn,
        args=(ap, images),
        in_shardings=(psh, _named(mesh, img_spec)),
        out_shardings=None,
        loop_trips=trips, probe="two_point" if trips else "none")


# --- entry point -----------------------------------------------------------------


def build_workload(arch: ArchConfig, shape_name: str,
                   mesh: Optional[Mesh]) -> Workload:
    shape = arch.shape(shape_name)
    fam = arch.family
    kind = shape.kind
    if fam == "lm":
        if kind == "train":
            return _lm_train(arch, shape, mesh)
        if kind == "prefill":
            return _lm_prefill(arch, shape, mesh)
        if kind == "decode":
            return _lm_decode(arch, shape, mesh)
    elif fam in ("dit", "mmdit", "unet", "vdit"):
        if kind == "train":
            return _diffusion_train(arch, shape, mesh)
        if kind == "generate":
            return _diffusion_generate(arch, shape, mesh)
    elif fam in ("vit", "effnet"):
        if kind == "train":
            return _vision_train(arch, shape, mesh)
        if kind == "classify":
            return _vision_serve(arch, shape, mesh)
    raise ValueError(f"no workload for family={fam} kind={kind}")
