"""Offline pattern search: classify (layer, head) attention structure.

Runs calibration traffic through the dispatch path, scores every
template in the bank per (layer, head) against reference attention
(PSNR + realized skip rate), classifies heads static vs dynamic, and
persists the versioned assignment artifact (core/patterns.py,
DESIGN.md §16) next to the autotune cache::

    python -m repro.launch.pattern_search --grid 8x16x16 --layers 4 \
        --heads 8 --steps 3 --prompts 2 --out /tmp/patterns.json

The calibration traffic is synthetic but head-diverse: heads cycle
through temporal (AR(1)-correlated same-site tokens), spatial
(frame-local smoothed tokens), and dynamic (unstructured) characters,
so the search exercises every branch of the tri-branch classification.
Swap in real activations by calling
:func:`repro.core.patterns.search_patterns` with your own samples.
"""

from __future__ import annotations

import argparse
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp

from repro.core import patterns
from repro.data.synthetic import correlated_video_latents


def _head_traffic(key: jax.Array, character: str,
                  grid: Tuple[int, int, int], d: int, gain: float):
    """(1, N, d) q/k for one head of the given character."""
    t, h, w = grid
    n = t * h * w
    kq, kk, kn = jax.random.split(key, 3)
    if character == "dynamic":
        return (jax.random.normal(kq, (1, n, d)),
                jax.random.normal(kk, (1, n, d)))
    if character == "temporal" and t > 1:
        lat = correlated_video_latents(kq, 1, grid, d,
                                       temporal_rho=0.998,
                                       spatial_smooth=0)
    else:  # spatial (also the temporal slot's fallback on T=1 grids)
        lat = correlated_video_latents(kq, 1, grid, d,
                                       temporal_rho=0.05,
                                       spatial_smooth=3)
    x = gain * lat.reshape(1, n, d)
    noise = 0.05 * jax.random.normal(kn, (1, n, d))
    return x, x + noise


def calibration_traffic(*, grid: Tuple[int, int, int], layers: int,
                        heads: int, steps: int, prompts: int, d: int,
                        seed: int = 0, gain: float = 4.0,
                        characters: Tuple[str, ...] = ("temporal",
                                                       "spatial",
                                                       "dynamic")
                        ) -> Iterator[Tuple[int, jax.Array, jax.Array,
                                            jax.Array]]:
    """Yield (layer, q, k, v) samples with per-head characters held
    fixed across steps/prompts — static heads must present a *stable*
    winner, dynamic heads must not."""
    kinds = tuple(characters)
    for layer in range(layers):
        for prompt in range(prompts):
            for step in range(steps):
                base = jax.random.PRNGKey(
                    seed + 7919 * layer + 101 * prompt + step)
                qs, ks = [], []
                for head in range(heads):
                    character = kinds[(head + layer) % len(kinds)]
                    qh, kh = _head_traffic(
                        jax.random.fold_in(base, head), character, grid,
                        d, gain)
                    qs.append(qh)
                    ks.append(kh)
                q = jnp.stack(qs, axis=1)
                k = jnp.stack(ks, axis=1)
                v = jax.random.normal(jax.random.fold_in(base, 10_000),
                                      q.shape)
                yield layer, q, k, v


def _parse_dims(text: str, n: int, flag: str) -> Tuple[int, ...]:
    parts = text.lower().split("x")
    if len(parts) != n or not all(p.isdigit() for p in parts):
        raise argparse.ArgumentTypeError(
            f"{flag} wants {n} x-separated ints, got {text!r}")
    return tuple(int(p) for p in parts)


def main(argv=None) -> patterns.PatternArtifact:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", default="8x16x16",
                    help="TxHxW token grid (T=1 => spatial-only bank)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--heads", type=int, default=3)
    ap.add_argument("--steps", type=int, default=2,
                    help="calibration denoising steps per prompt")
    ap.add_argument("--prompts", type=int, default=2)
    ap.add_argument("--d", type=int, default=32, help="head dim")
    ap.add_argument("--block", default="128x128",
                    help="BQxBK block shape skip rates are scored at")
    ap.add_argument("--tolerance-db", type=float, default=25.0,
                    help="min PSNR vs reference for a template to win")
    ap.add_argument("--stability", type=float, default=0.6,
                    help="min fraction of samples agreeing on the winner")
    ap.add_argument("--gain", type=float, default=4.0,
                    help="logit sharpening of the structured heads")
    ap.add_argument("--characters", default="temporal,spatial,dynamic",
                    help="comma list of head characters the calibration "
                         "traffic cycles through")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="artifact path (default: REPRO_PATTERN_ARTIFACT "
                         "or the user cache dir)")
    args = ap.parse_args(argv)

    grid = _parse_dims(args.grid, 3, "--grid")
    block = _parse_dims(args.block, 2, "--block")
    samples = calibration_traffic(
        grid=grid, layers=args.layers, heads=args.heads, steps=args.steps,
        prompts=args.prompts, d=args.d, seed=args.seed, gain=args.gain,
        characters=tuple(args.characters.split(",")))
    art = patterns.search_patterns(
        samples, grid, block_shape=block, tolerance_db=args.tolerance_db,
        stability_min=args.stability,
        meta={"traffic": "synthetic", "layers": args.layers,
              "heads": args.heads, "steps": args.steps,
              "prompts": args.prompts, "seed": args.seed})

    for (layer, head), a in sorted(art.heads.items()):
        print(f"L{layer}/H{head}: {a.spec.label:<28} "
              f"{'static ' if a.static else 'dynamic'} "
              f"branch={a.branch:<8} psnr={min(a.psnr_db, 999.0):6.1f}dB "
              f"skip={a.skip_rate:.2f} stability={a.stability:.2f}")
    print(f"static fraction: {art.static_fraction():.2f} "
          f"({len(art.heads)} heads, version {art.version})")
    path = patterns.save_pattern_artifact(art, args.out)
    print(f"wrote {path}")
    return art


if __name__ == "__main__":
    main()
