"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_global / (chips x 197e12 FLOP/s bf16)
    memory     = HLO_bytes_global / (chips x 819e9 B/s HBM)
    collective = collective_bytes_per_chip / (50e9 B/s per ICI link)

``compiled.cost_analysis()`` under SPMD reports the *local* (per-device)
partitioned module (verified empirically: an 8-way sharded matmul reports
1/8 the flops), so HLO_FLOPs_global / chips == the local value and the
terms below use the local numbers against single-chip peaks — identical
math to the spec formula.  Collective

bytes are NOT in cost_analysis: we parse the optimized HLO text and sum
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (per-device bytes: XLA HLO shapes
after SPMD partitioning are local shapes).

Also reported: MODEL_FLOPS = 6·N·D (dense LM) or 6·N_active·D (MoE), and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs that exposes remat and
redundant-compute waste.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

from repro.config.base import ArchConfig, LMConfig, ShapeSpec

PEAK_FLOPS = 197e12        # bf16 per chip, TPU v5e
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (~per-chip effective)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    if not _SHAPE_RE.search(shape_str):
        # scalar like 'f32[]' handled above; bare 'f32' means scalar
        base = shape_str.strip().strip("()")
        if base in _DTYPE_BYTES:
            total += _DTYPE_BYTES[base]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind output bytes (per device) summed over the HLO.

    '-start' ops are counted once ('-done' carries the same buffer).
    """
    out: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # skip the -done halves so async collectives count once
        line = hlo_text[m.start(): hlo_text.find("(", m.end(2))]
        if f"{kind}-done" in line:
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per-device FLOPs (local SPMD module)
    hlo_bytes: float            # per-device HBM bytes
    collective_bytes: float     # per-device bytes over the program
    collective_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    steps_multiplier: int = 1
    peak_mem_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        global_flops = self.hlo_flops * self.chips
        return self.model_flops / global_flops if global_flops else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
            "useful_ratio": self.useful_ratio,
            "peak_mem_gb": self.peak_mem_bytes / 1e9,
        }


def model_flops_estimate(arch: ArchConfig, shape: ShapeSpec,
                         param_count: int, active_param_count: int) -> float:
    """6·N·D per trained token (fwd+bwd); 2·N·D per inference token."""
    if shape.kind == "train":
        if arch.family == "lm":
            tokens = shape.global_batch * shape.seq_len
        else:
            tokens = _vision_tokens(arch, shape) * shape.batch
        return 6.0 * active_param_count * tokens
    # inference kinds: 2·N_active·D per processed token per step
    if arch.family == "lm":
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
        else:  # decode: one new token per sequence
            tokens = shape.global_batch * 1
        return 2.0 * active_param_count * tokens
    tokens = _vision_tokens(arch, shape) * shape.batch
    from repro.launch.workloads import _cfg_factor
    f = _cfg_factor(arch) if shape.kind == "generate" else 1
    return 2.0 * active_param_count * tokens * f


def _vision_tokens(arch: ArchConfig, shape: ShapeSpec) -> int:
    m = arch.model
    fam = arch.family
    res = shape.img_res
    if fam in ("dit",):
        return (res // m.vae_factor // m.patch) ** 2
    if fam == "mmdit":
        return (res // 8 // m.patch) ** 2 + m.txt_tokens
    if fam == "unet":
        return (res // 8) ** 2           # dominated by the top level
    if fam == "vdit":
        g = m.grid(img_res=res)
        return g[0] * g[1] * g[2] + m.txt_tokens
    if fam == "vit":
        return (res // m.patch) ** 2 + 1
    if fam == "effnet":
        return (res // 32) ** 2          # proxy: bottleneck grid
    raise ValueError(fam)


def analyze_values(flops: float, byts: float, coll: Dict[str, int],
                   arch: ArchConfig, shape: ShapeSpec, mesh_desc: str,
                   chips: int, param_count: int,
                   active_param_count: Optional[int] = None,
                   steps_multiplier: int = 1) -> RooflineReport:
    """Roofline report from already-extracted per-device cost values
    (the dry-run's two-point/unrolled probes produce these)."""
    coll_total = float(sum(coll.values()))
    mf = model_flops_estimate(arch, shape, param_count,
                              active_param_count or param_count)
    return RooflineReport(
        arch=arch.name, shape=shape.name, mesh=mesh_desc, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes=coll_total, collective_breakdown=coll,
        compute_s=flops / PEAK_FLOPS,      # local flops vs one chip's peak
        memory_s=byts / HBM_BW,
        collective_s=coll_total / ICI_BW,
        model_flops=mf, steps_multiplier=steps_multiplier)


def analyze(compiled, hlo_text: str, arch: ArchConfig, shape: ShapeSpec,
            mesh_desc: str, chips: int, param_count: int,
            active_param_count: Optional[int] = None,
            steps_multiplier: int = 1) -> RooflineReport:
    """Single-artifact analysis (no loop correction — prefer the probe
    path in dryrun.run_cell for scanned models)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return analyze_values(
        float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0)),
        collective_bytes_from_hlo(hlo_text), arch, shape, mesh_desc, chips,
        param_count, active_param_count, steps_multiplier)


def active_params_lm(cfg: LMConfig) -> int:
    """Active (per-token) parameter count for MoE LMs."""
    from repro.models import transformer_lm as lm_lib
    from repro.models.params import param_count as pc
    defs = lm_lib.lm_defs(cfg)
    total = pc(defs)
    if cfg.moe is None:
        return total
    from repro.models.moe import moe_defs
    moe = moe_defs(cfg.d_model, cfg.moe)
    routed = pc({k: moe[k] for k in ("wi_gate", "wi_up", "wo")}) \
        * cfg.num_layers
    active_routed = routed * cfg.moe.top_k / cfg.moe.num_experts
    return int(total - routed + active_routed)
