"""Production mesh definitions.

Single pod: 16x16 = 256 chips (TPU v5e pod slice), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the pod axis
carries pure data parallelism so cross-pod traffic is gradient-only
(DCN-friendly); see DESIGN.md §7.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state — required for the
dry-run's XLA_FLAGS device-count override to work.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
