"""Production mesh definitions.

Single pod: 16x16 = 256 chips (TPU v5e pod slice), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the pod axis
carries pure data parallelism so cross-pod traffic is gradient-only
(DCN-friendly); see DESIGN.md §7.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module does not touch jax device state — required for the
dry-run's XLA_FLAGS device-count override to work.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def init_distributed(coordinator_address=None, num_processes=None,
                     process_id=None) -> bool:
    """Multi-host ``jax.distributed`` init for the serving fleet
    (DESIGN.md §15.4), gated behind the launcher's ``--distributed``
    flag.

    The Levanter idiom (SNIPPETS.md §1): initialize the cross-host
    runtime exactly once, *before* any call that touches jax device
    state, then build meshes over ``jax.devices()`` — which now spans
    every host — and let ``multihost_utils`` / shard_map handle the
    rest.  Arguments default to None so single-binary cloud launchers
    (GKE/TPU pods) can rely on jax's environment auto-detection; on
    bare hosts pass all three explicitly.  Returns True when the
    runtime was initialized, False when it already was (idempotent —
    a router restart must not re-init).

    The already-initialized probe must not touch jax device state:
    ``jax.process_count()`` initializes the local XLA backend, after
    which ``jax.distributed.initialize()`` unconditionally raises
    ("must be called before any JAX computations are executed").  So we
    ask the distributed runtime's own global state whether a client
    exists instead.
    """
    global _distributed_initialized
    if _distributed_initialized or _distributed_client_active():
        return False  # already initialized by an earlier caller
    kwargs = {}
    if coordinator_address is not None:
        kwargs["coordinator_address"] = coordinator_address
    if num_processes is not None:
        kwargs["num_processes"] = num_processes
    if process_id is not None:
        kwargs["process_id"] = process_id
    try:
        jax.distributed.initialize(**kwargs)
    except RuntimeError as e:
        # Another component (launcher, test harness) beat us to it —
        # treat as the idempotent case rather than crashing the server.
        # jax <=0.4 phrases this "should only be called once", newer
        # versions "already initialized".
        if ("should only be called once" in str(e)
                or "already initialized" in str(e)):
            _distributed_initialized = True
            return False
        raise
    _distributed_initialized = True
    return True


_distributed_initialized = False


def _distributed_client_active() -> bool:
    """Is the jax.distributed client already up?  Reads the runtime's
    global state directly — unlike ``jax.process_count()`` this never
    initializes the local backend (private API, so fail open: jax
    versions without it fall through to ``initialize()``'s own
    already-initialized error, handled above)."""
    try:
        from jax._src import distributed as _dist

        return _dist.global_state.client is not None
    except Exception:  # noqa: BLE001 — private-API drift must not crash init
        return False


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def parse_mesh_spec(spec: str):
    """``--mesh DxM`` / ``--mesh DxMxS`` CLI flags -> a dispatch mesh.

    '8' means (data=8, model=1); '4x2' means (data=4, model=2); a third
    component adds the context-parallel ``seq`` axis (DESIGN.md §14) —
    '1x1x2' shards the token axis 2-way for ring attention.  Raises with
    an actionable message when the host has too few devices (on CPU set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``)."""
    parts = spec.lower().replace("×", "x").split("x")
    if not 1 <= len(parts) <= 3:
        raise ValueError(f"mesh spec {spec!r}: expected 'D', 'DxM' or "
                         f"'DxMxS'")
    dims = [int(p) for p in parts] + [1] * (3 - len(parts))
    d, m, s = dims
    if d < 1 or m < 1 or s < 1:
        raise ValueError(f"mesh spec {spec!r}: axes must be >= 1")
    avail = len(jax.devices())
    if d * m * s > avail:
        raise ValueError(
            f"mesh {d}x{m}x{s} needs {d * m * s} devices but only {avail} "
            f"are visible; on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={d * m * s}")
    if len(parts) == 3:
        return jax.make_mesh((d, m, s), ("data", "model", "seq"))
    return jax.make_mesh((d, m), ("data", "model"))
