import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and emit the roofline table.

This proves — without hardware — that the distribution config is
coherent: shardings propagate, collectives exist for every resharding,
and the per-device footprint fits a TPU v5e (16 GB).  Failures here are
bugs in the system, not environment problems.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch dit-xl2 \
        --shape train_256 [--multi-pod] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST run before any other jax-touching import —
jax locks the device count at first backend init.  Do not set this
anywhere global (tests and benches must see 1 device).
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.workloads import build_workload, model_fns
from repro.models.params import param_count
from repro.utils.logging import get_logger

log = get_logger("dryrun")


def run_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, verbose: bool = True, cost_probe: bool = True,
             arch=None):
    """Lower + compile one (arch x shape x mesh) cell; return the
    RooflineReport (raises on any sharding/compile failure).

    Two compiles per cell:
      1. the production form (rolled scan-over-layers, chunked attention)
         — proves compile + gives memory_analysis (what actually runs);
      2. the *cost probe* (``cost_probe_mode``: loops unrolled, chunking
         off) — exact FLOPs / bytes / collective-bytes, since XLA's cost
         analysis counts a while-loop body only once.  Collectives are
         parsed from the compiled (post-SPMD) HLO text.
    Multi-pod validation passes ``cost_probe=False`` (pass/fail + memory;
    the roofline table is single-pod only).
    """
    import dataclasses

    from repro.utils.loops import cost_probe_mode, unroll_mode

    if arch is None:
        arch = get_config(arch_name)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    wl = build_workload(arch, shape_name, mesh)
    with jax.sharding.set_mesh(mesh):
        compiled = wl.lower().compile()
    t1 = time.time()
    mem = compiled.memory_analysis()

    def _measure(c):
        cost = c.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll = rl.collective_bytes_from_hlo(c.as_text())
        return (float(cost.get("flops", 0.0)),
                float(cost.get("bytes accessed", 0.0)), coll)

    if cost_probe:
        # probe config: grad-accum folded out (identical total cost for a
        # single-level layers loop; memory is irrelevant at compile time)
        probe_arch = arch
        if arch.train.grad_accum > 1:
            probe_arch = dataclasses.replace(
                arch, train=dataclasses.replace(arch.train, grad_accum=1))
        if wl.probe == "two_point" and wl.loop_trips >= 2:
            with jax.sharding.set_mesh(mesh), cost_probe_mode():
                with unroll_mode(1):
                    m1 = _measure(build_workload(
                        probe_arch, shape_name, mesh).lower().compile())
                with unroll_mode(2):
                    m2 = _measure(build_workload(
                        probe_arch, shape_name, mesh).lower().compile())
            # m(u) = out + u·body  =>  total(L) = m1 + (L-1)·(m2-m1)
            L = wl.loop_trips
            flops = m1[0] + (L - 1) * max(m2[0] - m1[0], 0.0)
            byts = m1[1] + (L - 1) * max(m2[1] - m1[1], 0.0)
            coll = {k: m1[2].get(k, 0) + (L - 1) * max(
                m2[2].get(k, 0) - m1[2].get(k, 0), 0)
                for k in set(m1[2]) | set(m2[2])}
        elif wl.probe == "unroll":
            # MMDiT has two scans with different trip counts; the unroll
            # two-point can only lump their bodies.  Exact decomposition:
            # probe the double-only and single-only model variants with
            # the two-point identity, plus a zero-block outer probe:
            #   total = two_point(double-only) + two_point(single-only)
            #           − m(zero blocks)
            m = probe_arch.model
            def variant(D, S):
                return dataclasses.replace(probe_arch,
                    model=dataclasses.replace(
                        m, n_double_blocks=D, n_single_blocks=S))

            def two_point(a, L):
                with jax.sharding.set_mesh(mesh), cost_probe_mode():
                    with unroll_mode(1):
                        m1 = _measure(build_workload(
                            a, shape_name, mesh).lower().compile())
                    if L < 2:
                        return m1
                    with unroll_mode(2):
                        m2 = _measure(build_workload(
                            a, shape_name, mesh).lower().compile())
                keys = set(m1[2]) | set(m2[2])
                return (m1[0] + (L - 1) * max(m2[0] - m1[0], 0.0),
                        m1[1] + (L - 1) * max(m2[1] - m1[1], 0.0),
                        {k: m1[2].get(k, 0) + (L - 1) * max(
                            m2[2].get(k, 0) - m1[2].get(k, 0), 0)
                         for k in keys})

            D, S = m.n_double_blocks, m.n_single_blocks
            md = two_point(variant(D, 0), D)
            msb = two_point(variant(0, S), S)
            m0 = two_point(variant(0, 0), 0)
            flops = md[0] + msb[0] - m0[0]
            byts = md[1] + msb[1] - m0[1]
            keys = set(md[2]) | set(msb[2]) | set(m0[2])
            coll = {k: max(md[2].get(k, 0) + msb[2].get(k, 0)
                           - m0[2].get(k, 0), 0) for k in keys}
        else:
            with jax.sharding.set_mesh(mesh), cost_probe_mode(), \
                    unroll_mode(1):
                flops, byts, coll = _measure(build_workload(
                    probe_arch, shape_name, mesh).lower().compile())
    else:
        flops, byts, coll = _measure(compiled)
    t2 = time.time()

    defs = model_fns(arch)
    n_params = param_count(defs)
    active = (rl.active_params_lm(arch.model) if arch.family == "lm"
              else n_params)
    report = rl.analyze_values(
        flops, byts, coll, arch, arch.shape(shape_name),
        mesh_desc="x".join(str(s) for s in mesh.devices.shape),
        chips=chips, param_count=n_params, active_param_count=active,
        steps_multiplier=wl.steps_multiplier)
    # memory figures always from the production (rolled) compile
    report.peak_mem_bytes = float(
        mem.argument_size_in_bytes + mem.temp_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    if verbose:
        print(f"--- {arch_name} x {shape_name} on {report.mesh} "
              f"({chips} chips), compile {t1 - t0:.1f}s"
              + (f" + probe {t2 - t1:.1f}s" if cost_probe else ""))
        print(f"    memory_analysis: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB "
              f"out={mem.output_size_in_bytes/1e9:.2f}GB "
              f"alias={mem.alias_size_in_bytes/1e9:.2f}GB")
        print(f"    cost_analysis: flops/dev={report.hlo_flops:.3e} "
              f"bytes/dev={report.hlo_bytes:.3e}")
        print(f"    collectives/dev: {report.collective_breakdown}")
        print(f"    roofline: compute={report.compute_s*1e3:.2f}ms "
              f"memory={report.memory_s*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms "
              f"-> {report.dominant}-bound; useful={report.useful_ratio:.2f}")
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512 chips) instead of 16x16 (256)")
    ap.add_argument("--json", help="append reports to this JSON-lines file")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the cost probe (pass/fail + memory only; "
                         "used for the multi-pod validation pass)")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cells = []
    if args.all:
        for a in ASSIGNED_ARCHS:
            for s in get_config(a).shapes:
                cells.append((a, s.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells.append((args.arch, args.shape))

    failures = []
    for arch_name, shape_name in cells:
        try:
            report = run_cell(arch_name, shape_name, mesh=mesh,
                              cost_probe=not args.no_probe)
            if args.json:
                with open(args.json, "a") as f:
                    row = report.row()
                    row["collectives"] = report.collective_breakdown
                    row["steps_multiplier"] = report.steps_multiplier
                    f.write(json.dumps(row) + "\n")
        except Exception as e:  # noqa: BLE001 — report all failures at end
            traceback.print_exc()
            failures.append((arch_name, shape_name, repr(e)))

    print(f"\n=== dry-run complete: {len(cells) - len(failures)}/{len(cells)} "
          f"cells passed on mesh {'x'.join(str(s) for s in mesh.devices.shape)}")
    for f_ in failures:
        print("FAILED:", f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
