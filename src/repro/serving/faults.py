"""Deterministic fault injection — the chaos harness (DESIGN.md §17.3).

The guardrail / watchdog / quarantine machinery is only trustworthy if
it is *exercised*, so this module turns failure modes into a reproducible
spec instead of an outage: a seeded, counted plan of faults that the
dispatch layer, the engine, and the serving drivers consult at
well-defined points.  Install via ``serve.py --inject-faults SPEC`` /
``benchmarks.serve_mixed --inject-faults SPEC`` or the ``REPRO_FAULTS``
environment variable.

Spec grammar (``;``-separated faults, ``,``-separated ``key=value``
params; ints/floats parsed, everything else kept as string)::

    REPRO_FAULTS="attn_nan:step=1;kill_replica:after=1"
    REPRO_FAULTS="seed=7;raise:count=2,msg=transient;poison:rid=3"

Fault kinds and where they fire:

  ``attn_nan``       traced into ``attention_dispatch``: the attention
                     output of every *non-dense* backend is flipped to
                     NaN at denoising step ``step`` (default 0).  Scoped
                     to sparse backends on purpose — the degradation
                     ladder's dense recompile must clear the fault, the
                     way a real sparse-kernel bug would.
  ``artifact_corrupt``  engine loop, after ``after`` served batches
                     (default 1): garbage bytes are written over the
                     pattern artifact file and the in-memory install is
                     dropped, so the next load takes the
                     warn-and-regenerate path (DESIGN.md §16).
  ``hang``           engine worker, before the sampler runs: sleeps
                     ``seconds`` (default 3600) — watchdog fodder.
  ``raise``          engine worker: raises RuntimeError(``msg``) —
                     transient, retry-with-backoff outlasts ``count``.
  ``poison``         engine worker: raises whenever request ``rid`` is
                     in the batch, every time (``count=-1`` default) —
                     the bisection quarantine's deterministic prey.
  ``kill_replica``   host drivers (serve.py / serve_mixed): fail a
                     router replica after ``after`` completed results.
  ``crash``          host drivers: SIGKILL the whole serving process
                     after ``after`` completed results — a hard kill,
                     no drain, no clean-shutdown marker (DESIGN.md
                     §18).  With ``wait_ckpt=1`` (default) the driver
                     first waits for at least one in-flight request's
                     chunk checkpoint to land, so "mid-generation" is
                     deterministic; the restart drill then recovers
                     from the journal with ``--resume``.

``count`` (default 1; ``-1`` = unlimited) bounds how many times a
host-level fault fires; ``attn_nan`` is trace-scoped instead (armed
while installed, cleared by the dense recompile).  All arming decisions
are plain counters under a lock — no wall clock, no RNG — so a spec
replays identically; ``seed`` is carried for fault kinds that may want
randomized placement later and is mixed into nothing today.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional

from repro.utils.logging import get_logger

log = get_logger("serve.faults")

__all__ = ["ENV_VAR", "FaultPlan", "FaultSpec", "active_faults",
           "clear_faults", "install_faults", "install_from_env",
           "parse_faults"]

ENV_VAR = "REPRO_FAULTS"

_KINDS = ("attn_nan", "artifact_corrupt", "hang", "raise", "poison",
          "kill_replica", "crash")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    kind: str
    count: int = 1  # -1 = unlimited
    params: Dict[str, object] = dataclasses.field(default_factory=dict)

    def param(self, key: str, default=None):
        return self.params.get(key, default)


def _coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    return v


def parse_faults(spec: str) -> "FaultPlan":
    """Parse the spec grammar (module docstring) into a
    :class:`FaultPlan`.  Raises ValueError on unknown fault kinds or
    malformed segments — a chaos drill with a typo'd spec must fail
    loudly, not silently inject nothing."""
    specs: List[FaultSpec] = []
    seed = 0
    for seg in (s.strip() for s in spec.split(";")):
        if not seg:
            continue
        if seg.startswith("seed="):
            seed = int(seg.split("=", 1)[1])
            continue
        kind, _, rest = seg.partition(":")
        kind = kind.strip()
        if kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in {spec!r}; known: {_KINDS}")
        params: Dict[str, object] = {}
        for pair in (p.strip() for p in rest.split(",") if p.strip()):
            if "=" not in pair:
                raise ValueError(
                    f"malformed fault param {pair!r} in {seg!r} "
                    "(expected key=value)")
            k, v = pair.split("=", 1)
            params[k.strip()] = _coerce(v.strip())
        count = int(params.pop("count", -1 if kind == "poison" else 1))
        specs.append(FaultSpec(kind=kind, count=count, params=params))
    return FaultPlan(specs, seed=seed)


class FaultPlan:
    """A parsed fault spec plus its firing counters (thread-safe)."""

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self._remaining = {id(s): s.count for s in self.specs}
        self._fired: Dict[str, int] = {}
        self._lock = threading.Lock()

    def spec(self, kind: str) -> Optional[FaultSpec]:
        """Static lookup (trace-time arming check) — does not consume."""
        for s in self.specs:
            if s.kind == kind:
                return s
        return None

    def take(self, kind: str) -> Optional[FaultSpec]:
        """Consume one firing of ``kind`` if any remain; None otherwise."""
        with self._lock:
            for s in self.specs:
                if s.kind != kind:
                    continue
                left = self._remaining[id(s)]
                if left == 0:
                    continue
                if left > 0:
                    self._remaining[id(s)] = left - 1
                self._fired[kind] = self._fired.get(kind, 0) + 1
                return s
        return None

    def note_fired(self, kind: str) -> None:
        """Count a firing decided elsewhere (e.g. ``attn_nan`` arming a
        trace)."""
        with self._lock:
            self._fired[kind] = self._fired.get(kind, 0) + 1

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {f"fault_{k}": v for k, v in sorted(self._fired.items())}

    # -- engine-worker hooks (host level, called inside _run_batch) --------

    def maybe_hang(self) -> bool:
        s = self.take("hang")
        if s is None:
            return False
        seconds = float(s.param("seconds", 3600.0))
        log.warning("fault injection: hanging sampler for %.1fs", seconds)
        time.sleep(seconds)
        return True

    def maybe_raise(self) -> None:
        s = self.take("raise")
        if s is not None:
            raise RuntimeError(
                f"injected fault: {s.param('msg', 'transient worker error')}")

    def check_poison(self, request_ids) -> None:
        s = self.spec("poison")
        if s is None:
            return
        rid = s.param("rid")
        if rid in list(request_ids) and self.take("poison") is not None:
            raise RuntimeError(f"injected poison fault: request {rid}")

    def maybe_corrupt_artifact(self, batches_served: int) -> bool:
        s = self.spec("artifact_corrupt")
        if s is None or batches_served < int(s.param("after", 1)):
            return False
        if self.take("artifact_corrupt") is None:
            return False
        from repro.core import patterns

        path = patterns.pattern_artifact_path()
        try:
            with open(path, "wb") as f:
                f.write(b"\x00corrupt-by-fault-injection\xff{")
        except OSError as e:  # no artifact file to corrupt: still drop RAM
            log.warning("fault injection: could not corrupt %s (%s)",
                        path, e)
        patterns.set_active_artifact(None)
        log.warning("fault injection: corrupted pattern artifact at %s",
                    path)
        return True


# ---------------------------------------------------------------------------
# Process-wide install (mirrors dispatch's active-mesh idiom)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def install_faults(plan) -> Optional[FaultPlan]:
    """Install a :class:`FaultPlan` (or a spec string) process-wide;
    returns the previous plan.  ``install_faults(None)`` uninstalls."""
    global _ACTIVE
    if isinstance(plan, str):
        plan = parse_faults(plan)
    prev, _ACTIVE = _ACTIVE, plan
    if plan is not None:
        log.warning("fault injection armed: %s",
                    [(s.kind, s.count, s.params) for s in plan.specs])
    return prev


def clear_faults() -> None:
    install_faults(None)


def active_faults() -> Optional[FaultPlan]:
    return _ACTIVE


def install_from_env() -> Optional[FaultPlan]:
    """Arm ``REPRO_FAULTS`` if set (no-op otherwise); returns the
    installed plan."""
    spec = os.environ.get(ENV_VAR, "").strip()
    if not spec:
        return None
    install_faults(parse_faults(spec))
    return _ACTIVE
