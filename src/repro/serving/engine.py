"""Serving engines.

DiffusionEngine: shape-bucketed continuous batching for text-to-image /
video generation.  Requests are keyed into a **bucket** by
``(latent_shape, steps, policy, reuse_every, seq_shards, txt_shape,
stream_every)``; the batcher drains buckets under an SLO-aware policy
(DESIGN.md §15): starvation aging first, then earliest-feasible-deadline
over deadline-carrying heads (EDF), then hottest (deepest) bucket for
deadline-less traffic — so heterogeneous traffic never pads or mixes
shapes inside one sampler invocation and tight SLOs are not stuck
behind deep hot buckets.  Admission control sheds requests at submit
time when they *provably* cannot meet their deadline
(:func:`repro.serving.slo.admission_decision`); shed requests cost zero
compute.  Each bucket owns a jitted (optionally mesh-sharded) sampler
obtained from ``sampler_factory`` and held in a bounded LRU of compiled
entries — the hottest bucket's sampler always survives eviction.
Per-request PRNG keys are threaded through ``sample_fn`` as a full
``(B, 2)`` key batch (vmap inside the sampler), so requests in one
batch never share sampler randomness.  TimeRipple's reuse schedule is
stateless per denoising step (no KV-style cache, paper Tbl. 2), which
is what makes this continuous batching safe: a bucket switch carries
zero eviction cost.  Attention inside the sampler routes through
``core.dispatch.attention_dispatch`` (DESIGN.md §8, §10); ``plan_fn``
lets the launcher log the resolved
:class:`~repro.core.dispatch.DispatchPlan` per bucket at first compile.

Streaming (DESIGN.md §15.3): a sampler factory that honours
``stream_every`` returns a *generator* sample_fn yielding intermediate
latents every K denoising steps; the engine publishes each chunk to
:meth:`DiffusionEngine.stream` subscribers as it lands and records
time-to-first-frame (``GenResult.ttff_s``, measured from submit) as a
first-class latency metric next to completion time.

Crash safety (DESIGN.md §18): with a ``journal`` attached the engine
writes a WAL record per lifecycle event (submitted before enqueue /
chunk / finished / shed); with a ``checkpoint_store`` each streamed
chunk additionally persists the per-request ``(x_t, decision-cache
state, step_offset)`` snapshot the sampler exposes via its chunk aux
(``aux["__ckpt__"]``).  A request carrying a ``resume`` payload lands
in a bucket keyed by its resume step and is served from the checkpoint
through the sampler's ``resume=`` keyword — bitwise-equal to the
uninterrupted run via the PR 7 ``step_offset``/``total_steps`` chunked
contract.  Failover-marked errors are never journaled as finished, so
a crashed replica's requests stay pending for warm restart.

LMEngine: KV-cache prefill + decode loop (used by the decode_32k /
long_500k shape cells and the LM serving example).
"""

from __future__ import annotations

import dataclasses
import inspect
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.guardrail import DegradationLadder, GuardrailConfig
from repro.serving import faults as fault_lib
from repro.serving import slo as slo_lib
from repro.serving.slo import ServiceEstimator, ShedError
from repro.utils.logging import get_logger

log = get_logger("serve")

# Error-message markers that mean "the *replica* failed, not the
# request" — the router requeues matching failures onto a healthy
# replica instead of surfacing them (§15.4, §17.4).  Substring-matched
# because errors cross the engine/router seam as strings.
FAILOVER_MARKERS = ("engine stopped", "watchdog")


def is_failover_error(msg: object) -> bool:
    """Does this error text name a replica-level failure (stop /
    watchdog trip) rather than a request-level one?"""
    text = str(msg)
    return any(marker in text for marker in FAILOVER_MARKERS)

# (latent_shape, steps, policy, reuse_every, seq_shards, txt_shape,
# stream_every); legacy single-sampler engines use steps=-1 so requests
# with differing ``steps`` still share the one compiled entry; policy is
# the reuse-policy name (None = the engine / sampler default), so
# requests under different sparsity strategies never share a compiled
# sampler; reuse_every is the decision-cache cadence (DESIGN.md §13;
# None = the sampler default) — it is baked into the compiled sampler's
# refresh cond, so mixed-cadence traffic must never share one compiled
# entry either; seq_shards is the context-parallel degree of the
# dispatch mesh at *submit* time (DESIGN.md §14) — a sampler compiled
# under a ring mesh runs a different program, so long-video requests
# route to the context-parallel replica shape and never share a
# compiled entry with unsharded traffic (and the mesh must not change
# while traffic is queued, §15.4); txt_shape is the text-embedding
# shape — two requests with different prompt lengths L can never stack
# into one ``(B, L, D)`` batch, so L is bucket identity, not a
# stack-time crash; stream_every is the chunked-delivery cadence
# (None = monolithic) — it changes the compiled chunk program; the
# trailing pattern token is the bucket policy's ``plan_token`` (the
# pattern artifact's content-hash version, DESIGN.md §16) — a
# ``static``/``rainfusion`` sampler bakes the artifact's constant masks
# into its compiled program, so traffic after an artifact swap must
# never share the stale compiled entry; the final element is the
# **resume step** (DESIGN.md §18): 0 for fresh traffic, the checkpoint
# step_offset for requests resuming mid-flight after a crash/failover —
# batchmates must share it (one sampler invocation has one step range),
# but it is *excluded* from the compiled-sampler LRU key (``key[:8]``)
# because the chunked sampler's traced step offset serves every resume
# point with one compiled program.
BucketKey = Tuple[Tuple[int, ...], int, Optional[str], Optional[int], int,
                  Tuple[int, ...], Optional[int], Optional[str], int]


def _seq_shards() -> int:
    """Seq-shard degree of the active dispatch mesh (1 = no context
    parallelism)."""
    from repro.core import dispatch as dispatch_lib

    mesh = dispatch_lib.active_dispatch_mesh()
    if mesh is not None and "seq" in mesh.axis_names:
        return int(mesh.shape["seq"])
    return 1


def _pattern_token(policy_name: Optional[str]) -> Optional[str]:
    """The bucket policy's plan token (pattern-artifact version), so an
    artifact swap between requests invalidates compiled samplers instead
    of silently replaying a stale constant plan."""
    from repro.core.policy import get_policy

    if not policy_name:
        return None
    try:
        pol = get_policy(policy_name)
    except KeyError:
        return None
    tok = getattr(pol, "plan_token", None)
    return tok(None) if callable(tok) else None


def _positional_arity(fn: Optional[Callable]) -> int:
    """How many positional arguments ``fn`` accepts.  Legacy
    two-argument factories / plan_fns keep working unchanged;
    policy-aware ones take a third, cadence-aware ones a fourth,
    streaming-aware ones a fifth.  A ``*args`` factory counts as 3 —
    exactly what such factories have received since the policy seam
    landed — so pre-cadence var-positional factories keep unpacking
    (shape, steps, policy); declare further named parameters to opt
    into the cadence / streaming arguments."""
    if fn is None:
        return 0
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):
        return 2
    if any(p.kind == p.VAR_POSITIONAL for p in params):
        return 3
    return len([p for p in params
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)])


def _takes_policy(fn: Optional[Callable]) -> bool:
    """Does ``fn`` accept a third positional (policy) argument?"""
    return _positional_arity(fn) >= 3


@dataclasses.dataclass
class GenRequest:
    request_id: int
    txt: np.ndarray            # (L, txt_dim) precomputed embeddings
    steps: int = 50
    seed: int = 0
    guidance: float = 4.0
    # None -> the engine's default latent shape (single-shape traffic).
    latent_shape: Optional[Tuple[int, ...]] = None
    # Reuse-policy name for this request (core.policy registry); None ->
    # the engine's default policy.  Part of the bucket identity.
    policy: Optional[str] = None
    # Decision-cache cadence for this request (RippleConfig.reuse_every,
    # DESIGN.md §13); None -> the engine default.  Part of the bucket
    # identity — the cadence is compiled into the sampler's refresh cond.
    reuse_every: Optional[int] = None
    # Absolute wall-clock deadline (time.time() seconds; DESIGN.md §15).
    # None -> no SLO: never shed, scheduled behind deadline traffic by
    # depth.  Callers with relative SLOs stamp time.time() + slo_ms/1e3.
    deadline_s: Optional[float] = None
    # Chunked streaming cadence: deliver intermediate latents every K
    # denoising steps through DiffusionEngine.stream (§15.3).  None ->
    # monolithic delivery.  Part of the bucket identity.
    stream_every: Optional[int] = None
    # Mid-flight resume payload (DESIGN.md §18): ``{"step": int, "x":
    # latent array at that step, "dstate": decision-cache field->array
    # mapping or None}`` from a chunk-boundary checkpoint.  Attached by
    # the warm-restart recovery path and router failover, never by
    # clients; the resume step joins the bucket identity so batchmates
    # share one step range.
    resume: Optional[dict] = dataclasses.field(default=None, repr=False)
    # Was this request resubmitted from a journal recovery scan
    # (counts toward ``recovered_count``)?
    recovered: bool = False


@dataclasses.dataclass
class GenResult:
    request_id: int
    latents: Optional[np.ndarray]
    walltime_s: float
    error: Optional[str] = None
    batch_index: int = -1  # which sampler invocation served this request
    # Time-to-first-frame, measured from submit: first streamed chunk
    # for streaming buckets, completion for monolithic ones (§15.3).
    ttff_s: float = -1.0
    # Deadline outcome (None = the request carried no deadline).
    deadline_met: Optional[bool] = None
    # Was the serving bucket degraded below its requested reuse policy
    # by the guardrail ladder when this result was produced (§17.2)?
    degraded: bool = False


class DiffusionEngine:
    """Continuous-batching engine over bucketed samplers.

    ``sampler_factory(latent_shape, steps[, policy[, reuse_every[,
    stream_every]]]) -> sample_fn`` builds (and jits) the sampler for
    one bucket; ``sample_fn(latents0, txt, rngs)`` takes a ``(B, 2)``
    uint32 batch of per-request PRNG keys and returns latents or
    ``(latents, aux)`` with decision-cache telemetry — or, for
    streaming buckets, a *generator* yielding those per chunk.
    Factories (and ``plan_fn``) that accept a third positional argument
    receive the bucket's reuse-policy name (``GenRequest.policy`` /
    ``default_policy``); a fourth receives the decision-cache cadence
    (``GenRequest.reuse_every`` / ``default_reuse_every``, DESIGN.md
    §13); a fifth the streaming cadence (``GenRequest.stream_every``).
    Two-argument factories keep working.  The legacy single-sampler
    form ``DiffusionEngine(sample_fn, latent_shape)`` is still
    accepted: every request then lands in one default bucket.

    ``scheduler`` picks the drain policy (``"edf"`` default,
    ``"hottest"`` for the pre-SLO behaviour); ``admission_control``
    sheds provably-infeasible requests at submit with
    :class:`~repro.serving.slo.ShedError` (DESIGN.md §15.2).
    """

    def __init__(self, sample_fn: Optional[Callable] = None,
                 latent_shape: Optional[Tuple[int, ...]] = None,
                 *, sampler_factory: Optional[Callable] = None,
                 max_batch: int = 8, max_wait_s: float = 0.05,
                 max_compiled: int = 8, starve_after_s: float = 2.0,
                 attn_plan: Optional[Any] = None,
                 plan_fn: Optional[Callable] = None,
                 default_policy: Optional[str] = None,
                 default_reuse_every: Optional[int] = None,
                 scheduler: str = "edf",
                 admission_control: bool = True,
                 error_ttl_s: float = 60.0,
                 estimator: Optional[ServiceEstimator] = None,
                 guardrail: Any = None,
                 batch_timeout_s: Optional[float] = None,
                 max_retries: int = 1,
                 retry_backoff_s: float = 0.05,
                 bisect_on_error: bool = True,
                 journal: Any = None,
                 checkpoint_store: Any = None):
        if scheduler not in ("edf", "hottest"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if sampler_factory is None:
            if sample_fn is None:
                raise ValueError("need sample_fn or sampler_factory")
            sampler_factory = lambda shape, steps: sample_fn  # noqa: E731
        self._factory = sampler_factory
        self._factory_arity = _positional_arity(sampler_factory)
        self._factory_takes_policy = self._factory_arity >= 3
        self._factory_takes_reuse = self._factory_arity >= 4
        self._factory_takes_stream = self._factory_arity >= 5
        self._plan_fn_takes_policy = _takes_policy(plan_fn)
        self._legacy = sample_fn is not None
        if default_policy is not None and not self._factory_takes_policy:
            raise ValueError(
                "default_policy is set but the sampler factory does not "
                "take a policy argument — it could not honour it")
        if default_reuse_every is not None and not self._factory_takes_reuse:
            raise ValueError(
                "default_reuse_every is set but the sampler factory does "
                "not take a reuse_every argument — it could not honour it")
        self.default_policy = default_policy
        self.default_reuse_every = default_reuse_every
        self.latent_shape = latent_shape
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_compiled = max_compiled
        self.starve_after_s = starve_after_s
        self.scheduler = scheduler
        self.admission_control = admission_control
        self.error_ttl_s = error_ttl_s
        self.estimator = estimator if estimator is not None \
            else ServiceEstimator()
        # Guardrail ladder (§17.2): True -> own ladder with defaults, a
        # GuardrailConfig -> own ladder with it, a DegradationLadder ->
        # shared (router replicas share one so degraded state survives
        # failover), None/False -> sentinels not enforced.
        if guardrail is None or guardrail is False:
            self._ladder: Optional[DegradationLadder] = None
        elif isinstance(guardrail, DegradationLadder):
            self._ladder = guardrail
        elif isinstance(guardrail, GuardrailConfig):
            self._ladder = DegradationLadder(guardrail)
        elif guardrail is True:
            self._ladder = DegradationLadder()
        else:
            raise ValueError(f"guardrail must be True, a GuardrailConfig "
                             f"or a DegradationLadder, got {guardrail!r}")
        if self._ladder is not None and not self._factory_takes_policy:
            raise ValueError(
                "guardrail degradation rewrites the bucket policy, but "
                "this engine's sampler factory does not take a policy "
                "argument — it could not serve a degraded bucket")
        # Watchdog / retry / quarantine knobs (§17.4).  batch_timeout_s
        # is the hang-watchdog floor (scaled by the estimator's
        # timeout_hint once the bucket has observations); None disables
        # the watchdog and runs batches inline.
        self.batch_timeout_s = batch_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.bisect_on_error = bisect_on_error
        self.watchdog_trips = 0
        self.batch_retries = 0
        self.quarantined = 0
        self.attn_plan = attn_plan  # DispatchPlan metadata (or None)
        self.plan_fn = plan_fn      # (latent_shape, steps) -> DispatchPlan
        # bucket deques hold (enqueue_time, request) for starvation
        # aging, deadline lookup, and TTFF accounting
        self._buckets: Dict[BucketKey, deque] = {}
        self._compiled: "OrderedDict[BucketKey, Callable]" = OrderedDict()
        self._results: Dict[int, GenResult] = {}
        # errored results stay retrievable until their TTL so a caller
        # retrying after TimeoutError sees the original batch error —
        # rid -> eviction time (DESIGN.md §15.2)
        self._error_expiry: Dict[int, float] = {}
        # Tombstones for successes consumed by result(): rid -> eviction
        # time.  A stream() consumer still iterating when result() pops
        # the record needs a termination signal — without it the stream
        # hangs until TimeoutError.  Partials stay readable until the
        # tombstone expires.
        self._finished_expiry: Dict[int, float] = {}
        # streaming chunks: rid -> [np latents per delivered chunk]
        self._partials: Dict[int, List[np.ndarray]] = {}
        self._batches_served = 0
        self.shed_count = 0
        self.deadlines_met = 0
        self.deadlines_missed = 0
        # Crash-safety seam (DESIGN.md §18): a serving.journal.Journal
        # records request lifecycle events (submit-before-enqueue, WAL
        # order), a serving.journal.CheckpointStore persists chunk-
        # boundary generation state.  Replicas behind one router share
        # both (same journal directory).
        self._journal = journal
        self._store = checkpoint_store
        self.recovered_count = 0    # journal-recovered resubmissions seen
        self.resumed_count = 0      # requests served from a checkpoint
        self.last_resume_step = 0   # deepest checkpoint step resumed from
        self._lock = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None

    # -- public API -----------------------------------------------------------

    def start(self):
        if self.attn_plan is not None:
            log.info("engine attention plan: %s", self.attn_plan.summary())
        with self._lock:
            self._stop = False  # allow stop() -> start() restart cycles
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True):
        """Stop the batcher.  With ``drain`` (default) every already-
        submitted request is served before the thread exits, so no result
        is orphaned; ``drain=False`` discards queued requests with an
        error result instead."""
        with self._lock:
            self._stop = True
            if not drain:
                for dq in self._buckets.values():
                    for _, r in dq:
                        self._results[r.request_id] = GenResult(
                            r.request_id, None, 0.0, error="engine stopped")
                        self._error_expiry[r.request_id] = (
                            time.time() + self.error_ttl_s)
                self._buckets.clear()
            self._lock.notify_all()
        if self._thread:
            self._thread.join()
            self._thread = None

    def healthy(self) -> bool:
        """Is the batcher thread alive and accepting work?"""
        with self._lock:
            stopped = self._stop
        return (not stopped and self._thread is not None
                and self._thread.is_alive())

    def submit(self, req: GenRequest):
        """Enqueue one request.  Raises
        :class:`~repro.serving.slo.ShedError` when admission control
        proves the request's deadline cannot be met under the current
        queue depth (shed at the door — zero compute spent).  Malformed
        requests raise ValueError here, at the door, instead of taking
        down a whole continuous batch inside the serve loop."""
        self._validate(req)
        if req.policy is not None and not self._factory_takes_policy:
            # Silently serving the default strategy while the bucket key
            # pretends otherwise would be worse than refusing.
            raise ValueError(
                f"request {req.request_id} sets policy={req.policy!r} but "
                "this engine's sampler factory does not take a policy "
                "argument")
        if req.reuse_every is not None and not self._factory_takes_reuse:
            raise ValueError(
                f"request {req.request_id} sets "
                f"reuse_every={req.reuse_every!r} but this engine's "
                "sampler factory does not take a reuse_every argument")
        if req.stream_every is not None and not self._factory_takes_stream:
            raise ValueError(
                f"request {req.request_id} sets "
                f"stream_every={req.stream_every!r} but this engine's "
                "sampler factory does not take a stream_every argument")
        key = self._bucket_key(req)
        now = time.time()
        # WAL order (§18): the lifecycle record lands *before* the
        # request is accepted, so a crash after this point can lose the
        # result but never the request.  A later shed/refusal is its own
        # record (or surfaces synchronously to the caller) — recovery
        # resubmits anything journaled-but-unfinished, at-least-once.
        if self._journal is not None:
            self._journal.record_submitted(req)
        try:
            with self._lock:
                if self._stop:
                    raise RuntimeError("engine is stopped")
                if self.admission_control and req.deadline_s is not None:
                    dq = self._buckets.get(key)
                    reason = slo_lib.admission_decision(
                        req.deadline_s, now, len(dq) if dq else 0,
                        self.max_batch, self.estimator.lower_bound(key))
                    if reason is not None:
                        self.shed_count += 1
                        raise ShedError(
                            f"request {req.request_id} shed: {reason}")
                self._buckets.setdefault(key, deque()).append((now, req))
                if req.recovered:
                    self.recovered_count += 1
                self._lock.notify_all()
        except ShedError as e:
            if self._journal is not None:
                self._journal.record_shed(req.request_id, str(e))
            raise

    def _validate(self, req: GenRequest) -> None:
        """Reject malformed requests at submit (§17 satellite): a bad
        field would otherwise stack fine, then crash the sampler and
        fail every batchmate."""
        rid = req.request_id
        if not isinstance(req.steps, (int, np.integer)) or req.steps <= 0:
            raise ValueError(
                f"request {rid}: steps must be a positive int, "
                f"got {req.steps!r}")
        if req.latent_shape is not None:
            shape = tuple(req.latent_shape)
            if not shape or not all(
                    isinstance(d, (int, np.integer)) and d > 0
                    for d in shape):
                raise ValueError(
                    f"request {rid}: latent_shape must be a non-empty "
                    f"tuple of positive ints, got {req.latent_shape!r}")
        if req.deadline_s is not None and req.deadline_s <= 0:
            raise ValueError(
                f"request {rid}: deadline_s must be an absolute "
                f"time.time() deadline (> 0), got {req.deadline_s!r}")
        if req.reuse_every is not None and req.reuse_every <= 0:
            raise ValueError(
                f"request {rid}: reuse_every must be positive, "
                f"got {req.reuse_every!r}")
        if req.stream_every is not None and req.stream_every <= 0:
            raise ValueError(
                f"request {rid}: stream_every must be positive, "
                f"got {req.stream_every!r}")
        if req.resume is not None:
            step = req.resume.get("step") if isinstance(req.resume, dict) \
                else None
            if (not isinstance(step, (int, np.integer)) or step < 0
                    or step >= req.steps or "x" not in req.resume):
                raise ValueError(
                    f"request {rid}: resume payload needs an int step in "
                    f"[0, steps) and an 'x' latent, got {req.resume!r:.80}")
            if req.stream_every and step % req.stream_every != 0:
                raise ValueError(
                    f"request {rid}: resume step {step} is not a chunk "
                    f"boundary of stream_every={req.stream_every} — the "
                    "chunk partitioning would diverge from the "
                    "uninterrupted run (DESIGN.md §18)")

    def result(self, request_id: int, timeout: float = 300.0) -> GenResult:
        deadline = time.time() + timeout
        with self._lock:
            self._evict_expired_errors_locked()
            while request_id not in self._results:
                remaining = deadline - time.time()
                # Clamp: a spurious wakeup near the deadline used to
                # hand Condition.wait a negative timeout.  Re-check the
                # dict after every wakeup so a result landing exactly at
                # the deadline is returned, not reported as a timeout.
                if remaining <= 0:
                    raise TimeoutError(f"request {request_id}")
                self._lock.wait(timeout=remaining)
            res = self._results[request_id]
            if res.error is None:
                self._results.pop(request_id)
                # Tombstone the consumed success (and keep its partials)
                # until the TTL so a stream() consumer that has not yet
                # finished iterating terminates cleanly instead of
                # hanging until TimeoutError.
                self._finished_expiry.setdefault(
                    request_id, time.time() + self.error_ttl_s)
            else:
                # Keep errored results retrievable until their TTL so a
                # caller that catches TimeoutError and retries gets the
                # original batch error, not a misleading second timeout.
                self._error_expiry.setdefault(
                    request_id, time.time() + self.error_ttl_s)
        if res.error is not None:
            raise RuntimeError(
                f"request {request_id} failed: {res.error}")
        return res

    def peek_result(self, request_id: int) -> Optional[GenResult]:
        """Non-blocking, non-consuming result lookup (router failover
        uses this to tell served from lost requests, §15.4)."""
        with self._lock:
            return self._results.get(request_id)

    def stream(self, request_id: int,
               timeout: float = 300.0) -> Iterator[np.ndarray]:
        """Yield intermediate latents for a streaming request as chunks
        land (one array per delivered chunk, in order), terminating when
        the final result is available — fetch it with :meth:`result`.
        Raises TimeoutError if no progress arrives within ``timeout``
        of the previous chunk."""
        idx = 0
        while True:
            chunk = None
            deadline = time.time() + timeout
            with self._lock:
                while True:
                    chunks = self._partials.get(request_id, ())
                    if len(chunks) > idx:
                        chunk = chunks[idx]
                        idx += 1
                        break
                    if (request_id in self._results
                            or request_id in self._finished_expiry):
                        return
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"request {request_id} stream stalled")
                    self._lock.wait(timeout=remaining)
            yield chunk  # outside the lock

    def pending(self) -> int:
        with self._lock:
            return sum(len(dq) for dq in self._buckets.values())

    def metrics(self) -> Dict[str, int]:
        """Serving counters (DESIGN.md §15/§17): batches served,
        admission sheds, deadline outcomes, robustness counters, and —
        when a guardrail ladder is attached — its degradation
        counters."""
        with self._lock:
            m = {"batches_served": self._batches_served,
                 "shed_count": self.shed_count,
                 "deadlines_met": self.deadlines_met,
                 "deadlines_missed": self.deadlines_missed,
                 "watchdog_trips": self.watchdog_trips,
                 "batch_retries": self.batch_retries,
                 "quarantined": self.quarantined,
                 "recovered_count": self.recovered_count,
                 "resumed_count": self.resumed_count,
                 "last_resume_step": self.last_resume_step}
        if self._journal is not None:
            m.update({k: int(v) for k, v in self._journal.metrics().items()})
        if self._store is not None:
            m.update({k: int(v) for k, v in self._store.metrics().items()})
        if self._ladder is not None:
            m.update(self._ladder.metrics())
        return m

    # -- batching loop ----------------------------------------------------------

    def _evict_expired_errors_locked(self):
        # Strictly-after comparison: a tombstone lives *through* its
        # expiry instant, so a result() retry landing exactly at TTL
        # expiry still gets the stored error instead of watching this
        # very call evict it and then reporting a spurious timeout.
        now = time.time()
        for rid in [r for r, exp in self._error_expiry.items() if exp < now]:
            self._error_expiry.pop(rid, None)
            self._results.pop(rid, None)
            self._partials.pop(rid, None)
        for rid in [r for r, exp in self._finished_expiry.items()
                    if exp < now]:
            self._finished_expiry.pop(rid, None)
            self._partials.pop(rid, None)

    def _bucket_key(self, req: GenRequest) -> BucketKey:
        shape = tuple(req.latent_shape) if req.latent_shape is not None \
            else tuple(self.latent_shape or ())
        if not shape:
            raise ValueError(f"request {req.request_id}: no latent shape "
                             "(set GenRequest.latent_shape or the engine "
                             "default)")
        return (shape, -1 if self._legacy else req.steps,
                req.policy or self.default_policy,
                req.reuse_every if req.reuse_every is not None
                else self.default_reuse_every,
                _seq_shards(),
                tuple(np.shape(req.txt)),
                req.stream_every,
                _pattern_token(req.policy or self.default_policy),
                int(req.resume["step"]) if req.resume else 0)

    def _next_bucket(self) -> Optional[BucketKey]:
        """SLO-aware drain order (DESIGN.md §15.1, logic in
        :func:`repro.serving.slo.choose_bucket`): starvation aging, then
        earliest-feasible-deadline, then hottest (deepest) bucket."""
        heads = {k: (dq[0][0], dq[0][1].deadline_s, len(dq))
                 for k, dq in self._buckets.items() if dq}
        return slo_lib.choose_bucket(
            heads, time.time(), scheduler=self.scheduler,
            starve_after_s=self.starve_after_s, estimator=self.estimator)

    def _take_batch(self):
        """Block for traffic, pick a bucket (see :meth:`_next_bucket`),
        linger briefly for batch-mates from the *same* bucket — the
        linger is event-driven (woken by ``submit``'s notify), never a
        poll loop.  Returns (key, batch of (enqueue_time, request)) or
        (None, None) once stopped and fully drained."""
        with self._lock:
            while True:
                key = self._next_bucket()
                if key is not None:
                    break
                if self._stop:
                    return None, None
                self._lock.wait(timeout=0.2)
            batch = [self._buckets[key].popleft()]
            deadline = time.time() + self.max_wait_s
            while len(batch) < self.max_batch and not self._stop:
                dq = self._buckets.get(key)
                while dq and len(batch) < self.max_batch:
                    batch.append(dq.popleft())
                if len(batch) >= self.max_batch:
                    break
                remaining = deadline - time.time()
                if remaining <= 0:
                    break
                self._lock.wait(timeout=remaining)
        return key, batch

    def _sampler(self, key: BucketKey) -> Callable:
        """Bounded LRU over compiled samplers; MRU (the hottest bucket)
        survives eviction.  The LRU is keyed on the bucket identity
        *minus* the resume step (``key[:8]``): the chunked sampler
        traces its step offset, so resumed traffic reuses the fresh
        bucket's compiled entry instead of recompiling per resume
        point."""
        key = key[:8]
        fn = self._compiled.get(key)
        if fn is None:
            shape, steps, pol, reuse = key[:4]
            stream = key[6]
            args = (shape, steps, pol, reuse,
                    stream)[:min(self._factory_arity, 5)]
            fn = self._factory(*args)
            self._compiled[key] = fn
            if self.plan_fn is not None:
                try:
                    plan = (self.plan_fn(shape, steps, pol)
                            if self._plan_fn_takes_policy
                            else self.plan_fn(shape, steps))
                    # None = no plan to report (e.g. UNet's multi-
                    # resolution attention has no single dispatch plan)
                    if plan is not None:
                        log.info("bucket %s plan: %s", key, plan.summary())
                except Exception:  # noqa: BLE001 — logging must not kill serving
                    log.exception("plan_fn failed for bucket %s", key)
        self._compiled.move_to_end(key)
        while len(self._compiled) > self.max_compiled:
            evicted, _ = self._compiled.popitem(last=False)
            log.info("evicted compiled sampler for bucket %s", evicted)
        return fn

    def _publish_chunk(self, batch, lat_np: np.ndarray, pub: Dict,
                       chunk_idx: int, abandoned: threading.Event):
        """Deliver one streamed chunk to every request's subscribers and
        stamp TTFF on first delivery.  ``pub`` survives re-serves (§17):
        chunks a previous attempt already delivered are not re-published
        (``pub["count"]``), and a watchdog-abandoned worker's late
        chunks are dropped (``abandoned``)."""
        now = time.time()
        with self._lock:
            if abandoned.is_set():
                return
            for i, (t_enq, r) in enumerate(batch):
                if chunk_idx < pub["count"].get(r.request_id, 0):
                    continue
                pub["ttff"].setdefault(r.request_id, now - t_enq)
                self._partials.setdefault(r.request_id, []).append(lat_np[i])
                pub["count"][r.request_id] = chunk_idx + 1
            self._lock.notify_all()

    @staticmethod
    def _split_out(out) -> Tuple[Any, Optional[dict]]:
        """(latents, aux) vs bare latents."""
        if isinstance(out, (tuple, list)) and len(out) == 2:
            return out[0], out[1]
        return out, None

    def _log_aux(self, key: BucketKey, aux: Optional[dict]):
        """Cache-aware samplers return decision-cache telemetry
        (DESIGN.md §13) — log the hit rate so the amortization is
        observable in serving, not just benches."""
        if not aux:
            return
        hits = int(jax.device_get(aux.get("cache_hits", 0)))
        refr = int(jax.device_get(aux.get("cache_refreshes", 0)))
        if hits + refr:
            log.info(
                "bucket %s decision cache: %d hits / %d refreshes "
                "(hit rate %.2f)", key, hits, refr,
                hits / max(hits + refr, 1))
        if "ring_elided_hops" in aux:
            # Context-parallel telemetry (DESIGN.md §14): ring hops the
            # block map let the seq shards skip.
            log.info("bucket %s ring: %d elided hop(s)", key,
                     int(jax.device_get(aux["ring_elided_hops"])))

    # -- guardrail / watchdog serve path (DESIGN.md §17) ----------------------

    @staticmethod
    def _family(key: BucketKey):
        """Bucket identity minus the policy and its pattern token — the
        unit the degradation ladder keys on: every policy rung of one
        (shape, steps, cadence, shards, txt, stream) family shares one
        health record."""
        return key[:2] + key[3:7]

    @staticmethod
    def _rekey(key: BucketKey, policy: Optional[str]) -> BucketKey:
        """The same bucket one ladder rung down: policy and pattern
        token rewritten, everything else identical — so the degraded
        bucket compiles its own sampler instead of replaying the
        tripped program."""
        return (key[:2] + (policy,) + key[3:7]
                + (_pattern_token(policy),) + key[8:])

    def _sentinel_verdict(self, lat: Optional[np.ndarray],
                          aux: Optional[dict]) -> Optional[str]:
        """Read the batch's sentinels: ``None`` when clean, else a trip
        reason.  The host ``isfinite`` over the returned latents covers
        samplers that thread no cache; the aux counters cover the
        in-graph sentinels (latent carry + attention-output carry +
        drift probe)."""
        gcfg = self._ladder.config
        if lat is not None and not np.all(np.isfinite(lat)):
            return "non-finite final latents"
        if aux:
            nf = 0
            for k in ("latent_nonfinite", "sentinel_nonfinite"):
                if k in aux:
                    nf += int(jax.device_get(aux[k]))
            if nf > gcfg.max_nonfinite:
                return f"{nf} non-finite sentinel entr(ies)"
            if "sentinel_drift" in aux:
                drift = float(jax.device_get(aux["sentinel_drift"]))
                if not np.isfinite(drift):
                    return "non-finite drift probe"
                if gcfg.drift_tol > 0 and drift > gcfg.drift_tol:
                    return (f"drift probe {drift:.3g} > "
                            f"tol {gcfg.drift_tol:.3g}")
        return None

    # -- crash-safety seam (DESIGN.md §18) ------------------------------------

    @staticmethod
    def _accepts_resume(fn: Callable) -> bool:
        """Does this sampler take a ``resume=`` keyword?  Factories
        that predate the checkpoint seam don't — resumed requests then
        fall back to deterministic replay-from-step-0, which is slower
        but returns identical latents."""
        try:
            params = inspect.signature(fn).parameters
        except (TypeError, ValueError):
            return False
        return "resume" in params or any(
            p.kind == p.VAR_KEYWORD for p in params.values())

    def _assemble_resume(self, key: BucketKey,
                         batch: List[Tuple[float, GenRequest]],
                         fn: Callable) -> Optional[Dict[str, Any]]:
        """Build the batch-level resume payload ``{"x", "step",
        "dstate"}`` from the per-request checkpoints, or ``None`` for
        fresh traffic / samplers without resume support.  The bucket
        key pins the resume step, so every batchmate shares it; their
        latents stack on axis 0 and their decision-state slices merge
        back along the batch axis."""
        step = key[8] if len(key) > 8 else 0
        if step <= 0:
            return None
        payloads = [r.resume for _, r in batch]
        if any(p is None for p in payloads):
            return None  # defensive: bucket identity should prevent this
        if not self._accepts_resume(fn):
            log.warning(
                "bucket %s: sampler takes no resume argument; replaying "
                "%d checkpointed request(s) from step 0", key, len(batch))
            return None
        from repro.core import decision_cache

        xs = jnp.stack([jnp.asarray(p["x"]) for p in payloads])
        dstates = [p.get("dstate") for p in payloads]
        merged = None
        if all(d is not None for d in dstates):
            merged = decision_cache.merge_states(
                [decision_cache.state_from_arrays(d) for d in dstates])
        elif any(d is not None for d in dstates):
            log.warning("bucket %s: mixed cache/cache-less checkpoints "
                        "in one batch; resuming without decision state",
                        key)
        return {"x": xs, "step": int(step), "dstate": merged}

    def _record_chunk(self, key: BucketKey,
                      batch: List[Tuple[float, GenRequest]],
                      lat_np: np.ndarray, ck: Optional[Dict],
                      ci: int, abandoned: threading.Event):
        """Durable side of one delivered chunk (§18): a ``chunk``
        journal record per request, and — when a checkpoint store is
        attached, the sampler exposed its ``__ckpt__`` state, and the
        bucket is unsharded — the per-request ``(x_t, dstate, step)``
        checkpoint.  Runs outside the engine lock (fsync latency must
        not block submitters); a watchdog-abandoned zombie writes
        nothing."""
        if (self._journal is None and self._store is None) \
                or abandoned.is_set():
            return
        step = ck.get("step") if ck else None
        stream = key[6] or 0
        base_ci = (key[8] // stream) if len(key) > 8 and stream else 0
        if self._journal is not None:
            for _, r in batch:
                try:
                    self._journal.record_chunk(r.request_id, base_ci + ci,
                                               step)
                except RuntimeError:
                    return  # journal closed mid-shutdown
        steps = key[1]
        if (self._store is None or ck is None or step is None
                or key[4] != 1 or (steps > 0 and int(step) >= steps)):
            # No store, no sampler state, a context-parallel bucket
            # (per-shard state cannot be re-sliced per request), or the
            # final chunk (the request is about to finish and the
            # checkpoint would be discarded immediately).
            return
        arrays = None
        dstate = ck.get("dstate")
        if dstate is not None:
            from repro.core import decision_cache

            arrays = decision_cache.state_to_arrays(dstate)
            if any(a is not None and a.ndim < 2 for a in arrays.values()):
                # Not a layer-stacked batched state: no batch axis to
                # slice per request — skip checkpointing, keep serving.
                arrays = None
        for i, (_, r) in enumerate(batch):
            per = None
            if arrays is not None:
                # Batch axis 1 of every (layers, batch, ...) leaf,
                # kept as a size-1 dim so merge_states is the inverse.
                per = {k: (None if v is None else v[:, i:i + 1])
                       for k, v in arrays.items()}
            try:
                self._store.put(r.request_id, step=int(step),
                                x=lat_np[i], seed=r.seed,
                                bucket=key[:8], dstate=per)
            except OSError as e:
                log.warning("checkpoint write failed for request %d: %s",
                            r.request_id, e)

    def _run_batch(self, key: BucketKey,
                   batch: List[Tuple[float, GenRequest]], pub: Dict,
                   abandoned: threading.Event):
        """Run one sampler invocation, optionally under the hang
        watchdog.  Returns ``(res, hung, budget)`` where ``res`` holds
        ``lat``/``aux`` on success, ``err`` (the exception) on failure,
        or ``sentinel`` (a trip reason) when a streamed chunk went
        non-finite — caught *before* publication, so subscribers never
        see the bad frames."""
        res: Dict[str, Any] = {}

        def work():
            try:
                fault = fault_lib.active_faults()
                if fault is not None:
                    fault.check_poison([r.request_id for _, r in batch])
                    fault.maybe_raise()
                    if fault.maybe_hang():
                        return  # hung past the watchdog; batch is lost
                fn = self._sampler(key)
                shape = key[0]
                txt = jnp.stack([jnp.asarray(r.txt) for _, r in batch])
                rngs = jnp.stack([jax.random.PRNGKey(r.seed)
                                  for _, r in batch])
                resume = self._assemble_resume(key, batch, fn)
                if resume is not None:
                    # Mid-flight resume (§18): the checkpointed x_t
                    # replaces the initial noise and the sampler starts
                    # at the checkpoint's step offset with the cached
                    # decision state — the remaining schedule slice is
                    # bitwise-identical to the uninterrupted run.
                    noise = resume.pop("x")
                    out = fn(noise, txt, rngs, resume=resume)
                else:
                    noise = jax.vmap(
                        lambda k: jax.random.normal(k, shape))(rngs)
                    # The full (B, 2) key batch goes to the sampler —
                    # every request keeps its own randomness inside one
                    # batch.
                    out = fn(noise, txt, rngs)
                if inspect.isgenerator(out):
                    # Streaming bucket (§15.3): each yielded chunk is
                    # published to stream() subscribers as it lands; the
                    # last chunk is the final latents.
                    lat = aux = None
                    for ci, chunk in enumerate(out):
                        lat, aux = self._split_out(chunk)
                        ck = None
                        if isinstance(aux, dict):
                            ck = aux.pop("__ckpt__", None)
                        lat = np.asarray(jax.device_get(lat))
                        if (self._ladder is not None
                                and not np.all(np.isfinite(lat))):
                            res["sentinel"] = \
                                f"non-finite streamed chunk {ci}"
                            return
                        self._publish_chunk(batch, lat, pub, ci, abandoned)
                        self._record_chunk(key, batch, lat, ck, ci,
                                           abandoned)
                    if lat is None:
                        raise RuntimeError(
                            "streaming sampler yielded nothing")
                else:
                    lat, aux = self._split_out(out)
                    lat = np.asarray(jax.device_get(lat))
                res["lat"], res["aux"] = lat, aux
            except Exception as e:  # noqa: BLE001 — fail the batch, not the engine
                log.exception("bucket %s batch failed", key)
                res["err"] = e

        if self.batch_timeout_s is None:
            work()
            return res, False, 0.0
        budget = self.estimator.timeout_hint(key, self.batch_timeout_s)
        worker = threading.Thread(target=work, daemon=True)
        worker.start()
        worker.join(timeout=budget)
        return res, worker.is_alive(), budget

    def _trip_watchdog(self, key: BucketKey,
                       batch: List[Tuple[float, GenRequest]],
                       budget: float, abandoned: threading.Event):
        """A batch hung past its watchdog budget: the worker cannot be
        killed (it is stuck inside compiled code), so the *replica*
        steps down — mark the engine stopped (``healthy()`` goes False),
        error the hung and queued requests with failover-marked messages
        so the router requeues them elsewhere, and suppress any late
        chunk publishes from the zombie worker."""
        abandoned.set()
        log.error("watchdog: bucket %s batch of %d hung past %.1fs — "
                  "marking replica unhealthy", key, len(batch), budget)
        now = time.time()
        with self._lock:
            self.watchdog_trips += 1
            self._stop = True
            err = f"watchdog: batch hung after {budget:.1f}s"
            for t_enq, r in batch:
                if r.deadline_s is not None:
                    self.deadlines_missed += 1
                self._results[r.request_id] = GenResult(
                    r.request_id, None, now - t_enq, error=err,
                    deadline_met=False if r.deadline_s is not None
                    else None)
                self._error_expiry[r.request_id] = now + self.error_ttl_s
            for dq in self._buckets.values():
                for _, r in dq:
                    self._results[r.request_id] = GenResult(
                        r.request_id, None, 0.0,
                        error="engine stopped (watchdog)")
                    self._error_expiry[r.request_id] = (
                        now + self.error_ttl_s)
            self._buckets.clear()
            self._lock.notify_all()

    def _publish_batch(self, key: BucketKey,
                       batch: List[Tuple[float, GenRequest]],
                       lat: np.ndarray, dt: float, pub: Dict,
                       err: Optional[str], degraded: bool):
        now = time.time()
        with self._lock:
            bi = self._batches_served
            self._batches_served += 1
            for i, (t_enq, r) in enumerate(batch):
                met = None
                if r.deadline_s is not None:
                    met = err is None and now <= r.deadline_s
                    if met:
                        self.deadlines_met += 1
                    else:
                        self.deadlines_missed += 1
                self._results[r.request_id] = GenResult(
                    r.request_id, None if err else lat[i], dt, error=err,
                    batch_index=bi,
                    ttff_s=pub["ttff"].get(r.request_id,
                                           -1.0 if err else now - t_enq),
                    deadline_met=met, degraded=degraded)
                if err is not None:
                    self._error_expiry[r.request_id] = (
                        time.time() + self.error_ttl_s)
            if err is None and len(key) > 8 and key[8] > 0:
                self.resumed_count += len(batch)
                self.last_resume_step = max(self.last_resume_step,
                                            int(key[8]))
            self._lock.notify_all()
        # Durable terminal records, outside the lock (§18).  Failover-
        # marked errors (replica died, watchdog) are *not* journaled as
        # finished — the request is still owed a result, so it must stay
        # pending for recovery; request-level errors (poison, quarantine,
        # guardrail dead-ends) are, so a restart never resurrects them.
        if self._journal is not None or self._store is not None:
            for _, r in batch:
                if err is not None and is_failover_error(err):
                    continue
                if self._journal is not None:
                    try:
                        self._journal.record_finished(r.request_id,
                                                      error=err)
                    except RuntimeError:
                        break  # journal closed mid-shutdown
                if self._store is not None:
                    self._store.discard(r.request_id)

    def _serve(self, key: BucketKey, batch: List[Tuple[float, GenRequest]]):
        pub: Dict[str, Dict] = {"ttff": {}, "count": {}}
        self._serve_rec(key, batch, 0, pub, threading.Event())

    def _serve_rec(self, key: BucketKey,
                   batch: List[Tuple[float, GenRequest]], depth: int,
                   pub: Dict, abandoned: threading.Event):
        """Serve one (sub-)batch with the full §17 escalation chain:
        sentinel trip -> degrade one ladder rung and re-serve; hang ->
        watchdog (replica down); transient error -> retry with backoff,
        then bisect so a single poison request is quarantined alone
        while its batchmates succeed."""
        t0 = time.time()
        base_pol = key[2]
        fam = self._family(key)
        attempt = 0
        while True:
            eff_key = key
            if self._ladder is not None:
                eff_pol, _probing = self._ladder.effective_policy(
                    fam, base_pol)
                if eff_pol != base_pol:
                    eff_key = self._rekey(key, eff_pol)
            res, hung, budget = self._run_batch(eff_key, batch, pub,
                                                abandoned)
            if hung:
                self._trip_watchdog(eff_key, batch, budget, abandoned)
                return
            sent = res.get("sentinel")
            exc = res.get("err")
            if exc is None and sent is None and "lat" not in res:
                exc = RuntimeError("sampler worker produced no output")
            if exc is None and sent is None and self._ladder is not None:
                sent = self._sentinel_verdict(res.get("lat"),
                                              res.get("aux"))
            if sent is not None and self._ladder is not None:
                nxt = self._ladder.trip(fam, base_pol)
                if nxt is not None:
                    log.warning(
                        "bucket %s guardrail trip (%s): degrading to %r "
                        "and re-serving", key, sent, nxt)
                    continue
                exc = RuntimeError(
                    f"guardrail: {sent} at the dense floor — no "
                    "degradation step left")
            elif sent is None and exc is None and self._ladder is not None:
                self._ladder.record_clean(fam)
            if exc is None:
                dt = time.time() - t0
                self._log_aux(eff_key, res.get("aux"))
                self.estimator.observe(key, dt)
                if eff_key != key:
                    self.estimator.observe(eff_key, dt)
                self._publish_batch(
                    key, batch, res["lat"], dt, pub, None,
                    degraded=(self._ladder is not None
                              and self._ladder.degraded(fam)))
                log.info("served bucket %s batch of %d in %.2fs%s", key,
                         len(batch), dt,
                         " (degraded)" if eff_key != key else "")
                return
            # Error path.  Sentinel dead-ends (dense floor) are not
            # transient: no retry, no bisection — every rung failed.
            attempt += 1
            if sent is None and attempt <= self.max_retries:
                backoff = self.retry_backoff_s * (2 ** (attempt - 1))
                with self._lock:
                    self.batch_retries += 1
                log.warning("bucket %s batch failed (attempt %d/%d), "
                            "retrying in %.2fs: %r", key, attempt,
                            self.max_retries + 1, backoff, exc)
                time.sleep(backoff)
                continue
            if sent is None and self.bisect_on_error and len(batch) > 1:
                mid = len(batch) // 2
                log.warning("bucket %s: bisecting failed batch of %d to "
                            "isolate the poison request", key, len(batch))
                self._serve_rec(key, batch[:mid], depth + 1, pub,
                                abandoned)
                self._serve_rec(key, batch[mid:], depth + 1, pub,
                                abandoned)
                return
            if depth > 0 and len(batch) == 1:
                # Bisection bottomed out on one request: quarantine it —
                # it fails alone, its former batchmates already served.
                with self._lock:
                    self.quarantined += 1
                log.error("bucket %s: request %d quarantined after "
                          "bisection: %r", key,
                          batch[0][1].request_id, exc)
            self._publish_batch(key, batch, None, time.time() - t0, pub,
                                repr(exc), degraded=False)
            return

    def _loop(self):
        while True:
            key, batch = self._take_batch()
            if key is None:
                return  # stopped and drained
            self._serve(key, batch)
            fault = fault_lib.active_faults()
            if fault is not None:
                fault.maybe_corrupt_artifact(self._batches_served)


class LMEngine:
    """Prefill + decode serving for the LM family."""

    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 max_len: int):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.max_len = max_len

    def generate(self, tokens: jax.Array, num_new: int,
                 temperature: float = 0.0, rng=None) -> jax.Array:
        """tokens: (B, S) prompt -> (B, num_new) continuations (greedy or
        temperature sampling).  Temperature sampling requires an
        explicit ``rng`` key; ``prompt + num_new`` must fit the engine's
        ``max_len`` KV budget."""
        B, S = tokens.shape
        if S + num_new > self.max_len:
            raise ValueError(
                f"prompt ({S}) + num_new ({num_new}) = {S + num_new} "
                f"exceeds max_len={self.max_len}; the KV cache was "
                f"allocated for max_len positions")
        if temperature > 0 and rng is None:
            raise ValueError(
                "temperature > 0 requires an explicit rng key "
                "(jax.random.split(None) is not a key)")
        logits, cache = self.prefill_fn(tokens)
        out = []
        index = jnp.asarray(S, jnp.int32)
        cur = None
        for i in range(num_new):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                nxt = jax.random.categorical(sub, logits[:, -1] / temperature)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            out.append(nxt)
            cur = nxt[:, None]
            logits, cache = self.decode_fn(cur, cache, index)
            index = index + 1
        return jnp.stack(out, axis=1)
