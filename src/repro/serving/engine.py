"""Serving engines.

DiffusionEngine: batched text-to-image/video generation.  Requests queue
up; the batcher groups compatible requests (same steps / resolution) into
one jitted sampler invocation; the denoising loop threads the step index
into TimeRipple's Eq. 4 schedule — acceleration happens *per step* with
no per-request state, which is why the paper's method needs no KV-style
cache and adds no serving memory (Tbl. 2 Mem column).  Attention inside
the sampler routes through ``core.dispatch.attention_dispatch``
(DESIGN.md §8); launchers hand the engine the resolved
:class:`~repro.core.dispatch.DispatchPlan` so the serving log records
which backend/block sizes the traffic actually runs on.

LMEngine: KV-cache prefill + decode loop (used by the decode_32k /
long_500k shape cells and the LM serving example).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("serve")


@dataclasses.dataclass
class GenRequest:
    request_id: int
    txt: np.ndarray            # (L, txt_dim) precomputed embeddings
    steps: int = 50
    seed: int = 0
    guidance: float = 4.0


@dataclasses.dataclass
class GenResult:
    request_id: int
    latents: np.ndarray
    walltime_s: float


class DiffusionEngine:
    """sample_fn(latents0, txt, rng) -> latents; built by the launcher with
    the model, sampler, and RippleConfig baked in (steps static)."""

    def __init__(self, sample_fn: Callable, latent_shape: Tuple[int, ...],
                 max_batch: int = 8, max_wait_s: float = 0.05,
                 attn_plan: Optional[Any] = None):
        self.sample_fn = sample_fn
        self.latent_shape = latent_shape
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.attn_plan = attn_plan  # DispatchPlan metadata (or None)
        self._q: "queue.Queue[GenRequest]" = queue.Queue()
        self._results: Dict[int, GenResult] = {}
        self._lock = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None

    # -- public API -----------------------------------------------------------

    def start(self):
        if self.attn_plan is not None:
            log.info("engine attention plan: %s", self.attn_plan.summary())
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop = True
        if self._thread:
            self._thread.join()

    def submit(self, req: GenRequest):
        self._q.put(req)

    def result(self, request_id: int, timeout: float = 300.0) -> GenResult:
        deadline = time.time() + timeout
        with self._lock:
            while request_id not in self._results:
                if not self._lock.wait(timeout=deadline - time.time()):
                    raise TimeoutError(f"request {request_id}")
            return self._results.pop(request_id)

    # -- batching loop ----------------------------------------------------------

    def _take_batch(self) -> List[GenRequest]:
        batch: List[GenRequest] = []
        try:
            batch.append(self._q.get(timeout=0.2))
        except queue.Empty:
            return batch
        t0 = time.time()
        while len(batch) < self.max_batch and \
                time.time() - t0 < self.max_wait_s:
            try:
                batch.append(self._q.get_nowait())
            except queue.Empty:
                time.sleep(0.005)
        return batch

    def _loop(self):
        while not self._stop:
            batch = self._take_batch()
            if not batch:
                continue
            t0 = time.time()
            B = len(batch)
            txt = jnp.stack([jnp.asarray(r.txt) for r in batch])
            rngs = jnp.stack(
                [jax.random.PRNGKey(r.seed) for r in batch])
            noise = jax.vmap(
                lambda k: jax.random.normal(k, self.latent_shape))(rngs)
            lat = self.sample_fn(noise, txt, rngs[0])
            lat = np.asarray(jax.device_get(lat))
            dt = time.time() - t0
            with self._lock:
                for i, r in enumerate(batch):
                    self._results[r.request_id] = GenResult(
                        r.request_id, lat[i], dt)
                self._lock.notify_all()
            log.info("served batch of %d in %.2fs", B, dt)


class LMEngine:
    """Prefill + decode serving for the LM family."""

    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 max_len: int):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.max_len = max_len

    def generate(self, tokens: jax.Array, num_new: int,
                 temperature: float = 0.0, rng=None) -> jax.Array:
        """tokens: (B, S) prompt -> (B, num_new) continuations (greedy or
        temperature sampling)."""
        B, S = tokens.shape
        logits, cache = self.prefill_fn(tokens)
        out = []
        index = jnp.asarray(S, jnp.int32)
        cur = None
        for i in range(num_new):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                nxt = jax.random.categorical(sub, logits[:, -1] / temperature)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            out.append(nxt)
            cur = nxt[:, None]
            logits, cache = self.decode_fn(cur, cache, index)
            index = index + 1
        return jnp.stack(out, axis=1)
