"""Serving engines.

DiffusionEngine: shape-bucketed continuous batching for text-to-image /
video generation.  Requests are keyed into a **bucket** by
``(latent_shape, steps)``; the batcher drains whichever bucket is
hottest (deepest queue) so heterogeneous traffic never pads or mixes
shapes inside one sampler invocation.  Each bucket owns a jitted
(optionally mesh-sharded) sampler obtained from ``sampler_factory`` and
held in a bounded LRU of compiled entries — the hottest bucket's sampler
always survives eviction.  Per-request PRNG keys are threaded through
``sample_fn`` as a full ``(B, 2)`` key batch (vmap inside the sampler),
so requests in one batch never share sampler randomness.  TimeRipple's
reuse schedule is stateless per denoising step (no KV-style cache,
paper Tbl. 2), which is what makes this continuous batching safe: a
bucket switch carries zero eviction cost.  Attention inside the sampler
routes through ``core.dispatch.attention_dispatch`` (DESIGN.md §8, §10);
``plan_fn`` lets the launcher log the resolved
:class:`~repro.core.dispatch.DispatchPlan` per bucket at first compile.

LMEngine: KV-cache prefill + decode loop (used by the decode_32k /
long_500k shape cells and the LM serving example).
"""

from __future__ import annotations

import dataclasses
import inspect
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("serve")

# (latent_shape, steps, policy, reuse_every, seq_shards); legacy
# single-sampler engines use steps=-1 so requests with differing
# ``steps`` still share the one compiled entry; policy is the
# reuse-policy name (None = the engine / sampler default), so requests
# under different sparsity strategies never share a compiled sampler;
# reuse_every is the decision-cache cadence (DESIGN.md §13; None = the
# sampler default) — it is baked into the compiled sampler's refresh
# cond, so mixed-cadence traffic must never share one compiled entry
# either; seq_shards is the context-parallel degree of the dispatch
# mesh at bucket time (DESIGN.md §14) — a sampler compiled under a ring
# mesh runs a different program, so long-video requests route to the
# context-parallel replica shape and never share a compiled entry with
# unsharded traffic.
BucketKey = Tuple[Tuple[int, ...], int, Optional[str], Optional[int], int]


def _seq_shards() -> int:
    """Seq-shard degree of the active dispatch mesh (1 = no context
    parallelism)."""
    from repro.core import dispatch as dispatch_lib

    mesh = dispatch_lib.active_dispatch_mesh()
    if mesh is not None and "seq" in mesh.axis_names:
        return int(mesh.shape["seq"])
    return 1


def _positional_arity(fn: Optional[Callable]) -> int:
    """How many positional arguments ``fn`` accepts.  Legacy
    two-argument factories / plan_fns keep working unchanged;
    policy-aware ones take a third, cadence-aware ones a fourth.  A
    ``*args`` factory counts as 3 — exactly what such factories have
    received since the policy seam landed — so pre-cadence var-positional
    factories keep unpacking (shape, steps, policy); declare a fourth
    named parameter to opt into the cadence."""
    if fn is None:
        return 0
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):
        return 2
    if any(p.kind == p.VAR_POSITIONAL for p in params):
        return 3
    return len([p for p in params
                if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)])


def _takes_policy(fn: Optional[Callable]) -> bool:
    """Does ``fn`` accept a third positional (policy) argument?"""
    return _positional_arity(fn) >= 3


@dataclasses.dataclass
class GenRequest:
    request_id: int
    txt: np.ndarray            # (L, txt_dim) precomputed embeddings
    steps: int = 50
    seed: int = 0
    guidance: float = 4.0
    # None -> the engine's default latent shape (single-shape traffic).
    latent_shape: Optional[Tuple[int, ...]] = None
    # Reuse-policy name for this request (core.policy registry); None ->
    # the engine's default policy.  Part of the bucket identity.
    policy: Optional[str] = None
    # Decision-cache cadence for this request (RippleConfig.reuse_every,
    # DESIGN.md §13); None -> the engine default.  Part of the bucket
    # identity — the cadence is compiled into the sampler's refresh cond.
    reuse_every: Optional[int] = None


@dataclasses.dataclass
class GenResult:
    request_id: int
    latents: Optional[np.ndarray]
    walltime_s: float
    error: Optional[str] = None
    batch_index: int = -1  # which sampler invocation served this request


class DiffusionEngine:
    """Continuous-batching engine over bucketed samplers.

    ``sampler_factory(latent_shape, steps[, policy[, reuse_every]]) ->
    sample_fn`` builds (and jits) the sampler for one bucket;
    ``sample_fn(latents0, txt, rngs)`` takes a ``(B, 2)`` uint32 batch
    of per-request PRNG keys and returns latents or ``(latents, aux)``
    with decision-cache telemetry.  Factories (and ``plan_fn``) that
    accept a third positional argument receive the bucket's reuse-policy
    name (``GenRequest.policy`` / ``default_policy``); a fourth receives
    the decision-cache cadence (``GenRequest.reuse_every`` /
    ``default_reuse_every``, DESIGN.md §13).  Two-argument factories
    keep working.  The legacy single-sampler form
    ``DiffusionEngine(sample_fn, latent_shape)`` is still accepted:
    every request then lands in one default bucket.
    """

    def __init__(self, sample_fn: Optional[Callable] = None,
                 latent_shape: Optional[Tuple[int, ...]] = None,
                 *, sampler_factory: Optional[Callable] = None,
                 max_batch: int = 8, max_wait_s: float = 0.05,
                 max_compiled: int = 8, starve_after_s: float = 2.0,
                 attn_plan: Optional[Any] = None,
                 plan_fn: Optional[Callable] = None,
                 default_policy: Optional[str] = None,
                 default_reuse_every: Optional[int] = None):
        if sampler_factory is None:
            if sample_fn is None:
                raise ValueError("need sample_fn or sampler_factory")
            sampler_factory = lambda shape, steps: sample_fn  # noqa: E731
        self._factory = sampler_factory
        self._factory_arity = _positional_arity(sampler_factory)
        self._factory_takes_policy = self._factory_arity >= 3
        self._factory_takes_reuse = self._factory_arity >= 4
        self._plan_fn_takes_policy = _takes_policy(plan_fn)
        self._legacy = sample_fn is not None
        if default_policy is not None and not self._factory_takes_policy:
            raise ValueError(
                "default_policy is set but the sampler factory does not "
                "take a policy argument — it could not honour it")
        if default_reuse_every is not None and not self._factory_takes_reuse:
            raise ValueError(
                "default_reuse_every is set but the sampler factory does "
                "not take a reuse_every argument — it could not honour it")
        self.default_policy = default_policy
        self.default_reuse_every = default_reuse_every
        self.latent_shape = latent_shape
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.max_compiled = max_compiled
        self.starve_after_s = starve_after_s
        self.attn_plan = attn_plan  # DispatchPlan metadata (or None)
        self.plan_fn = plan_fn      # (latent_shape, steps) -> DispatchPlan
        # bucket deques hold (enqueue_time, request) for starvation aging
        self._buckets: Dict[BucketKey, deque] = {}
        self._compiled: "OrderedDict[BucketKey, Callable]" = OrderedDict()
        self._results: Dict[int, GenResult] = {}
        self._batches_served = 0
        self._lock = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None

    # -- public API -----------------------------------------------------------

    def start(self):
        if self.attn_plan is not None:
            log.info("engine attention plan: %s", self.attn_plan.summary())
        with self._lock:
            self._stop = False  # allow stop() -> start() restart cycles
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True):
        """Stop the batcher.  With ``drain`` (default) every already-
        submitted request is served before the thread exits, so no result
        is orphaned; ``drain=False`` discards queued requests with an
        error result instead."""
        with self._lock:
            self._stop = True
            if not drain:
                for dq in self._buckets.values():
                    for _, r in dq:
                        self._results[r.request_id] = GenResult(
                            r.request_id, None, 0.0, error="engine stopped")
                self._buckets.clear()
            self._lock.notify_all()
        if self._thread:
            self._thread.join()
            self._thread = None

    def submit(self, req: GenRequest):
        if req.policy is not None and not self._factory_takes_policy:
            # Silently serving the default strategy while the bucket key
            # pretends otherwise would be worse than refusing.
            raise ValueError(
                f"request {req.request_id} sets policy={req.policy!r} but "
                "this engine's sampler factory does not take a policy "
                "argument")
        if req.reuse_every is not None and not self._factory_takes_reuse:
            raise ValueError(
                f"request {req.request_id} sets "
                f"reuse_every={req.reuse_every!r} but this engine's "
                "sampler factory does not take a reuse_every argument")
        key = self._bucket_key(req)
        with self._lock:
            if self._stop:
                raise RuntimeError("engine is stopped")
            self._buckets.setdefault(key, deque()).append((time.time(), req))
            self._lock.notify_all()

    def result(self, request_id: int, timeout: float = 300.0) -> GenResult:
        deadline = time.time() + timeout
        with self._lock:
            while request_id not in self._results:
                if not self._lock.wait(timeout=deadline - time.time()):
                    raise TimeoutError(f"request {request_id}")
            res = self._results.pop(request_id)
        if res.error is not None:
            raise RuntimeError(
                f"request {request_id} failed: {res.error}")
        return res

    def pending(self) -> int:
        with self._lock:
            return sum(len(dq) for dq in self._buckets.values())

    # -- batching loop ----------------------------------------------------------

    def _bucket_key(self, req: GenRequest) -> BucketKey:
        shape = tuple(req.latent_shape) if req.latent_shape is not None \
            else tuple(self.latent_shape or ())
        if not shape:
            raise ValueError(f"request {req.request_id}: no latent shape "
                             "(set GenRequest.latent_shape or the engine "
                             "default)")
        return (shape, -1 if self._legacy else req.steps,
                req.policy or self.default_policy,
                req.reuse_every if req.reuse_every is not None
                else self.default_reuse_every,
                _seq_shards())

    def _next_bucket(self) -> Optional[BucketKey]:
        """Hottest (deepest) bucket first — unless some bucket's head
        request has waited past ``starve_after_s``, in which case the
        oldest head wins (aging prevents cold-bucket starvation under
        sustained hot-bucket traffic)."""
        live = {k: dq for k, dq in self._buckets.items() if dq}
        if not live:
            return None
        oldest = min(live, key=lambda k: live[k][0][0])
        if time.time() - live[oldest][0][0] > self.starve_after_s:
            return oldest
        return max(live, key=lambda k: len(live[k]))

    def _take_batch(self):
        """Block for traffic, pick a bucket (see :meth:`_next_bucket`),
        linger briefly for batch-mates from the *same* bucket.  Returns
        (key, batch) or (None, None) once stopped and fully drained."""
        with self._lock:
            while True:
                key = self._next_bucket()
                if key is not None:
                    break
                if self._stop:
                    return None, None
                self._lock.wait(timeout=0.2)
            batch = [self._buckets[key].popleft()[1]]
        deadline = time.time() + self.max_wait_s
        while len(batch) < self.max_batch:
            with self._lock:
                dq = self._buckets.get(key)
                while dq and len(batch) < self.max_batch:
                    batch.append(dq.popleft()[1])
            if len(batch) >= self.max_batch or self._stop \
                    or time.time() >= deadline:
                break
            time.sleep(0.005)
        return key, batch

    def _sampler(self, key: BucketKey) -> Callable:
        """Bounded LRU over compiled samplers; MRU (the hottest bucket)
        survives eviction."""
        fn = self._compiled.get(key)
        if fn is None:
            shape, steps, pol, reuse = key[:4]
            args = (shape, steps, pol, reuse)[:min(self._factory_arity, 4)]
            fn = self._factory(*args)
            self._compiled[key] = fn
            if self.plan_fn is not None:
                try:
                    plan = (self.plan_fn(shape, steps, pol)
                            if self._plan_fn_takes_policy
                            else self.plan_fn(shape, steps))
                    # None = no plan to report (e.g. UNet's multi-
                    # resolution attention has no single dispatch plan)
                    if plan is not None:
                        log.info("bucket %s plan: %s", key, plan.summary())
                except Exception:  # noqa: BLE001 — logging must not kill serving
                    log.exception("plan_fn failed for bucket %s", key)
        self._compiled.move_to_end(key)
        while len(self._compiled) > self.max_compiled:
            evicted, _ = self._compiled.popitem(last=False)
            log.info("evicted compiled sampler for bucket %s", evicted)
        return fn

    def _serve(self, key: BucketKey, batch: List[GenRequest]):
        t0 = time.time()
        shape = key[0]
        try:
            fn = self._sampler(key)
            txt = jnp.stack([jnp.asarray(r.txt) for r in batch])
            rngs = jnp.stack([jax.random.PRNGKey(r.seed) for r in batch])
            noise = jax.vmap(lambda k: jax.random.normal(k, shape))(rngs)
            # The full (B, 2) key batch goes to the sampler — every
            # request keeps its own randomness inside one batch.
            lat = fn(noise, txt, rngs)
            # Cache-aware samplers return (latents, aux) with decision-
            # cache telemetry (DESIGN.md §13) — log the hit rate so the
            # amortization is observable in serving, not just benches.
            if isinstance(lat, (tuple, list)) and len(lat) == 2:
                lat, aux = lat
                hits = int(jax.device_get(aux.get("cache_hits", 0)))
                refr = int(jax.device_get(aux.get("cache_refreshes", 0)))
                if hits + refr:
                    log.info(
                        "bucket %s decision cache: %d hits / %d refreshes "
                        "(hit rate %.2f)", key, hits, refr,
                        hits / max(hits + refr, 1))
                if "ring_elided_hops" in aux:
                    # Context-parallel telemetry (DESIGN.md §14): ring
                    # hops the block map let the seq shards skip.
                    log.info(
                        "bucket %s ring: %d elided hop(s)", key,
                        int(jax.device_get(aux["ring_elided_hops"])))
            lat = np.asarray(jax.device_get(lat))
            err = None
        except Exception as e:  # noqa: BLE001 — fail the batch, not the engine
            log.exception("bucket %s batch failed", key)
            lat, err = None, repr(e)
        dt = time.time() - t0
        with self._lock:
            bi = self._batches_served
            self._batches_served += 1
            for i, r in enumerate(batch):
                self._results[r.request_id] = GenResult(
                    r.request_id, None if err else lat[i], dt, error=err,
                    batch_index=bi)
            self._lock.notify_all()
        log.info("served bucket %s batch of %d in %.2fs", key, len(batch),
                 dt)

    def _loop(self):
        while True:
            key, batch = self._take_batch()
            if key is None:
                return  # stopped and drained
            self._serve(key, batch)


class LMEngine:
    """Prefill + decode serving for the LM family."""

    def __init__(self, prefill_fn: Callable, decode_fn: Callable,
                 max_len: int):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.max_len = max_len

    def generate(self, tokens: jax.Array, num_new: int,
                 temperature: float = 0.0, rng=None) -> jax.Array:
        """tokens: (B, S) prompt -> (B, num_new) continuations (greedy or
        temperature sampling)."""
        B, S = tokens.shape
        logits, cache = self.prefill_fn(tokens)
        out = []
        index = jnp.asarray(S, jnp.int32)
        cur = None
        for i in range(num_new):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                nxt = jax.random.categorical(sub, logits[:, -1] / temperature)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            out.append(nxt)
            cur = nxt[:, None]
            logits, cache = self.decode_fn(cur, cache, index)
            index = index + 1
        return jnp.stack(out, axis=1)
