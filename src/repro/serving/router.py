"""Front-door router over N DiffusionEngine replicas (DESIGN.md §15.4).

One process can hold several engine replicas (each wrapping its own
sampler factory — on a real fleet each replica owns a device slice;
under ``jax.distributed`` each host runs one router in front of its
local replicas, see :func:`repro.launch.mesh.init_distributed`).  The
router is the admission point the ROADMAP's millions-of-users shape
needs in front of the engines:

  * **load balancing** — submit routes to the healthy replica with the
    shallowest queue (queue-depth accounting via ``engine.pending()``
    plus the router's own in-flight ledger, so bursts don't all land on
    the replica whose queue the OS scheduler drained first);
  * **shed propagation** — a replica's admission control may shed
    (:class:`~repro.serving.slo.ShedError`); the router then tries the
    other replicas (a request infeasible on a deep queue may be
    feasible on a shallow one) and only sheds fleet-wide when every
    healthy replica refuses;
  * **failover** — :meth:`fail_replica` (or a dead engine discovered at
    submit) drains the failed replica and *requeues* every request it
    had accepted but not successfully served onto the survivors, so a
    replica loss costs retries, not lost requests; blocked
    :meth:`result` waits and :meth:`stream` consumers both follow the
    request to its new replica.

The router keeps the original :class:`~repro.serving.engine.GenRequest`
for every in-flight request — requeue is replay, which is safe because
generation is deterministic in (seed, txt, bucket): a request served
twice returns the same latents.  With a
:class:`~repro.serving.journal.CheckpointStore` attached (DESIGN.md
§18), requeue is *resume* instead of replay: the latest chunk-boundary
checkpoint is snapshotted onto the request at requeue time (so a
zombie batch on the dead replica racing newer writes cannot change
what the survivor serves) and the survivor picks up mid-flight via the
engine's resume path — same latents, only the remaining steps paid.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.serving.engine import (DiffusionEngine, GenRequest, GenResult,
                                  is_failover_error)
from repro.serving.slo import ShedError
from repro.utils.logging import get_logger

log = get_logger("serve.router")

__all__ = ["Router"]


class Router:
    """Load-balancing front door over ``replicas`` (started/stopped as a
    group).  All public methods are thread-safe."""

    def __init__(self, replicas: List[DiffusionEngine],
                 probe_interval_s: Optional[float] = None,
                 checkpoint_store=None):
        if not replicas:
            raise ValueError("need at least one engine replica")
        self._replicas = list(replicas)
        self._healthy = [True] * len(replicas)
        # Shared chunk-boundary checkpoint store (DESIGN.md §18): when
        # set, failover hands the survivor the latest checkpoint
        # instead of replaying from step 0.
        self._store = checkpoint_store
        self.resumed_count = 0
        self.resumed_from_step = 0
        # rid -> chunks already delivered by the replica that wrote the
        # checkpoint the current assignment resumed from; the stream
        # dedup baseline (a resumed replica only emits the *remaining*
        # chunks, so plain skip-counting would swallow real ones).
        self._resume_base: Dict[int, int] = {}
        # Health probing (§17.4): every probe_interval_s the router
        # re-checks downed replicas and re-admits any whose engine is
        # healthy again (externally restarted via engine.start()).
        # None = no probe thread; probe_health() can still be called
        # manually.
        self.probe_interval_s = probe_interval_s
        self.readmitted_count = 0
        self._probe_stop = threading.Event()
        self._probe_thread: Optional[threading.Thread] = None
        # rid -> replica index currently responsible for the request
        self._assigned: Dict[int, int] = {}
        # rid -> original request, kept until result() hands it out so
        # failover can requeue verbatim
        self._requests: Dict[int, GenRequest] = {}
        self._inflight = [0] * len(replicas)
        self.shed_count = 0
        self.requeued_count = 0
        self._lock = threading.Lock()
        # Serializes requeue decisions: result() waiters, stream
        # consumers, and fail_replica()/_mark_down all race to move a
        # request off a dead replica; without this two of them can
        # submit the same request twice.  Always acquired before
        # self._lock, never while holding it.
        self._failover_lock = threading.Lock()

    # -- lifecycle ------------------------------------------------------------

    def start(self):
        with self._lock:
            self._healthy = [True] * len(self._replicas)
        for eng in self._replicas:
            eng.start()
        if self.probe_interval_s is not None:
            self._probe_stop.clear()
            self._probe_thread = threading.Thread(
                target=self._probe_loop, daemon=True)
            self._probe_thread.start()

    def stop(self, drain: bool = True):
        # Stop the probe thread FIRST: marking replicas down below must
        # not race a probe re-admitting them.
        self._probe_stop.set()
        if self._probe_thread is not None:
            self._probe_thread.join()
            self._probe_thread = None
        # Claim every still-healthy replica under the lock (marking it
        # down) so a concurrent fail_replica()/_mark_down cannot stop
        # the same engine twice or stop a just-downed replica with
        # drain=True; the engine.stop calls themselves stay outside the
        # lock so submitters are never blocked behind a drain.
        with self._lock:
            to_stop = [i for i, h in enumerate(self._healthy) if h]
            for i in to_stop:
                self._healthy[i] = False
        for i in to_stop:
            self._replicas[i].stop(drain=drain)

    def healthy_replicas(self) -> List[int]:
        with self._lock:
            return [i for i, h in enumerate(self._healthy)
                    if h and self._replicas[i].healthy()]

    def depths(self) -> Dict[int, int]:
        """Per-replica load: queued + router-tracked in-flight."""
        with self._lock:
            return {i: self._replicas[i].pending() + self._inflight[i]
                    for i, h in enumerate(self._healthy) if h}

    # -- request path ---------------------------------------------------------

    def submit(self, req: GenRequest) -> int:
        """Route to the shallowest healthy replica; returns the replica
        index.  Raises :class:`ShedError` only when *every* healthy
        replica sheds the request, RuntimeError when none is healthy."""
        last_shed: Optional[ShedError] = None
        for idx in self._by_depth():
            try:
                self._replicas[idx].submit(req)
            except ShedError as e:
                last_shed = e
                continue
            except RuntimeError:
                # replica died between the health check and the submit —
                # mark it down and keep trying survivors
                self._mark_down(idx)
                continue
            with self._lock:
                self._assigned[req.request_id] = idx
                self._requests[req.request_id] = req
                self._inflight[idx] += 1
            return idx
        if last_shed is not None:
            with self._lock:
                self.shed_count += 1
            raise last_shed
        raise RuntimeError("no healthy replica accepted the request")

    def result(self, request_id: int, timeout: float = 300.0) -> GenResult:
        """Wait for the request's result, following it across failovers:
        if the responsible replica dies (its engine errors the request
        with "engine stopped"), the request is requeued to a survivor
        and the wait continues against the new assignment.

        On TimeoutError the ledger entry is kept — deliberately — so
        the caller can retry ``result()`` and still reach the request.
        A caller that gives up for good must call :meth:`forget` to
        release the entry, otherwise the replica's in-flight count
        stays inflated and skews least-loaded routing."""
        deadline = time.time() + timeout
        while True:
            with self._lock:
                idx = self._assigned.get(request_id)
            if idx is None:
                raise KeyError(f"request {request_id} was never routed "
                               "(or its result was already consumed)")
            remaining = deadline - time.time()
            if remaining <= 0:
                raise TimeoutError(f"request {request_id}")
            try:
                res = self._replicas[idx].result(request_id,
                                                 timeout=remaining)
            except RuntimeError as e:
                if is_failover_error(e):
                    # the replica died under this request: requeue to a
                    # survivor and keep waiting — unless no survivor
                    # would take it, then surface the original error
                    self._requeue_one(request_id, dead=idx)
                    with self._lock:
                        moved = self._assigned.get(request_id) != idx
                    if moved:
                        continue
                self.forget(request_id)
                raise
            self.forget(request_id)
            return res

    def stream(self, request_id: int,
               timeout: float = 300.0) -> Iterator[np.ndarray]:
        """Chunk stream for the request, following it across failovers:
        each time the replica's stream ends (cleanly or with an error)
        while the assignment has moved to a survivor, the stream is
        replayed against the new replica — replay is deterministic
        (module docstring), so already-delivered chunks are skipped and
        the consumer sees one contiguous chunk sequence."""
        with self._lock:
            if request_id not in self._assigned:
                raise KeyError(f"request {request_id} was never routed")

        def _chunks():
            delivered = 0
            while True:
                with self._lock:
                    idx = self._assigned.get(request_id)
                    # A checkpointed-resume assignment emits only the
                    # chunks after its resume point, so count its first
                    # chunk as (base + 1), not 1 (§18).
                    base = self._resume_base.get(request_id, 0)
                if idx is None:
                    return  # result already consumed; nothing to stream
                moved = False
                try:
                    seen = base
                    for chunk in self._replicas[idx].stream(
                            request_id, timeout=timeout):
                        seen += 1
                        if seen <= delivered:
                            continue  # replayed chunk from before failover
                        delivered = seen
                        yield chunk
                except (RuntimeError, TimeoutError):
                    # Stalled replica: if the request moved (failover
                    # requeued it), chase it; otherwise surface.
                    with self._lock:
                        moved = self._assigned.get(request_id) \
                            not in (None, idx)
                    if not moved:
                        raise
                if not moved:
                    # Clean termination — but the terminating record may
                    # be a dead engine's "engine stopped" error (the
                    # consumer can wake before fail_replica's own
                    # requeue loop runs), so requeue like result() does
                    # and only finish if the request truly stays here.
                    rec = self._replicas[idx].peek_result(request_id)
                    if (rec is not None and rec.error is not None
                            and is_failover_error(rec.error)):
                        self._requeue_one(request_id, dead=idx)
                    with self._lock:
                        if self._assigned.get(request_id) in (None, idx):
                            return

        return _chunks()

    def forget(self, request_id: int):
        """Release the ledger entry for a request the caller has
        abandoned (e.g. after giving up on a ``result()`` timeout).
        Idempotent; without this the assigned replica's in-flight count
        stays inflated and skews least-loaded routing."""
        with self._lock:
            idx = self._assigned.pop(request_id, None)
            self._requests.pop(request_id, None)
            self._resume_base.pop(request_id, None)
            if idx is not None:
                self._inflight[idx] = max(self._inflight[idx] - 1, 0)

    # -- failover -------------------------------------------------------------

    def fail_replica(self, idx: int):
        """Take replica ``idx`` out of rotation: mark it down, requeue
        everything it had accepted but not yet served onto the
        survivors, then stop it without drain.  Requeue happens BEFORE
        the stop on purpose — ``engine.stop`` joins the batcher thread,
        so stopping first would wait out the in-flight batch and every
        checkpointed mid-generation request would look "served" by the
        time failover reads it.  Requeue-first treats the in-flight
        batch as the zombie it would be on a truly dead host: the
        survivor resumes from the §18 checkpoint snapshot while the
        zombie's late results/chunks are superseded by the reassignment
        (stream dedup drops its duplicate chunks)."""
        with self._lock:
            was_healthy = self._healthy[idx]
            self._healthy[idx] = False
        moved = 0
        for rid in self._assigned_to(idx):
            res = self._replicas[idx].peek_result(rid)
            if res is not None and res.error is None:
                continue  # served before the failure; result() will find it
            self._requeue_one(rid, dead=idx)
            moved += 1
        log.info("replica %d failed: requeued %d request(s) onto %s",
                 idx, moved, self.healthy_replicas())
        if was_healthy:
            self._replicas[idx].stop(drain=False)

    def probe_health(self) -> List[int]:
        """Re-admit downed replicas whose engine reports healthy again
        (restarted externally via ``engine.start()``).  Returns the
        re-admitted indices.  A watchdog-tripped or failed replica stays
        down until someone actually restarts its engine — the probe
        verifies recovery, it does not cause it."""
        readmitted = []
        with self._lock:
            for i, h in enumerate(self._healthy):
                if not h and self._replicas[i].healthy():
                    self._healthy[i] = True
                    self.readmitted_count += 1
                    readmitted.append(i)
        for i in readmitted:
            log.info("replica %d recovered: re-admitted to rotation", i)
        return readmitted

    def _probe_loop(self):
        while not self._probe_stop.wait(self.probe_interval_s):
            try:
                self.probe_health()
            except Exception:  # noqa: BLE001 — probing must not die
                log.exception("health probe failed")

    def metrics(self) -> Dict[str, int]:
        m = {"router_shed_count": self.shed_count,
             "router_requeued": self.requeued_count,
             "router_readmitted": self.readmitted_count,
             "router_resumed": self.resumed_count,
             "router_resumed_from_step": self.resumed_from_step}
        for i, eng in enumerate(self._replicas):
            for k, v in eng.metrics().items():
                m[f"replica{i}_{k}"] = v
        return m

    # -- internals ------------------------------------------------------------

    def _by_depth(self) -> List[int]:
        depths = self.depths()
        alive = [i for i in depths if self._replicas[i].healthy()]
        return sorted(alive, key=lambda i: depths[i])

    def _assigned_to(self, idx: int) -> List[int]:
        with self._lock:
            return [rid for rid, i in self._assigned.items() if i == idx]

    def _mark_down(self, idx: int):
        with self._lock:
            was = self._healthy[idx]
            self._healthy[idx] = False
        if was:
            log.warning("replica %d is down; draining its requests", idx)
            for rid in self._assigned_to(idx):
                res = self._replicas[idx].peek_result(rid)
                if res is None or res.error is not None:
                    self._requeue_one(rid, dead=idx)

    def _with_checkpoint(self, req: GenRequest) -> GenRequest:
        """Snapshot the latest chunk-boundary checkpoint onto the
        request (DESIGN.md §18).  Read-once at requeue time under the
        failover lock: a zombie batch on the dead replica may keep
        writing newer checkpoints, but the survivor serves exactly this
        snapshot.  Falls back to the unmodified request (replay from
        step 0) when there is no store, no streaming cadence, or no
        usable checkpoint — resume is an optimization, never a
        requirement."""
        if self._store is None or not req.stream_every:
            return req
        ck = self._store.get(req.request_id)
        if not ck:
            return req
        step = int(ck.get("step") or 0)
        prev = int(req.resume["step"]) if req.resume else 0
        if (step <= prev or step >= req.steps
                or step % req.stream_every != 0):
            return req
        return dataclasses.replace(
            req, resume={"step": step, "x": ck["x"],
                         "dstate": ck.get("dstate")})

    def _requeue_one(self, request_id: int, dead: int):
        with self._failover_lock:
            with self._lock:
                req = self._requests.get(request_id)
                if req is None or self._assigned.get(request_id) != dead:
                    return  # already moved or consumed
                self._inflight[dead] = max(self._inflight[dead] - 1, 0)
            req = self._with_checkpoint(req)
            for idx in self._by_depth():
                if idx == dead:
                    continue
                try:
                    self._replicas[idx].submit(req)
                except (ShedError, RuntimeError):
                    continue
                with self._lock:
                    self._assigned[request_id] = idx
                    self._requests[request_id] = req
                    self._inflight[idx] += 1
                    self.requeued_count += 1
                    if req.resume is not None:
                        step = int(req.resume["step"])
                        self.resumed_count += 1
                        self.resumed_from_step = max(
                            self.resumed_from_step, step)
                        self._resume_base[request_id] = (
                            step // req.stream_every)
                log.info(
                    "request %d requeued from replica %d to %d%s",
                    request_id, dead, idx,
                    f" (resuming from step {req.resume['step']})"
                    if req.resume else "")
                return
            # no survivor took it: leave the assignment pointing at the
            # dead replica so result() surfaces the original error
            log.error("request %d could not be requeued off replica %d",
                      request_id, dead)
