"""Front-door router over N DiffusionEngine replicas (DESIGN.md §15.4).

One process can hold several engine replicas (each wrapping its own
sampler factory — on a real fleet each replica owns a device slice;
under ``jax.distributed`` each host runs one router in front of its
local replicas, see :func:`repro.launch.mesh.init_distributed`).  The
router is the admission point the ROADMAP's millions-of-users shape
needs in front of the engines:

  * **load balancing** — submit routes to the healthy replica with the
    shallowest queue (queue-depth accounting via ``engine.pending()``
    plus the router's own in-flight ledger, so bursts don't all land on
    the replica whose queue the OS scheduler drained first);
  * **shed propagation** — a replica's admission control may shed
    (:class:`~repro.serving.slo.ShedError`); the router then tries the
    other replicas (a request infeasible on a deep queue may be
    feasible on a shallow one) and only sheds fleet-wide when every
    healthy replica refuses;
  * **failover** — :meth:`fail_replica` (or a dead engine discovered at
    submit) drains the failed replica and *requeues* every request it
    had accepted but not successfully served onto the survivors, so a
    replica loss costs retries, not lost requests.

The router keeps the original :class:`~repro.serving.engine.GenRequest`
for every in-flight request — requeue is replay, which is safe because
generation is deterministic in (seed, txt, bucket): a request served
twice returns the same latents.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.serving.engine import DiffusionEngine, GenRequest, GenResult
from repro.serving.slo import ShedError
from repro.utils.logging import get_logger

log = get_logger("serve.router")

__all__ = ["Router"]


class Router:
    """Load-balancing front door over ``replicas`` (started/stopped as a
    group).  All public methods are thread-safe."""

    def __init__(self, replicas: List[DiffusionEngine]):
        if not replicas:
            raise ValueError("need at least one engine replica")
        self._replicas = list(replicas)
        self._healthy = [True] * len(replicas)
        # rid -> replica index currently responsible for the request
        self._assigned: Dict[int, int] = {}
        # rid -> original request, kept until result() hands it out so
        # failover can requeue verbatim
        self._requests: Dict[int, GenRequest] = {}
        self._inflight = [0] * len(replicas)
        self.shed_count = 0
        self.requeued_count = 0
        self._lock = threading.Lock()

    # -- lifecycle ------------------------------------------------------------

    def start(self):
        for eng in self._replicas:
            eng.start()

    def stop(self, drain: bool = True):
        for i, eng in enumerate(self._replicas):
            if self._healthy[i]:
                eng.stop(drain=drain)

    def healthy_replicas(self) -> List[int]:
        with self._lock:
            return [i for i, h in enumerate(self._healthy)
                    if h and self._replicas[i].healthy()]

    def depths(self) -> Dict[int, int]:
        """Per-replica load: queued + router-tracked in-flight."""
        with self._lock:
            return {i: self._replicas[i].pending() + self._inflight[i]
                    for i, h in enumerate(self._healthy) if h}

    # -- request path ---------------------------------------------------------

    def submit(self, req: GenRequest) -> int:
        """Route to the shallowest healthy replica; returns the replica
        index.  Raises :class:`ShedError` only when *every* healthy
        replica sheds the request, RuntimeError when none is healthy."""
        last_shed: Optional[ShedError] = None
        for idx in self._by_depth():
            try:
                self._replicas[idx].submit(req)
            except ShedError as e:
                last_shed = e
                continue
            except RuntimeError:
                # replica died between the health check and the submit —
                # mark it down and keep trying survivors
                self._mark_down(idx)
                continue
            with self._lock:
                self._assigned[req.request_id] = idx
                self._requests[req.request_id] = req
                self._inflight[idx] += 1
            return idx
        if last_shed is not None:
            with self._lock:
                self.shed_count += 1
            raise last_shed
        raise RuntimeError("no healthy replica accepted the request")

    def result(self, request_id: int, timeout: float = 300.0) -> GenResult:
        """Wait for the request's result, following it across failovers:
        if the responsible replica dies (its engine errors the request
        with "engine stopped"), the request is requeued to a survivor
        and the wait continues against the new assignment."""
        deadline = time.time() + timeout
        while True:
            with self._lock:
                idx = self._assigned.get(request_id)
            if idx is None:
                raise KeyError(f"request {request_id} was never routed "
                               "(or its result was already consumed)")
            remaining = deadline - time.time()
            if remaining <= 0:
                raise TimeoutError(f"request {request_id}")
            try:
                res = self._replicas[idx].result(request_id,
                                                 timeout=remaining)
            except RuntimeError as e:
                if "engine stopped" in str(e):
                    # the replica died under this request: requeue to a
                    # survivor and keep waiting — unless no survivor
                    # would take it, then surface the original error
                    self._requeue_one(request_id, dead=idx)
                    with self._lock:
                        moved = self._assigned.get(request_id) != idx
                    if moved:
                        continue
                self._forget(request_id, idx)
                raise
            self._forget(request_id, idx)
            return res

    def stream(self, request_id: int,
               timeout: float = 300.0) -> Iterator[np.ndarray]:
        """Pass-through to the responsible replica's chunk stream."""
        with self._lock:
            idx = self._assigned.get(request_id)
        if idx is None:
            raise KeyError(f"request {request_id} was never routed")
        return self._replicas[idx].stream(request_id, timeout=timeout)

    # -- failover -------------------------------------------------------------

    def fail_replica(self, idx: int):
        """Take replica ``idx`` out of rotation: stop it without drain
        (in-flight batch still completes; queued requests error), then
        requeue everything it had accepted but not successfully served
        onto the survivors."""
        with self._lock:
            was_healthy = self._healthy[idx]
            self._healthy[idx] = False
        if was_healthy:
            self._replicas[idx].stop(drain=False)
        moved = 0
        for rid in self._assigned_to(idx):
            res = self._replicas[idx].peek_result(rid)
            if res is not None and res.error is None:
                continue  # served before the failure; result() will find it
            self._requeue_one(rid, dead=idx)
            moved += 1
        log.info("replica %d failed: requeued %d request(s) onto %s",
                 idx, moved, self.healthy_replicas())

    def metrics(self) -> Dict[str, int]:
        m = {"router_shed_count": self.shed_count,
             "router_requeued": self.requeued_count}
        for i, eng in enumerate(self._replicas):
            for k, v in eng.metrics().items():
                m[f"replica{i}_{k}"] = v
        return m

    # -- internals ------------------------------------------------------------

    def _by_depth(self) -> List[int]:
        depths = self.depths()
        alive = [i for i in depths if self._replicas[i].healthy()]
        return sorted(alive, key=lambda i: depths[i])

    def _assigned_to(self, idx: int) -> List[int]:
        with self._lock:
            return [rid for rid, i in self._assigned.items() if i == idx]

    def _mark_down(self, idx: int):
        with self._lock:
            was = self._healthy[idx]
            self._healthy[idx] = False
        if was:
            log.warning("replica %d is down; draining its requests", idx)
            for rid in self._assigned_to(idx):
                res = self._replicas[idx].peek_result(rid)
                if res is None or res.error is not None:
                    self._requeue_one(rid, dead=idx)

    def _requeue_one(self, request_id: int, dead: int):
        with self._lock:
            req = self._requests.get(request_id)
            if req is None or self._assigned.get(request_id) != dead:
                return  # already moved or consumed
            self._inflight[dead] = max(self._inflight[dead] - 1, 0)
        for idx in self._by_depth():
            if idx == dead:
                continue
            try:
                self._replicas[idx].submit(req)
            except (ShedError, RuntimeError):
                continue
            with self._lock:
                self._assigned[request_id] = idx
                self._inflight[idx] += 1
                self.requeued_count += 1
            log.info("request %d requeued from replica %d to %d",
                     request_id, dead, idx)
            return
        # no survivor took it: leave the assignment pointing at the dead
        # replica so result() surfaces the original error
        log.error("request %d could not be requeued off replica %d",
                  request_id, dead)

    def _forget(self, request_id: int, idx: int):
        with self._lock:
            self._assigned.pop(request_id, None)
            self._requests.pop(request_id, None)
            self._inflight[idx] = max(self._inflight[idx] - 1, 0)
