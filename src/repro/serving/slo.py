"""SLO primitives for the serving engine (DESIGN.md §15).

The scheduling problem TimeRipple leaves behind: once the per-step
attention cost drops ~85% (PAPER.md Tbl. 2), end-to-end latency under
real traffic is dominated by *queueing*, not compute.  This module holds
the pieces the :class:`~repro.serving.engine.DiffusionEngine` composes
into deadline-aware serving:

  * :class:`ServiceEstimator` — per-bucket batch service-time tracking.
    Two statistics per bucket: an optimistic **lower bound** (the
    fastest batch ever observed) used for *provable* admission
    decisions, and an **EWMA** used for feasibility ranking inside the
    scheduler.  A bucket with no observation yet has no bound — the
    engine then admits (never shed on a guess).
  * :func:`admission_decision` — shed-at-the-door check: a request is
    rejected only when it *provably* cannot meet its deadline, i.e. its
    deadline already passed, or the optimistic lower bound on draining
    the requests already ahead of it in its own bucket (FIFO within a
    bucket) plus its own batch exceeds the deadline.  Conservative by
    construction: sheds only what hottest-first or EDF could not have
    saved either.
  * :func:`choose_bucket` — the drain policy.  Starvation aging first
    (a head request older than ``starve_after_s`` always wins, exactly
    as before this seam existed); then, under the ``"edf"`` scheduler,
    earliest-feasible-deadline among buckets whose head carries a
    deadline (falling back to earliest-even-if-infeasible so a late
    request is still served, just not at the cost of feasible ones);
    deadline-less traffic — and the ``"hottest"`` scheduler — drain
    hottest (deepest) bucket first.

Deadlines are absolute ``time.time()`` seconds on
:class:`~repro.serving.engine.GenRequest.deadline_s`; callers that
think in relative SLOs stamp ``time.time() + slo_ms / 1e3`` at submit.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, Hashable, Mapping, Optional, Sequence, Tuple

__all__ = ["ShedError", "ServiceEstimator", "admission_decision",
           "choose_bucket"]


class ShedError(RuntimeError):
    """Raised by ``submit`` when admission control proves the request
    cannot meet its deadline under the current queue depth.  Shed at
    the door: no compute was spent, no result record exists."""


class ServiceEstimator:
    """Per-bucket batch service-time statistics (thread-safe).

    ``observe`` is called by the engine after every served batch;
    ``lower_bound`` is the fastest observation (the provable-admission
    bound), ``expected`` an EWMA (the scheduling estimate).  Unknown
    buckets return ``None`` for both — callers must treat that as
    "cannot prove anything".
    """

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._min: Dict[Hashable, float] = {}
        self._ewma: Dict[Hashable, float] = {}
        self._lock = threading.Lock()

    def observe(self, key: Hashable, seconds: float) -> None:
        with self._lock:
            prev = self._min.get(key)
            self._min[key] = seconds if prev is None else min(prev, seconds)
            ew = self._ewma.get(key)
            self._ewma[key] = seconds if ew is None else (
                self.alpha * seconds + (1.0 - self.alpha) * ew)

    def lower_bound(self, key: Hashable) -> Optional[float]:
        with self._lock:
            return self._min.get(key)

    def expected(self, key: Hashable) -> Optional[float]:
        with self._lock:
            return self._ewma.get(key)

    def timeout_hint(self, key: Hashable, floor_s: float,
                     mult: float = 8.0) -> float:
        """Watchdog budget for one batch of ``key`` (DESIGN.md §17.4):
        ``mult`` × the EWMA service time once observed, never below
        ``floor_s`` — so the hang detector scales with what the bucket
        actually costs (first-batch compiles included in the EWMA)
        instead of a blind constant, and an unobserved bucket gets the
        caller's floor rather than a guess of zero."""
        est = self.expected(key)
        if est is None:
            return floor_s
        return max(floor_s, mult * est)

    # -- warm-restart persistence (DESIGN.md §18) --------------------------

    def to_json(self) -> str:
        """Serialize the per-bucket statistics.  Keys are stored as
        ``repr`` strings — bucket keys are tuples of ints/strings/None,
        which round-trip exactly through ``ast.literal_eval``."""
        import json

        with self._lock:
            return json.dumps({
                "alpha": self.alpha,
                "min": {repr(k): v for k, v in self._min.items()},
                "ewma": {repr(k): v for k, v in self._ewma.items()},
            })

    @classmethod
    def from_json(cls, text: str) -> "ServiceEstimator":
        """Rebuild an estimator from :meth:`to_json` output, so a warm
        restart keeps its admission bounds and watchdog budgets instead
        of re-learning them (and re-admitting provably-infeasible
        traffic) from scratch.  Unparseable keys are skipped, not
        fatal — stale persisted state must never block a restart."""
        import ast
        import json

        d = json.loads(text)
        est = cls(alpha=float(d.get("alpha", 0.3)))
        for attr, src in (("_min", d.get("min", {})),
                          ("_ewma", d.get("ewma", {}))):
            out = getattr(est, attr)
            for ks, v in src.items():
                try:
                    out[ast.literal_eval(ks)] = float(v)
                except (ValueError, SyntaxError):
                    continue
        return est


def _batches_needed(queued_ahead: int, max_batch: int) -> int:
    """Minimum sampler invocations before a request joining a bucket
    with ``queued_ahead`` requests ahead of it comes back (FIFO within
    the bucket, batches of at most ``max_batch``)."""
    return int(math.ceil((queued_ahead + 1) / max(max_batch, 1)))


def admission_decision(deadline_s: Optional[float], now: float,
                       queued_ahead: int, max_batch: int,
                       lower_bound_s: Optional[float]) -> Optional[str]:
    """``None`` to admit, else a human-readable shed reason.

    Sheds only on proof: the deadline already passed, or even the
    fastest-ever batch time for this bucket cannot drain the FIFO ahead
    of the request plus the request itself before the deadline.
    """
    if deadline_s is None:
        return None
    if deadline_s <= now:
        return f"deadline passed {now - deadline_s:.3f}s before submit"
    if lower_bound_s is None:
        return None  # no observation yet: cannot prove infeasibility
    need = _batches_needed(queued_ahead, max_batch) * lower_bound_s
    if now + need > deadline_s:
        return (f"needs >= {need:.3f}s ({queued_ahead} ahead, "
                f"best batch {lower_bound_s:.3f}s) but only "
                f"{deadline_s - now:.3f}s of budget remains")
    return None


# head of each live bucket: (enqueue_time, deadline_s or None, depth)
HeadInfo = Tuple[float, Optional[float], int]


def choose_bucket(heads: Mapping[Hashable, HeadInfo], now: float, *,
                  scheduler: str = "edf", starve_after_s: float = 2.0,
                  estimator: Optional[ServiceEstimator] = None):
    """Pick the next bucket to drain (``None`` if ``heads`` is empty).

    Aging first: the oldest head past ``starve_after_s`` wins
    unconditionally, so deadline-less (or far-deadline) traffic is
    never starved by a stream of tight SLOs — the same guard the
    hottest-first engine shipped with.  Then EDF over deadline-carrying
    heads, preferring feasible ones (``now + expected <= deadline``;
    heads in buckets without an estimate count as feasible); if every
    deadline is already infeasible, the earliest still goes first —
    late is better than later.  Buckets without any deadline at the
    head, or the ``"hottest"`` scheduler, drain deepest-first.
    """
    if not heads:
        return None
    oldest = min(heads, key=lambda k: heads[k][0])
    if now - heads[oldest][0] > starve_after_s:
        return oldest
    if scheduler == "edf":
        with_dl = {k: v[1] for k, v in heads.items() if v[1] is not None}
        if with_dl:
            def feasible(k):
                est = estimator.expected(k) if estimator is not None else None
                return est is None or now + est <= with_dl[k]
            pool = {k: d for k, d in with_dl.items() if feasible(k)}
            if not pool:
                pool = with_dl
            return min(pool, key=pool.get)
    return max(heads, key=lambda k: heads[k][2])
