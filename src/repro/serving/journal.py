"""Crash-safe serving state: request journal + generation checkpoints
(DESIGN.md §18).

PR 9 gave the fleet *detection* (sentinels, watchdogs, the degradation
ladder); this module gives it *recovery*.  Two durable structures live
side by side in one journal directory:

  * :class:`Journal` — an append-only, CRC-framed write-ahead log of
    request lifecycle events (``submitted`` / ``chunk`` / ``finished``
    / ``shed``).  Every record is ``<u32 length, u32 crc32>`` followed
    by a JSON payload; the recovery scan (:func:`scan_records`) stops
    at the first frame that fails its length or CRC check, so a crash
    mid-append costs exactly the torn final record and nothing before
    it.  The fsync policy is configurable (``always`` / ``interval`` /
    ``never``) because the durability/latency trade belongs to the
    operator, not the engine.  A clean shutdown writes a ``CLEAN``
    marker (tmp + fsync + ``os.replace``, the hardened ``patterns.py``
    idiom via :mod:`repro.utils.diskio`) carrying the last journal
    sequence number — recovery treats the state as crashed unless the
    marker exists *and* matches the scan, so a stale marker from a
    previous clean run never masks a later crash.

  * :class:`CheckpointStore` — a bounded on-disk store of per-request
    generation checkpoints ``(x_t, decision-cache state, step_offset,
    seed, bucket key)`` written at streaming chunk boundaries.  The PR 7
    chunked sampler contract (``step_offset``/``total_steps`` chaining
    is bitwise-equal to the monolithic scan) is what makes these
    checkpoints *exact*: a warm restart or router failover that resumes
    from ``(x_t, dstate, step)`` replays the identical remaining
    schedule slice and lands on bitwise-identical final latents.  Array
    leaves are serialized as raw byte buffers with dtype names (NumPy's
    savez cannot hold ``bfloat16``), each file is written atomically
    with a body CRC, and a corrupt or torn checkpoint degrades to
    replay-from-step-0 instead of an error — the checkpoint is an
    optimization, the journal is the source of truth.

:func:`recover` folds a journal directory into a
:class:`RecoveryState`: the pending request set (submitted, never
finished or shed), the latest delivered chunk per request, and the
clean/crashed verdict that ``launch/serve.py --resume`` acts on.
"""

from __future__ import annotations

import ast
import base64
import dataclasses
import json
import os
import struct
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.utils.diskio import atomic_write_bytes
from repro.utils.logging import get_logger

log = get_logger("serve.journal")

__all__ = ["CheckpointStore", "Journal", "RecoveryState", "recover",
           "request_from_dict", "request_to_dict", "scan_records"]

# Frame header: payload length + payload crc32, little-endian u32 each.
_HDR = struct.Struct("<II")
# A length field beyond this is treated as frame corruption, not an
# instruction to allocate gigabytes.
_MAX_RECORD = 16 << 20

JOURNAL_FILE = "journal.log"
CLEAN_MARKER = "CLEAN"
FSYNC_POLICIES = ("always", "interval", "never")


def _np_dtype(name: str) -> np.dtype:
    """dtype from its saved name, including the ml_dtypes extension
    types (``bfloat16``) that ``np.dtype(str)`` alone cannot resolve."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _encode_array(a: np.ndarray) -> Dict[str, Any]:
    a = np.ascontiguousarray(a)
    return {"shape": list(a.shape), "dtype": a.dtype.name,
            "b64": base64.b64encode(a.tobytes()).decode("ascii")}


def _decode_array(d: Dict[str, Any]) -> np.ndarray:
    buf = base64.b64decode(d["b64"])
    return np.frombuffer(buf, dtype=_np_dtype(d["dtype"])).reshape(
        tuple(d["shape"])).copy()


# ---------------------------------------------------------------------------
# GenRequest <-> JSON (journal payload for `submitted` events)
# ---------------------------------------------------------------------------


def request_to_dict(req) -> Dict[str, Any]:
    """JSON-able snapshot of one
    :class:`~repro.serving.engine.GenRequest`.  The ``resume`` /
    ``recovered`` runtime fields are deliberately excluded — they
    describe *this process's* serving attempt, not the request."""
    return {
        "request_id": int(req.request_id),
        "txt": _encode_array(np.asarray(req.txt)),
        "steps": int(req.steps),
        "seed": int(req.seed),
        "guidance": float(req.guidance),
        "latent_shape": (None if req.latent_shape is None
                         else [int(d) for d in req.latent_shape]),
        "policy": req.policy,
        "reuse_every": (None if req.reuse_every is None
                        else int(req.reuse_every)),
        "deadline_s": (None if req.deadline_s is None
                       else float(req.deadline_s)),
        "stream_every": (None if req.stream_every is None
                         else int(req.stream_every)),
    }


def request_from_dict(d: Dict[str, Any]):
    """Rebuild the :class:`~repro.serving.engine.GenRequest` a
    ``submitted`` journal event recorded.  The absolute ``deadline_s``
    is carried verbatim — recovery callers that resubmit after a
    restart strip it (it has almost certainly expired, and shedding a
    journaled request at the recovery door would violate the
    every-journaled-request-completes contract)."""
    from repro.serving.engine import GenRequest

    return GenRequest(
        request_id=int(d["request_id"]),
        txt=_decode_array(d["txt"]),
        steps=int(d["steps"]),
        seed=int(d["seed"]),
        guidance=float(d["guidance"]),
        latent_shape=(None if d["latent_shape"] is None
                      else tuple(d["latent_shape"])),
        policy=d["policy"],
        reuse_every=d["reuse_every"],
        deadline_s=d["deadline_s"],
        stream_every=d["stream_every"],
    )


# ---------------------------------------------------------------------------
# The write-ahead journal
# ---------------------------------------------------------------------------


def scan_records(path: str) -> Tuple[List[Dict[str, Any]], bool]:
    """Read every intact record of a journal file, in order.  Returns
    ``(records, torn)`` where ``torn`` means the file ends in a frame
    that fails its length/CRC/JSON check — expected after a crash
    mid-append, never an error: everything before the torn frame is
    trusted, nothing after it is read."""
    records: List[Dict[str, Any]] = []
    if not os.path.exists(path):
        return records, False
    with open(path, "rb") as f:
        data = f.read()
    off = 0
    while off < len(data):
        if off + _HDR.size > len(data):
            return records, True
        length, crc = _HDR.unpack_from(data, off)
        if length > _MAX_RECORD or off + _HDR.size + length > len(data):
            return records, True
        payload = data[off + _HDR.size: off + _HDR.size + length]
        if zlib.crc32(payload) != crc:
            return records, True
        try:
            records.append(json.loads(payload.decode("utf-8")))
        except (UnicodeDecodeError, json.JSONDecodeError):
            return records, True
        off += _HDR.size + length
    return records, False


class Journal:
    """Append-only request-lifecycle WAL (module docstring).  Opening a
    journal removes any clean-shutdown marker — the process is running
    now, so the state on disk is by definition no longer a clean
    snapshot until :meth:`close` says so again.  Thread-safe; every
    append is flushed to the OS before returning (a SIGKILL can then
    tear at most the record an OS/power crash could — which the scan
    tolerates)."""

    def __init__(self, dirpath: str, *, fsync: str = "always",
                 fsync_interval: int = 8,
                 time_fn: Callable[[], float] = time.time):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(f"fsync policy must be one of "
                             f"{FSYNC_POLICIES}, got {fsync!r}")
        os.makedirs(dirpath, exist_ok=True)
        self.dir = dirpath
        self.path = os.path.join(dirpath, JOURNAL_FILE)
        self.fsync_policy = fsync
        self.fsync_interval = max(int(fsync_interval), 1)
        self._time = time_fn
        # Continue the sequence after whatever the existing log holds —
        # a torn tail is fine, we append after the last *intact* frame.
        records, torn = scan_records(self.path)
        self._seq = records[-1]["seq"] if records else 0
        valid = 0
        if records:
            with open(self.path, "rb") as f:
                data = f.read()
            off = 0
            for _ in records:
                length, _crc = _HDR.unpack_from(data, off)
                off += _HDR.size + length
            valid = off
        if torn:
            log.warning("journal %s has a torn tail; truncating to %d "
                        "intact record(s)", self.path, len(records))
        self._f = open(self.path, "ab")
        if torn and self._f.tell() > valid:
            self._f.truncate(valid)
        # Running again: the on-disk state is live, not a clean snapshot.
        marker = os.path.join(dirpath, CLEAN_MARKER)
        if os.path.exists(marker):
            os.unlink(marker)
        self._lock = threading.Lock()
        self._appends_since_fsync = 0
        self.appends = 0
        self.bytes_written = 0
        self.fsyncs = 0
        self.fsync_ms = 0.0
        self._closed = False

    # -- append path -------------------------------------------------------

    def append(self, event: str, rid: int, **fields) -> int:
        """Append one lifecycle record; returns its sequence number."""
        with self._lock:
            if self._closed:
                raise RuntimeError("journal is closed")
            self._seq += 1
            rec = {"seq": self._seq, "ev": event, "rid": int(rid)}
            rec.update(fields)
            payload = json.dumps(rec, separators=(",", ":")).encode("utf-8")
            self._f.write(_HDR.pack(len(payload), zlib.crc32(payload)))
            self._f.write(payload)
            self._f.flush()
            self.appends += 1
            self.bytes_written += _HDR.size + len(payload)
            self._appends_since_fsync += 1
            if self.fsync_policy == "always" or (
                    self.fsync_policy == "interval"
                    and self._appends_since_fsync >= self.fsync_interval):
                self._fsync_locked()
            return self._seq

    def _fsync_locked(self):
        t0 = time.perf_counter()
        os.fsync(self._f.fileno())
        self.fsync_ms += (time.perf_counter() - t0) * 1e3
        self.fsyncs += 1
        self._appends_since_fsync = 0

    # -- lifecycle convenience wrappers ------------------------------------

    def record_submitted(self, req) -> int:
        return self.append("submitted", req.request_id,
                           req=request_to_dict(req))

    def record_chunk(self, rid: int, chunk: int,
                     step: Optional[int] = None) -> int:
        return self.append("chunk", rid, chunk=int(chunk),
                           step=None if step is None else int(step))

    def record_finished(self, rid: int, error: Optional[str] = None) -> int:
        return self.append("finished", rid, error=error)

    def record_shed(self, rid: int, reason: str = "") -> int:
        return self.append("shed", rid, reason=str(reason))

    # -- shutdown ----------------------------------------------------------

    def close(self, clean: bool = True):
        """Flush + fsync the log; with ``clean`` also write the
        clean-shutdown marker stamping the final sequence number, so
        the next :func:`recover` can tell a graceful drain from a
        crash.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._f.flush()
            if self.fsync_policy != "never":
                self._fsync_locked()
            self._f.close()
            if clean:
                atomic_write_bytes(
                    os.path.join(self.dir, CLEAN_MARKER),
                    json.dumps({"last_seq": self._seq,
                                "time": self._time()}).encode("utf-8"),
                    fsync=self.fsync_policy != "never")

    def metrics(self) -> Dict[str, float]:
        with self._lock:
            return {"journal_appends": self.appends,
                    "journal_bytes": self.bytes_written,
                    "journal_fsyncs": self.fsyncs,
                    "journal_fsync_ms": self.fsync_ms}


# ---------------------------------------------------------------------------
# Recovery
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RecoveryState:
    """What a journal directory says happened (:func:`recover`)."""

    clean: bool                      # clean-shutdown marker matched the scan
    torn: bool                       # the log ended in a torn frame
    last_seq: int
    events: int
    # rid -> request dict (latest `submitted`, never finished/shed)
    pending: Dict[int, Dict[str, Any]]
    # rid -> {"chunk": int, "step": Optional[int]} latest delivered chunk
    chunks: Dict[int, Dict[str, Any]]
    finished: Dict[int, Optional[str]]   # rid -> error (None = success)
    shed: Dict[int, str]                 # rid -> shed reason


def recover(dirpath: str) -> RecoveryState:
    """Fold the journal into the sets a warm restart needs.  Event
    order is authoritative: a request is *pending* iff its latest
    ``submitted`` record has no later ``finished``/``shed`` record.
    Clean means the marker exists, parses, and stamps exactly the last
    intact sequence number — a marker from an older clean run followed
    by more journal records is a crash, not a clean shutdown."""
    path = os.path.join(dirpath, JOURNAL_FILE)
    records, torn = scan_records(path)
    pending: Dict[int, Dict[str, Any]] = {}
    chunks: Dict[int, Dict[str, Any]] = {}
    finished: Dict[int, Optional[str]] = {}
    shed: Dict[int, str] = {}
    last_seq = records[-1]["seq"] if records else 0
    for rec in records:
        rid = rec.get("rid")
        ev = rec.get("ev")
        if ev == "submitted":
            pending[rid] = rec.get("req", {})
            finished.pop(rid, None)
            shed.pop(rid, None)
        elif ev == "chunk":
            chunks[rid] = {"chunk": rec.get("chunk"),
                           "step": rec.get("step")}
        elif ev == "finished":
            pending.pop(rid, None)
            finished[rid] = rec.get("error")
        elif ev == "shed":
            pending.pop(rid, None)
            shed[rid] = rec.get("reason", "")
    clean = not torn
    marker = os.path.join(dirpath, CLEAN_MARKER)
    if os.path.exists(marker):
        try:
            with open(marker, "r", encoding="utf-8") as f:
                m = json.load(f)
            clean = clean and int(m.get("last_seq", -1)) == last_seq
        except (OSError, ValueError):
            clean = False
    else:
        # No marker: clean only in the trivial no-journal case.
        clean = clean and not records and not os.path.exists(path)
    return RecoveryState(clean=clean, torn=torn, last_seq=last_seq,
                         events=len(records), pending=pending,
                         chunks=chunks, finished=finished, shed=shed)


# ---------------------------------------------------------------------------
# Chunk-boundary generation checkpoints
# ---------------------------------------------------------------------------


class CheckpointStore:
    """Bounded per-request checkpoint files under ``<dir>/ckpt/``.

    One file per request id, overwritten at every chunk boundary with
    the latest ``(x_t, decision-cache arrays, step, seed, bucket)``
    snapshot; :meth:`discard` removes it when the request finishes, so
    steady state holds only in-flight work.  ``max_entries`` bounds the
    pathological case (a flood of abandoned requests): the
    least-recently-written id is evicted first.  Writes are atomic
    (tmp + optional fsync + replace) and the body carries a CRC — a
    torn or corrupt file makes :meth:`get` return ``None`` (resume
    degrades to replay-from-0) rather than raise."""

    def __init__(self, dirpath: str, *, max_entries: int = 64,
                 fsync: bool = True):
        self.dir = os.path.join(dirpath, "ckpt")
        os.makedirs(self.dir, exist_ok=True)
        self.max_entries = max(int(max_entries), 1)
        self.fsync = fsync
        self._lock = threading.Lock()
        # rid -> path, in least-recently-written order (existing files
        # re-adopted oldest-mtime-first so restarts keep the bound).
        self._files: "Dict[int, str]" = {}
        try:
            names = [(os.path.getmtime(os.path.join(self.dir, n)), n)
                     for n in os.listdir(self.dir)
                     if n.startswith("ckpt_") and n.endswith(".bin")]
        except OSError:
            names = []
        for _, n in sorted(names):
            try:
                rid = int(n[len("ckpt_"):-len(".bin")])
            except ValueError:
                continue
            self._files[rid] = os.path.join(self.dir, n)
        self.writes = 0
        self.bytes_written = 0
        self.write_ms = 0.0

    def _path(self, rid: int) -> str:
        return os.path.join(self.dir, f"ckpt_{int(rid)}.bin")

    def put(self, rid: int, *, step: int, x: np.ndarray, seed: int,
            bucket: Any = None,
            dstate: Optional[Dict[str, Optional[np.ndarray]]] = None):
        """Persist the latest checkpoint for ``rid``.  ``dstate`` is
        the field-name -> host-array mapping from
        :func:`repro.core.decision_cache.state_to_arrays` (None for
        samplers that thread no cache)."""
        t0 = time.perf_counter()
        x = np.ascontiguousarray(np.asarray(x))
        bufs = [x.tobytes()]
        meta: Dict[str, Any] = {
            "rid": int(rid), "step": int(step), "seed": int(seed),
            "bucket": repr(bucket),
            "x": {"shape": list(x.shape), "dtype": x.dtype.name,
                  "len": len(bufs[0])},
            "dstate": None,
        }
        if dstate is not None:
            dmeta: Dict[str, Any] = {}
            for name, arr in dstate.items():
                if arr is None:
                    dmeta[name] = None
                    continue
                arr = np.ascontiguousarray(np.asarray(arr))
                buf = arr.tobytes()
                bufs.append(buf)
                dmeta[name] = {"shape": list(arr.shape),
                               "dtype": arr.dtype.name, "len": len(buf)}
            meta["dstate"] = dmeta
        blob = b"".join(bufs)
        meta["crc"] = zlib.crc32(blob)
        header = json.dumps(meta, separators=(",", ":")).encode("utf-8")
        body = struct.pack("<I", len(header)) + header + blob
        path = self._path(rid)
        atomic_write_bytes(path, body, fsync=self.fsync)
        with self._lock:
            self._files.pop(rid, None)   # re-insert as most recent
            self._files[rid] = path
            evict = []
            while len(self._files) > self.max_entries:
                old_rid = next(iter(self._files))
                evict.append(self._files.pop(old_rid))
            self.writes += 1
            self.bytes_written += len(body)
            self.write_ms += (time.perf_counter() - t0) * 1e3
        for p in evict:
            try:
                os.unlink(p)
            except OSError:
                pass

    def get(self, rid: int) -> Optional[Dict[str, Any]]:
        """Latest checkpoint for ``rid`` as ``{"step", "seed",
        "bucket", "x", "dstate"}`` with decoded host arrays, or ``None``
        when absent/corrupt (resume then replays from step 0)."""
        path = self._path(rid)
        try:
            with open(path, "rb") as f:
                body = f.read()
        except OSError:
            return None
        try:
            if len(body) < 4:
                raise ValueError("truncated header length")
            (hlen,) = struct.unpack_from("<I", body, 0)
            header = body[4:4 + hlen]
            meta = json.loads(header.decode("utf-8"))
            blob = body[4 + hlen:]
            if zlib.crc32(blob) != meta["crc"]:
                raise ValueError("checkpoint body CRC mismatch")
            off = 0

            def take(m):
                nonlocal off
                buf = blob[off:off + m["len"]]
                if len(buf) != m["len"]:
                    raise ValueError("truncated checkpoint buffer")
                off += m["len"]
                return np.frombuffer(buf, dtype=_np_dtype(m["dtype"])) \
                    .reshape(tuple(m["shape"])).copy()

            out: Dict[str, Any] = {"step": int(meta["step"]),
                                   "seed": int(meta["seed"]),
                                   "x": take(meta["x"]), "dstate": None}
            try:
                out["bucket"] = ast.literal_eval(meta.get("bucket", "None"))
            except (ValueError, SyntaxError):
                out["bucket"] = None
            if meta.get("dstate") is not None:
                out["dstate"] = {
                    name: (None if m is None else take(m))
                    for name, m in meta["dstate"].items()}
            return out
        except (ValueError, KeyError, TypeError,
                json.JSONDecodeError, struct.error) as e:
            log.warning("checkpoint for request %d unreadable (%s); "
                        "resume will replay from step 0", rid, e)
            return None

    def discard(self, rid: int):
        """Drop the checkpoint for a finished request (idempotent)."""
        with self._lock:
            path = self._files.pop(rid, self._path(rid))
        try:
            os.unlink(path)
        except OSError:
            pass

    def count(self) -> int:
        with self._lock:
            return len(self._files)

    def rids(self) -> List[int]:
        with self._lock:
            return list(self._files)

    def metrics(self) -> Dict[str, float]:
        with self._lock:
            return {"checkpoint_writes": self.writes,
                    "checkpoint_bytes": self.bytes_written,
                    "checkpoint_write_ms": self.write_ms}
