"""Typed configuration system.

Every architecture in ``repro/configs`` builds an :class:`ArchConfig`; the
launcher (`repro.launch`) selects one with ``--arch`` and a workload shape
with ``--shape``.  Configs are plain frozen dataclasses so they hash, print,
and diff cleanly, and can be overridden from the CLI with
``key.subkey=value`` strings via :func:`apply_overrides`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple


# ---------------------------------------------------------------------------
# TimeRipple (the paper's technique)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RippleConfig:
    """Configuration of the TimeRipple reuse technique (paper §3.3).

    The technique snaps spatio-temporally similar (token, channel) entries
    of Q and K to their window representative, which is exactly equivalent
    to reusing their partial attention scores (DESIGN.md §2).
    """

    enabled: bool = False
    # Which grid axes participate in the similarity checks. Subset of
    # ("t", "x", "y"); image models use ("x", "y").
    axes: Tuple[str, ...] = ("t", "x", "y")
    # 'channel': per-channel Δ test (abstract reading; default).
    # 'token'  : mean-Δ over the RoPE channel group gates the whole token.
    granularity: str = "channel"
    # Reuse window size along each axis (paper Fig. 11: 2 is the sweet spot).
    window: int = 2
    # Eq. 4 adaptive threshold schedule. Steps < i_min and the final step
    # run dense; linear ramp theta_min -> theta_max on [i_min, i_max];
    # plateau at theta_max afterwards.
    theta_min: float = 0.2
    theta_max: float = 0.5
    i_min: int = 10
    i_max: int = 20
    # Fixed threshold mode (paper Tbl. 3 'Fixed' ablation).
    fixed_threshold: Optional[float] = None
    # Per-axis thresholds; None means the shared schedule value is used
    # for every axis (paper: "setting θt, θx, θy with the same threshold
    # is more efficient and effective").
    theta_t: Optional[float] = None
    theta_x: Optional[float] = None
    theta_y: Optional[float] = None
    # RoPE channel-group split (temporal, x, y) as fractions of head_dim.
    # HunyuanVideo: 16/56/56 of 128.
    channel_groups: Tuple[float, float, float] = (0.125, 0.4375, 0.4375)
    # Apply reuse to Q, K or both (paper: both).
    snap_q: bool = True
    snap_k: bool = True
    # Combine with SVG-style block masking (paper TIMERIPPLE+SVG variant).
    svg_mask: bool = False
    svg_keep_ratio: float = 0.3
    # Structured TPU execution path: collapse fully-reused K pairs and
    # skip fully-reused Q rows (DESIGN.md §4). 'reference' computes the
    # snapped attention densely (paper-faithful accounting only).
    execution: str = "reference"  # 'reference' | 'collapse'
    # Reuse-policy *strategy* (DESIGN.md §11): which registered
    # ``core.policy.ReusePolicy`` decides the masks/snaps.  Built-ins:
    # 'ripple' (the paper), 'svg' (head-classified block masks),
    # 'equal_mse' (Fig. 9 equal-impact schedule), 'dense' (no-op
    # baseline); out-of-tree strategies register under their own name.
    policy: str = "ripple"
    # Attention backend consumed by ``core.dispatch.attention_dispatch``
    # (DESIGN.md §8).  'auto' picks the block-sparse masked flash kernel
    # for block-map-emitting policies (DESIGN.md §12), the Pallas ripple
    # kernel on TPU when the shape is eligible, and otherwise falls back
    # to ``execution``; the explicit values force one path ('dense'
    # disables the pipeline).
    # 'auto' | 'dense' | 'reference' | 'collapse' | 'pallas' | 'sparse'
    backend: str = "auto"
    # Fused on-device Δ-check + snap (kernels/reuse_mask, DESIGN.md §8).
    # 'auto' uses the fused kernel only where it is a win (TPU); 'on'
    # forces it (interpret mode on CPU — tests/benchmarks), 'off' keeps
    # the host-side jnp mask computation from ``core.reuse``.
    fused_mask: str = "auto"  # 'auto' | 'on' | 'off'
    # Cross-step decision cache (DESIGN.md §13): re-decide the reuse
    # masks / snap sources / block map only every ``reuse_every`` steps
    # and cheaply re-apply the cached decision to the fresh Q/K in
    # between (the per-step math stays exact; only the *decision* is
    # stale).  1 = decide every step (the pre-cache behaviour).
    reuse_every: int = 1
    # Optional drift guard: when > 0, a sampled-channel Δ statistic of
    # the fresh operands is compared against the statistic recorded when
    # the cached decision was made; a relative change above ``drift_tol``
    # forces an early refresh before the cadence is due.  0 disables.
    drift_tol: float = 0.0
    # How many channels the drift statistic samples (stride-subsampled).
    drift_channels: int = 8
    # Runtime quality sentinels (core/guardrail, DESIGN.md §17): count
    # non-finite attention-output entries into the decision-cache carry
    # so the serving engine's degradation ladder can trip on them.
    sentinel: bool = False
    # Dense drift probe cadence: every K denoising steps re-compute one
    # (batch, head) slice densely and max-accumulate the relative error
    # into the carry.  0 disables the probe (non-finite sentinel only).
    sentinel_probe_every: int = 0
    # Experimental 1-D reuse on LM sequence windows. Off by default and
    # not part of the reproduction claims.
    enable_1d: bool = False

    def active(self) -> bool:
        return self.enabled


# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 2
    expert_ffw_dim: int = 0
    # Token-capacity factor for fixed-shape dispatch at scale.
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class LMConfig:
    """Decoder-only transformer LM (dense or MoE)."""

    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # Sliding-window attention: window size for local layers, and the
    # local:global interleave pattern (gemma3: 5 local then 1 global).
    sliding_window: int = 0
    local_global_pattern: int = 0  # N -> every (N+1)th layer is global
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads


@dataclass(frozen=True)
class DiTConfig:
    """Image diffusion transformer (DiT, arXiv:2212.09748)."""

    img_res: int
    patch: int
    num_layers: int
    d_model: int
    num_heads: int
    in_channels: int = 4  # VAE latent channels
    vae_factor: int = 8
    num_classes: int = 1000
    mlp_ratio: float = 4.0
    learn_sigma: bool = True

    def latent_res(self, img_res: Optional[int] = None) -> int:
        return (img_res or self.img_res) // self.vae_factor

    def num_tokens(self, img_res: Optional[int] = None) -> int:
        side = self.latent_res(img_res) // self.patch
        return side * side


@dataclass(frozen=True)
class MMDiTConfig:
    """Flux-style MMDiT: double-stream joint blocks + single-stream blocks."""

    img_res: int
    latent_res: int
    n_double_blocks: int
    n_single_blocks: int
    d_model: int
    num_heads: int
    in_channels: int = 16
    patch: int = 2
    txt_tokens: int = 512
    txt_dim: int = 4096
    mlp_ratio: float = 4.0
    axes_dim: Tuple[int, ...] = (16, 56, 56)  # RoPE split (t/ids, x, y)


@dataclass(frozen=True)
class UNetConfig:
    """SD1.5-style latent UNet (arXiv:2112.10752)."""

    img_res: int
    latent_res: int
    ch: int
    ch_mult: Tuple[int, ...]
    n_res_blocks: int
    attn_res: Tuple[int, ...]  # downsample factors at which attention runs
    ctx_dim: int
    in_channels: int = 4
    num_heads: int = 8
    ctx_tokens: int = 77


@dataclass(frozen=True)
class ViTConfig:
    img_res: int
    patch: int
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    num_classes: int = 1000
    in_channels: int = 3
    pool: str = "cls"  # 'cls' | 'gap'


@dataclass(frozen=True)
class EffNetConfig:
    img_res: int
    width_mult: float
    depth_mult: float
    num_classes: int = 1000
    dropout: float = 0.5
    in_channels: int = 3


@dataclass(frozen=True)
class VDiTConfig:
    """The paper's native setting: a video DiT with (t, x, y) token grid
    and factorized RoPE channel groups."""

    frames: int
    img_res: int
    patch: int
    t_patch: int
    num_layers: int
    d_model: int
    num_heads: int
    in_channels: int = 16
    vae_factor: int = 8
    t_vae_factor: int = 4
    mlp_ratio: float = 4.0
    txt_tokens: int = 256
    txt_dim: int = 4096
    # RoPE channel split (t, x, y) in head-dim units; Hunyuan: 16/56/56.
    axes_dim: Tuple[int, ...] = (16, 56, 56)

    def grid(self, frames=None, img_res=None) -> Tuple[int, int, int]:
        t = (frames or self.frames) // self.t_vae_factor // self.t_patch
        s = (img_res or self.img_res) // self.vae_factor // self.patch
        return (max(t, 1), s, s)


# ---------------------------------------------------------------------------
# Workload shapes & top-level arch config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One workload cell: (architecture x input shape)."""

    name: str
    kind: str  # 'train' | 'prefill' | 'decode' | 'generate' | 'classify' | 'serve'
    # LM shapes
    seq_len: int = 0
    global_batch: int = 0
    # diffusion shapes
    img_res: int = 0
    batch: int = 0
    steps: int = 0


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"  # 'cosine' | 'linear' | 'constant'
    grad_accum: int = 1
    ema_decay: float = 0.0  # 0 disables EMA
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # 'full' rematerializes everything; 'dots' saves matmul outputs
    # (fewer recomputed FLOPs, more live memory).
    remat_policy: str = "full"
    # Megatron-style sequence parallelism: residual-stream activations
    # shard their token dim over 'model'; XLA turns the TP all-reduces
    # into reduce-scatter/all-gather pairs and norms run on 1/16 tokens.
    seq_parallel: bool = False
    # Cross-pod int8 gradient compression with error feedback.
    grad_compression: bool = False


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/tmp/repro_ckpt"
    interval_steps: int = 100
    keep: int = 3
    async_save: bool = True


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'lm' | 'dit' | 'mmdit' | 'unet' | 'vit' | 'effnet' | 'vdit'
    model: Any
    shapes: Tuple[ShapeSpec, ...]
    ripple: RippleConfig = field(default_factory=RippleConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    checkpoint: CheckpointConfig = field(default_factory=CheckpointConfig)
    source: str = ""  # provenance tag from the assignment brief
    # decode-time sharding variant (§Perf): replicate q-heads so the KV
    # cache's sequence dim owns the model axis without resharding.
    decode_replicate_heads: bool = False
    # decode-time weights: plain TP (replicated over data) instead of
    # FSDP — kills the per-step weight all-gather when batch is small.
    decode_no_fsdp: bool = False

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.name}: unknown shape {name!r}; have "
                       f"{[s.name for s in self.shapes]}")


# ---------------------------------------------------------------------------
# CLI overrides
# ---------------------------------------------------------------------------


def _coerce(value: str, target: Any) -> Any:
    if isinstance(target, bool):
        return value.lower() in ("1", "true", "yes", "on")
    if isinstance(target, int):
        return int(value)
    if isinstance(target, float):
        return float(value)
    if isinstance(target, tuple):
        items = [v for v in value.split(",") if v]
        if target and isinstance(target[0], (int, float)):
            cast = type(target[0])
            return tuple(cast(v) for v in items)
        return tuple(items)
    return value


def apply_overrides(cfg, overrides):
    """Apply ``a.b.c=value`` CLI override strings to a nested dataclass."""
    for item in overrides:
        key, _, raw = item.partition("=")
        parts = key.split(".")
        cfg = _apply_one(cfg, parts, raw)
    return cfg


def _apply_one(cfg, parts, raw):
    if len(parts) == 1:
        current = getattr(cfg, parts[0])
        return replace(cfg, **{parts[0]: _coerce(raw, current)})
    child = getattr(cfg, parts[0])
    if not dataclasses.is_dataclass(child):
        raise TypeError(f"cannot descend into non-dataclass field {parts[0]}")
    return replace(cfg, **{parts[0]: _apply_one(child, parts[1:], raw)})
