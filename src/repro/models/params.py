"""Parameter definition system — one source of truth per model.

No flax in this environment, so models declare their parameters as a
nested dict of :class:`ParamDef` (shape, dtype, initializer, *logical
axes*).  From that single tree we derive:

* ``init_params``      — real arrays for training/tests,
* ``abstract_params``  — ShapeDtypeStructs for the dry-run (no allocation),
* ``logical_axes``     — the logical-axis tree that
  ``repro.distributed.sharding`` maps onto the production mesh.

Logical axis names used across the zoo:
  "embed"   model width (FSDP-sharded on ("pod","data") for params)
  "heads"   attention heads / head-major fused dims (tensor-sharded)
  "kv"      KV heads
  "mlp"     FFN hidden (tensor-sharded)
  "vocab"   vocabulary (tensor-sharded)
  "expert"  MoE expert count (expert-parallel over "model")
  "layers"  stacked scan-over-layers dim (never sharded)
  None      replicated dimension
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: Initializer
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


# --- initializers -----------------------------------------------------------


def zeros(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype):
    return jnp.ones(shape, dtype)


def normal(stddev: float = 0.02) -> Initializer:
    def f(key, shape, dtype):
        return stddev * jax.random.normal(key, shape, dtype)
    return f


def fan_in(scale: float = 1.0, fan_axes: Optional[Tuple[int, ...]] = None) -> Initializer:
    """LeCun/He-style variance scaling on the input fan."""
    def f(key, shape, dtype):
        if fan_axes is None:
            fan = int(np.prod(shape[:-1]))
        else:
            fan = int(np.prod([shape[a] for a in fan_axes]))
        std = (scale / max(fan, 1)) ** 0.5
        return std * jax.random.normal(key, shape, dtype)
    return f


def uniform_scale(scale: float) -> Initializer:
    def f(key, shape, dtype):
        return jax.random.uniform(key, shape, dtype, -scale, scale)
    return f


# --- tree derivations -------------------------------------------------------


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs, key: jax.Array):
    """Materialize real parameters; keys split deterministically by path."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [d.init(k, d.shape, d.dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(defs):
    """ShapeDtypeStructs only — used by the multi-pod dry-run."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=_is_def)


def logical_axes(defs):
    """Same-structure tree of logical-axis tuples."""
    return jax.tree_util.tree_map(lambda d: d.axes, defs, is_leaf=_is_def)


def param_count(defs) -> int:
    return sum(int(np.prod(d.shape))
               for d in jax.tree_util.tree_leaves(defs, is_leaf=_is_def))


def stack_layer_defs(defs, num_layers: int):
    """Add a leading 'layers' dim to every def (scan-over-layers stacking)."""
    def add(d: ParamDef) -> ParamDef:
        return ParamDef((num_layers,) + d.shape, ("layers",) + d.axes,
                        _stacked_init(d.init, num_layers), d.dtype)
    return jax.tree_util.tree_map(add, defs, is_leaf=_is_def)


def _stacked_init(init: Initializer, num_layers: int) -> Initializer:
    def f(key, shape, dtype):
        keys = jax.random.split(key, num_layers)
        return jax.vmap(lambda k: init(k, shape[1:], dtype))(keys)
    return f
