"""The paper's native setting: a video diffusion transformer (vDiT).

3-D (t, x, y) latent token grid, factorized RoPE whose channel groups
carry temporal / x / y information (paper §3.1 — HunyuanVideo splits the
128-dim head into 16/56/56), text tokens joined to the sequence for
joint self-attention (MMDiT-lite), adaLN conditioning on the timestep.

TimeRipple runs in full 3-D mode here: Δ checks along all three axes,
Eq. 4 threshold schedule over denoising steps, text tokens excluded from
snapping via ``grid_slice``.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig, VDiTConfig
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.utils.loops import scan_layers
from repro.models.attention import attention_defs, mha_attention
from repro.models.common import (layernorm, linear, linear_defs, mlp,
                                 mlp_defs, rope_3d_angles,
                                 sincos_timestep_embed)
from repro.models.params import ParamDef, fan_in, normal, zeros, stack_layer_defs

_RIPPLE_OFF = RippleConfig()


def _block_defs(cfg: VDiTConfig):
    d = cfg.d_model
    hd = d // cfg.num_heads
    return {
        "attn": attention_defs(d, cfg.num_heads, cfg.num_heads, hd,
                               qk_norm=True),
        "mlp": mlp_defs(d, int(d * cfg.mlp_ratio), gated=True),
        "ada": {"w": ParamDef((d, 6 * d), ("embed", None), zeros),
                "b": ParamDef((6 * d,), (None,), zeros)},
    }


def vdit_defs(cfg: VDiTConfig):
    d = cfg.d_model
    p = cfg.patch
    tp = cfg.t_patch
    in_dim = tp * p * p * cfg.in_channels
    return {
        "patch": {"w": ParamDef((in_dim, d), (None, "embed"), fan_in()),
                  "b": ParamDef((d,), ("embed",), zeros)},
        "txt_proj": linear_defs(cfg.txt_dim, d, axes=(None, "embed")),
        "t_mlp1": linear_defs(256, d, axes=("embed", "mlp")),
        "t_mlp2": linear_defs(d, d, axes=("mlp", "embed")),
        "blocks": stack_layer_defs(_block_defs(cfg), cfg.num_layers),
        "final_ada": {"w": ParamDef((d, 2 * d), ("embed", None), zeros),
                      "b": ParamDef((2 * d,), (None,), zeros)},
        "final": linear_defs(d, in_dim, axes=("embed", None), init=zeros),
    }


def patchify_3d(x, t_patch, patch):
    """(B, T, H, W, C) -> (B, T/tp * H/p * W/p, tp*p*p*C), (t,y,x) order."""
    B, T, H, W, C = x.shape
    tp, p = t_patch, patch
    x = x.reshape(B, T // tp, tp, H // p, p, W // p, p, C)
    x = x.transpose(0, 1, 3, 5, 2, 4, 6, 7)
    return x.reshape(B, (T // tp) * (H // p) * (W // p), tp * p * p * C)


def unpatchify_3d(x, t_patch, patch, tg, hg, wg, out_ch):
    B = x.shape[0]
    tp, p = t_patch, patch
    x = x.reshape(B, tg, hg, wg, tp, p, p, out_ch)
    x = x.transpose(0, 1, 4, 2, 5, 3, 6, 7)
    return x.reshape(B, tg * tp, hg * p, wg * p, out_ch)


def vdit_apply(
    params: Dict,
    latents: jax.Array,    # (B, T_lat, H_lat, W_lat, C)
    t: jax.Array,          # (B,) diffusion time
    txt: jax.Array,        # (B, L_txt, txt_dim) — precomputed text embeds
    cfg: VDiTConfig,
    *,
    ripple: RippleConfig = _RIPPLE_OFF,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    ctx: ShardCtx = NULL_CTX,
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
    decision_state=None,
) -> jax.Array:
    """Apply the vDiT.  ``decision_state`` (optional) is the per-layer
    cross-step decision-cache state (DESIGN.md §13): a stacked
    :class:`~repro.core.decision_cache.CachedDecision` whose leading dim
    is ``num_layers`` (``launch.workloads.vdit_decision_state`` builds
    it).  Each layer's slice rides the scan-over-layers as a per-layer
    input and the updated slices are restacked, so the sampler can carry
    the whole thing through its denoising scan; the function then
    returns ``(out, new_decision_state)``."""
    dt = compute_dtype
    B, T, H, W, C = latents.shape
    tg, hg, wg = T // cfg.t_patch, H // cfg.patch, W // cfg.patch
    grid = (tg, hg, wg)
    n_img = tg * hg * wg
    L_txt = txt.shape[1]

    img = patchify_3d(latents.astype(dt), cfg.t_patch, cfg.patch)
    img = jnp.einsum("bnd,df->bnf", img, params["patch"]["w"].astype(dt)) \
        + params["patch"]["b"].astype(dt)
    txt_tok = linear(params["txt_proj"], txt.astype(dt))
    x = jnp.concatenate([txt_tok, img], axis=1)  # text first, then grid
    x = ctx.c(x, ("batch", "seq", "embed"))

    temb = sincos_timestep_embed(t, 256).astype(dt)
    c = jax.nn.silu(linear(params["t_mlp2"],
                           jax.nn.silu(linear(params["t_mlp1"], temb))))

    hd = cfg.d_model // cfg.num_heads
    # Factorized 3-D RoPE; text tokens sit at grid origin with a pure
    # temporal index beyond the video range so they never alias a frame.
    cos_g, sin_g = rope_3d_angles(grid, cfg.axes_dim)
    txt_pos = tg + jnp.arange(L_txt)
    ang_t = txt_pos[:, None].astype(jnp.float32) * \
        (1.0 / (10000.0 ** (jnp.arange(cfg.axes_dim[0] // 2, dtype=jnp.float32)
                            / (cfg.axes_dim[0] // 2))))
    ang_rest = jnp.zeros((L_txt, (cfg.axes_dim[1] + cfg.axes_dim[2]) // 2))
    cos_t = jnp.cos(jnp.concatenate([ang_t, ang_rest], axis=-1))
    sin_t = jnp.sin(jnp.concatenate([ang_t, ang_rest], axis=-1))
    rope_cos = jnp.concatenate([cos_t, cos_g], axis=0)
    rope_sin = jnp.concatenate([sin_t, sin_g], axis=0)

    def block(x, bp, dcache):
        ada = linear(bp["ada"], c)
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(ada, 6, axis=-1)
        h_ = layernorm({}, x) * (1 + sc1[:, None]) + sh1[:, None]
        attn = mha_attention(
            bp["attn"], h_, n_heads=cfg.num_heads, head_dim=hd, grid=grid,
            ripple=ripple, step=step, total_steps=total_steps,
            rope_cos=rope_cos, rope_sin=rope_sin,
            grid_slice=(L_txt, n_img), cached_decision=dcache,
            return_decision=dcache is not None, ctx=ctx)
        if dcache is not None:
            attn, dcache = attn
        x = x + g1[:, None] * attn
        h_ = layernorm({}, x) * (1 + sc2[:, None]) + sh2[:, None]
        x = x + g2[:, None] * mlp(bp["mlp"], h_)
        return ctx.c(x, ("batch", "seq", "embed")), dcache

    if decision_state is None:
        def body(x, bp):
            return block(x, bp, None)
        xs = params["blocks"]
    else:
        def body(x, layer_in):
            return block(x, layer_in[0], layer_in[1])
        xs = (params["blocks"], decision_state)

    if remat:
        body = jax.checkpoint(body)
    x, new_state = scan_layers(body, x, xs)

    sh, sc = jnp.split(linear(params["final_ada"], c), 2, axis=-1)
    x = layernorm({}, x[:, L_txt:]) * (1 + sc[:, None]) + sh[:, None]
    x = linear(params["final"], x)
    out = unpatchify_3d(x, cfg.t_patch, cfg.patch, tg, hg, wg, C)
    if decision_state is not None:
        return out, new_state
    return out
