"""ViT (arXiv:2010.11929) — assigned ``vit-l16``.

Standard pre-norm encoder with a CLS token.  TimeRipple is available as
a beyond-paper extension in 2-D mode (single forward pass ⇒ fixed
threshold, no Eq. 4 schedule); off by default — DESIGN.md §6.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig, ViTConfig
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.utils.loops import scan_layers
from repro.models.attention import attention_defs, mha_attention
from repro.models.common import (layernorm, layernorm_defs, linear,
                                 linear_defs, mlp, mlp_defs, patch_embed,
                                 patch_embed_defs, sincos_pos_embed_2d)
from repro.models.params import ParamDef, normal, stack_layer_defs

_RIPPLE_OFF = RippleConfig()


def _block_defs(cfg: ViTConfig):
    d = cfg.d_model
    return {
        "ln1": layernorm_defs(d),
        "attn": attention_defs(d, cfg.num_heads, cfg.num_heads,
                               d // cfg.num_heads, bias=False),
        "ln2": layernorm_defs(d),
        "mlp": mlp_defs(d, cfg.d_ff, gated=False, bias=True),
    }


def vit_defs(cfg: ViTConfig):
    return {
        "patch": patch_embed_defs(cfg.patch, cfg.in_channels, cfg.d_model),
        "cls": ParamDef((1, 1, cfg.d_model), (None, None, "embed"),
                        normal(0.02)),
        "blocks": stack_layer_defs(_block_defs(cfg), cfg.num_layers),
        "ln_f": layernorm_defs(cfg.d_model),
        "head": linear_defs(cfg.d_model, cfg.num_classes,
                            axes=("embed", "vocab")),
    }


def vit_apply(
    params: Dict,
    images: jax.Array,   # (B, H, W, 3)
    cfg: ViTConfig,
    *,
    ripple: RippleConfig = _RIPPLE_OFF,
    ctx: ShardCtx = NULL_CTX,
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
) -> jax.Array:
    dt = compute_dtype
    B, H, W, _ = images.shape
    h, w = H // cfg.patch, W // cfg.patch
    x = patch_embed(params["patch"], images.astype(dt), cfg.patch)
    pos = sincos_pos_embed_2d(h, w, cfg.d_model).astype(dt)
    x = x + pos[None]
    cls = jnp.broadcast_to(params["cls"].astype(dt), (B, 1, cfg.d_model))
    x = ctx.c(jnp.concatenate([cls, x], axis=1), ("batch", "seq", "embed"))
    hd = cfg.d_model // cfg.num_heads

    def body(x, bp):
        a = mha_attention(
            bp["attn"], layernorm(bp["ln1"], x), n_heads=cfg.num_heads,
            head_dim=hd, grid=(1, h, w), ripple=ripple,
            step=jnp.zeros(()), total_steps=2, grid_slice=(1, h * w), ctx=ctx)
        x = x + a
        x = x + mlp(bp["mlp"], layernorm(bp["ln2"], x), act=jax.nn.gelu)
        return ctx.c(x, ("batch", "seq", "embed")), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = scan_layers(body, x, params["blocks"])
    x = layernorm(params["ln_f"], x)
    feat = x[:, 0] if cfg.pool == "cls" else jnp.mean(x[:, 1:], axis=1)
    return linear(params["head"], feat)
