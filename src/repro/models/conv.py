"""Convolution substrate for UNet / EfficientNet (NHWC throughout)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, fan_in, ones, zeros


def conv_defs(k: int, c_in: int, c_out: int, bias: bool = True,
              depthwise: bool = False):
    if depthwise:
        w = ParamDef((k, k, 1, c_in), (None, None, None, "heads"),
                     fan_in(fan_axes=(0, 1)))
    else:
        w = ParamDef((k, k, c_in, c_out), (None, None, None, "heads"),
                     fan_in(fan_axes=(0, 1, 2)))
    defs = {"w": w}
    if bias:
        defs["b"] = ParamDef((c_out,), ("heads",), zeros)
    return defs


def conv2d(params, x, stride: int = 1, padding="SAME", depthwise: bool = False):
    w = params["w"].astype(x.dtype)
    groups = x.shape[-1] if depthwise else 1
    out = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if "b" in params:
        out = out + params["b"].astype(x.dtype)
    return out


def groupnorm_defs(c: int):
    return {"scale": ParamDef((c,), (None,), ones),
            "bias": ParamDef((c,), (None,), zeros)}


def groupnorm(params, x, groups: int = 32, eps: float = 1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    x32 = x.astype(jnp.float32).reshape(B, H, W, g, C // g)
    mu = jnp.mean(x32, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(x32, axis=(1, 2, 4), keepdims=True)
    out = ((x32 - mu) * jax.lax.rsqrt(var + eps)).reshape(B, H, W, C)
    return (out * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def batchnorm_defs(c: int):
    return {"scale": ParamDef((c,), (None,), ones),
            "bias": ParamDef((c,), (None,), zeros)}


def batchnorm(params, x, eps: float = 1e-3):
    """Batch-statistics normalization over (N, H, W).  At serving batch=1
    the spatial extent still provides the statistics (DESIGN.md notes the
    running-stats substitution)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x32, axis=(0, 1, 2), keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def avg_pool(x, window: int, stride: Optional[int] = None):
    stride = stride or window
    out = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")
    return (out / (window * window)).astype(x.dtype)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def upsample_nearest(x, factor: int = 2):
    B, H, W, C = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :],
                         (B, H, factor, W, factor, C))
    return x.reshape(B, H * factor, W * factor, C)
