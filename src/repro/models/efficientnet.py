"""EfficientNet (arXiv:1905.11946) — assigned ``efficientnet-b7``
(width_mult 2.0, depth_mult 3.1, img_res 600).

MBConv blocks with squeeze-excitation, swish activation, batch-statistics
normalization (running-stats substitution noted in DESIGN.md).  Attention-
free — TimeRipple is inapplicable (DESIGN.md §6); built without it.
"""

from __future__ import annotations

import math
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.config.base import EffNetConfig
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.common import linear, linear_defs
from repro.models.conv import (batchnorm, batchnorm_defs, conv2d, conv_defs,
                               global_avg_pool)

# (expand_ratio, channels, layers, stride, kernel) — EfficientNet-B0 base
_B0_STAGES = [
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
]


def round_filters(c: int, width: float, divisor: int = 8) -> int:
    c = c * width
    new_c = max(divisor, int(c + divisor / 2) // divisor * divisor)
    if new_c < 0.9 * c:
        new_c += divisor
    return int(new_c)


def round_repeats(r: int, depth: float) -> int:
    return int(math.ceil(depth * r))


def _mbconv_defs(c_in: int, c_out: int, expand: int, kernel: int):
    c_mid = c_in * expand
    c_se = max(1, c_in // 4)
    defs: Dict = {}
    if expand != 1:
        defs["expand"] = conv_defs(1, c_in, c_mid, bias=False)
        defs["bn0"] = batchnorm_defs(c_mid)
    defs["dw"] = conv_defs(kernel, c_mid, c_mid, bias=False, depthwise=True)
    defs["bn1"] = batchnorm_defs(c_mid)
    defs["se_reduce"] = conv_defs(1, c_mid, c_se)
    defs["se_expand"] = conv_defs(1, c_se, c_mid)
    defs["project"] = conv_defs(1, c_mid, c_out, bias=False)
    defs["bn2"] = batchnorm_defs(c_out)
    return defs


def _mbconv(params, x, stride: int, expand: int):
    h = x
    if "expand" in params:
        h = jax.nn.silu(batchnorm(params["bn0"], conv2d(params["expand"], h)))
    h = conv2d(params["dw"], h, stride=stride, depthwise=True)
    h = jax.nn.silu(batchnorm(params["bn1"], h))
    # squeeze-excitation
    se = jnp.mean(h, axis=(1, 2), keepdims=True)
    se = jax.nn.silu(conv2d(params["se_reduce"], se))
    se = jax.nn.sigmoid(conv2d(params["se_expand"], se))
    h = h * se
    h = batchnorm(params["bn2"], conv2d(params["project"], h))
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h


def effnet_stages(cfg: EffNetConfig):
    """Resolved (expand, c_in, c_out, repeats, stride, kernel) list."""
    stages = []
    c_prev = round_filters(32, cfg.width_mult)
    for expand, c, r, s, k in _B0_STAGES:
        c_out = round_filters(c, cfg.width_mult)
        stages.append((expand, c_prev, c_out, round_repeats(r, cfg.depth_mult),
                       s, k))
        c_prev = c_out
    return stages


def effnet_defs(cfg: EffNetConfig):
    stem_c = round_filters(32, cfg.width_mult)
    head_c = round_filters(1280, cfg.width_mult)
    defs: Dict = {
        "stem": conv_defs(3, cfg.in_channels, stem_c, bias=False),
        "stem_bn": batchnorm_defs(stem_c),
        "stages": [],
    }
    for expand, c_in, c_out, repeats, stride, kernel in effnet_stages(cfg):
        blocks = []
        for i in range(repeats):
            blocks.append(_mbconv_defs(c_in if i == 0 else c_out, c_out,
                                       expand, kernel))
        defs["stages"].append(blocks)
    last_c = effnet_stages(cfg)[-1][2]
    defs["head"] = conv_defs(1, last_c, head_c, bias=False)
    defs["head_bn"] = batchnorm_defs(head_c)
    defs["classifier"] = linear_defs(head_c, cfg.num_classes,
                                     axes=(None, "vocab"))
    return defs


def effnet_apply(
    params: Dict,
    images: jax.Array,   # (B, H, W, 3)
    cfg: EffNetConfig,
    *,
    ctx: ShardCtx = NULL_CTX,
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
) -> jax.Array:
    dt = compute_dtype
    x = ctx.c(images.astype(dt), ("batch", "seq", None, None))
    x = jax.nn.silu(batchnorm(params["stem_bn"],
                              conv2d(params["stem"], x, stride=2)))
    stage_cfg = effnet_stages(cfg)
    for (expand, _, _, repeats, stride, kernel), blocks in zip(
            stage_cfg, params["stages"]):
        for i, bp in enumerate(blocks):
            fn = _mbconv
            if remat:
                fn = jax.checkpoint(_mbconv, static_argnums=(2, 3))
            x = fn(bp, x, stride if i == 0 else 1, expand)
        x = ctx.c(x, ("batch", "seq", None, None))
    x = jax.nn.silu(batchnorm(params["head_bn"], conv2d(params["head"], x)))
    feat = global_avg_pool(x)
    return linear(params["classifier"], feat)
