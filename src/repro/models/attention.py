"""Attention layers.

Two entry points:

* :func:`gqa_attention` — decoder-side attention for the LM family:
  grouped-query heads, optional qk-norm, causal / sliding-window masks,
  KV-cache prefill and decode.
* :func:`mha_attention` — bidirectional attention for the diffusion /
  vision families, routed through the unified dispatch layer
  (``core.dispatch``, DESIGN.md §8): when a :class:`RippleConfig` is
  active the post-RoPE Q/K go through the reuse pipeline (snap →
  collapse/kernel) and the dispatcher picks the execution backend;
  otherwise it runs the plain dense path.

All activations flow through :class:`ShardCtx` constraints so the same
code serves 1 CPU device and the 512-chip production mesh.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig
from repro.core.dispatch import attention_dispatch
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.common import rmsnorm, rmsnorm_defs
from repro.models.params import ParamDef, fan_in
from repro.utils.loops import in_cost_probe, map_chunks

_NEG = -2.3819763e38  # matches XLA's mask constant; safely below any logit


def attention_defs(d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool = False, bias: bool = False):
    defs = {
        "wq": ParamDef((d_model, n_heads * head_dim), ("embed", "heads"), fan_in()),
        "wk": ParamDef((d_model, n_kv * head_dim), ("embed", "kv"), fan_in()),
        "wv": ParamDef((d_model, n_kv * head_dim), ("embed", "kv"), fan_in()),
        "wo": ParamDef((n_heads * head_dim, d_model), ("heads", "embed"), fan_in()),
    }
    if qk_norm:
        defs["q_norm"] = rmsnorm_defs(head_dim)
        defs["k_norm"] = rmsnorm_defs(head_dim)
    return defs


def _project(params, x, n_heads, n_kv, head_dim, ctx: ShardCtx):
    dt = x.dtype
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dt))
    q = ctx.c(q.reshape(B, S, n_heads, head_dim),
              ("batch", "attn_seq", "heads", None))
    k = ctx.c(k.reshape(B, S, n_kv, head_dim),
              ("batch", "attn_seq", "kv", None))
    v = ctx.c(v.reshape(B, S, n_kv, head_dim),
              ("batch", "attn_seq", "kv", None))
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    return q, k, v


# Above this many logits per (batch·head) the core switches to the
# query-chunked (Rabe-Staats) path so 32k-token prefill never
# materializes an (S, S) map.
_CHUNK_LOGIT_BUDGET = 4096 * 8192
_Q_CHUNK = 1024


def _gqa_core_dense(q, k, v, mask, ctx: ShardCtx = NULL_CTX):
    """Flat-head GQA: K/V are repeated to Hq at compute time so the head
    dim shards cleanly over 'model' even when Hkv doesn't divide it
    (e.g. 8 kv heads on a 16-way model axis).  The repeat is a transient
    bf16 view; caches stay at Hkv."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        k = ctx.c(k, ("batch", "kv_seq", "heads", None))
        v = ctx.c(v, ("batch", "kv_seq", "heads", None))
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    if mask is not None:
        logits = logits + mask  # (B|1, 1, S, Skv)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return out


def _gqa_core(q, k, v, mask, ctx: ShardCtx):
    """q: (B,S,Hq,hd); k,v: (B,Skv,Hkv,hd); mask additive (B|1,1,S,Skv)."""
    B, S, Hq, hd = q.shape
    Skv = k.shape[1]
    if S * Skv <= _CHUNK_LOGIT_BUDGET or S % _Q_CHUNK != 0 \
            or in_cost_probe():
        return _gqa_core_dense(q, k, v, mask, ctx)

    nchunks = S // _Q_CHUNK
    qc = q.reshape(B, nchunks, _Q_CHUNK, Hq, hd)
    if mask is not None:
        mb = jnp.broadcast_to(mask, (mask.shape[0], 1, S, Skv))
        mb = mb.reshape(mask.shape[0], 1, nchunks, _Q_CHUNK, Skv)

    def chunk(i):
        m_i = None if mask is None else mb[:, :, i]
        return _gqa_core_dense(qc[:, i], k, v, m_i, ctx)

    out = map_chunks(chunk, nchunks)  # (nchunks, B, qc, Hq, hd)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, Hq, hd)


def causal_mask(S_q: int, S_kv: int, q_offset, sliding_window=0):
    """Additive causal (+ optional sliding window) mask (1, 1, S_q, S_kv).

    ``q_offset`` is the absolute position of query row 0 (scalar or
    traced) — used at decode time where S_q == 1 and the cache holds
    S_kv past positions.  ``sliding_window`` may be a traced scalar
    (scan-over-layers with a local:global interleave); <= 0 disables it."""
    qi = jnp.arange(S_q)[:, None] + q_offset
    kj = jnp.arange(S_kv)[None, :]
    ok = kj <= qi
    window = jnp.asarray(sliding_window)
    win_ok = jnp.logical_or(window <= 0, kj > qi - window)
    ok = jnp.logical_and(ok, win_ok)
    return jnp.where(ok, 0.0, _NEG)[None, None].astype(jnp.float32)


def valid_mask(S_q: int, S_kv: int, kv_len):
    """Mask for decode against a partially-filled cache: keys ≥ kv_len
    are invalid. kv_len: scalar or (B,)."""
    kj = jnp.arange(S_kv)[None, :]
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = kv_len[None]
    ok = kj < kv_len[:, None]
    return jnp.where(ok, 0.0, _NEG)[:, None, None].astype(jnp.float32)


def gqa_attention(
    params: Dict,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    positions: jax.Array,
    rope_theta: float = 10000.0,
    sliding_window=0,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    ctx: ShardCtx = NULL_CTX,
):
    """LM attention. x: (B, S, d).

    Modes:
      * train/prefill: ``cache is None`` → causal self-attention; returns
        (out, (k, v)) so callers can keep the cache.
      * decode: ``cache=(k_cache, v_cache)`` of shape (B, S_max, Hkv, hd)
        and ``cache_index`` = current length; S must be 1.  Returns
        (out, updated_cache).
    """
    from repro.models.common import apply_rope_1d

    B, S, _ = x.shape
    q, k, v = _project(params, x, n_heads, n_kv, head_dim, ctx)
    q = apply_rope_1d(q, positions, rope_theta)
    k = apply_rope_1d(k, positions, rope_theta)

    if cache is None:
        mask = causal_mask(S, S, 0, sliding_window)
        out = _gqa_core(q, k, v, mask, ctx)
        new_cache = (k, v)
    else:
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_index, axis=1)
        k_cache = ctx.c(k_cache, ("batch", "kv_seq", "kv", None))
        v_cache = ctx.c(v_cache, ("batch", "kv_seq", "kv", None))
        S_kv = k_cache.shape[1]
        mask = valid_mask(S, S_kv, cache_index + S) \
            + causal_mask(S, S_kv, cache_index, sliding_window)
        out = _gqa_core(q, k_cache, v_cache, mask, ctx)
        new_cache = (k_cache, v_cache)

    out = ctx.c(out, ("batch", "attn_seq", "heads", None))
    B, S, Hq, hd = out.shape
    out = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, Hq * hd),
                     params["wo"].astype(x.dtype))
    return ctx.c(out, ("batch", "seq", "embed")), new_cache


def mha_attention(
    params: Dict,
    x: jax.Array,
    *,
    n_heads: int,
    head_dim: int,
    grid: Tuple[int, int, int],
    ripple: RippleConfig,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    rope_cos: Optional[jax.Array] = None,
    rope_sin: Optional[jax.Array] = None,
    grid_slice: Optional[Tuple[int, int]] = None,
    encoder_out: Optional[jax.Array] = None,
    backend: Optional[str] = None,
    cached_decision=None,
    return_decision: bool = False,
    ctx: ShardCtx = NULL_CTX,
):
    """Bidirectional MHA through the dispatch layer. x: (B, N, d).

    ``encoder_out`` switches to cross-attention (K/V from the encoder;
    ripple never applies — no grid on text tokens — so the dispatcher is
    forced onto its dense backend).  ``backend`` overrides
    ``ripple.backend`` for this call.  ``rope_cos/sin`` are precomputed
    factorized 3-D RoPE tables (``common.rope_3d_angles``); None means
    no RoPE (e.g. DiT's absolute sin-cos embeddings).

    ``cached_decision`` / ``return_decision`` thread the cross-step
    decision cache (DESIGN.md §13) through to ``attention_dispatch``;
    when either is set the layer returns ``(out, CachedDecision)`` so
    the model can carry per-layer decision state across denoising
    steps.  Self-attention only (cross-attention has no grid)."""
    from repro.models.common import apply_rope_precomputed

    dt = x.dtype
    B, N, _ = x.shape
    kv_src = encoder_out if encoder_out is not None else x
    Nk = kv_src.shape[1]
    q = jnp.einsum("bnd,dh->bnh", x, params["wq"].astype(dt))
    k = jnp.einsum("bnd,dh->bnh", kv_src, params["wk"].astype(dt))
    v = jnp.einsum("bnd,dh->bnh", kv_src, params["wv"].astype(dt))
    q = q.reshape(B, N, n_heads, head_dim)
    k = k.reshape(B, Nk, n_heads, head_dim)
    v = v.reshape(B, Nk, n_heads, head_dim)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if rope_cos is not None:
        q = apply_rope_precomputed(q, rope_cos, rope_sin)
        k = apply_rope_precomputed(k, rope_cos, rope_sin)
    # (B, H, N, hd) layout for the ripple/core path
    q = ctx.c(q.transpose(0, 2, 1, 3), ("batch", "heads", "attn_seq", None))
    k = ctx.c(k.transpose(0, 2, 1, 3), ("batch", "heads", None, None))
    v = ctx.c(v.transpose(0, 2, 1, 3), ("batch", "heads", None, None))

    # Cross-attention has no grid to snap: force the dense backend so
    # the dispatcher bypasses the reuse pipeline entirely.
    eff_backend = "dense" if encoder_out is not None else backend
    want_cache = cached_decision is not None or return_decision
    if want_cache and encoder_out is not None:
        raise ValueError("decision caching applies to grid self-attention "
                         "only, not cross-attention")
    new_cache = None
    if want_cache:
        out, new_cache = attention_dispatch(
            q, k, v, grid=grid, cfg=ripple, step=step,
            total_steps=total_steps, grid_slice=grid_slice,
            backend=eff_backend, cached_decision=cached_decision,
            return_decision=True)
    else:
        out = attention_dispatch(
            q, k, v, grid=grid, cfg=ripple, step=step,
            total_steps=total_steps, grid_slice=grid_slice,
            backend=eff_backend)

    out = out.transpose(0, 2, 1, 3).reshape(B, N, n_heads * head_dim)
    out = jnp.einsum("bnh,hd->bnd", out, params["wo"].astype(dt))
    out = ctx.c(out, ("batch", "seq", "embed"))
    if want_cache:
        return out, new_cache
    return out
