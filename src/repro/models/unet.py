"""SD1.5-style latent UNet (arXiv:2112.10752), assigned ``unet-sd15``.

4 levels (ch_mult 1-2-4-4), 2 res blocks per level, spatial transformer
blocks (self-attn + text cross-attn + geglu FF) at the attn_res
downsample factors, mid block with attention, skip connections.

TimeRipple applies to the *self*-attention of the transformer blocks in
2-D mode on each level's (h, w) grid; cross-attention (text K/V has no
grid) is never snapped — DESIGN.md §6.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig, UNetConfig
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.attention import attention_defs, mha_attention
from repro.models.common import linear, linear_defs, sincos_timestep_embed
from repro.models.conv import (conv2d, conv_defs, groupnorm, groupnorm_defs,
                               upsample_nearest)
from repro.models.params import ParamDef, fan_in, zeros

_RIPPLE_OFF = RippleConfig()


def _resblock_defs(c_in: int, c_out: int, t_dim: int):
    defs = {
        "norm1": groupnorm_defs(c_in),
        "conv1": conv_defs(3, c_in, c_out),
        "temb": linear_defs(t_dim, c_out, axes=(None, None)),
        "norm2": groupnorm_defs(c_out),
        "conv2": conv_defs(3, c_out, c_out),
    }
    if c_in != c_out:
        defs["skip"] = conv_defs(1, c_in, c_out)
    return defs


def _resblock(params, x, temb):
    h = conv2d(params["conv1"], jax.nn.silu(groupnorm(params["norm1"], x)))
    h = h + linear(params["temb"], jax.nn.silu(temb))[:, None, None, :]
    h = conv2d(params["conv2"], jax.nn.silu(groupnorm(params["norm2"], h)))
    skip = conv2d(params["skip"], x) if "skip" in params else x
    return skip + h


def _xformer_defs(c: int, n_heads: int, ctx_dim: int):
    return {
        "norm": groupnorm_defs(c),
        "proj_in": conv_defs(1, c, c),
        "ln1": {"scale": ParamDef((c,), (None,), lambda k, s, t: jnp.ones(s, t)),
                "bias": ParamDef((c,), (None,), zeros)},
        "self_attn": attention_defs(c, n_heads, n_heads, c // n_heads),
        "ln2": {"scale": ParamDef((c,), (None,), lambda k, s, t: jnp.ones(s, t)),
                "bias": ParamDef((c,), (None,), zeros)},
        "cross_q": ParamDef((c, c), ("embed", "heads"), fan_in()),
        "cross_k": ParamDef((ctx_dim, c), (None, "heads"), fan_in()),
        "cross_v": ParamDef((ctx_dim, c), (None, "heads"), fan_in()),
        "cross_o": ParamDef((c, c), ("heads", "embed"), fan_in()),
        "ln3": {"scale": ParamDef((c,), (None,), lambda k, s, t: jnp.ones(s, t)),
                "bias": ParamDef((c,), (None,), zeros)},
        "ff1": ParamDef((c, 8 * c), ("embed", "mlp"), fan_in()),  # geglu
        "ff2": ParamDef((4 * c, c), ("mlp", "embed"), fan_in()),
        "proj_out": conv_defs(1, c, c),
    }


def _layernorm_sb(p, x):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + 1e-5)
    return (out * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def _xformer(params, x, ctx_tokens, n_heads, ripple, step, total_steps, ctx):
    B, H, W, C = x.shape
    hd = C // n_heads
    h = conv2d(params["proj_in"], groupnorm(params["norm"], x))
    tok = h.reshape(B, H * W, C)
    # self-attention with the ripple hook on the (1, H, W) grid
    a = mha_attention(
        params["self_attn"], _layernorm_sb(params["ln1"], tok),
        n_heads=n_heads, head_dim=hd, grid=(1, H, W), ripple=ripple,
        step=step, total_steps=total_steps, ctx=ctx)
    tok = tok + a
    # cross-attention to text
    q = jnp.einsum("bnd,dh->bnh", _layernorm_sb(params["ln2"], tok),
                   params["cross_q"].astype(tok.dtype))
    k = jnp.einsum("bld,dh->blh", ctx_tokens, params["cross_k"].astype(tok.dtype))
    v = jnp.einsum("bld,dh->blh", ctx_tokens, params["cross_v"].astype(tok.dtype))
    q = q.reshape(B, -1, n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, -1, n_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, -1, n_heads, hd).transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / (hd ** 0.5)
    attn = jax.nn.softmax(logits, -1).astype(tok.dtype)
    o = jnp.einsum("bhqk,bhkd->bhqd", attn, v).transpose(0, 2, 1, 3)
    o = jnp.einsum("bnh,hd->bnd", o.reshape(B, -1, C),
                   params["cross_o"].astype(tok.dtype))
    tok = tok + o
    # geglu FF
    hff = jnp.einsum("bnd,df->bnf", _layernorm_sb(params["ln3"], tok),
                     params["ff1"].astype(tok.dtype))
    a_, b_ = jnp.split(hff, 2, axis=-1)
    hff = a_ * jax.nn.gelu(b_)
    tok = tok + jnp.einsum("bnf,fd->bnd", hff, params["ff2"].astype(tok.dtype))
    return x + conv2d(params["proj_out"], tok.reshape(B, H, W, C))


def unet_defs(cfg: UNetConfig):
    ch = cfg.ch
    t_dim = ch * 4
    chans = [ch * m for m in cfg.ch_mult]
    defs: Dict = {
        "t_mlp1": linear_defs(ch, t_dim, axes=(None, None)),
        "t_mlp2": linear_defs(t_dim, t_dim, axes=(None, None)),
        "conv_in": conv_defs(3, cfg.in_channels, ch),
        "down": [], "up": [],
    }
    c_cur = ch
    for lvl, c_out in enumerate(chans):
        level = {"res": [], "attn": []}
        for i in range(cfg.n_res_blocks):
            level["res"].append(_resblock_defs(c_cur, c_out, t_dim))
            c_cur = c_out
            if 2 ** lvl in cfg.attn_res:
                level["attn"].append(_xformer_defs(c_out, cfg.num_heads,
                                                   cfg.ctx_dim))
        if lvl < len(chans) - 1:
            level["down"] = conv_defs(3, c_out, c_out)
        defs["down"].append(level)
    defs["mid"] = {
        "res1": _resblock_defs(c_cur, c_cur, t_dim),
        "attn": _xformer_defs(c_cur, cfg.num_heads, cfg.ctx_dim),
        "res2": _resblock_defs(c_cur, c_cur, t_dim),
    }
    skip_chans = _skip_channels(cfg)
    for lvl in reversed(range(len(chans))):
        c_out = chans[lvl]
        level = {"res": [], "attn": []}
        for i in range(cfg.n_res_blocks + 1):
            c_skip = skip_chans.pop()
            level["res"].append(_resblock_defs(c_cur + c_skip, c_out, t_dim))
            c_cur = c_out
            if 2 ** lvl in cfg.attn_res:
                level["attn"].append(_xformer_defs(c_out, cfg.num_heads,
                                                   cfg.ctx_dim))
        if lvl > 0:
            level["up"] = conv_defs(3, c_out, c_out)
        defs["up"].append(level)
    defs["norm_out"] = groupnorm_defs(ch)
    defs["conv_out"] = conv_defs(3, ch, cfg.in_channels)
    return defs


def _skip_channels(cfg: UNetConfig) -> List[int]:
    ch = cfg.ch
    chans = [ch * m for m in cfg.ch_mult]
    skips = [ch]
    c_cur = ch
    for lvl, c_out in enumerate(chans):
        for _ in range(cfg.n_res_blocks):
            c_cur = c_out
            skips.append(c_cur)
        if lvl < len(chans) - 1:
            skips.append(c_cur)
    return skips


def unet_apply(
    params: Dict,
    latents: jax.Array,   # (B, H_lat, W_lat, C)
    t: jax.Array,         # (B,)
    ctx_tokens: jax.Array,  # (B, 77, ctx_dim)
    cfg: UNetConfig,
    *,
    ripple: RippleConfig = _RIPPLE_OFF,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    ctx: ShardCtx = NULL_CTX,
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
) -> jax.Array:
    dt = compute_dtype
    x = latents.astype(dt)
    ctx_tokens = ctx_tokens.astype(dt)
    temb = sincos_timestep_embed(t, cfg.ch).astype(dt)
    temb = linear(params["t_mlp2"],
                  jax.nn.silu(linear(params["t_mlp1"], temb)))

    resblock = jax.checkpoint(_resblock) if remat else _resblock

    def run_xformer(p, h):
        def fn(p_, h_):
            # non-array config args stay in the closure (checkpoint only
            # sees array inputs)
            return _xformer(p_, h_, ctx_tokens, cfg.num_heads, ripple,
                            step, total_steps, ctx)
        return jax.checkpoint(fn)(p, h) if remat else fn(p, h)

    h = conv2d(params["conv_in"], x)
    skips = [h]
    n_levels = len(cfg.ch_mult)
    for lvl, level in enumerate(params["down"]):
        for i, rp in enumerate(level["res"]):
            h = resblock(rp, h, temb)
            if level["attn"]:
                h = run_xformer(level["attn"][i], h)
            skips.append(h)
        if "down" in level:
            h = conv2d(level["down"], h, stride=2)
            skips.append(h)

    h = resblock(params["mid"]["res1"], h, temb)
    h = run_xformer(params["mid"]["attn"], h)
    h = resblock(params["mid"]["res2"], h, temb)

    for idx, level in enumerate(params["up"]):
        lvl = n_levels - 1 - idx
        for i, rp in enumerate(level["res"]):
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = resblock(rp, h, temb)
            if level["attn"]:
                h = run_xformer(level["attn"][i], h)
        if "up" in level:
            h = upsample_nearest(h, 2)
            h = conv2d(level["up"], h)

    h = jax.nn.silu(groupnorm(params["norm_out"], h))
    return conv2d(params["conv_out"], h)
