"""Decoder-only transformer LM family.

One implementation covers all four assigned LM archs via ``LMConfig``:

* qwen3-32b      — dense, GQA (64q/8kv), qk-norm
* gemma3-4b      — dense, GQA (8q/4kv), 5:1 local:global sliding window
* qwen2-moe      — 60 routed experts top-4 + 4 shared experts
* phi3.5-moe     — 16 routed experts top-2

Layers are stacked and executed with ``lax.scan`` so the HLO (and compile
time on the 512-device dry-run) is depth-independent; remat wraps the
block body for training.

TimeRipple does not apply to 1-D text tokens (DESIGN.md §6) — these
models are built without the technique. ``ripple.enable_1d`` routes Q/K
through the experimental sequence-window reuse for curiosity benchmarks
only.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import LMConfig, RippleConfig
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models import moe as moe_lib
from repro.models.attention import attention_defs, gqa_attention
from repro.models.common import mlp, mlp_defs, rmsnorm, rmsnorm_defs
from repro.models.params import (ParamDef, fan_in, normal, init_params,
                                 abstract_params, logical_axes,
                                 stack_layer_defs)
from repro.utils.loops import map_chunks, scan_layers


# --- parameter tree ----------------------------------------------------------


def _block_defs(cfg: LMConfig):
    hd = cfg.resolved_head_dim
    defs = {
        "attn_norm": rmsnorm_defs(cfg.d_model),
        "attn": attention_defs(cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                               hd, qk_norm=cfg.qk_norm),
        "mlp_norm": rmsnorm_defs(cfg.d_model),
    }
    if cfg.moe is not None:
        defs["moe"] = moe_lib.moe_defs(cfg.d_model, cfg.moe)
    else:
        defs["mlp"] = mlp_defs(cfg.d_model, cfg.d_ff, gated=True)
    return defs


def lm_defs(cfg: LMConfig):
    defs = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                          normal(0.02)),
        "blocks": stack_layer_defs(_block_defs(cfg), cfg.num_layers),
        "final_norm": rmsnorm_defs(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size),
                                   ("embed", "vocab"), normal(0.02))
    return defs


def layer_windows(cfg: LMConfig) -> np.ndarray:
    """Per-layer sliding window (0 = global). gemma3: every (N+1)-th
    layer is global, the rest local."""
    if cfg.sliding_window <= 0 or cfg.local_global_pattern <= 0:
        return np.zeros((cfg.num_layers,), np.int32)
    pat = cfg.local_global_pattern
    win = np.full((cfg.num_layers,), cfg.sliding_window, np.int32)
    win[pat::pat + 1] = 0  # every (pat+1)-th layer global
    return win


# --- forward -----------------------------------------------------------------


def _block(cfg: LMConfig, ctx: ShardCtx, x, bp, window, positions,
           cache=None, cache_index=None):
    hd = cfg.resolved_head_dim
    h = rmsnorm(bp["attn_norm"], x)
    attn_out, new_cache = gqa_attention(
        bp["attn"], h, n_heads=cfg.num_heads, n_kv=cfg.num_kv_heads,
        head_dim=hd, positions=positions, rope_theta=cfg.rope_theta,
        sliding_window=window, cache=cache, cache_index=cache_index, ctx=ctx)
    x = x + attn_out
    h = rmsnorm(bp["mlp_norm"], x)
    if cfg.moe is not None:
        ffn_out, aux = moe_lib.moe_ffn(bp["moe"], h, cfg.moe, ctx=ctx)
    else:
        ffn_out, aux = mlp(bp["mlp"], h), jnp.zeros((), jnp.float32)
    x = ctx.c(x + ffn_out, ("batch", "seq", "embed"))
    return x, new_cache, aux


def lm_apply(
    params: Dict,
    tokens: jax.Array,
    cfg: LMConfig,
    *,
    ctx: ShardCtx = NULL_CTX,
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    logits_mode: str = "all",  # 'all' | 'last' | 'none'
    remat_policy: str = "full",
):
    """Forward pass. tokens: (B, S) int32.

    With ``cache=(k, v)`` of shape (L, B, S_max, Hkv, hd) this is a
    decode/continuation step writing at ``cache_index``.
    Returns (logits-or-hidden, new_cache, aux_loss).
    """
    B, S = tokens.shape
    x = params["embed"].astype(compute_dtype)[tokens]
    x = ctx.c(x, ("batch", "seq", "embed"))
    windows = jnp.asarray(layer_windows(cfg))
    if cache is None:
        positions = jnp.arange(S)[None, :]
    else:
        positions = cache_index + jnp.arange(S)[None, :]

    def body(carry, layer_in):
        x = carry
        if cache is None:
            bp, window = layer_in
            x, _, aux = _block(cfg, ctx, x, bp, window, positions)
            return x, aux
        bp, window, (kc, vc) = layer_in
        x, new_c, aux = _block(cfg, ctx, x, bp, window, positions,
                               cache=(kc, vc), cache_index=cache_index)
        return x, (aux, new_c)

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)

    if cache is None:
        x, auxs = scan_layers(body, x, (params["blocks"], windows))
        new_cache = None
        aux = jnp.sum(auxs)
    else:
        x, (auxs, new_cache) = scan_layers(
            body, x, (params["blocks"], windows, cache))
        aux = jnp.sum(auxs)

    x = rmsnorm(params["final_norm"], x)
    if logits_mode == "none":
        return x, new_cache, aux
    if logits_mode == "last":
        x = x[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = ctx.c(logits, ("batch", "seq", "vocab"))
    return logits, new_cache, aux


# Sequence-chunked cross entropy: above this many (token x vocab) cells
# the logits never materialize for the whole sequence at once; each chunk
# is rematerialized in the backward pass.
_CE_CELL_BUDGET = 2048 * 65536
_CE_CHUNK = 512


def _ce(logits, targets):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def lm_loss(params, tokens, targets, cfg: LMConfig, *, ctx=NULL_CTX,
            compute_dtype=jnp.bfloat16, remat: bool = True,
            remat_policy: str = "full"):
    """Next-token cross entropy. tokens/targets: (B, S)."""
    B, S = tokens.shape
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(compute_dtype)

    if S * cfg.vocab_size <= _CE_CELL_BUDGET or S % _CE_CHUNK != 0:
        logits, _, aux = lm_apply(params, tokens, cfg, ctx=ctx,
                                  compute_dtype=compute_dtype, remat=remat,
                                  remat_policy=remat_policy)
        nll = _ce(logits, targets) / (B * S)
        return nll + aux, {"nll": nll, "aux": aux}

    hidden, _, aux = lm_apply(params, tokens, cfg, ctx=ctx,
                              compute_dtype=compute_dtype, remat=remat,
                              logits_mode="none", remat_policy=remat_policy)

    @jax.checkpoint
    def chunk_ce(h_c, t_c):
        logits = jnp.einsum("bsd,dv->bsv", h_c, head)
        logits = ctx.c(logits, ("batch", "seq", "vocab"))
        return _ce(logits, t_c)

    n = S // _CE_CHUNK
    h = hidden.reshape(B, n, _CE_CHUNK, -1)
    t = targets.reshape(B, n, _CE_CHUNK)
    total = map_chunks(lambda i: chunk_ce(h[:, i], t[:, i]), n)
    nll = jnp.sum(total) / (B * S)
    return nll + aux, {"nll": nll, "aux": aux}


# --- KV cache / serving ------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def abstract_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
    sd = jax.ShapeDtypeStruct(shape, dtype)
    return (sd, sd)


def cache_logical_axes():
    ax = ("layers", "batch", "kv_seq", "kv", None)
    return (ax, ax)


def lm_prefill(params, tokens, cfg: LMConfig, max_len: int, *, ctx=NULL_CTX,
               compute_dtype=jnp.bfloat16):
    """Prefill: run the prompt, return (last_logits, cache at len S)."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len, compute_dtype)
    # Constrain the fresh cache like the rules dictate before the scan.
    cache = tuple(ctx.c(c, ("layers", "batch", "kv_seq", "kv", None))
                  for c in cache)
    logits, new_cache, _ = lm_apply(
        params, tokens, cfg, ctx=ctx, compute_dtype=compute_dtype,
        cache=cache, cache_index=jnp.zeros((), jnp.int32),
        logits_mode="last")
    return logits, new_cache


def lm_decode_step(params, token, cache, cache_index, cfg: LMConfig, *,
                   ctx=NULL_CTX, compute_dtype=jnp.bfloat16):
    """One decode step. token: (B, 1); returns (logits (B,1,V), cache)."""
    logits, new_cache, _ = lm_apply(
        params, token, cfg, ctx=ctx, compute_dtype=compute_dtype,
        cache=cache, cache_index=cache_index, logits_mode="last")
    return logits, new_cache
