"""DiT — scalable image diffusion transformer (Peebles & Xie, arXiv:2212.09748).

Covers the assigned ``dit-xl2`` and ``dit-b2`` configs.  adaLN-zero
conditioning on (timestep, class label); fixed 2-D sin-cos position
embeddings; patchify via exact reshape+matmul.  TimeRipple applies in 2-D
mode (x/y axes; no temporal axis — DESIGN.md §6), driven by the sampler's
denoising step.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import DiTConfig, RippleConfig
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.utils.loops import scan_layers
from repro.models.attention import attention_defs, mha_attention
from repro.models.common import (layernorm, linear, linear_defs, mlp,
                                 mlp_defs, patch_embed, patch_embed_defs,
                                 sincos_pos_embed_2d, sincos_timestep_embed,
                                 unpatchify)
from repro.models.params import (ParamDef, fan_in, normal, zeros,
                                 stack_layer_defs)

_RIPPLE_OFF = RippleConfig()


def _block_defs(cfg: DiTConfig):
    d = cfg.d_model
    hd = d // cfg.num_heads
    return {
        "attn": attention_defs(d, cfg.num_heads, cfg.num_heads, hd),
        "mlp": mlp_defs(d, int(d * cfg.mlp_ratio), gated=False, bias=True),
        # adaLN-zero: c -> (shift, scale, gate) x (attn, mlp); zero-init.
        "ada": {"w": ParamDef((d, 6 * d), ("embed", None), zeros),
                "b": ParamDef((6 * d,), (None,), zeros)},
    }


def dit_defs(cfg: DiTConfig):
    d = cfg.d_model
    p = cfg.patch
    out_ch = cfg.in_channels * (2 if cfg.learn_sigma else 1)
    return {
        "patch": patch_embed_defs(p, cfg.in_channels, d),
        "t_mlp1": linear_defs(256, d, axes=("embed", "mlp")),
        "t_mlp2": linear_defs(d, d, axes=("mlp", "embed")),
        "label_embed": ParamDef((cfg.num_classes + 1, d), (None, "embed"),
                                normal(0.02)),  # +1 = CFG null class
        "blocks": stack_layer_defs(_block_defs(cfg), cfg.num_layers),
        "final_ada": {"w": ParamDef((d, 2 * d), ("embed", None), zeros),
                      "b": ParamDef((2 * d,), (None,), zeros)},
        "final": linear_defs(d, p * p * out_ch, axes=("embed", None),
                             init=zeros),
    }


def _conditioning(params, t, labels, cfg: DiTConfig, dt):
    temb = sincos_timestep_embed(t, 256).astype(dt)
    c = jax.nn.silu(linear(params["t_mlp1"], temb))
    c = linear(params["t_mlp2"], c)
    c = c + params["label_embed"].astype(dt)[labels]
    return jax.nn.silu(c)  # (B, d)


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None, :]) + shift[:, None, :]


def dit_apply(
    params: Dict,
    latents: jax.Array,   # (B, H_lat, W_lat, C)
    t: jax.Array,         # (B,)
    labels: jax.Array,    # (B,) int
    cfg: DiTConfig,
    *,
    ripple: RippleConfig = _RIPPLE_OFF,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    ctx: ShardCtx = NULL_CTX,
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
) -> jax.Array:
    """Predict noise (+ sigma if learn_sigma): (B, H_lat, W_lat, out_ch)."""
    dt = compute_dtype
    B, H, W, C = latents.shape
    p = cfg.patch
    h, w = H // p, W // p
    grid = (1, h, w)

    x = patch_embed(params["patch"], latents.astype(dt), p)
    pos = sincos_pos_embed_2d(h, w, cfg.d_model).astype(dt)
    x = ctx.c(x + pos[None], ("batch", "seq", "embed"))
    c = _conditioning(params, t, labels, cfg, dt)
    hd = cfg.d_model // cfg.num_heads

    def body(x, bp):
        ada = linear(bp["ada"], c)  # (B, 6d)
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(ada, 6, axis=-1)
        h_ = _modulate(layernorm({}, x), sh1, sc1)
        attn = mha_attention(
            bp["attn"], h_, n_heads=cfg.num_heads, head_dim=hd, grid=grid,
            ripple=ripple, step=step, total_steps=total_steps, ctx=ctx)
        x = x + g1[:, None, :] * attn
        h_ = _modulate(layernorm({}, x), sh2, sc2)
        x = x + g2[:, None, :] * mlp(bp["mlp"], h_, act=jax.nn.gelu)
        return ctx.c(x, ("batch", "seq", "embed")), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = scan_layers(body, x, params["blocks"])

    sh, sc = jnp.split(linear(params["final_ada"], c), 2, axis=-1)
    x = _modulate(layernorm({}, x), sh, sc)
    x = linear(params["final"], x)
    out_ch = cfg.in_channels * (2 if cfg.learn_sigma else 1)
    return unpatchify(x, p, h, w, out_ch)
