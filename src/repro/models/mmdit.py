"""Flux-style MMDiT: double-stream joint blocks + single-stream blocks.

Matches the assigned ``flux-dev`` topology: 19 double blocks (separate
img/txt streams, joint attention), 38 single blocks (fused stream),
d=3072, 24 heads, rectified-flow conditioning vector (timestep +
guidance + pooled text).  Factorized RoPE with axes_dim (16, 56, 56).

TimeRipple applies to the image-grid tokens inside joint attention
(2-D mode, x/y axes); text tokens are never snapped (DESIGN.md §6).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import MMDiTConfig, RippleConfig
from repro.core.dispatch import attention_dispatch
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.utils.loops import scan_layers
from repro.models.common import (layernorm, linear, linear_defs,
                                 rope_3d_angles, sincos_timestep_embed,
                                 apply_rope_precomputed)
from repro.models.params import (ParamDef, fan_in, normal, zeros,
                                 stack_layer_defs)

_RIPPLE_OFF = RippleConfig()


def _stream_defs(d: int, n_heads: int, mlp_ratio: float, qk_norm=True):
    hd = d // n_heads
    defs = {
        "mod": linear_defs(d, 6 * d, axes=("embed", None), init=zeros),
        "wqkv": ParamDef((d, 3 * d), ("embed", "heads"), fan_in()),
        "wo": ParamDef((d, d), ("heads", "embed"), fan_in()),
        "mlp_in": ParamDef((d, int(d * mlp_ratio)), ("embed", "mlp"), fan_in()),
        "mlp_in_b": ParamDef((int(d * mlp_ratio),), ("mlp",), zeros),
        "mlp_out": ParamDef((int(d * mlp_ratio), d), ("mlp", "embed"), fan_in()),
        "mlp_out_b": ParamDef((d,), ("embed",), zeros),
    }
    if qk_norm:
        defs["q_norm"] = {"scale": ParamDef((hd,), (None,), lambda k, s, t: jnp.ones(s, t))}
        defs["k_norm"] = {"scale": ParamDef((hd,), (None,), lambda k, s, t: jnp.ones(s, t))}
    return defs


def _single_defs(d: int, n_heads: int, mlp_ratio: float):
    hd = d // n_heads
    F = int(d * mlp_ratio)
    return {
        "mod": linear_defs(d, 3 * d, axes=("embed", None), init=zeros),
        "lin1": ParamDef((d, 3 * d + F), ("embed", "heads"), fan_in()),
        "lin1_b": ParamDef((3 * d + F,), ("heads",), zeros),
        "lin2": ParamDef((d + F, d), ("heads", "embed"), fan_in()),
        "lin2_b": ParamDef((d,), ("embed",), zeros),
        "q_norm": {"scale": ParamDef((hd,), (None,), lambda k, s, t: jnp.ones(s, t))},
        "k_norm": {"scale": ParamDef((hd,), (None,), lambda k, s, t: jnp.ones(s, t))},
    }


def mmdit_defs(cfg: MMDiTConfig):
    d = cfg.d_model
    p = cfg.patch
    return {
        "img_in": linear_defs(p * p * cfg.in_channels, d, axes=(None, "embed")),
        "txt_in": linear_defs(cfg.txt_dim, d, axes=(None, "embed")),
        "t_mlp1": linear_defs(256, d, axes=(None, "embed")),
        "t_mlp2": linear_defs(d, d, axes=("embed", "embed")),
        "vec_in": linear_defs(768, d, axes=(None, "embed")),
        "double": {
            "img": stack_layer_defs(
                _stream_defs(d, cfg.num_heads, cfg.mlp_ratio), cfg.n_double_blocks),
            "txt": stack_layer_defs(
                _stream_defs(d, cfg.num_heads, cfg.mlp_ratio), cfg.n_double_blocks),
        },
        "single": stack_layer_defs(
            _single_defs(d, cfg.num_heads, cfg.mlp_ratio), cfg.n_single_blocks),
        "final_mod": linear_defs(d, 2 * d, axes=("embed", None), init=zeros),
        "final": linear_defs(d, p * p * cfg.in_channels, axes=("embed", None),
                             init=zeros),
    }


def _rmsn(scale, x):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
            * scale["scale"].astype(jnp.float32)).astype(x.dtype)


def _qkv(bp, x, n_heads, hd):
    B, N, d = x.shape
    qkv = jnp.einsum("bnd,dh->bnh", x, bp["wqkv"].astype(x.dtype))
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = _rmsn(bp["q_norm"], q.reshape(B, N, n_heads, hd))
    k = _rmsn(bp["k_norm"], k.reshape(B, N, n_heads, hd))
    v = v.reshape(B, N, n_heads, hd)
    return q, k, v


def _joint_attention(q, k, v, rope_cos, rope_sin, grid, grid_slice, ripple,
                     step, total_steps, ctx):
    """q/k/v: (B, N, H, hd) already normed; returns (B, N, H*hd)."""
    q = apply_rope_precomputed(q, rope_cos, rope_sin)
    k = apply_rope_precomputed(k, rope_cos, rope_sin)
    qT = ctx.c(q.transpose(0, 2, 1, 3), ("batch", "heads", "attn_seq", None))
    kT = ctx.c(k.transpose(0, 2, 1, 3), ("batch", "heads", None, None))
    vT = ctx.c(v.transpose(0, 2, 1, 3), ("batch", "heads", None, None))
    out = attention_dispatch(qT, kT, vT, grid=grid, cfg=ripple, step=step,
                             total_steps=total_steps, grid_slice=grid_slice)
    B, H, N, hd = out.shape
    return out.transpose(0, 2, 1, 3).reshape(B, N, H * hd)


def mmdit_apply(
    params: Dict,
    latents: jax.Array,    # (B, H_lat, W_lat, C)
    t: jax.Array,          # (B,)
    txt: jax.Array,        # (B, L, txt_dim)
    vec: jax.Array,        # (B, 768) pooled conditioning
    cfg: MMDiTConfig,
    *,
    ripple: RippleConfig = _RIPPLE_OFF,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    ctx: ShardCtx = NULL_CTX,
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
) -> jax.Array:
    dt = compute_dtype
    B, Hl, Wl, C = latents.shape
    p = cfg.patch
    h, w = Hl // p, Wl // p
    grid = (1, h, w)
    L = txt.shape[1]
    n_img = h * w
    hd = cfg.d_model // cfg.num_heads

    img = latents.astype(dt).reshape(B, h, p, w, p, C).transpose(0, 1, 3, 2, 4, 5)
    img = img.reshape(B, n_img, p * p * C)
    img = linear(params["img_in"], img)
    txt_tok = linear(params["txt_in"], txt.astype(dt))

    temb = sincos_timestep_embed(t, 256).astype(dt)
    c = linear(params["t_mlp2"], jax.nn.silu(linear(params["t_mlp1"], temb)))
    c = jax.nn.silu(c + linear(params["vec_in"], vec.astype(dt)))

    cos_g, sin_g = rope_3d_angles(grid, cfg.axes_dim)
    ang_t = (1 + jnp.arange(L))[:, None].astype(jnp.float32) * (
        1.0 / (10000.0 ** (jnp.arange(cfg.axes_dim[0] // 2, dtype=jnp.float32)
                           / (cfg.axes_dim[0] // 2))))
    rest = jnp.zeros((L, (cfg.axes_dim[1] + cfg.axes_dim[2]) // 2))
    rope_cos = jnp.concatenate(
        [jnp.cos(jnp.concatenate([ang_t, rest], -1)), cos_g], axis=0)
    rope_sin = jnp.concatenate(
        [jnp.sin(jnp.concatenate([ang_t, rest], -1)), sin_g], axis=0)

    def mod6(bp, x_):
        m = linear(bp["mod"], jax.nn.silu(c))
        return jnp.split(m, 6, axis=-1)

    def stream_pre(bp, x_):
        sh, sc, g, sh2, sc2, g2 = mod6(bp, x_)
        h_ = layernorm({}, x_) * (1 + sc[:, None]) + sh[:, None]
        return h_, (g, sh2, sc2, g2)

    def stream_post(bp, x_, attn_out, mods):
        g, sh2, sc2, g2 = mods
        x_ = x_ + g[:, None] * jnp.einsum(
            "bnh,hd->bnd", attn_out, bp["wo"].astype(dt))
        h_ = layernorm({}, x_) * (1 + sc2[:, None]) + sh2[:, None]
        m = jax.nn.gelu(jnp.einsum("bnd,df->bnf", h_, bp["mlp_in"].astype(dt))
                        + bp["mlp_in_b"].astype(dt))
        m = jnp.einsum("bnf,fd->bnd", m, bp["mlp_out"].astype(dt)) \
            + bp["mlp_out_b"].astype(dt)
        return ctx.c(x_ + g2[:, None] * m, ("batch", "seq", "embed"))

    def double_body(carry, bp):
        txt_x, img_x = carry
        ti, im = bp["txt"], bp["img"]
        th, tmods = stream_pre(ti, txt_x)
        ih, imods = stream_pre(im, img_x)
        tq, tk, tv = _qkv(ti, th, cfg.num_heads, hd)
        iq, ik, iv = _qkv(im, ih, cfg.num_heads, hd)
        q = jnp.concatenate([tq, iq], axis=1)
        k = jnp.concatenate([tk, ik], axis=1)
        v = jnp.concatenate([tv, iv], axis=1)
        out = _joint_attention(q, k, v, rope_cos, rope_sin, grid, (L, n_img),
                               ripple, step, total_steps, ctx)
        txt_x = stream_post(ti, txt_x, out[:, :L], tmods)
        img_x = stream_post(im, img_x, out[:, L:], imods)
        return (txt_x, img_x), None

    def single_body(x_, bp):
        m = linear(bp["mod"], jax.nn.silu(c))
        sh, sc, g = jnp.split(m, 3, axis=-1)
        h_ = layernorm({}, x_) * (1 + sc[:, None]) + sh[:, None]
        F = int(cfg.d_model * cfg.mlp_ratio)
        fused = jnp.einsum("bnd,dh->bnh", h_, bp["lin1"].astype(dt)) \
            + bp["lin1_b"].astype(dt)
        qkv, mlp_h = fused[..., :3 * cfg.d_model], fused[..., 3 * cfg.d_model:]
        B_, N_ = h_.shape[:2]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _rmsn(bp["q_norm"], q.reshape(B_, N_, cfg.num_heads, hd))
        k = _rmsn(bp["k_norm"], k.reshape(B_, N_, cfg.num_heads, hd))
        v = v.reshape(B_, N_, cfg.num_heads, hd)
        attn = _joint_attention(q, k, v, rope_cos, rope_sin, grid, (L, n_img),
                                ripple, step, total_steps, ctx)
        both = jnp.concatenate([attn, jax.nn.gelu(mlp_h)], axis=-1)
        out = jnp.einsum("bnh,hd->bnd", both, bp["lin2"].astype(dt)) \
            + bp["lin2_b"].astype(dt)
        return ctx.c(x_ + g[:, None] * out, ("batch", "seq", "embed")), None

    if remat:
        double_body = jax.checkpoint(double_body)
        single_body = jax.checkpoint(single_body)

    (txt_x, img_x), _ = scan_layers(double_body, (txt_tok, img),
                                    params["double"])
    x = jnp.concatenate([txt_x, img_x], axis=1)
    x, _ = scan_layers(single_body, x, params["single"])
    img_x = x[:, L:]

    sh, sc = jnp.split(linear(params["final_mod"], jax.nn.silu(c)), 2, axis=-1)
    img_x = layernorm({}, img_x) * (1 + sc[:, None]) + sh[:, None]
    out = linear(params["final"], img_x)  # (B, n_img, p*p*C)
    out = out.reshape(B, h, w, p, p, C).transpose(0, 1, 3, 2, 4, 5)
    return out.reshape(B, Hl, Wl, C)
