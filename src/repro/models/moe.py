"""Mixture-of-Experts FFN (Qwen-MoE / Phi-3.5-MoE style).

Routed experts with top-k gating plus optional always-on shared experts.
Two execution paths:

* ``dense`` — every expert computed on every token, gate-weighted
  combine.  Exact (no capacity drops); used for small configs and as the
  oracle in tests.
* ``ep`` — expert parallelism over the ``model`` mesh axis via
  ``shard_map``.  Token activations entering the FFN are replicated
  across ``model`` (standard Megatron TP invariant), so each model rank
  selects the tokens routed to *its own* expert shard locally — dispatch
  needs **no all_to_all**; the combine is the same ``psum`` over
  ``model`` that TP FFN output already performs.  Expert weights are
  FSDP-sharded on their input dim and all-gathered on use (ZeRO-3).
  Tokens beyond the per-(rank, expert) capacity are dropped, exactly as
  GShard/Switch do at scale.

Router aux losses: load-balancing loss (Switch §2.2) and router z-loss.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import MoEConfig
from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.params import ParamDef, fan_in, normal


def moe_defs(d_model: int, cfg: MoEConfig):
    E = cfg.num_experts
    F = cfg.expert_ffw_dim
    defs = {
        "router": ParamDef((d_model, E), ("embed", None), normal(0.02)),
        "wi_gate": ParamDef((E, d_model, F), ("expert", "embed", None),
                            fan_in(fan_axes=(1,))),
        "wi_up": ParamDef((E, d_model, F), ("expert", "embed", None),
                          fan_in(fan_axes=(1,))),
        "wo": ParamDef((E, F, d_model), ("expert", None, "embed"),
                       fan_in(fan_axes=(1,))),
    }
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        defs["shared"] = {
            "wi_gate": ParamDef((d_model, Fs), ("embed", "mlp"), fan_in()),
            "wi_up": ParamDef((d_model, Fs), ("embed", "mlp"), fan_in()),
            "wo": ParamDef((Fs, d_model), ("mlp", "embed"), fan_in()),
            "gate": ParamDef((d_model, 1), ("embed", None), normal(0.02)),
        }
    return defs


def _expert_ffn(w_gate, w_up, w_out, x):
    """x: (E, C, d); expert-batched gated FFN."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def _router(params, x, cfg: MoEConfig):
    """Returns (topk_idx (N,k), topk_w (N,k), aux_loss scalar). x: (N, d)."""
    logits = jnp.einsum("nd,de->ne", x, params["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, cfg.top_k)
    topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)
    # Switch-style load-balance loss + z-loss
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_idx, E), axis=1), axis=0) / cfg.top_k
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = cfg.aux_loss_weight * (lb + 1e-3 * z)
    return topk_idx, topk_w, aux


def _dense_moe(params, x_flat, cfg: MoEConfig):
    """All-experts compute, gate-weighted combine. x_flat: (N, d)."""
    topk_idx, topk_w, aux = _router(params, x_flat, cfg)
    E = cfg.num_experts
    gates = jnp.sum(
        jax.nn.one_hot(topk_idx, E) * topk_w[..., None], axis=1)  # (N, E)
    dt = x_flat.dtype
    xe = jnp.broadcast_to(x_flat[None], (E, *x_flat.shape))
    ye = _expert_ffn(params["wi_gate"].astype(dt), params["wi_up"].astype(dt),
                     params["wo"].astype(dt), xe)  # (E, N, d)
    out = jnp.einsum("ne,end->nd", gates.astype(dt), ye)
    return out, aux


def _local_dispatch_ffn(params_local, x, topk_idx, topk_w, e_lo, E_loc, C, dt):
    """One model-rank's expert work: select tokens routed to experts in
    [e_lo, e_lo + E_loc), up to capacity C per expert, compute, and
    scatter back.  ``e_lo`` may be traced (from axis_index); ``E_loc``
    must be static.

    x: (N, d) local tokens (replicated over 'model'); params_local hold
    this rank's expert slab (E_loc, ...). Returns (N, d) partial output —
    zero for tokens this rank doesn't own — to be psum'd over 'model'.
    """
    N, d = x.shape
    k = topk_idx.shape[1]
    e_hi = e_lo + E_loc
    slot_e = topk_idx.reshape(-1)                      # (N·k,)
    slot_w = topk_w.reshape(-1)
    slot_tok = jnp.arange(N * k) // k
    mine = jnp.logical_and(slot_e >= e_lo, slot_e < e_hi)
    local_e = jnp.where(mine, slot_e - e_lo, E_loc)    # E_loc = trash bin
    # position of each slot within its expert queue (stable by slot order)
    onehot = jax.nn.one_hot(local_e, E_loc + 1, dtype=jnp.int32)  # (N·k, E_loc+1)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.sum(pos * onehot, axis=1)                # (N·k,)
    keep = jnp.logical_and(mine, pos < C)
    dest_e = jnp.where(keep, local_e, E_loc)
    dest_c = jnp.where(keep, pos, 0)
    buf = jnp.zeros((E_loc + 1, C, d), dt)
    buf = buf.at[dest_e, dest_c].add(jnp.where(keep[:, None], x[slot_tok], 0))
    y = _expert_ffn(params_local["wi_gate"].astype(dt),
                    params_local["wi_up"].astype(dt),
                    params_local["wo"].astype(dt), buf[:E_loc])
    y = jnp.concatenate([y, jnp.zeros((1, C, d), y.dtype)], axis=0)
    gathered = y[dest_e, dest_c]                       # (N·k, d)
    gathered = jnp.where(keep[:, None], gathered, 0) * slot_w[:, None].astype(dt)
    out = jnp.zeros((N, d), dt).at[slot_tok].add(gathered)
    return out


def moe_ffn(
    params: Dict,
    x: jax.Array,
    cfg: MoEConfig,
    ctx: ShardCtx = NULL_CTX,
    impl: str = "auto",
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    x_flat = x.reshape(B * S, d)
    use_ep = impl == "ep" or (
        impl == "auto" and ctx.mesh is not None and "model" in ctx.mesh.axis_names
        and cfg.num_experts % ctx.mesh.shape["model"] == 0)

    if not use_ep:
        out, aux = _dense_moe(params, x_flat, cfg)
    else:
        out, aux = _ep_moe(params, x_flat, cfg, ctx)

    if cfg.num_shared_experts:
        from repro.models.common import mlp
        sh = params["shared"]
        s_out = mlp({k: sh[k] for k in ("wi_gate", "wi_up", "wo")}, x_flat)
        s_gate = jax.nn.sigmoid(
            jnp.einsum("nd,dg->ng", x_flat, sh["gate"].astype(x.dtype)))
        out = out + s_out * s_gate
    return out.reshape(B, S, d), aux


def _ep_moe(params, x_flat, cfg: MoEConfig, ctx: ShardCtx):
    mesh = ctx.mesh
    model_n = mesh.shape["model"]
    E = cfg.num_experts
    E_loc = E // model_n
    N = x_flat.shape[0]
    bd = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_tok_shards = 1
    for a in bd:
        n_tok_shards *= mesh.shape[a]
    N_loc = N // n_tok_shards if N % n_tok_shards == 0 else N
    tok_spec = bd if N % n_tok_shards == 0 else ()
    C = max(int(N_loc * cfg.top_k * cfg.capacity_factor / E), 8)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    router_w = params["router"]
    expert_params = {k: params[k] for k in ("wi_gate", "wi_up", "wo")}

    def body(x_loc, router_w, wi_gate, wi_up, wo):
        # x_loc: (N_loc, d) — replicated over 'model'.
        idx = jax.lax.axis_index("model")
        e_lo = idx * E_loc
        topk_idx, topk_w, aux = _router({"router": router_w}, x_loc, cfg)
        partial = _local_dispatch_ffn(
            {"wi_gate": wi_gate, "wi_up": wi_up, "wo": wo},
            x_loc, topk_idx, topk_w, e_lo, E_loc, C, x_loc.dtype)
        out = jax.lax.psum(partial, "model")
        aux = jax.lax.pmean(aux, "model")
        if tok_spec:
            aux = jax.lax.pmean(aux, tok_spec)
        return out, aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(tok_spec or None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(tok_spec or None, None), P()),
    )(x_flat, router_w, expert_params["wi_gate"], expert_params["wi_up"],
      expert_params["wo"])
    return out, aux
