"""Shared layers: norms, MLPs, RoPE (1-D and factorized 3-D), patch embed,
sinusoidal embeddings.  Pure functions over param dicts."""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.params import ParamDef, fan_in, normal, ones, zeros


# --- norms ------------------------------------------------------------------


def rmsnorm_defs(d: int):
    return {"scale": ParamDef((d,), (None,), ones)}


def rmsnorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_defs(d: int, elementwise: bool = True):
    if not elementwise:
        return {}
    return {"scale": ParamDef((d,), (None,), ones),
            "bias": ParamDef((d,), (None,), zeros)}


def layernorm(params, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if params:
        out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# --- linear / mlp -----------------------------------------------------------


def linear_defs(d_in: int, d_out: int, axes=("embed", "mlp"), bias: bool = True,
                init=None, out_axis_bias=None):
    defs = {"w": ParamDef((d_in, d_out), axes, init or fan_in())}
    if bias:
        defs["b"] = ParamDef((d_out,), (out_axis_bias or axes[1],), zeros)
    return defs


def linear(params, x):
    out = jnp.einsum("...d,df->...f", x, params["w"].astype(x.dtype))
    if "b" in params:
        out = out + params["b"].astype(x.dtype)
    return out


def mlp_defs(d: int, d_ff: int, gated: bool = True, bias: bool = False):
    if gated:
        return {
            "wi_gate": ParamDef((d, d_ff), ("embed", "mlp"), fan_in()),
            "wi_up": ParamDef((d, d_ff), ("embed", "mlp"), fan_in()),
            "wo": ParamDef((d_ff, d), ("mlp", "embed"), fan_in()),
        }
    defs = {
        "wi": ParamDef((d, d_ff), ("embed", "mlp"), fan_in()),
        "wo": ParamDef((d_ff, d), ("mlp", "embed"), fan_in()),
    }
    if bias:
        defs["bi"] = ParamDef((d_ff,), ("mlp",), zeros)
        defs["bo"] = ParamDef((d,), ("embed",), zeros)
    return defs


def mlp(params, x, act=jax.nn.silu):
    dt = x.dtype
    if "wi_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"].astype(dt))
        u = jnp.einsum("...d,df->...f", x, params["wi_up"].astype(dt))
        h = act(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
        if "bi" in params:
            h = h + params["bi"].astype(dt)
        h = act(h)
    out = jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))
    if "bo" in params:
        out = out + params["bo"].astype(dt)
    return out


# --- rotary embeddings ------------------------------------------------------


def rope_freqs(dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope_1d(x: jax.Array, positions: jax.Array,
                  theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope_3d_angles(grid: Tuple[int, int, int], axes_dim: Sequence[int],
                   theta: float = 10000.0):
    """Factorized (t, x, y) RoPE angles for a token grid (paper §3.1).

    Channel groups carry distinct spatio-temporal roles: the first
    ``axes_dim[0]`` channels rotate with the frame index, the next with
    the x coordinate, the last with y.  Returns (cos, sin): (N, sum/2).
    """
    T, H, W = grid
    tt, yy, xx = jnp.meshgrid(jnp.arange(T), jnp.arange(H), jnp.arange(W),
                              indexing="ij")
    coords = [tt.reshape(-1), xx.reshape(-1), yy.reshape(-1)]  # t, x, y
    parts = []
    for dim, pos in zip(axes_dim, coords):
        freqs = rope_freqs(dim, theta)
        parts.append(pos[:, None].astype(jnp.float32) * freqs)
    ang = jnp.concatenate(parts, axis=-1)  # (N, sum(axes_dim)/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope_precomputed(x: jax.Array, cos: jax.Array, sin: jax.Array):
    """x: (..., N, H, hd) with hd == 2·cos.shape[-1]; rotate-half form
    matching :func:`apply_rope_1d` (split-half pairing)."""
    c = cos[..., None, :]
    s = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(x.dtype)


# --- patch embed (reshape + matmul: exact for non-overlapping patches) ------


def patch_embed_defs(patch: int, in_ch: int, d: int):
    return {
        "w": ParamDef((patch * patch * in_ch, d), (None, "embed"), fan_in()),
        "b": ParamDef((d,), ("embed",), zeros),
    }


def patch_embed(params, x, patch: int):
    """x: (B, H, W, C) -> (B, H/p * W/p, d)."""
    B, H, W, C = x.shape
    x = x.reshape(B, H // patch, patch, W // patch, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(B, (H // patch) * (W // patch),
                                              patch * patch * C)
    return jnp.einsum("...d,df->...f", x, params["w"].astype(x.dtype)) + \
        params["b"].astype(x.dtype)


def unpatchify(x, patch: int, h: int, w: int, out_ch: int):
    """(B, h*w, p*p*C) -> (B, h*p, w*p, C)."""
    B = x.shape[0]
    x = x.reshape(B, h, w, patch, patch, out_ch)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, h * patch, w * patch, out_ch)


# --- timestep / positional embeddings ---------------------------------------


def sincos_timestep_embed(t: jax.Array, dim: int, max_period: float = 10000.0):
    """DDPM sinusoidal timestep embedding. t: (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-np.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t[:, None].astype(jnp.float32) * freqs[None]
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb


def sincos_pos_embed_2d(h: int, w: int, dim: int):
    """Fixed 2-D sin-cos position embedding (DiT/ViT style): (h*w, dim)."""
    def _1d(n, d):
        pos = jnp.arange(n, dtype=jnp.float32)
        omega = 1.0 / (10000.0 ** (jnp.arange(d // 2, dtype=jnp.float32) / (d // 2)))
        out = pos[:, None] * omega[None]
        return jnp.concatenate([jnp.sin(out), jnp.cos(out)], axis=1)

    eh = _1d(h, dim // 2)  # (h, dim/2)
    ew = _1d(w, dim // 2)
    emb = jnp.concatenate(
        [jnp.repeat(eh, w, axis=0), jnp.tile(ew, (h, 1))], axis=1)
    return emb  # (h*w, dim)
