"""Fused adaLN-zero modulation Pallas kernel (DiT hot path).

DiT blocks apply, per token row x and per-sample conditioning vectors
(shift, scale, gate):

    y = LayerNorm(x) * (1 + scale) + shift         (pre-block)
    r = residual + gate * f(y)                     (post-block)

The pre-block form is fused here: one VMEM pass computes the
parameter-free LayerNorm statistics and the modulation, instead of four
HBM round trips.  Token rows tile the grid; conditioning vectors are
broadcast per sample.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _adaln_kernel(x_ref, shift_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (bt, d)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    norm = xc * jax.lax.rsqrt(var + eps)
    scale = scale_ref[...].astype(jnp.float32)  # (1, d)
    shift = shift_ref[...].astype(jnp.float32)
    o_ref[...] = (norm * (1.0 + scale) + shift).astype(o_ref.dtype)


def adaln_modulate_kernel(x: jax.Array, shift: jax.Array, scale: jax.Array,
                          *, eps: float = 1e-6, block_t: int = 256,
                          interpret: bool = False) -> jax.Array:
    """x: (B, N, d); shift/scale: (B, d) per-sample conditioning."""
    B, N, d = x.shape
    block_t = min(block_t, N)
    assert N % block_t == 0
    grid = (B, N // block_t)
    kernel = functools.partial(_adaln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_t, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, d), lambda b, i: (b, 0)),
            pl.BlockSpec((None, d), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_t, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, shift, scale)
