"""Pure-jnp oracle for the fused adaLN modulation kernel."""

from __future__ import annotations

import jax.numpy as jnp


def adaln_modulate_ref(x, shift, scale, eps: float = 1e-6):
    """x: (B, N, d); shift/scale: (B, d)."""
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    norm = (x32 - mu) / jnp.sqrt(var + eps)
    out = norm * (1.0 + scale[:, None, :].astype(jnp.float32)) \
        + shift[:, None, :].astype(jnp.float32)
    return out.astype(x.dtype)
