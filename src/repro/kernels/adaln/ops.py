"""Jitted wrapper for the fused adaLN modulation kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.adaln.kernel import adaln_modulate_kernel


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit, static_argnames=("eps", "block_t", "interpret"))
def adaln_modulate(x, shift, scale, *, eps: float = 1e-6, block_t: int = 256,
                   interpret: bool | None = None):
    """x: (B, N, d); shift/scale: (B, d)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, N, d = x.shape
    bt = min(block_t, N)
    Np = -(-N // bt) * bt
    xp = jnp.pad(x, ((0, 0), (0, Np - N), (0, 0))) if Np != N else x
    out = adaln_modulate_kernel(xp, shift, scale, eps=eps, block_t=bt,
                                interpret=interpret)
    return out[:, :N]
