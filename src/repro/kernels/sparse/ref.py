"""Pure-jnp oracle for the block-sparse masked flash-attention kernel.

Reproduces the kernel's exact block-map semantics (DESIGN.md §12):
SKIP tiles contribute nothing, FULL tiles ignore the bias, PARTIAL
tiles add it; rows whose every tile is skipped (or fully −inf-masked)
emit zeros rather than NaN, matching the kernel's finite running-max
convention.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.sparse.kernel import FULL, PARTIAL, SKIP


def sparse_grid(n_q: int, n_k: int, block_q: int,
                block_k: int) -> Tuple[int, int, int, int]:
    """Effective (block_q, block_k, nq, nk) for a (n_q, n_k) score map.

    The one clamping rule shared by the kernel wrapper, the oracle, and
    the policy-side block-map builders — both sides must tile the score
    map identically or the map rides on the wrong tiles.
    """
    bq = min(block_q, max(n_q, 1))
    bk = min(block_k, max(n_k, 1))
    return bq, bk, -(-n_q // bq), -(-n_k // bk)


def expand_block_map(block_map: jax.Array, n_q: int, n_k: int,
                     block_q: int, block_k: int) -> jax.Array:
    """Broadcast tile states back to a token-level (..., n_q, n_k) map."""
    bq, bk, nq, nk = sparse_grid(n_q, n_k, block_q, block_k)
    assert block_map.shape[-2:] == (nq, nk), (block_map.shape, nq, nk)
    e = jnp.repeat(jnp.repeat(block_map, bq, axis=-2), bk, axis=-1)
    return e[..., :n_q, :n_k]


def sparse_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         bias: Optional[jax.Array] = None,
                         block_map: Optional[jax.Array] = None,
                         block_q: int = 128, block_k: int = 128,
                         scale: Optional[float] = None) -> jax.Array:
    """q: (..., Nq, d), k: (..., Nk, d), v: (..., Nk, dv) -> (..., Nq, dv).

    ``block_map`` (..., nq, nk) int states; None means every tile is
    PARTIAL when a bias exists (dense masked attention) and FULL
    otherwise — the same degradation the ops wrapper applies.
    """
    n_q, n_k = q.shape[-2], k.shape[-2]
    if scale is None:
        scale = float(1.0 / (q.shape[-1] ** 0.5))
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    if block_map is None:
        if bias is not None:
            s = s + bias.astype(jnp.float32)
    else:
        st = expand_block_map(block_map, n_q, n_k, block_q, block_k)
        if bias is not None:
            s = jnp.where(st == PARTIAL, s + bias.astype(jnp.float32), s)
        s = jnp.where(st == SKIP, -jnp.inf, s)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("...qk,...kv->...qv", p, v.astype(jnp.float32))
    return (out / jnp.where(l > 0.0, l, 1.0)).astype(q.dtype)
