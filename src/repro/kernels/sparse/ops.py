"""Jitted public wrapper for the block-sparse masked flash kernel.

Handles (B, H, N, d) layouts, pads token dims to block multiples
(padded keys are neutralized with the same flag-channel trick as the
dense flash wrapper, so every block-map state stays correct on the
padded tail), builds the scalar-prefetched fetch-index tables that let
the kernel elide DMA for skipped tiles, and runs in interpret mode on
CPU.

Also home of the policy-facing helpers:

* :func:`block_map_from_keep` — tile a boolean keep-mask into the
  kernel's SKIP/FULL/PARTIAL states (how SVG's head-classified masks
  become a block map, DESIGN.md §12).
* :func:`sparse_block_stats` — realized skipped-tile fraction, the
  *structural* savings a mask policy actually gets on this backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sparse.kernel import (_LANES, _M_INIT, FULL, PARTIAL,
                                         SKIP, sparse_attention_kernel)
from repro.kernels.sparse.ref import sparse_grid

__all__ = ["FULL", "PARTIAL", "SKIP", "block_map_from_keep",
           "sparse_attention_pallas", "sparse_block_stats", "sparse_grid"]

_NEG_INF = -1e30


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x, target, axis):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def block_map_from_keep(keep: jax.Array, block_q: int,
                        block_k: int) -> jax.Array:
    """(..., Nq, Nk) bool keep-mask -> (..., nq, nk) int32 block map.

    A tile that keeps everything is FULL (mask-free fast path), one that
    keeps nothing is SKIP, anything mixed is PARTIAL (the −inf bias is
    applied in-kernel).  Ragged edges are padded with the edge value so
    padding can never flip a FULL/SKIP verdict to PARTIAL.
    """
    *lead, n_q, n_k = keep.shape
    bq, bk, nq, nk = sparse_grid(n_q, n_k, block_q, block_k)
    widths = [(0, 0)] * len(lead) + [(0, nq * bq - n_q), (0, nk * bk - n_k)]
    tiled = jnp.pad(keep, widths, mode="edge") \
        .reshape(*lead, nq, bq, nk, bk)
    any_keep = jnp.any(tiled, axis=(-3, -1))
    all_keep = jnp.all(tiled, axis=(-3, -1))
    return jnp.where(all_keep, FULL,
                     jnp.where(any_keep, PARTIAL, SKIP)).astype(jnp.int32)


def sparse_block_stats(block_map: jax.Array) -> jax.Array:
    """Fraction of (q_block, k_block) tiles the kernel skips outright —
    score matmul, softmax update, and AV matmul all elided."""
    return jnp.mean((block_map == SKIP).astype(jnp.float32))


def _fetch_table(needed: jax.Array) -> jax.Array:
    """Per-tile fetch index: ``ki`` where ``needed``, else the last
    needed index (0 before any) — consecutive equal indices make the
    Pallas pipeline skip the corresponding HBM→VMEM copy."""
    nk = needed.shape[-1]
    ki = jnp.arange(nk, dtype=jnp.int32)
    marked = jnp.where(needed, ki, -1)
    last = jax.lax.cummax(marked, axis=needed.ndim - 1)
    return jnp.maximum(last, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret", "return_state"))
def sparse_attention_pallas(q, k, v, *, bias=None, block_map=None,
                            block_q: int = 128, block_k: int = 128,
                            interpret: bool | None = None,
                            carry=None, return_state: bool = False):
    """q,k,v: (B, H, N, d) -> (B, H, N, dv).

    ``block_map``: (..., nq, nk) int states broadcastable over (B, H),
    tiled as :func:`sparse_grid` tiles the (Nq, Nk) score map.  ``None``
    degrades gracefully: all-PARTIAL when a ``bias`` exists (dense
    masked flash attention), all-FULL otherwise (plain flash).  ``bias``
    is additive on logits and read only inside PARTIAL tiles — FULL
    tiles must correspond to an all-zero bias region, SKIP tiles to
    all-−inf (``block_map_from_keep`` guarantees both).

    Ring-hop chaining (DESIGN.md §14): with ``return_state=True`` the
    call also returns the online-softmax state ``(m, l, acc)`` of shapes
    ((B, H, Nq) f32 ×2, (B, H, Nq, dv) f32); feeding that triple back as
    ``carry`` on the next call — against the *next* key slice — resumes
    the accumulation, so a chain of calls over column slices of K equals
    one full-width call up to summation-order rounding.  The per-call
    ``out`` is the normalized prefix result; only the last hop's ``out``
    (or an explicit ``acc / l``) is the final answer.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, H, Nq, d = q.shape
    Nk = k.shape[2]
    dv = v.shape[3]
    scale = float(1.0 / (d ** 0.5))
    bq, bk, nq, nk = sparse_grid(Nq, Nk, block_q, block_k)
    Nq_p, Nk_p = nq * bq, nk * bk

    qf = _pad_to(q, Nq_p, 2).reshape(B * H, Nq_p, d)
    kf = _pad_to(k, Nk_p, 2).reshape(B * H, Nk_p, d)
    vf = _pad_to(v, Nk_p, 2).reshape(B * H, Nk_p, dv)
    if Nk_p != Nk:
        # Padded keys attend to nothing: flag channel projects a huge
        # negative for them (queries project 1), exactly as in flash/ops.
        flag_q = jnp.ones((B * H, Nq_p, 1), qf.dtype)
        flag_k = jnp.zeros((B * H, Nk_p, 1), kf.dtype)
        kmask = (jnp.arange(Nk_p) >= Nk)[None, :, None]
        flag_k = jnp.where(kmask, _NEG_INF / 128.0, flag_k)
        qf = jnp.concatenate([qf, flag_q], axis=-1)
        kf = jnp.concatenate([kf, flag_k], axis=-1)

    if block_map is None:
        state = PARTIAL if bias is not None else FULL
        bmap = jnp.full((B * H, nq, nk), state, jnp.int32)
    else:
        bmap = jnp.broadcast_to(block_map, (B, H, nq, nk)) \
            .reshape(B * H, nq, nk).astype(jnp.int32)

    k_fetch = _fetch_table(bmap != SKIP)
    bias_fetch = _fetch_table(bmap == PARTIAL)

    if bias is None:
        bias_f = jnp.zeros((1, bq, bk), jnp.float32)
    else:
        bias_f = jnp.broadcast_to(bias.astype(jnp.float32),
                                  (B, H, Nq, Nk)).reshape(B * H, Nq, Nk)
        bias_f = _pad_to(_pad_to(bias_f, Nq_p, 1), Nk_p, 2)

    carry_f = None
    if carry is not None or return_state:
        if carry is None:
            m_c = jnp.full((B, H, Nq), _M_INIT, jnp.float32)
            l_c = jnp.zeros((B, H, Nq), jnp.float32)
            acc_c = jnp.zeros((B, H, Nq, dv), jnp.float32)
        else:
            m_c, l_c, acc_c = carry
        # Padded query rows carry the fresh state so they stay inert.
        m_c = jnp.pad(m_c.astype(jnp.float32), [(0, 0), (0, 0),
                      (0, Nq_p - Nq)], constant_values=_M_INIT)
        l_c = _pad_to(l_c.astype(jnp.float32), Nq_p, 2)
        acc_c = _pad_to(acc_c.astype(jnp.float32), Nq_p, 2)
        carry_f = (jnp.broadcast_to(m_c.reshape(B * H, Nq_p, 1),
                                    (B * H, Nq_p, _LANES)),
                   jnp.broadcast_to(l_c.reshape(B * H, Nq_p, 1),
                                    (B * H, Nq_p, _LANES)),
                   acc_c.reshape(B * H, Nq_p, dv))

    res = sparse_attention_kernel(
        qf, kf, vf, bias_f, bmap, k_fetch, bias_fetch,
        scale=scale, block_q=bq, block_k=bk, interpret=interpret,
        carry=carry_f)
    if carry_f is not None:
        out, (m, l, acc) = res
        state = (m[:, :, 0].reshape(B, H, Nq_p)[:, :, :Nq],
                 l[:, :, 0].reshape(B, H, Nq_p)[:, :, :Nq],
                 acc.reshape(B, H, Nq_p, dv)[:, :, :Nq, :])
        out = out.reshape(B, H, Nq_p, dv)[:, :, :Nq, :]
        return (out, state) if return_state else out
    return res.reshape(B, H, Nq_p, dv)[:, :, :Nq, :]
