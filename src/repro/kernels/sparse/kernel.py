"""Block-sparse masked flash-attention Pallas TPU kernel (DESIGN.md §12).

The dense flash kernel's online-softmax loop, driven by a per-
``(q_block, k_block)`` scalar-prefetched **block map** with three
states:

* ``SKIP`` (0)    — the tile contributes nothing: no score matmul, no
  softmax update, no AV matmul.  This is where a mask-emitting policy's
  modeled savings become real MXU skips.
* ``FULL`` (1)    — every entry of the tile is kept: dense tile on the
  mask-free fast path (the bias block is never read).
* ``PARTIAL`` (2) — the tile is mixed: dense tile plus the additive
  logit bias applied in-kernel (−inf entries drop exactly, matching the
  host-side masked softmax).

Two scalar-prefetched fetch-index tables make the skips pay in HBM
traffic too, not just MXU work: the K/V (and bias) index maps remap a
skipped tile's block index to the **last non-skipped** one, so
consecutive grid steps over skipped tiles resolve to the same block and
the Pallas pipeline elides the copy instead of streaming tiles the
kernel would never read.

The running max is initialized to a large *finite* negative
(``_M_INIT``) rather than −inf so a partial tile whose entire row is
masked (bias −inf) still produces ``exp(−inf − m) == 0`` instead of
``exp(−inf + inf) == NaN``; rows that never meet a non-skipped tile end
with ``l == 0`` and emit zeros (the pure-jnp oracle in ``ref.py``
mirrors both conventions).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_LANES = 128
# Finite stand-in for -inf in the running max: keeps exp(s - m) defined
# when every score of a partial tile's row is bias-masked to -inf.
_M_INIT = -1e30

# Block-map states (int32).
SKIP, FULL, PARTIAL = 0, 1, 2


def _sparse_kernel(bmap_ref, kfetch_ref, bfetch_ref,
                   q_ref, k_ref, v_ref, bias_ref,
                   *refs, scale: float, nk: int, with_state: bool):
    if with_state:
        # Cross-hop accumulator convention (DESIGN.md §14): the running
        # (m, l, acc) softmax state enters as three carry inputs and
        # leaves as three extra outputs, so ring hops chain the online
        # softmax exactly as consecutive k-blocks do within one call.
        (m_in_ref, l_in_ref, acc_in_ref,
         o_ref, m_out_ref, l_out_ref, acc_out_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    state = bmap_ref[b, qi, ki]

    @pl.when(ki == 0)
    def _init():
        if with_state:
            m_ref[...] = m_in_ref[...]
            l_ref[...] = l_in_ref[...]
            acc_ref[...] = acc_in_ref[...]
        else:
            m_ref[...] = jnp.full_like(m_ref, _M_INIT)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

    def scores():
        return jax.lax.dot_general(
            q_ref[...], k_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    def update(s):
        """One online-softmax update on this tile's scores."""
        m_prev = m_ref[...][:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            alpha * l_ref[...][:, :1] + jnp.sum(p, axis=1, keepdims=True),
            l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    # SKIP tiles fall through: no matmuls, no softmax-state update.
    @pl.when(state == FULL)
    def _full():
        update(scores())

    @pl.when(state == PARTIAL)
    def _partial():
        update(scores() + bias_ref[...].astype(jnp.float32))

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[...][:, :1]
        # l == 0: every tile of the row was skipped / fully masked.
        out = acc_ref[...] / jnp.where(l > 0.0, l, 1.0)
        o_ref[...] = out.astype(o_ref.dtype)
        if with_state:
            m_out_ref[...] = m_ref[...]
            l_out_ref[...] = l_ref[...]
            acc_out_ref[...] = acc_ref[...]


def sparse_attention_kernel(
    q, k, v, bias, block_map, k_fetch, bias_fetch,
    *, scale: float, block_q: int = 128, block_k: int = 128,
    interpret: bool = False, carry=None,
):
    """q: (BH, Nq, d), k/v: (BH, Nk, d|dv), bias: (BH, Nq, Nk) f32 or a
    (1, block_q, block_k) zero dummy when no policy bias exists.

    block_map: (BH, nq, nk) int32 of SKIP/FULL/PARTIAL states.
    k_fetch / bias_fetch: (BH, nq, nk) int32 fetch-index tables — for
    each grid step the k-block (resp. bias-block) index to resident in
    VMEM; equal to ``ki`` wherever the state needs the block and to the
    last needed index elsewhere (so the pipeline elides the copy).

    Returns (BH, Nq, dv); with ``carry`` — a running-softmax
    ``(m, l, acc)`` triple of shapes ((BH, Nq, _LANES) f32 ×2,
    (BH, Nq, dv) f32) from a previous call — the online softmax resumes
    from that state instead of the fresh ``(_M_INIT, 0, 0)`` and the
    updated triple is returned alongside: ``(o, (m, l, acc))``.  This is
    the cross-hop accumulator convention of the ring driver
    (DESIGN.md §14): chaining calls over column slices of the key axis
    is the same online-softmax recurrence as the kernel's own k-block
    loop, so the final ``acc / l`` matches a single full-width call up
    to hop-ordering rounding.
    """
    BH, Nq, d = q.shape
    Nk = k.shape[1]
    dv = v.shape[2]
    assert Nq % block_q == 0 and Nk % block_k == 0, (Nq, Nk, block_q, block_k)
    nq = Nq // block_q
    nk = Nk // block_k
    assert block_map.shape == (BH, nq, nk), (block_map.shape, BH, nq, nk)
    dummy_bias = bias.shape[0] == 1 and bias.shape[1:] == (block_q, block_k)
    with_state = carry is not None

    kernel = functools.partial(_sparse_kernel, scale=scale, nk=nk,
                               with_state=with_state)

    def qmap(b, qi, ki, *_):
        return (b, qi, 0)

    def kvmap(b, qi, ki, bmap_ref, kfetch_ref, bfetch_ref):
        return (b, kfetch_ref[b, qi, ki], 0)

    if dummy_bias:
        def biasmap(b, qi, ki, *_):
            return (0, 0, 0)
    else:
        def biasmap(b, qi, ki, bmap_ref, kfetch_ref, bfetch_ref):
            return (b, qi, bfetch_ref[b, qi, ki])

    mspec = pl.BlockSpec((None, block_q, _LANES), qmap)
    accspec = pl.BlockSpec((None, block_q, dv), qmap)
    in_specs = [
        pl.BlockSpec((None, block_q, d), qmap),
        pl.BlockSpec((None, block_k, d), kvmap),
        pl.BlockSpec((None, block_k, dv), kvmap),
        pl.BlockSpec((None, block_q, block_k), biasmap),
    ]
    out_specs = accspec
    out_shape = jax.ShapeDtypeStruct((BH, Nq, dv), q.dtype)
    operands = (block_map, k_fetch, bias_fetch, q, k, v, bias)
    if with_state:
        m_in, l_in, acc_in = carry
        assert m_in.shape == (BH, Nq, _LANES) and \
            l_in.shape == (BH, Nq, _LANES) and \
            acc_in.shape == (BH, Nq, dv), (m_in.shape, l_in.shape,
                                           acc_in.shape)
        in_specs = in_specs + [mspec, mspec, accspec]
        out_specs = [out_specs, mspec, mspec, accspec]
        f32 = jnp.float32
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((BH, Nq, _LANES), f32),
                     jax.ShapeDtypeStruct((BH, Nq, _LANES), f32),
                     jax.ShapeDtypeStruct((BH, Nq, dv), f32)]
        operands = operands + (m_in.astype(f32), l_in.astype(f32),
                               acc_in.astype(f32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BH, nq, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
    )
    res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    if with_state:
        o, m, l, acc = res
        return o, (m, l, acc)
    return res
