"""Version tolerance for the Pallas TPU API surface the kernels use.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(jax ≥ 0.5); kernels import the symbol from here so they run on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or _pltpu.TPUCompilerParams
