"""TimeRipple pair-collapse flash attention Pallas TPU kernel.

This is the TPU-native execution of the paper's reuse (DESIGN.md §4).
Operands arrive pair-split: ``x_even``/``x_odd`` hold the window
representatives and followers of adjacent window-2 pairs.  Two per-block
scalar flag vectors (SMEM, scalar-prefetched) mark blocks whose pairs are
*fully* snapped:

* ``k_flags[b, ki] == 1`` → every K pair in block ki is value-identical:
  the kernel computes **one** score matmul (q·k_evenᵀ) with softmax
  multiplicity 2 and **one** AV matmul against (v_even + v_odd) — the
  exact collapse identity — instead of two of each.
* ``q_flags[b, qi] == 1`` → every Q pair in block qi is value-identical:
  the odd-row state is never computed; the even-row output is copied at
  the end.

Fully-collapsed (q, k) block pairs therefore run 2 MXU matmuls instead
of 8 — a real 75% skip, not the paper's proportional estimate.  Mixed
blocks fall back to dense-snapped compute and stay bit-exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_LANES = 128


def _rowmax(s):
    return jnp.max(s, axis=1, keepdims=True)


def _ripple_kernel(
    q_flags_ref, k_flags_ref,          # scalar prefetch (SMEM)
    q_e_ref, q_o_ref, k_e_ref, k_o_ref, v_e_ref, v_o_ref,
    o_e_ref, o_o_ref,
    m_e, l_e, acc_e, m_o, l_o, acc_o,
    *, scale: float, nk: int,
):
    b = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    qf = q_flags_ref[b, qi]
    kf = k_flags_ref[b, ki]

    @pl.when(ki == 0)
    def _init():
        for m, l, a in ((m_e, l_e, acc_e), (m_o, l_o, acc_o)):
            m[...] = jnp.full_like(m, -jnp.inf)
            l[...] = jnp.zeros_like(l)
            a[...] = jnp.zeros_like(a)

    k_e = k_e_ref[...]
    v_e = v_e_ref[...]

    def dot(a, b_, transpose_b=True):
        dims = (((1,), (1,)), ((), ())) if transpose_b else (((1,), (0,)), ((), ()))
        return jax.lax.dot_general(a, b_, dims, preferred_element_type=jnp.float32)

    def update_half(q, m, l, acc):
        """One online-softmax update for one row-parity half."""
        s_ee = dot(q, k_e) * scale  # always needed: representative columns

        @pl.when(kf == 1)
        def _collapsed():
            m_prev = m[...][:, :1]
            m_new = jnp.maximum(m_prev, _rowmax(s_ee))
            p = jnp.exp(s_ee - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l[...] = jnp.broadcast_to(
                alpha * l[...][:, :1] + 2.0 * jnp.sum(p, axis=1, keepdims=True),
                l.shape)
            v_sum = (v_e + v_o_ref[...]).astype(jnp.float32)
            acc[...] = acc[...] * alpha + dot(p, v_sum, transpose_b=False)
            m[...] = jnp.broadcast_to(m_new, m.shape)

        @pl.when(kf == 0)
        def _dense():
            k_o = k_o_ref[...]
            v_o = v_o_ref[...]
            s_eo = dot(q, k_o) * scale
            m_prev = m[...][:, :1]
            m_new = jnp.maximum(m_prev,
                                jnp.maximum(_rowmax(s_ee), _rowmax(s_eo)))
            p_ee = jnp.exp(s_ee - m_new)
            p_eo = jnp.exp(s_eo - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l[...] = jnp.broadcast_to(
                alpha * l[...][:, :1]
                + jnp.sum(p_ee, axis=1, keepdims=True)
                + jnp.sum(p_eo, axis=1, keepdims=True),
                l.shape)
            acc[...] = (acc[...] * alpha
                        + dot(p_ee, v_e.astype(jnp.float32), transpose_b=False)
                        + dot(p_eo, v_o.astype(jnp.float32), transpose_b=False))
            m[...] = jnp.broadcast_to(m_new, m.shape)

    update_half(q_e_ref[...], m_e, l_e, acc_e)

    @pl.when(qf == 0)
    def _odd_rows():
        update_half(q_o_ref[...], m_o, l_o, acc_o)

    @pl.when(ki == nk - 1)
    def _finish():
        out_e = (acc_e[...] / l_e[...][:, :1]).astype(o_e_ref.dtype)
        o_e_ref[...] = out_e

        @pl.when(qf == 1)
        def _copy():
            o_o_ref[...] = out_e  # followers reuse the representative row

        @pl.when(qf == 0)
        def _own():
            o_o_ref[...] = (acc_o[...] / l_o[...][:, :1]).astype(o_o_ref.dtype)


def ripple_attention_kernel(
    q_even, q_odd, k_even, k_odd, v_even, v_odd,
    q_flags, k_flags,
    *, scale: float, block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
):
    """All pair-split operands: (BH, Npairs, d/dv); flags (BH, nblocks) int32.

    Returns (o_even, o_odd): (BH, Nq_pairs, dv) each.
    """
    BH, nq_pairs, d = q_even.shape
    nk_pairs = k_even.shape[1]
    dv = v_even.shape[2]
    block_q = min(block_q, nq_pairs)
    block_k = min(block_k, nk_pairs)
    assert nq_pairs % block_q == 0 and nk_pairs % block_k == 0
    nq = nq_pairs // block_q
    nk = nk_pairs // block_k
    assert q_flags.shape == (BH, nq) and k_flags.shape == (BH, nk)

    kernel = functools.partial(_ripple_kernel, scale=scale, nk=nk)
    grid = (BH, nq, nk)

    def qmap(b, qi, ki, *_):
        return (b, qi, 0)

    def kmap(b, qi, ki, *_):
        return (b, ki, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), qmap),
            pl.BlockSpec((None, block_q, d), qmap),
            pl.BlockSpec((None, block_k, d), kmap),
            pl.BlockSpec((None, block_k, d), kmap),
            pl.BlockSpec((None, block_k, dv), kmap),
            pl.BlockSpec((None, block_k, dv), kmap),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, dv), qmap),
            pl.BlockSpec((None, block_q, dv), qmap),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, nq_pairs, dv), q_even.dtype),
            jax.ShapeDtypeStruct((BH, nq_pairs, dv), q_even.dtype),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_flags, k_flags, q_even, q_odd, k_even, k_odd, v_even, v_odd)
