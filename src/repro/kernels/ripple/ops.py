"""Jitted wrapper for the ripple (pair-collapse) attention kernel.

Accepts standard (B, H, N, d) snapped operands, derives the per-block
collapse flags from value equality, pair-splits, pads to block multiples
(padded K pairs attend to nothing via a flag channel), runs the kernel,
and re-interleaves the two output halves.

Also exports :func:`ripple_block_stats` so benchmarks can report the
fraction of MXU work the kernel actually skipped (the *structural*
savings, as opposed to the paper's partial-score accounting).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ripple.kernel import ripple_attention_kernel
from repro.kernels.ripple.ref import block_flags, split_pairs

_PAD_NEG = -1e9


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("window", "block_q", "block_k", "interpret"))
def ripple_attention_pallas(q, k, v, *, bias: Optional[jax.Array] = None,
                            window: int = 2, block_q: int = 128,
                            block_k: int = 128,
                            interpret: bool | None = None):
    """q,k,v: (B, H, N, d) snapped operands -> (B, H, N, dv)."""
    assert bias is None, "ripple kernel path does not take a bias"
    assert window == 2, "kernel implements the paper's window-2 sweet spot"
    if interpret is None:
        interpret = not _on_tpu()
    B, H, N, d = q.shape
    dv = v.shape[-1]
    assert N % 2 == 0, "pair-collapse needs an even token count"
    scale = float(1.0 / (d ** 0.5))

    qf = q.reshape(B * H, N, d)
    kf = k.reshape(B * H, N, d)
    vf = v.reshape(B * H, N, dv)
    q_e, q_o = split_pairs(qf)
    k_e, k_o = split_pairs(kf)
    v_e, v_o = split_pairs(vf)

    P = N // 2
    bq = min(block_q, P)
    bk = min(block_k, P)
    Pq = -(-P // bq) * bq
    Pk = -(-P // bk) * bk

    def pad(x, target):
        padw = target - x.shape[1]
        if padw <= 0:
            return x
        return jnp.pad(x, ((0, 0), (0, padw), (0, 0)))

    q_e, q_o = pad(q_e, Pq), pad(q_o, Pq)
    k_e, k_o, v_e, v_o = pad(k_e, Pk), pad(k_o, Pk), pad(v_e, Pk), pad(v_o, Pk)
    if Pk != P or Pq != P:
        # flag channel: queries project 1, padded keys project −1e9.
        ones_q = jnp.ones((B * H, Pq, 1), q_e.dtype)
        flag_k = jnp.zeros((B * H, Pk, 1), k_e.dtype)
        kmask = (jnp.arange(Pk) >= P)[None, :, None]
        flag_k = jnp.where(kmask, _PAD_NEG, flag_k)
        q_e = jnp.concatenate([q_e, ones_q], axis=-1)
        q_o = jnp.concatenate([q_o, ones_q], axis=-1)
        k_e = jnp.concatenate([k_e, flag_k], axis=-1)
        k_o = jnp.concatenate([k_o, flag_k], axis=-1)

    qflags = block_flags(q_e, q_o, bq)
    kflags = block_flags(k_e, k_o, bk)

    o_e, o_o = ripple_attention_kernel(
        q_e, q_o, k_e, k_o, v_e, v_o, qflags, kflags,
        scale=scale, block_q=bq, block_k=bk, interpret=interpret)
    o = jnp.stack([o_e[:, :P], o_o[:, :P]], axis=2)  # (BH, P, 2, dv)
    return o.reshape(B, H, N, dv)


def ripple_block_stats(q, k, *, block_q: int = 128, block_k: int = 128):
    """Fraction of MXU matmul work the kernel skips for these operands.

    Per (q, k) block pair the dense cost is 8 block-matmuls; k-collapse
    alone leaves 4 (scores s_ee/s_oe + AV even/odd → wait, see kernel:
    collapsed-k does 1 score + 1 AV per row half), q-collapse halves the
    row halves.  cost = (2 − qc) · (1 + 1 if kc else 2 + 2)/... computed
    explicitly below; dense = 8.
    """
    B, H, N, d = q.shape
    qf2 = q.reshape(B * H, N, d)
    kf2 = k.reshape(B * H, N, d)
    q_e, q_o = split_pairs(qf2)
    k_e, k_o = split_pairs(kf2)
    P = N // 2
    bq, bk = min(block_q, P), min(block_k, P)
    qc = block_flags(q_e[:, : (P // bq) * bq], q_o[:, : (P // bq) * bq], bq)
    kc = block_flags(k_e[:, : (P // bk) * bk], k_o[:, : (P // bk) * bk], bk)
    # per (qi, ki): row halves computed = 2 - qc; matmuls per half = 2 if kc else 4
    halves = (2.0 - qc.astype(jnp.float32))[:, :, None]          # (BH, nq, 1)
    per_half = jnp.where(kc.astype(jnp.float32)[:, None, :] > 0, 2.0, 4.0)
    cost = jnp.mean(halves * per_half) / 8.0
    return 1.0 - cost
