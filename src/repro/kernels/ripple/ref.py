"""Pure-jnp oracle for the ripple kernel.

The collapse identities are exact (DESIGN.md §2), so the oracle for the
pair-collapse kernel is simply dense softmax attention on the *snapped*
operands.  Any deviation of the kernel from this oracle is a bug, never
an "approximation error" — the approximation lives entirely in the
snapping step, which is shared by both paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ripple_attention_ref(q_snapped: jax.Array, k_snapped: jax.Array,
                         v: jax.Array, scale: float | None = None) -> jax.Array:
    if scale is None:
        scale = float(1.0 / (q_snapped.shape[-1] ** 0.5))
    s = jnp.einsum("...qd,...kd->...qk", q_snapped, k_snapped)
    s = s.astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kv->...qv", p.astype(v.dtype), v)


def split_pairs(x: jax.Array):
    """(..., N, d) -> even/odd (..., N/2, d); N must be even."""
    return x[..., 0::2, :], x[..., 1::2, :]


def block_flags(x_even: jax.Array, x_odd: jax.Array, block: int) -> jax.Array:
    """(BH, P, d) pair-split values -> (BH, P/block) int32; 1 where every
    pair in the block is value-identical (follower fully snapped)."""
    eq = jnp.all(x_even == x_odd, axis=-1)  # (BH, P)
    BH, P = eq.shape
    nb = P // block
    return jnp.all(eq[:, : nb * block].reshape(BH, nb, block), axis=-1).astype(jnp.int32)
