# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   flash/      baseline tiled online-softmax attention
#   ripple/     pair-collapse block-skipping attention (the paper's reuse,
#               restructured for the MXU — DESIGN.md §4)
#   sparse/     block-sparse masked flash attention driven by a
#               scalar-prefetched skip/full/partial block map — the
#               backend that makes policy masks pay (DESIGN.md §12)
#   reuse_mask/ fused Eq.3 Δ-check + snap (single-axis pair kernel and
#               the fused 3-axis mask pipeline — DESIGN.md §8)
#   adaln/      fused adaLN-zero modulation (DiT hot path)
# Each has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
# interpret=True on CPU), ref.py (pure-jnp oracle).
