"""Jitted wrappers for the reuse-snap kernels.

* :func:`reuse_snap` — single-axis adjacent-pair snap on (B, H, N, d)
  operands (permute with ``core.collapse.pair_major_order`` for t/y axes
  first).
* :func:`fused_reuse_snap` / :func:`fused_compute_reuse` — the full
  fused multi-axis Δ-check + OR-aggregated snap (DESIGN.md §8), the
  on-device replacement for the host-side ``core.reuse.compute_reuse``
  hot path.  :func:`fused_reuse_eligible` tells callers (the dispatch
  layer) whether a (grid, config) combination can take the fused path.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.reuse_mask.kernel import fused_reuse_kernel, reuse_snap_kernel


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def reuse_snap(x, theta, *, block: int = 256, interpret: bool | None = None):
    """x: (B, H, N, d), theta: scalar -> (snapped x, mask int8)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, N, d = x.shape
    assert N % 2 == 0
    P = N // 2
    xr = x.reshape(B * H, N, d)
    x_e, x_o = xr[:, 0::2], xr[:, 1::2]

    blk = min(block, P)
    Pp = -(-P // blk) * blk
    if Pp != P:
        padw = ((0, 0), (0, Pp - P), (0, 0))
        x_e = jnp.pad(x_e, padw)
        x_o = jnp.pad(x_o, padw)
    th = jnp.asarray([theta], jnp.float32).astype(x.dtype)
    o_o, m_o = reuse_snap_kernel(x_e, x_o, th, block=blk, interpret=interpret)
    o_o, m_o = o_o[:, :P], m_o[:, :P]

    snapped = jnp.stack([xr[:, 0::2], o_o], axis=2).reshape(B * H, N, d)
    mask = jnp.stack([jnp.zeros_like(m_o), m_o], axis=2).reshape(B * H, N, d)
    return snapped.reshape(B, H, N, d), mask.reshape(B, H, N, d)


# ---------------------------------------------------------------------------
# Fused multi-axis path (DESIGN.md §8)
# ---------------------------------------------------------------------------

# Target tokens per VMEM tile; the real block rounds down to a multiple
# of 2·W that divides a frame.
_TARGET_BLOCK = 2048


def fused_reuse_eligible(grid: Tuple[int, int, int], *, window: int = 2,
                         granularity: str = "channel",
                         axes: Sequence[str] = ("t", "x", "y")) -> bool:
    """Can the fused kernel reproduce ``compute_reuse`` for this setup?

    Requirements: the paper's window-2 sweet spot, channel/token
    granularity (the RoPE-'group' gate stays on the host path), even
    spatial dims, and an even frame count whenever the temporal check is
    active (T == 1 is fine — the t check never fires there, exactly as
    on the host).
    """
    T, H, W = grid
    if window != 2 or granularity not in ("channel", "token"):
        return False
    if H < 2 or H % 2 or W < 2 or W % 2:
        return False
    if "t" in axes and T > 1 and T % 2:
        return False
    if not set(axes) <= {"t", "x", "y"}:
        return False
    return True


def _pick_block(H: int, W: int) -> int:
    """Largest multiple of 2·W that divides H·W, ≲ the VMEM target."""
    row_pairs = H // 2
    m = max(1, min(row_pairs, _TARGET_BLOCK // (2 * W) or 1))
    while row_pairs % m:
        m -= 1
    return m * 2 * W


@functools.partial(
    jax.jit,
    static_argnames=("grid", "axes", "granularity", "block", "interpret"))
def fused_reuse_snap(x: jax.Array, thetas: jax.Array, *,
                     grid: Tuple[int, int, int],
                     axes: Tuple[str, ...] = ("t", "x", "y"),
                     granularity: str = "channel",
                     block: int = 0,
                     interpret: bool | None = None):
    """x: (..., N, d) grid tokens in (t, y, x) row-major order;
    thetas: (3,) f32 in (θt, θx, θy) order.  Returns (snapped, mask:bool)
    shaped like x — the fused equivalent of ``compute_reuse`` restricted
    to its eligible shapes (see :func:`fused_reuse_eligible`).
    """
    if interpret is None:
        interpret = not _on_tpu()
    T, H, W = grid
    *lead, N, d = x.shape
    assert N == T * H * W, (N, grid)
    R = math.prod(lead) if lead else 1
    with_t = ("t" in axes) and T >= 2
    TT = 2 if with_t else 1
    S = H * W
    blk = block or _pick_block(H, W)
    x4 = x.reshape(R * (T // TT), TT, S, d)
    th = thetas.astype(x.dtype)
    snapped, mask = fused_reuse_kernel(
        x4, th, axes=axes, granularity=granularity, width=W,
        with_t=with_t, block=blk, interpret=interpret)
    return (snapped.reshape(*lead, N, d),
            mask.reshape(*lead, N, d).astype(jnp.bool_))


def fused_compute_reuse(x: jax.Array, grid: Tuple[int, int, int],
                        thetas: Dict[str, jax.Array], *,
                        axes: Sequence[str] = ("t", "x", "y"),
                        granularity: str = "channel",
                        interpret: bool | None = None):
    """Dict-theta convenience mirroring ``compute_reuse``'s signature.

    Returns (snapped, mask).  Callers must have checked
    :func:`fused_reuse_eligible` first.
    """
    th = jnp.stack([jnp.asarray(thetas.get(a, 0.0), jnp.float32)
                    for a in ("t", "x", "y")])
    return fused_reuse_snap(x, th, grid=grid, axes=tuple(axes),
                            granularity=granularity, interpret=interpret)

