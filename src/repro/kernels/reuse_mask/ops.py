"""Jitted wrapper for the fused reuse-snap kernel.

Operates on (B, H, N, d) operands along adjacent window-2 pairs (permute
with ``core.collapse.pair_major_order`` for t/y axes first).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.reuse_mask.kernel import reuse_snap_kernel


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def reuse_snap(x, theta, *, block: int = 256, interpret: bool | None = None):
    """x: (B, H, N, d), theta: scalar -> (snapped x, mask int8)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, N, d = x.shape
    assert N % 2 == 0
    P = N // 2
    xr = x.reshape(B * H, N, d)
    x_e, x_o = xr[:, 0::2], xr[:, 1::2]

    blk = min(block, P)
    Pp = -(-P // blk) * blk
    if Pp != P:
        padw = ((0, 0), (0, Pp - P), (0, 0))
        x_e = jnp.pad(x_e, padw)
        x_o = jnp.pad(x_o, padw)
    th = jnp.asarray([theta], jnp.float32).astype(x.dtype)
    o_o, m_o = reuse_snap_kernel(x_e, x_o, th, block=blk, interpret=interpret)
    o_o, m_o = o_o[:, :P], m_o[:, :P]

    snapped = jnp.stack([xr[:, 0::2], o_o], axis=2).reshape(B * H, N, d)
    mask = jnp.stack([jnp.zeros_like(m_o), m_o], axis=2).reshape(B * H, N, d)
    return snapped.reshape(B, H, N, d), mask.reshape(B, H, N, d)
