"""Fused Δ-check + snap Pallas kernels (paper Fig. 6 steps ①-②).

Two kernels live here:

* :func:`reuse_snap_kernel` — the original single-axis pair kernel.
  Computes, for adjacent window-2 pairs of tokens (pair-major layout —
  callers permute other axes into adjacency with
  ``core.collapse.pair_major_order``):

      Δ_c   = |x[2j+1, c] − x[2j, c]| / 2          (Eq. 3 for K=2)
      snap  = Δ_c < θ
      out[2j+1, c] = snap ? x[2j, c] : x[2j+1, c]

  in one VMEM pass, emitting the snapped operand and the mask.

* :func:`fused_reuse_kernel` — the full TimeRipple step ①-② pipeline
  (DESIGN.md §8): windowed Δ checks along **all three** grid axes
  (t, x, y) plus the OR-aggregation into the final snap mask with the
  same first-wins axis priority as ``core.reuse.compute_reuse``, in one
  kernel launch.  Each program owns one frame *pair* (or a slab of it),
  so the t-partner, the y-row partner and the x-neighbour of every token
  are all resident in the same VMEM tile and the whole check costs one
  HBM read + two writes instead of the ~3 axis passes (slice, sub, abs,
  cmp, repeat, select each) of the host-side path.

θ arrives via scalar prefetch in both kernels so the same compiled
kernel serves every denoising step's threshold.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams


def _reuse_kernel(theta_ref, x_e_ref, x_o_ref, out_o_ref, mask_o_ref):
    theta = theta_ref[0]
    x_e = x_e_ref[...]
    x_o = x_o_ref[...]
    delta = jnp.abs(x_o - x_e) * 0.5
    snap = delta < theta
    out_o_ref[...] = jnp.where(snap, x_e, x_o)
    mask_o_ref[...] = snap.astype(jnp.int8)


def reuse_snap_kernel(x_even: jax.Array, x_odd: jax.Array, theta: jax.Array,
                      *, block: int = 256, interpret: bool = False):
    """x_even/x_odd: (R, P, d) pair-split tokens; theta: (1,) f32.

    Returns (snapped_odd, mask_odd:int8); the even (representative) half
    is unchanged by definition.
    """
    R, P, d = x_even.shape
    block = min(block, P)
    assert P % block == 0
    grid = (R, P // block)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
        ],
    )
    return pl.pallas_call(
        _reuse_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, P, d), x_even.dtype),
            jax.ShapeDtypeStruct((R, P, d), jnp.int8),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(theta, x_even, x_odd)


# ---------------------------------------------------------------------------
# Fused multi-axis Δ-check + snap (DESIGN.md §8)
# ---------------------------------------------------------------------------

_AXIS_SLOT = {"t": 0, "x": 1, "y": 2}  # θ prefetch layout


def _gate(delta, theta, granularity: str):
    """Δ < θ at the requested granularity, broadcast back to Δ's shape."""
    if granularity == "channel":
        return delta < theta
    # 'token': the mean Δ over channels gates every channel of the token.
    ok = jnp.mean(delta, axis=-1, keepdims=True) < theta
    return jnp.broadcast_to(ok, delta.shape)


def _delta2(a0, a1):
    """Window-2 Eq. 3 Δ, with the same op sequence as the host's
    ``reuse.window_delta`` (mean, square, mean, sqrt) — algebraically
    |a1−a0|/2, but kept bitwise-identical so a threshold can never land
    between the two paths' roundings and flip a mask bit."""
    m = (a0 + a1) * 0.5
    return jnp.sqrt((jnp.square(a0 - m) + jnp.square(a1 - m)) * 0.5)


def _fused_kernel(theta_ref, x_ref, out_ref, mask_ref,
                  *, axes, granularity: str, width: int, with_t: bool):
    """One program = one (frame-pair, token-slab) tile.

    x_ref: (TT, block, d) with TT == 2 when the temporal check is live
    (tile rows are the even/odd frames of one t-pair) and TT == 1 for
    single-frame grids.  ``block`` is a multiple of ``2 * width`` so both
    x-neighbours and both y-row partners of every token sit in-tile.
    """
    x = x_ref[...]
    TT, block, d = x.shape
    masks, reps = {}, {}

    # t axis: Δ between the two frames; only the odd frame ever snaps.
    if with_t:
        delta_t = _delta2(x[0], x[1])
        ok_t = _gate(delta_t, theta_ref[_AXIS_SLOT["t"]], granularity)
        masks["t"] = jnp.stack([jnp.zeros_like(ok_t), ok_t])
        reps["t"] = jnp.stack([x[0], x[0]])
    else:
        masks["t"] = jnp.zeros(x.shape, jnp.bool_)
        reps["t"] = x

    # x axis: adjacent even/odd tokens within a row.
    xp = x.reshape(TT, block // 2, 2, d)
    delta_x = _delta2(xp[:, :, 0], xp[:, :, 1])
    ok_x = _gate(delta_x, theta_ref[_AXIS_SLOT["x"]], granularity)
    masks["x"] = jnp.stack([jnp.zeros_like(ok_x), ok_x],
                           axis=2).reshape(TT, block, d)
    reps["x"] = jnp.broadcast_to(xp[:, :, :1], xp.shape) \
        .reshape(TT, block, d)

    # y axis: adjacent row pairs (rows are ``width`` tokens long).
    nr = block // width
    xr = x.reshape(TT, nr // 2, 2, width, d)
    delta_y = _delta2(xr[:, :, 0], xr[:, :, 1])
    ok_y = _gate(delta_y, theta_ref[_AXIS_SLOT["y"]], granularity)
    masks["y"] = jnp.stack([jnp.zeros_like(ok_y), ok_y],
                           axis=2).reshape(TT, block, d)
    reps["y"] = jnp.broadcast_to(xr[:, :, :1], xr.shape) \
        .reshape(TT, block, d)

    # Step ② OR-aggregation, first-wins copy-source priority (the same
    # semantics as core.reuse.compute_reuse — all masks derive from the
    # *original* operand, not the progressively snapped one).
    snapped = x
    claimed = jnp.zeros(x.shape, jnp.bool_)
    for a in axes:
        take = jnp.logical_and(masks[a], jnp.logical_not(claimed))
        snapped = jnp.where(take, reps[a], snapped)
        claimed = jnp.logical_or(claimed, masks[a])
    out_ref[...] = snapped
    mask_ref[...] = claimed.astype(jnp.int8)


def fused_reuse_kernel(x: jax.Array, thetas: jax.Array, *,
                       axes, granularity: str, width: int, with_t: bool,
                       block: int, interpret: bool = False):
    """x: (G, TT, S, d) frame-pair-major grid tokens; thetas: (3,) [θt, θx, θy].

    G indexes (lead × frame-pair), TT ∈ {1, 2} is the pair dim, S = H·W
    tokens per frame.  Returns (snapped, mask:int8) shaped like x.
    """
    G, TT, S, d = x.shape
    assert TT == (2 if with_t else 1)
    assert S % block == 0 and block % (2 * width) == 0, (S, block, width)
    grid = (G, S // block)

    kernel = functools.partial(_fused_kernel, axes=tuple(axes),
                               granularity=granularity, width=width,
                               with_t=with_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, TT, block, d), lambda g, i, *_: (g, 0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, TT, block, d), lambda g, i, *_: (g, 0, i, 0)),
            pl.BlockSpec((None, TT, block, d), lambda g, i, *_: (g, 0, i, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((G, TT, S, d), x.dtype),
            jax.ShapeDtypeStruct((G, TT, S, d), jnp.int8),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(thetas, x)
