"""Fused Δ-check + snap Pallas kernel (paper Fig. 6 steps ①-②).

Computes, for adjacent window-2 pairs of tokens (pair-major layout —
callers permute other axes into adjacency with
``core.collapse.pair_major_order``):

    Δ_c   = |x[2j+1, c] − x[2j, c]| / 2          (Eq. 3 for K=2)
    snap  = Δ_c < θ
    out[2j+1, c] = snap ? x[2j, c] : x[2j+1, c]

in one VMEM pass, emitting the snapped operand and the mask. This fuses
what would otherwise be 5 HBM round-trips (slice, sub, abs, cmp, select)
into one read + two writes. θ arrives via scalar prefetch so the same
compiled kernel serves every denoising step's threshold.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _reuse_kernel(theta_ref, x_e_ref, x_o_ref, out_o_ref, mask_o_ref):
    theta = theta_ref[0]
    x_e = x_e_ref[...]
    x_o = x_o_ref[...]
    delta = jnp.abs(x_o - x_e) * 0.5
    snap = delta < theta
    out_o_ref[...] = jnp.where(snap, x_e, x_o)
    mask_o_ref[...] = snap.astype(jnp.int8)


def reuse_snap_kernel(x_even: jax.Array, x_odd: jax.Array, theta: jax.Array,
                      *, block: int = 256, interpret: bool = False):
    """x_even/x_odd: (R, P, d) pair-split tokens; theta: (1,) f32.

    Returns (snapped_odd, mask_odd:int8); the even (representative) half
    is unchanged by definition.
    """
    R, P, d = x_even.shape
    block = min(block, P)
    assert P % block == 0
    grid = (R, P // block)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
            pl.BlockSpec((None, block, d), lambda r, i, *_: (r, i, 0)),
        ],
    )
    return pl.pallas_call(
        _reuse_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((R, P, d), x_even.dtype),
            jax.ShapeDtypeStruct((R, P, d), jnp.int8),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(theta, x_even, x_odd)
