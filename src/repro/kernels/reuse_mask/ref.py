"""Pure-jnp oracles for the reuse-snap kernels."""

from __future__ import annotations

import jax.numpy as jnp


def reuse_snap_ref(x_even, x_odd, theta):
    """Window-2 Eq. 3 check + snap along adjacent pairs."""
    delta = jnp.abs(x_odd - x_even) * 0.5
    snap = delta < theta
    return jnp.where(snap, x_even, x_odd), snap.astype(jnp.int8)


def fused_reuse_ref(x, grid, thetas, axes=("t", "x", "y"),
                    granularity="channel"):
    """Oracle for the fused multi-axis kernel: the host-side pipeline.

    ``core.reuse.compute_reuse`` *is* the reference semantics the fused
    kernel must reproduce bit-for-bit on its eligible shapes; the import
    is deferred so kernel modules stay importable without the core.
    """
    from repro.core.reuse import compute_reuse

    r = compute_reuse(x, grid, thetas, axes=axes, window=2,
                      granularity=granularity)
    return r.snapped, r.mask
