"""Pure-jnp oracle for the fused reuse-snap kernel."""

from __future__ import annotations

import jax.numpy as jnp


def reuse_snap_ref(x_even, x_odd, theta):
    """Window-2 Eq. 3 check + snap along adjacent pairs."""
    delta = jnp.abs(x_odd - x_even) * 0.5
    snap = delta < theta
    return jnp.where(snap, x_even, x_odd), snap.astype(jnp.int8)
