"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  scale: float | None = None) -> jax.Array:
    """q: (..., Nq, d), k: (..., Nk, d), v: (..., Nk, dv)."""
    if scale is None:
        scale = float(1.0 / (q.shape[-1] ** 0.5))
    s = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kv->...qv", p.astype(v.dtype), v)
