"""Jitted public wrapper for the flash attention kernel.

Handles (B, H, N, d) layouts, non-aligned sequence lengths (zero-pad +
renormalization via a padding key that attends nowhere), and interpret
mode on CPU (kernel body executed in Python for correctness validation —
this container has no TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash.kernel import flash_attention as _kernel

_NEG_INF = -1e30


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _pad_to(x, target, axis):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q,k,v: (B, H, N, d) -> (B, H, N, dv)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, H, Nq, d = q.shape
    Nk = k.shape[2]
    dv = v.shape[3]
    scale = float(1.0 / (d ** 0.5))

    bq = min(block_q, max(Nq, 1))
    bk = min(block_k, max(Nk, 1))
    Nq_p = -(-Nq // bq) * bq
    Nk_p = -(-Nk // bk) * bk

    qp = _pad_to(q, Nq_p, 2).reshape(B * H, Nq_p, d)
    kp = _pad_to(k, Nk_p, 2).reshape(B * H, Nk_p, d)
    vp = _pad_to(v, Nk_p, 2).reshape(B * H, Nk_p, dv)
    if Nk_p != Nk:
        # Padded keys must attend to nothing: push their logits to -inf by
        # scaling a huge negative into the padded K rows via a bias trick —
        # cheaper: set padded K rows to 0 and subtract mass afterwards is
        # wrong; instead give padded keys a large negative projection on a
        # constant channel. Simplest correct route: extend d by one channel
        # that is 1 for queries and -inf-ish for padded keys.
        flag_q = jnp.ones((B * H, Nq_p, 1), qp.dtype)
        flag_k = jnp.zeros((B * H, Nk_p, 1), kp.dtype)
        flag_k = flag_k.at[:, Nk:, :].set(_NEG_INF * scale * 0 + _NEG_INF / 128.0)
        qp = jnp.concatenate([qp, flag_q], axis=-1)
        kp = jnp.concatenate([kp, flag_k], axis=-1)
        # keep the same softmax scale as the unpadded head_dim
        scale_eff = scale
    else:
        scale_eff = scale

    out = _kernel(qp, kp, vp, scale=scale_eff, block_q=bq, block_k=bk,
                  interpret=interpret)
    return out.reshape(B, H, Nq_p, dv)[:, :, :Nq, :]
