"""Tiled online-softmax (flash) attention Pallas TPU kernel.

Baseline kernel every attention call in the framework can route through.
Grid: (batch·heads, q_blocks, k_blocks) with the k dimension innermost
("arbitrary" semantics) carrying running max / sum / accumulator in VMEM
scratch.  Block shapes are MXU-aligned (multiples of 128 on the token
dims; head_dim padded to 128 by the ops wrapper when needed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    m_prev = m_ref[...][:, :1]                      # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)       # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                          # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
    l_new = alpha * l_ref[...][:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / l_ref[...][:, :1]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q: (BH, Nq, d), k: (BH, Nk, d), v: (BH, Nk, dv) -> (BH, Nq, dv).

    Nq/Nk must be divisible by the block sizes (ops.py pads).
    """
    BH, Nq, d = q.shape
    Nk = k.shape[1]
    dv = v.shape[2]
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    block_q = min(block_q, Nq)
    block_k = min(block_k, Nk)
    nq = Nq // block_q
    nk = Nk // block_k
    assert Nq % block_q == 0 and Nk % block_k == 0, (Nq, Nk, block_q, block_k)

    kernel = functools.partial(_flash_kernel, scale=scale, nk=nk)
    grid = (BH, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((None, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((None, block_k, dv), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dv), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Nq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
