"""AdamW + LR schedules, built from scratch (no optax in this env).

Optimizer state is fp32 regardless of the parameter dtype; the sharding
of each state leaf follows its parameter (FSDP — the launcher maps both
through the same logical axes)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig
from repro.utils.pytree import tree_global_norm


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw_init(params) -> AdamWState:
    f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(f32zeros, params),
        nu=jax.tree_util.tree_map(f32zeros, params),
    )


def abstract_adamw_state(abstract_params) -> AdamWState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree_util.tree_map(f32, abstract_params),
        nu=jax.tree_util.tree_map(f32, abstract_params),
    )


def lr_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    peak = cfg.learning_rate
    warm = max(cfg.warmup_steps, 1)
    total = max(cfg.total_steps, warm + 1)

    def f(step):
        step = step.astype(jnp.float32)
        warmup = peak * step / warm
        if cfg.schedule == "constant":
            after = jnp.full_like(warmup, peak)
        elif cfg.schedule == "linear":
            frac = jnp.clip((step - warm) / (total - warm), 0.0, 1.0)
            after = peak * (1.0 - frac)
        else:  # cosine
            frac = jnp.clip((step - warm) / (total - warm), 0.0, 1.0)
            after = 0.5 * peak * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warm, warmup, after)

    return f


def adamw_update(grads, state: AdamWState, params, cfg: TrainConfig):
    """One AdamW step with global-norm clipping. Returns
    (new_params, new_state, metrics)."""
    gnorm = tree_global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.ones(())
    step = state.step + 1
    lr = lr_schedule(cfg)(step)
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
