"""Train-step construction + the host-side training loop.

``make_train_step`` composes: loss -> grad (with optional microbatch
accumulation via lax.scan) -> optional int8 error-feedback compression ->
AdamW -> optional EMA, into a single jittable function whose signature is
identical across model families:

    train_step(state, batch, rng) -> (state, metrics)

``TrainState`` is a NamedTuple so abstract versions can be built for the
dry-run without touching device memory.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import TrainConfig
from repro.distributed.collectives import (CompressionState,
                                           abstract_compression_state,
                                           compress_grads, compression_init)
from repro.training.optimizer import (AdamWState, abstract_adamw_state,
                                      adamw_init, adamw_update)
from repro.utils.logging import get_logger
from repro.utils.loops import scan_layers

log = get_logger("train")


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    compression: Optional[CompressionState]
    ema: Optional[Any]


def train_state_init(params, cfg: TrainConfig) -> TrainState:
    return TrainState(
        params=params,
        opt=adamw_init(params),
        compression=compression_init(params) if cfg.grad_compression else None,
        ema=jax.tree_util.tree_map(jnp.copy, params) if cfg.ema_decay else None,
    )


def abstract_train_state(abstract_params, cfg: TrainConfig) -> TrainState:
    return TrainState(
        params=abstract_params,
        opt=abstract_adamw_state(abstract_params),
        compression=(abstract_compression_state(abstract_params)
                     if cfg.grad_compression else None),
        ema=(jax.tree_util.tree_map(lambda p: p, abstract_params)
             if cfg.ema_decay else None),
    )


def train_state_logical_axes(param_axes, cfg: TrainConfig) -> TrainState:
    """Optimizer/EMA/compression state shards exactly like its param."""
    return TrainState(
        params=param_axes,
        opt=AdamWState(step=(), mu=param_axes, nu=param_axes),
        compression=(CompressionState(error=param_axes)
                     if cfg.grad_compression else None),
        ema=param_axes if cfg.ema_decay else None,
    )


def make_train_step(
    loss_fn: Callable[..., Tuple[jax.Array, Dict]],
    cfg: TrainConfig,
) -> Callable:
    """loss_fn(params, batch, rng) -> (scalar loss, metrics dict)."""

    def compute_grads(params, batch, rng):
        if cfg.grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, rng)
            return loss, metrics, grads

        # Microbatch accumulation: leading batch dim splits into
        # (accum, micro); scan keeps peak activation memory at 1 micro.
        def micro(carry, mb):
            acc, rng = carry
            rng, sub = jax.random.split(rng)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb, sub)
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, rng), (loss, metrics)

        split = lambda x: x.reshape(cfg.grad_accum,
                                    x.shape[0] // cfg.grad_accum, *x.shape[1:])
        micro_batch = jax.tree_util.tree_map(split, batch)
        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, _), (losses, metrics) = scan_layers(
            micro, (zero, rng), micro_batch)
        grads = jax.tree_util.tree_map(lambda g: g / cfg.grad_accum, grads)
        metrics = jax.tree_util.tree_map(jnp.mean, metrics)
        return jnp.mean(losses), metrics, grads

    def step(state: TrainState, batch, rng) -> Tuple[TrainState, Dict]:
        loss, metrics, grads = compute_grads(state.params, batch, rng)
        compression = state.compression
        if compression is not None:
            grads, compression = compress_grads(grads, compression)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, cfg)
        ema = state.ema
        if ema is not None:
            d = cfg.ema_decay
            ema = jax.tree_util.tree_map(
                lambda e, p: d * e + (1 - d) * p.astype(e.dtype),
                ema, new_params)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(new_params, new_opt, compression, ema), metrics

    return step


def run_train_loop(
    step_fn,
    state: TrainState,
    batch_iter,
    num_steps: int,
    *,
    rng: jax.Array,
    checkpointer=None,
    checkpoint_every: int = 0,
    log_every: int = 10,
    start_step: int = 0,
) -> Tuple[TrainState, list]:
    """Host loop: data feeding, metrics, periodic (async) checkpoints."""
    history = []
    t0 = time.time()
    for i in range(start_step, num_steps):
        batch = next(batch_iter)
        rng, sub = jax.random.split(rng)
        state, metrics = step_fn(state, batch, sub)
        if log_every and (i % log_every == 0 or i == num_steps - 1):
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": i, **m})
            log.info("step %d loss %.4f (%.2fs)", i, m.get("loss", float("nan")),
                     time.time() - t0)
        if checkpointer is not None and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            checkpointer.save(i + 1, state)
    return state, history
