"""Logical-axis sharding: the single place where logical names meet the mesh.

Models annotate parameters (``repro.models.params``) and activations with
*logical* axis names; workloads pick a rule table mapping logical names to
mesh axes.  The launcher composes these into concrete
``NamedSharding``/``PartitionSpec`` trees for pjit.

Rule tables are functions of the mesh because the production mesh comes
in two shapes — single-pod ``(data=16, model=16)`` and multi-pod
``(pod=2, data=16, model=16)`` — and the batch axis must absorb the
"pod" dimension only when it exists.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# --- parameter rules ---------------------------------------------------------

def param_rules(mesh: Optional[Mesh], fsdp: bool = True) -> Dict[str, Any]:
    """Logical param axis -> mesh axes. FSDP shards the 'embed' dim of
    weights over the batch axes (ZeRO-3 style); tensor dims over 'model'."""
    bd = batch_axes(mesh)
    return {
        "embed": bd if fsdp else None,
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",
        "layers": None,
        None: None,
    }


def spec_from_axes(axes: Sequence[Optional[str]], rules: Dict[str, Any],
                   shape: Optional[Tuple[int, ...]] = None,
                   mesh: Optional[Mesh] = None) -> P:
    """Map a logical-axis tuple to a PartitionSpec, dropping assignments
    that do not divide the dimension (e.g. kv heads 8 on a model axis of
    16 fall back to replicated)."""
    entries = []
    used = set()
    for i, a in enumerate(axes):
        target = rules.get(a, None)
        if target is not None and mesh is not None and shape is not None:
            if shape[i] % axis_size(mesh, target) != 0:
                target = None
        # one mesh axis may appear only once in a spec
        flat = (target,) if isinstance(target, str) else tuple(target or ())
        if any(t in used for t in flat):
            target = None
        else:
            used.update(flat)
        entries.append(target)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def param_sharding_tree(logical_tree, mesh: Mesh, rules: Dict[str, Any],
                        abstract_tree=None):
    """Tree of NamedShardings for a logical-axes tree (+shapes to validate
    divisibility when ``abstract_tree`` given)."""
    def is_axes(x):
        return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)

    if abstract_tree is None:
        return jax.tree_util.tree_map(
            lambda axes: NamedSharding(mesh, spec_from_axes(axes, rules)),
            logical_tree, is_leaf=is_axes)
    return jax.tree_util.tree_map(
        lambda axes, ab: NamedSharding(
            mesh, spec_from_axes(axes, rules, ab.shape, mesh)),
        logical_tree, abstract_tree, is_leaf=is_axes)


# --- activation rules / ShardCtx ---------------------------------------------


def train_act_rules(mesh: Optional[Mesh],
                    seq_parallel: bool = False) -> Dict[str, Any]:
    bd = batch_axes(mesh)
    return {
        "batch": bd, "seq": "model" if seq_parallel else None,
        "embed": None,
        "heads": "model", "kv": None, "mlp": "model", "vocab": "model",
        "expert": "model", "kv_seq": None, None: None,
        # attention operands always need the full sequence per head:
        "attn_seq": None,
    }


def decode_act_rules(mesh: Optional[Mesh], long_context: bool = False,
                     replicate_heads: bool = False) -> Dict[str, Any]:
    bd = batch_axes(mesh)
    rules = train_act_rules(mesh)
    # KV cache sequence shards over 'model' (flash-decode combine); for
    # 512k single-request decode it spreads over every axis.
    rules["kv_seq"] = (*bd, "model") if long_context else "model"
    if long_context:
        rules["batch"] = ()
    if replicate_heads:
        # decode attention FLOPs are tiny; replicating q-heads avoids the
        # heads<->kv_seq resharding ping-pong on the model axis.
        rules["heads"] = None
    return rules


def seqpar_act_rules(mesh: Optional[Mesh], batch: int) -> Dict[str, Any]:
    """Inference sequence-parallel rules for small-batch diffusion/vision:
    give the batch the largest prefix of (pod, data) that divides it and
    hand leftover axes to the token dim."""
    bd = list(batch_axes(mesh))
    b_axes, s_axes = [], []
    remaining = batch
    for a in bd:
        n = mesh.shape[a] if mesh else 1
        if remaining % n == 0 and remaining >= n:
            b_axes.append(a)
            remaining //= n
        else:
            s_axes.append(a)
    rules = train_act_rules(mesh)
    rules["batch"] = tuple(b_axes)
    rules["seq"] = tuple(s_axes)
    # inference sequence parallelism shards attention rows too
    rules["attn_seq"] = tuple(s_axes)
    return rules


@dataclasses.dataclass
class ShardCtx:
    """Threaded through model code; applies activation constraints."""

    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Any]] = None

    def c(self, x, axes: Sequence[Optional[str]]):
        """Constrain activation ``x`` whose dims carry logical ``axes``."""
        if self.mesh is None or self.rules is None:
            return x
        spec = spec_from_axes(axes, self.rules, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


NULL_CTX = ShardCtx()
