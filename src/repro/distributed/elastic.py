"""Elastic scaling: re-shard a training state onto a different mesh.

Checkpoints store unsharded (gathered) arrays keyed by tree path, so a
job restarted on a different topology rebuilds shardings from the same
logical-axis rules against the *new* mesh and device_puts each leaf.
Tested 1→4→2 fake-device transitions in tests/test_distributed.py.

At real 1000+ node scale arrays would be saved as per-shard files with
an index (same manifest pattern); the resharding math is identical —
logical axes are mesh-independent, which is the point of the indirection.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import param_rules, param_sharding_tree


def reshard_state(state, logical_tree, mesh: Mesh,
                  rules: Dict[str, Any] | None = None):
    """device_put every leaf of ``state`` per ``logical_tree`` on ``mesh``."""
    rules = rules or param_rules(mesh)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    shardings = param_sharding_tree(logical_tree, mesh, rules, abstract)
    return jax.tree_util.tree_map(jax.device_put, state, shardings)
