"""Distributed-optimization tricks: int8 gradient compression with error
feedback, and a straggler-tolerant bounded-staleness reducer.

At 1000+ nodes the cross-pod (DCN) all-reduce is the scaling wall; int8
per-tensor-scaled compression cuts those bytes 4x vs fp32 / 2x vs bf16.
Error feedback (Seide et al. '14; Karimireddy et al. '19) keeps the
quantization residual locally and re-injects it next step, preserving
convergence (unit-tested in tests/test_distributed.py).

Under GSPMD the data-parallel gradient reduction is implicit, so the
compression is applied as a gradient *transform* at the accumulation /
communication boundary: q(dq(g)+e) with residual e carried in the
optimizer extras.  On a real multi-pod deployment the same transform
wraps the cross-pod reduce (the collective then moves int8, which the
roofline collective term accounts for via the bytes model below).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # residual pytree (same structure as grads)


def compression_init(params) -> CompressionState:
    return CompressionState(
        error=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def abstract_compression_state(abstract_params) -> CompressionState:
    return CompressionState(
        error=jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
            abstract_params))


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, state: CompressionState):
    """Error-feedback int8 round trip: returns (decompressed grads,
    new residual state).  The int8 tensor is what crosses the wire."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), g32 - dq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return new_g, CompressionState(error=new_e)


def compressed_bytes(grads) -> int:
    """Bytes an int8-compressed reduce moves (for the roofline model)."""
    return sum(int(jnp.size(g)) for g in jax.tree_util.tree_leaves(grads)) \
        + 4 * len(jax.tree_util.tree_leaves(grads))
