"""Straggler mitigation: bounded-staleness barrier policy.

At thousands of hosts the slowest worker sets the step time; the standard
mitigations are (a) backup workers, (b) bounded staleness (skip a host's
contribution if it exceeds a deadline, rescale the gradient), (c)
checkpoint-evict-replace.  This module implements policy (b) as a
deterministic, unit-testable state machine the launcher consults each
step; the collective itself is simulated here (this container has one
host) and the policy decisions are what the tests assert on.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple


@dataclasses.dataclass
class StragglerPolicy:
    """Skip hosts slower than ``deadline_factor`` x median step time, but
    never drop more than ``max_skip_fraction`` of hosts, and evict hosts
    skipped ``evict_after`` consecutive steps (replace from spares)."""

    deadline_factor: float = 2.0
    max_skip_fraction: float = 0.05
    evict_after: int = 10

    def __post_init__(self):
        self.skip_streak: Dict[int, int] = {}

    def decide(self, step_times: Sequence[float]) -> Tuple[List[int], List[int]]:
        """step_times[i] = host i's reported duration for this step.
        Returns (skipped_hosts, evicted_hosts)."""
        n = len(step_times)
        ordered = sorted(step_times)
        median = ordered[n // 2]
        deadline = self.deadline_factor * median
        candidates = [i for i, t in enumerate(step_times) if t > deadline]
        max_skips = int(self.max_skip_fraction * n)
        # skip the slowest first, bounded
        candidates.sort(key=lambda i: -step_times[i])
        skipped = candidates[:max_skips]
        evicted = []
        for i in range(n):
            if i in skipped:
                self.skip_streak[i] = self.skip_streak.get(i, 0) + 1
                if self.skip_streak[i] >= self.evict_after:
                    evicted.append(i)
                    self.skip_streak[i] = 0
            else:
                self.skip_streak[i] = 0
        return skipped, evicted

    @staticmethod
    def gradient_rescale(n_hosts: int, skipped: Sequence[int]) -> float:
        """Contribution rescale so the expected gradient is unbiased when
        ``len(skipped)`` hosts' microbatches are excluded."""
        kept = n_hosts - len(skipped)
        return n_hosts / max(kept, 1)
