"""TimeRipple reuse: windowed Δ similarity checks + operand snapping.

Paper §3.3 steps ①-②.  For both Q and K, tokens on the (T, H, W) latent
grid undergo a similarity check along each of the temporal / x / y axes.
The similarity of a window ``a`` of ``K`` tokens at one channel is the
standard error (Eq. 3)::

    Δ(a) = sqrt( Σ_i (a_i − ā)² / K )

Windows partition each axis (window size 2 ⇒ "every two adjacent
frames").  Where Δ is below the axis threshold, the non-representative
window elements are *snapped* to the representative (the first element —
paper Fig. 5 reuses the first frame/row/token of each consecutive pair).
Because attention logits are bilinear, snapping the operand is exactly
equivalent to reusing the partial attention scores (DESIGN.md §2).

Token order convention: row-major ``(t, y, x)`` — ``index = (t*H + y)*W + x``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

AXES = ("t", "x", "y")
# Grid dims are (..., T, H, W, d): axis name -> which dim the window runs on
# (negative, counted from the channel dim at -1).
_AXIS_DIM = {"t": -4, "y": -3, "x": -2}


@dataclasses.dataclass
class ReuseResult:
    """Output of :func:`compute_reuse`.

    snapped:  x with reusable entries overwritten by their representative.
    mask:     bool, same shape as x; True where the value was snapped.
    axis_masks: per-axis bool masks (before priority resolution).
    src_idx:  only with ``want_src=True``: int32, same shape as x, the
              token index each entry's value was copied from (its own
              index where nothing snapped).  Re-applying the decision to
              fresh operands is then one ``take_along_axis`` gather —
              the cacheable half of the decision (DESIGN.md §13).
    """

    snapped: jax.Array
    mask: jax.Array
    axis_masks: Dict[str, jax.Array]
    src_idx: Optional[jax.Array] = None


def window_delta(x: jax.Array, dim: int, window: int) -> Tuple[jax.Array, jax.Array]:
    """Per-window, per-channel Δ (Eq. 3) and the window representative.

    ``x`` has the window axis at ``dim`` (length L); the trailing axis is
    channels. Returns ``(delta, rep)`` with the window axis reduced to
    ``L // window`` groups. Remainder elements (L % window) are excluded
    — callers never snap them.
    """
    dim = dim % x.ndim
    L = x.shape[dim]
    n = L // window
    head = jax.lax.slice_in_dim(x, 0, n * window, axis=dim)
    new_shape = head.shape[:dim] + (n, window) + head.shape[dim + 1 :]
    grouped = head.reshape(new_shape)
    mean = grouped.mean(axis=dim + 1, keepdims=True)
    # Population std over the window — for window 2 this is |a0 − a1| / 2.
    delta = jnp.sqrt(jnp.mean(jnp.square(grouped - mean), axis=dim + 1))
    rep = jax.lax.index_in_dim(grouped, 0, axis=dim + 1, keepdims=False)
    return delta, rep


def _expand_window(mask_or_rep: jax.Array, dim: int, window: int, length: int,
                   first_is_rep: bool) -> jax.Array:
    """Broadcast per-window values back to per-token positions.

    For masks, the representative slot (first of each window) is forced
    False when ``first_is_rep`` — the representative itself is always
    computed, only the followers reuse it.
    """
    dim = dim % (mask_or_rep.ndim)  # same rank as x
    n = mask_or_rep.shape[dim]
    expanded = jnp.repeat(mask_or_rep, window, axis=dim)
    if first_is_rep:
        # zero out every window-first position
        idx = jnp.arange(n * window) % window == 0
        shape = [1] * expanded.ndim
        shape[dim] = n * window
        expanded = jnp.logical_and(expanded, ~idx.reshape(shape))
    pad = length - n * window
    if pad > 0:
        pad_shape = list(expanded.shape)
        pad_shape[dim] = pad
        filler = jnp.zeros(pad_shape, dtype=expanded.dtype)
        expanded = jnp.concatenate([expanded, filler], axis=dim)
    return expanded


def _group_bounds(head_dim: int, channel_groups: Sequence[float]) -> Dict[str, Tuple[int, int]]:
    """RoPE channel-group slices (t, x, y) from fractional split."""
    ct = int(round(channel_groups[0] * head_dim))
    cx = int(round(channel_groups[1] * head_dim))
    ct = max(min(ct, head_dim), 0)
    cx = max(min(cx, head_dim - ct), 0)
    return {"t": (0, ct), "x": (ct, ct + cx), "y": (ct + cx, head_dim)}


def axis_reuse_mask(
    x_grid: jax.Array,
    axis: str,
    theta: jax.Array,
    window: int,
    granularity: str = "channel",
    channel_groups: Sequence[float] = (0.125, 0.4375, 0.4375),
) -> Tuple[jax.Array, jax.Array]:
    """Reuse mask and representative values along one grid axis.

    x_grid: (..., T, H, W, d).  Returns (mask, rep_values) both shaped
    like ``x_grid``; ``rep_values`` holds the representative's value at
    every position (identity at non-snappable positions).
    """
    dim = _AXIS_DIM[axis] % x_grid.ndim
    length = x_grid.shape[dim]
    if length < window:
        mask = jnp.zeros(x_grid.shape, dtype=jnp.bool_)
        return mask, x_grid
    delta, rep = window_delta(x_grid, dim, window)
    theta = jnp.asarray(theta, dtype=x_grid.dtype)
    if granularity == "channel":
        ok = delta < theta  # (..., n, H, W, d)
    elif granularity == "token":
        ok = jnp.mean(delta, axis=-1, keepdims=True) < theta
        ok = jnp.broadcast_to(ok, delta.shape)
    elif granularity == "group":
        # mean Δ within each RoPE channel group gates that group's channels.
        bounds = _group_bounds(x_grid.shape[-1], channel_groups)
        parts = []
        for name in AXES:
            lo, hi = bounds[name]
            if hi <= lo:
                continue
            seg = delta[..., lo:hi]
            seg_ok = jnp.mean(seg, axis=-1, keepdims=True) < theta
            parts.append(jnp.broadcast_to(seg_ok, seg.shape))
        ok = jnp.concatenate(parts, axis=-1)
    else:
        raise ValueError(f"unknown granularity {granularity!r}")
    mask = _expand_window(ok, dim, window, length, first_is_rep=True)
    rep_full = _expand_window(rep, dim, window, length, first_is_rep=False)
    # Remainder positions: rep_full was zero-padded; fall back to identity.
    n = (length // window) * window
    if n < length:
        idx = jnp.arange(length) < n
        shape = [1] * x_grid.ndim
        shape[dim] = length
        rep_full = jnp.where(idx.reshape(shape), rep_full, x_grid)
    return mask, rep_full


def axis_source_tokens(grid: Tuple[int, int, int], axis: str,
                       window: int) -> jax.Array:
    """(N,) int32 map: each token's window-representative token index
    along ``axis`` (identity on the remainder tail that never snaps).
    Token order is the module's row-major (t, y, x) convention."""
    T, H, W = grid
    t, y, x = jnp.meshgrid(jnp.arange(T), jnp.arange(H), jnp.arange(W),
                           indexing="ij")
    coords = {"t": t, "y": y, "x": x}
    length = {"t": T, "y": H, "x": W}[axis]
    n = (length // window) * window
    c = coords[axis]
    coords[axis] = jnp.where(c < n, (c // window) * window, c)
    flat = (coords["t"] * H + coords["y"]) * W + coords["x"]
    return flat.reshape(-1).astype(jnp.int32)


def compute_reuse(
    x: jax.Array,
    grid: Tuple[int, int, int],
    thetas: Dict[str, jax.Array],
    axes: Sequence[str] = AXES,
    window: int = 2,
    granularity: str = "channel",
    channel_groups: Sequence[float] = (0.125, 0.4375, 0.4375),
    protect_axis: Optional[str] = None,
    want_src: bool = False,
    t_valid: Optional[jax.Array] = None,
) -> ReuseResult:
    """Full TimeRipple reuse for one operand (Q or K).

    x: (..., N, d) with N == T*H*W tokens in (t, y, x) row-major order.
    thetas: per-axis thresholds {"t": θt, "x": θx, "y": θy} (jax scalars ok).
    Aggregation is a logical OR across axes (paper step ②); where several
    axes pass, the first axis in ``axes`` wins the copy source
    (they are interchangeable — all passed the similarity test).

    ``protect_axis`` is the collapse-aware scheduling refinement
    (beyond-paper, DESIGN.md §4): window *representatives* along that
    axis are never snapped by the *other* axes.  Without it, a high
    threshold lets x/y snap the t-representatives, the value-identity of
    t-pairs breaks, and the structured kernel loses its block skips —
    protecting the representatives costs only the cross-axis reuse of
    half the tokens but preserves the full pair-collapse structure.

    ``want_src`` additionally materializes ``ReuseResult.src_idx``, the
    per-entry snap-source token map the decision cache replays with a
    single gather (DESIGN.md §13).  ``take_along_axis(x, src_idx, -2)``
    is bitwise-identical to ``snapped``: both copy the representative's
    float entries verbatim.

    ``t_valid`` is a (T,) boolean (traced values allowed) gating the
    **temporal** axis only: frames where it is False never t-snap (their
    x/y checks still apply).  The context-parallel ring path (DESIGN.md
    §14) uses it to disqualify windows that extend past the *global*
    frame count when reuse runs on a halo-extended shard-local slab.
    """
    T, H, W = grid
    *lead, N, d = x.shape
    if N != T * H * W:
        raise ValueError(f"token count {N} != grid {grid}")
    x_grid = x.reshape(*lead, T, H, W, d)

    protected = None
    if protect_axis is not None:
        dim = _AXIS_DIM[protect_axis] % x_grid.ndim
        length = x_grid.shape[dim]
        is_rep = (jnp.arange(length) % window == 0) \
            & (jnp.arange(length) < (length // window) * window)
        shp = [1] * x_grid.ndim
        shp[dim] = length
        protected = jnp.broadcast_to(is_rep.reshape(shp), x_grid.shape)

    snapped = x_grid
    claimed = jnp.zeros(x_grid.shape, dtype=jnp.bool_)
    axis_masks: Dict[str, jax.Array] = {}
    src = None
    if want_src:
        src = jnp.broadcast_to(
            jnp.arange(N, dtype=jnp.int32).reshape(
                (1,) * len(lead) + (N, 1)), (*lead, N, d))
    for axis in axes:
        mask, rep = axis_reuse_mask(
            x_grid, axis, thetas[axis], window, granularity, channel_groups
        )
        if axis == "t" and t_valid is not None:
            shp = [1] * x_grid.ndim
            shp[_AXIS_DIM["t"] % x_grid.ndim] = T
            mask = jnp.logical_and(mask, t_valid.reshape(shp))
        if protected is not None and axis != protect_axis:
            mask = jnp.logical_and(mask, ~protected)
        axis_masks[axis] = mask
        take = jnp.logical_and(mask, ~claimed)  # first-wins priority
        snapped = jnp.where(take, rep, snapped)
        if want_src:
            ax_src = axis_source_tokens(grid, axis, window)
            src = jnp.where(take.reshape(*lead, N, d),
                            ax_src[:, None], src)
        claimed = jnp.logical_or(claimed, mask)

    return ReuseResult(
        snapped=snapped.reshape(*lead, N, d),
        mask=claimed.reshape(*lead, N, d),
        axis_masks={a: m.reshape(*lead, N, d) for a, m in axis_masks.items()},
        src_idx=src,
    )


def snap_tokens(
    x: jax.Array,
    grid: Tuple[int, int, int],
    thetas: Dict[str, jax.Array],
    **kw,
) -> jax.Array:
    """Convenience: snapped operand only."""
    return compute_reuse(x, grid, thetas, **kw).snapped


def sequence_reuse_1d(x: jax.Array, theta: jax.Array, window: int = 2) -> ReuseResult:
    """Experimental 1-D reuse on LM token sequences (DESIGN.md §6).

    Treats the sequence as a (T, 1, 1) grid with only the temporal check.
    Not part of the paper's claims; off by default everywhere.
    """
    *lead, N, d = x.shape
    return compute_reuse(
        x, (N, 1, 1), {"t": theta, "x": jnp.inf, "y": jnp.inf},
        axes=("t",), window=window,
    )
