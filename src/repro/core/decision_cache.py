"""Cross-step reuse-decision cache (DESIGN.md §13).

TIMERIPPLE's premise is that the spatio-temporal correlations the
Δ-checks measure are *stable* in latent space — yet the pipeline used to
recompute the full reuse decision (windowed Δ-stats on three axes,
snap-mask resolution, block-map tiling) on every attention call of every
denoising step, paying the decision ``steps × layers`` times per video
while the decided masks barely change between adjacent steps.  This
module amortizes that cost the way Sparse VideoGen amortizes online
profiling and Sparse-vDiT amortizes offline pattern search — but keeps
the per-step math exact, because only the *decision* is reused: the
cached plan is re-applied to the **fresh** Q/K values each step.

The cacheable plan of one :class:`~repro.core.policy.ReuseDecision` is a
:class:`CachedDecision`:

  * ``q_idx`` / ``k_idx`` — snap-source token maps (operand-rewriting
    policies); replaying one is a single ``take_along_axis`` gather,
  * ``bias`` / ``block_map`` — mask-emitting policies; reused verbatim,
    so for block-map policies a cache hit skips ``decide()`` entirely
    (the sparse kernel only needs the map),
  * ``ref_stat`` — the sampled-channel Δ statistic recorded when the
    decision was made (per (batch, head) cell, so shard_map slices it
    like the operands — decisions are shard-local, zero halo),
  * ``hits`` / ``refreshes`` — per-cell counters for serving telemetry.

Refresh policy: a decision is recomputed when ``step % cfg.reuse_every
== 0`` or, with ``cfg.drift_tol > 0``, when the cheap drift statistic of
the fresh operands moved more than ``drift_tol`` (relative) from
``ref_stat`` — so the cadence is adaptive, not blind.  The whole
refresh-vs-reuse choice runs under ``lax.cond`` inside
``attention_dispatch``, which makes the state scan-carriable: samplers
thread one stacked :class:`CachedDecision` per layer through their
``lax.scan`` carry (``diffusion.sampler``, ``models.vdit``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RippleConfig
from repro.core.policy import ReuseDecision, get_policy

__all__ = ["CachedDecision", "cache_from_decision", "drift_stat",
           "initial_state", "merge_states", "refresh_due", "slice_state",
           "state_from_arrays", "state_to_arrays", "supports_cache"]


@dataclasses.dataclass
class CachedDecision:
    """Scan-carriable plan half of one reuse decision (see module doc).

    Every array leaf keeps the operands' leading (batch, head, ...)
    dims, so under shard_map each shard carries exactly its own cache
    slice (DESIGN.md §10/§13)."""

    q_idx: Optional[jax.Array] = None      # int32 (..., Ng, d) snap sources
    k_idx: Optional[jax.Array] = None
    bias: Optional[jax.Array] = None       # f32 additive logit mask
    block_map: Optional[jax.Array] = None  # int32 (..., nq, nk) tile states
    ref_stat: Optional[jax.Array] = None   # f32 lead-shaped drift reference
    hits: Optional[jax.Array] = None       # i32 lead-shaped counters
    refreshes: Optional[jax.Array] = None
    # Ring-path telemetry (DESIGN.md §14): running count of elided ring
    # hops, a (1,) i32 per seq shard.  Only the context-parallel sparse
    # path populates it; everywhere else it stays None so existing
    # cache structures are untouched.
    elided: Optional[jax.Array] = None
    # Guardrail sentinels (DESIGN.md §17): running non-finite count of
    # the attention outputs and max dense-probe relative error, both
    # lead-shaped like ``hits``.  Populated only when ``cfg.sentinel``
    # is on; None otherwise, same contract as ``elided``.
    nonfinite: Optional[jax.Array] = None  # i32 lead-shaped
    probe_err: Optional[jax.Array] = None  # f32 lead-shaped


jax.tree_util.register_dataclass(
    CachedDecision,
    data_fields=["q_idx", "k_idx", "bias", "block_map", "ref_stat",
                 "hits", "refreshes", "elided", "nonfinite", "probe_err"],
    meta_fields=[])


def supports_cache(cfg: RippleConfig, policy=None) -> bool:
    """Can dispatch carry decisions across steps for this config?  The
    gate callers check before threading state: the config must be active
    and the resolved policy must declare the capability
    (``ReusePolicy.caches_decisions``) — pre-cache policies keep their
    original ``decide`` signature and simply never see the cache."""
    if not cfg.active():
        return False
    pol = get_policy(policy if policy is not None else cfg.policy)
    return (not pol.is_dense) and pol.will_cache_decisions(cfg)


def drift_stat(q: jax.Array, k: jax.Array, cfg: RippleConfig) -> jax.Array:
    """Cheap sampled-channel Δ statistic, one f32 scalar per leading
    (batch, head, ...) cell: mean |adjacent-token difference| over a
    strided sample of ``cfg.drift_channels`` channels of Q and K.  This
    is a O(N·c) proxy for the full windowed Δ the decision measured —
    if the latent correlations the cached decision is built on move,
    this moves with them.  Shard-oblivious: reduces only along tokens
    and channels, never across batch or heads."""
    c = max(int(cfg.drift_channels), 1)

    def stat(x):
        stride = max(x.shape[-1] // c, 1)
        xs = x[..., ::stride].astype(jnp.float32)
        return jnp.mean(jnp.abs(xs[..., 1:, :] - xs[..., :-1, :]),
                        axis=(-1, -2))

    return 0.5 * (stat(q) + stat(k))


def refresh_due(step, cfg: RippleConfig, stat: jax.Array,
                ref_stat: Optional[jax.Array],
                total_steps: Optional[int] = None):
    """Scalar bool: is the cached decision stale at ``step``?  Due on
    the ``reuse_every`` cadence; early when the drift guard is on and
    any (batch, head) cell's statistic moved more than ``drift_tol``
    relative to the cached reference; and always on the final denoising
    step — the Eq. 4 schedule forces it dense (quality-critical, paper
    §3.3), and applying a stale mask there would silently override
    that.

    Refresh granularity is the cond's scope: the ``jnp.any`` reduces
    over whatever cells this call sees — all of them single-device, one
    shard's slice under shard_map.  With ``drift_tol=0`` (the default)
    that makes no difference and sharded trajectories are bitwise-equal
    to single-device; with the guard on, a drifted sample refreshes its
    whole call single-device but only its own shard when sharded —
    deliberate (zero-halo: no collective in the decision path), traded
    against cross-topology bitwise reproducibility (DESIGN.md §13.3).
    """
    every = max(int(cfg.reuse_every), 1)
    step = jnp.asarray(step, jnp.int32)
    due = jnp.equal(jnp.mod(step, every), 0)
    if total_steps is not None:
        due = jnp.logical_or(due, step >= jnp.asarray(total_steps) - 1)
    if cfg.drift_tol > 0 and ref_stat is not None:
        rel = jnp.abs(stat - ref_stat) > cfg.drift_tol * (
            jnp.abs(ref_stat) + 1e-6)
        due = jnp.logical_or(due, jnp.any(rel))
    return due


def cache_from_decision(decision: ReuseDecision, stat: jax.Array,
                        prev: Optional[CachedDecision] = None
                        ) -> CachedDecision:
    """Extract the cacheable plan of a fresh ``decide(want_plan=True)``
    call, bumping the refresh counter (``prev=None`` starts them)."""
    if prev is None or prev.hits is None:
        hits = jnp.zeros(stat.shape, jnp.int32)
        refreshes = jnp.ones(stat.shape, jnp.int32)
    else:
        hits = prev.hits
        refreshes = prev.refreshes + 1
    return CachedDecision(
        q_idx=decision.q_src, k_idx=decision.k_src, bias=decision.bias,
        block_map=decision.block_map, ref_stat=stat, hits=hits,
        refreshes=refreshes,
        # Sentinel leaves accumulate *across* refreshes — both lax.cond
        # arms must carry them so the pytree structures match.
        nonfinite=None if prev is None else prev.nonfinite,
        probe_err=None if prev is None else prev.probe_err)


def bump_hit(cached: CachedDecision) -> CachedDecision:
    """The cache-hit branch's counter update."""
    return dataclasses.replace(cached, hits=cached.hits + 1)


# -- checkpoint (de)serialization (DESIGN.md §18) ---------------------------
#
# The serving engine persists the per-layer decision state at streaming
# chunk boundaries so a warm restart / router failover can resume
# mid-flight with the *same* cached plan — resuming without it would
# apply a freshly-zeroed decision at a non-refresh step and break the
# bitwise resume-equals-monolithic contract.  Leaves cross the disk
# boundary as host arrays keyed by field name (the journal layer turns
# them into raw byte buffers; np.savez cannot hold bfloat16).

_STATE_FIELDS = tuple(f.name for f in dataclasses.fields(CachedDecision))


def state_to_arrays(state: CachedDecision):
    """Host-array mapping of every leaf (None leaves stay None)."""
    return {name: (None if getattr(state, name) is None
                   else np.asarray(jax.device_get(getattr(state, name))))
            for name in _STATE_FIELDS}


def state_from_arrays(arrays) -> CachedDecision:
    """Inverse of :func:`state_to_arrays`; unknown keys are rejected so
    a checkpoint written by a different code version fails loudly."""
    extra = set(arrays) - set(_STATE_FIELDS)
    if extra:
        raise ValueError(f"unknown CachedDecision fields in checkpoint: "
                         f"{sorted(extra)}")
    return CachedDecision(**{
        name: (None if arrays.get(name) is None
               else jnp.asarray(arrays[name]))
        for name in _STATE_FIELDS})


def slice_state(state: CachedDecision, index: int,
                batch_axis: int = 1) -> CachedDecision:
    """One request's slice of a batched (layer-stacked) state: every
    leaf loses all but entry ``index`` of ``batch_axis`` (kept as a
    size-1 dim, so :func:`merge_states` is its exact inverse).  The
    ring-path ``elided`` leaf is per-shard, not per-request — the
    engine gates checkpointing to unsharded buckets, so a populated
    ``elided`` here is a contract violation, not a slicing case."""
    if state.elided is not None:
        raise ValueError("cannot slice a context-parallel (ring) decision "
                         "state per request; checkpointing is gated to "
                         "seq_shards == 1")

    def f(leaf):
        if leaf is None:
            return None
        return jax.lax.slice_in_dim(leaf, index, index + 1,
                                    axis=batch_axis)

    return CachedDecision(**{name: f(getattr(state, name))
                             for name in _STATE_FIELDS})


def merge_states(states, batch_axis: int = 1) -> CachedDecision:
    """Concatenate per-request states back into one batched state (the
    resume path's batch assembly).  Leaf presence must agree across all
    inputs — a mixed batch of cache-threading and cache-less
    checkpoints cannot share one sampler invocation."""
    states = list(states)
    if not states:
        raise ValueError("merge_states needs at least one state")
    out = {}
    for name in _STATE_FIELDS:
        leaves = [getattr(s, name) for s in states]
        nones = [lf is None for lf in leaves]
        if all(nones):
            out[name] = None
        elif any(nones):
            raise ValueError(f"checkpoint states disagree on field "
                             f"{name!r} (some None, some not)")
        else:
            out[name] = (leaves[0] if len(leaves) == 1
                         else jnp.concatenate(leaves, axis=batch_axis))
    return CachedDecision(**out)


def initial_state(q_shape: Tuple[int, ...], *,
                  grid: Tuple[int, int, int],
                  cfg: RippleConfig,
                  policy=None,
                  grid_slice: Optional[Tuple[int, int]] = None,
                  num_layers: Optional[int] = None,
                  dtype=jnp.float32,
                  backend: Optional[str] = None) -> CachedDecision:
    """All-zeros :class:`CachedDecision` with exactly the structure the
    dispatcher will carry for these operand shapes — built by
    ``eval_shape``-ing the dispatch call itself, so it can never drift
    from the runtime structure.  With ``num_layers`` every leaf gains a
    leading layer dim (the per-layer state a model threads through its
    scan-over-layers).  Safe to call inside a jit trace: the zeros
    become constants.  Step 0 always refreshes (``0 % R == 0``), so the
    dummy plan is never applied."""
    from repro.core.dispatch import attention_dispatch

    q = jax.ShapeDtypeStruct(tuple(q_shape), dtype)
    step = jax.ShapeDtypeStruct((), jnp.int32)

    def build(q, k, v, step):
        return attention_dispatch(
            q, k, v, grid=grid, cfg=cfg, step=step,
            total_steps=max(int(cfg.reuse_every), 2),
            grid_slice=grid_slice, backend=backend, policy=policy,
            return_decision=True)[1]

    shapes = jax.eval_shape(build, q, q, q, step)
    zeros = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    if num_layers is not None:
        zeros = jax.tree_util.tree_map(
            lambda a: jnp.zeros((num_layers,) + a.shape, a.dtype), zeros)
    return zeros
