"""Compute-savings accounting for TimeRipple.

The paper quantifies its benefit as the fraction of *partial attention
scores* (per-channel products ``q_{i,c}·k_{j,c}`` of the QKᵀ matmul)
obtained by copying instead of computing — e.g. "TIMERIPPLE_85%".  A
partial product (i, j, c) must be computed only when **neither** operand
entry is a snapped copy:

    computed(c) = (1 − fq_c) · (1 − fk_c)
    saved       = 1 − mean_c computed(c)

where ``fq_c``/``fk_c`` are the snapped fractions of Q/K at channel c.
(If ``q[i,c]`` is a copy of ``q[i',c]`` the whole row i of the channel-c
partial map equals row i'; if ``k[j,c]`` is a copy, column j equals its
representative column.)

We additionally report the *structural* savings realized by the TPU
collapse path (DESIGN.md §4), which also saves softmax+AV work for fully
collapsed pairs — the paper's accounting never includes AV.  The two
numbers are kept separate everywhere.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def partial_score_savings(q_mask: jax.Array, k_mask: jax.Array) -> jax.Array:
    """Paper-faithful savings ratio from boolean snap masks (..., N, d)."""
    fq = jnp.mean(q_mask.astype(jnp.float32), axis=-2)  # (..., d)
    fk = jnp.mean(k_mask.astype(jnp.float32), axis=-2)
    computed = jnp.mean((1.0 - fq) * (1.0 - fk), axis=-1)
    return 1.0 - jnp.mean(computed)


def pair_collapse_fractions(q_mask: jax.Array, k_mask: jax.Array,
                            window: int = 2) -> Tuple[jax.Array, jax.Array]:
    """Fractions of Q windows / K windows whose followers are fully snapped.

    A window collapses only when every non-representative member is
    snapped on **all** channels; masks are (..., N, d) with tokens in
    pair-major order along the collapse axis (caller's responsibility).
    """

    def frac(mask):
        *lead, N, d = mask.shape
        n = N // window
        m = mask[..., : n * window, :].reshape(*lead, n, window, d)
        followers = m[..., 1:, :]  # representative is never "snapped"
        full = jnp.all(followers, axis=(-1, -2))
        return jnp.mean(full.astype(jnp.float32))

    return frac(q_mask), frac(k_mask)


def collapse_savings(q_mask: jax.Array, k_mask: jax.Array, window: int = 2) -> jax.Array:
    """Structural FLOP savings of the collapse execution path.

    QKᵀ cost scales with rows_computed × cols_computed; AV with
    rows_computed × cols_computed as well (collapsed columns carry
    pair-summed V).  With fraction pq of Q windows and pk of K windows
    collapsed, each collapsed window does 1/window of the work:

        rows = 1 − pq·(window−1)/window,  cols = 1 − pk·(window−1)/window
        savings = 1 − rows · cols
    """
    pq, pk = pair_collapse_fractions(q_mask, k_mask, window)
    shrink = (window - 1) / window
    rows = 1.0 - pq * shrink
    cols = 1.0 - pk * shrink
    return 1.0 - rows * cols


def attention_flops(n_q: int, n_k: int, d: int, d_v: int, heads: int,
                    batch: int = 1) -> int:
    """Dense self-attention matmul FLOPs (QKᵀ + AV), multiply+add = 2."""
    qk = 2 * n_q * n_k * d
    av = 2 * n_q * n_k * d_v
    return batch * heads * (qk + av)


def theoretical_speedup(attn_fraction: float, savings: jax.Array) -> jax.Array:
    """End-to-end speedup the paper reports: self-attention is
    ``attn_fraction`` of total latency (paper Fig. 4: ~0.78 on average)
    and ``savings`` of it is skipped; the rest of the model is untouched.
    """
    return 1.0 / (1.0 - attn_fraction * savings)
