"""Adaptive per-denoising-step threshold schedule (paper Eq. 4).

Quality is highly sensitive to the denoising step and insensitive to the
prompt (paper Figs. 8-9), so a single schedule is shared across prompts:

* steps ``i < i_min`` and the final step run **dense** (θ = 0);
* on ``[i_min, i_max]`` the threshold ramps linearly θ_min → θ_max;
* after ``i_max`` it plateaus at θ_max.

Eq. 4 as printed ramps from zero and Tbl. 1's column headers are swapped
(θ_max < θ_min for every model); we implement the text's stated intent —
see DESIGN.md §5.  All functions are jittable so the schedule can live
inside a ``lax.scan`` over denoising steps.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig


def threshold_for_step(cfg: RippleConfig, step, total_steps) -> jax.Array:
    """Shared threshold θ_i for denoising step ``step`` (0-based).

    Returns 0.0 (dense) outside the active range. jittable in ``step``.
    """
    if cfg.fixed_threshold is not None:
        theta = jnp.asarray(cfg.fixed_threshold, jnp.float32)
    else:
        i = jnp.asarray(step, jnp.float32)
        span = max(cfg.i_max - cfg.i_min, 1)
        ramp = cfg.theta_min + (i - cfg.i_min) * (cfg.theta_max - cfg.theta_min) / span
        theta = jnp.clip(ramp, min(cfg.theta_min, cfg.theta_max),
                         max(cfg.theta_min, cfg.theta_max))
    active = jnp.logical_and(
        jnp.asarray(step) >= cfg.i_min,
        jnp.asarray(step) < jnp.asarray(total_steps) - 1,
    )
    return jnp.where(active, theta, 0.0)


def axis_thresholds(cfg: RippleConfig, step, total_steps) -> Dict[str, jax.Array]:
    """Per-axis thresholds {θ_t, θ_x, θ_y} for one step.

    The paper found one shared value "more efficient and effective"
    (§3.3); per-axis overrides exist for the Tbl. 3/4 ablations.
    """
    shared = threshold_for_step(cfg, step, total_steps)
    out = {}
    for axis, override in (("t", cfg.theta_t), ("x", cfg.theta_x), ("y", cfg.theta_y)):
        if override is None:
            out[axis] = shared
        else:
            # Override scales with the schedule's on/off gating.
            gate = jnp.where(shared > 0, 1.0, 0.0)
            out[axis] = jnp.asarray(override, jnp.float32) * gate
    return out


def threshold_schedule(cfg: RippleConfig, total_steps: int) -> jax.Array:
    """Vector of shared thresholds for all steps (host-side inspection)."""
    return jax.vmap(lambda i: threshold_for_step(cfg, i, total_steps))(
        jnp.arange(total_steps)
    )
