"""Unified attention dispatch — the single seam every attention-bearing
model targets (DESIGN.md §8).

``attention_dispatch(q, k, v, grid=..., cfg=..., ...)`` owns, in order:

  1. **Policy resolution** — which sparsity *strategy* decides the
     masks/snaps: a registered :class:`~repro.core.policy.ReusePolicy`
     ('ripple', 'svg', 'equal_mse', 'dense', or anything registered
     out-of-tree), resolved from ``cfg.policy`` / the explicit
     ``policy`` argument (DESIGN.md §11).  The policy produces one
     :class:`~repro.core.policy.ReuseDecision`; dispatch executes it.
  2. **Backend selection** — dense SDPA, the dense snapped reference,
     the exact pair-collapse math, the block-skipping Pallas ripple
     kernel, or the block-sparse masked flash kernel
     (``kernels/sparse``, DESIGN.md §12) for policies that tile their
     masks into a skip/full/partial block map; resolved from
     ``cfg.backend`` / the explicit ``backend`` argument, the platform,
     the policy's needs, and shape eligibility.
  3. **Mask pipeline placement** — the Fig. 6 step ①-② Δ-checks run
     either fused on-device (``kernels/reuse_mask``) or on the host
     (``core.reuse``), per ``cfg.fused_mask`` and grid eligibility.
  4. **Shape bucketing** — plan lookups key on power-of-two shape
     buckets, so nearby workload shapes share one resolved plan and the
     jit cache does not fragment per exact token count.
  5. **Block-size autotuning** — per (shape-bucket, backend) block sizes
     for the Pallas kernel come from a persistent on-disk cache
     (``REPRO_AUTOTUNE_CACHE``), populated offline by
     :func:`autotune_attention` (benchmarks/kernel_bench.py sweeps it);
     plan resolution never times kernels inside a trace.

Model code calls :func:`attention_dispatch` via
``models.attention.mha_attention``.

When a mesh is active (:func:`dispatch_mesh` / :func:`set_dispatch_mesh`
— the serving launchers install one), plan resolution additionally
records **batch/head sharding**: the leading batch dim shards over the
(pod, data) axes and the heads dim over 'model' whenever they divide,
and the whole pipeline — Δ-check mask computation included — runs under
``shard_map`` with the mask computed *per shard*.  The reuse windows run
along the t/x/y token axes, never along batch or heads, so the halo for
the sharded axes is exactly zero and per-shard results are bitwise equal
to the single-device path (DESIGN.md §10).  Indivisible shapes fall back
to replicated execution with the same plan cache entry semantics.

A mesh with a third ``seq`` axis additionally shards the **token axis**
— context-parallel ring attention with an explicit ``window − 1`` frame
halo for the Δ-checks and per-hop block-map elision (``core.ring``,
DESIGN.md §14) — for policies that declare ``will_seq_shard`` and
shapes where the grid covers the whole sequence and T divides by the
seq degree.  Everything else falls back to the replicated token axis,
never an error.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import time
import warnings
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config.base import RippleConfig
from repro.core.collapse import collapsed_attention
from repro.core.policy import (ReuseDecision, ReusePolicy, RippleStats,
                               get_policy, list_policies, register_policy)

__all__ = [
    "attention_dispatch", "autotune_attention", "DispatchPlan",
    "RippleStats", "ReuseDecision", "ReusePolicy", "dense_attention",
    "dispatch_mesh", "get_policy", "list_policies", "plan_for_shape",
    "register_policy", "resolve_backend", "resolve_plan",
    "set_dispatch_mesh", "shape_bucket",
]

BACKENDS = ("auto", "dense", "reference", "collapse", "pallas", "sparse")
_DEFAULT_BLOCKS = (128, 128)
# (block_q, block_k) candidates the autotuner sweeps; the ops-level
# wrappers pad to block multiples so every candidate is shape-legal.
BLOCK_CANDIDATES = ((64, 64), (128, 128), (128, 256), (256, 128),
                    (256, 256))


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Resolved execution plan for one (policy, shape-bucket, backend)
    cell.  ``policy`` is the resolved reuse-policy *name* (the object is
    looked up at execution time so re-registration takes effect); the
    plan/LRU caches and the shard_map path key on it."""

    backend: str  # 'dense' | 'reference' | 'collapse' | 'pallas' | 'sparse'
    policy: str = "ripple"
    block_q: int = 128
    block_k: int = 128
    fused_mask: bool = False
    bucket: Tuple[int, ...] = ()
    tuned: bool = False   # block sizes came from the autotune cache
    # Mesh sharding (DESIGN.md §10): which mesh axes shard the leading
    # batch dim / the heads dim; () / None means replicated execution.
    batch_axes: Tuple[str, ...] = ()
    head_axis: Optional[str] = None
    batch_shards: int = 1
    head_shards: int = 1
    # Context-parallel ring attention (DESIGN.md §14): the mesh axis
    # sharding the token axis, None when the tokens stay replicated.
    seq_axis: Optional[str] = None
    seq_shards: int = 1

    @property
    def sharded(self) -> bool:
        return self.batch_shards * self.head_shards * self.seq_shards > 1

    def summary(self) -> str:
        blk = (f" block={self.block_q}x{self.block_k}"
               f"{' (tuned)' if self.tuned else ''}"
               if self.backend in ("pallas", "sparse") else "")
        mask = " fused-mask" if self.fused_mask else ""
        shard = (f" shard=batch{self.batch_shards}x"
                 f"heads{self.head_shards}" if self.sharded else "")
        ring = (f" ring=seq{self.seq_shards}" if self.seq_axis else "")
        return (f"attention[{self.policy}/{self.backend}{blk}{mask}{shard}"
                f"{ring} bucket={self.bucket}]")


def dense_attention(q, k, v, scale, bias=None):
    """Plain SDPA; the 'dense' backend and the inactive-config path."""
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    if bias is not None:
        logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kv->...qv", probs.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Shape bucketing
# ---------------------------------------------------------------------------


def shape_bucket(n: int) -> int:
    """Round up to the next power of two (min 64) — plan-cache bucket."""
    return max(64, 1 << max(0, math.ceil(math.log2(max(n, 1)))))


def _bucket_key(q_shape, v_shape, backend: str) -> Tuple:
    *lead, n, d = q_shape
    bh = math.prod(lead) if lead else 1
    return (backend, shape_bucket(bh), shape_bucket(n), d, v_shape[-1])


# ---------------------------------------------------------------------------
# Active mesh (installed by launchers/engines; consulted by plan resolution)
# ---------------------------------------------------------------------------

_ACTIVE_MESH: Optional[Mesh] = None


def set_dispatch_mesh(mesh: Optional[Mesh]) -> Optional[Mesh]:
    """Install ``mesh`` as the dispatch-layer mesh; returns the previous
    one.  ``None`` restores single-device (replicated) execution."""
    global _ACTIVE_MESH
    prev = _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    return prev


def active_dispatch_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


@contextlib.contextmanager
def dispatch_mesh(mesh: Optional[Mesh]):
    """Scoped :func:`set_dispatch_mesh` (tests, benchmarks)."""
    prev = set_dispatch_mesh(mesh)
    try:
        yield mesh
    finally:
        set_dispatch_mesh(prev)


def _mesh_key(mesh: Optional[Mesh]) -> Optional[Tuple]:
    if mesh is None:
        return None
    return tuple((a, int(mesh.shape[a])) for a in mesh.axis_names)


def _resolve_sharding(mesh: Optional[Mesh], q_shape) -> Tuple:
    """(batch_axes, head_axis, batch_shards, head_shards) for q_shape.

    Greedy prefix of the (pod, data) axes that divides the leading batch
    dim; heads (dim 1 of a 4-D operand) shard over 'model' when they
    divide.  Anything indivisible stays replicated — never an error.
    """
    if mesh is None or len(q_shape) < 3:
        return (), None, 1, 1
    b_axes = []
    b_shards = 1
    B = q_shape[0]
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n = int(mesh.shape[a])
            if n > 1 and B % (b_shards * n) == 0:
                b_axes.append(a)
                b_shards *= n
    head_axis, h_shards = None, 1
    if len(q_shape) >= 4 and "model" in mesh.axis_names:
        n = int(mesh.shape["model"])
        if n > 1 and q_shape[1] % n == 0:
            head_axis, h_shards = "model", n
    return tuple(b_axes), head_axis, b_shards, h_shards


# ---------------------------------------------------------------------------
# Persistent autotune cache
# ---------------------------------------------------------------------------

_DISK_CACHE: Optional[Dict[str, dict]] = None
_DISK_CACHE_PATH: Optional[str] = None
# Bounded LRU: resolve_plan moves hits to the MRU end and evicts from the
# LRU end past the cap, so the hottest plans always survive eviction.
_PLAN_CACHE: "OrderedDict[Tuple, DispatchPlan]" = OrderedDict()
_PLAN_CACHE_CAP = int(os.environ.get("REPRO_PLAN_CACHE_CAP", "256"))


def autotune_cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro_timeripple",
                     "autotune.json"))


def clear_plan_cache():
    """Drop the in-memory caches (tests; after switching cache files)."""
    global _DISK_CACHE, _DISK_CACHE_PATH
    _DISK_CACHE = None
    _DISK_CACHE_PATH = None
    _PLAN_CACHE.clear()


# Versioned schema marker written into the autotune cache file.  Files
# without the marker are accepted as legacy; a *mismatched* marker (or
# corrupt/truncated JSON, or entries missing the block fields) warns
# and regenerates instead of raising — a bad cache file must never
# take down a launcher.
_AUTOTUNE_SCHEMA = "repro-autotune/1"


def _read_disk_cache(p: str) -> Dict[str, dict]:
    try:
        with open(p) as f:
            raw = json.load(f)
    except FileNotFoundError:
        return {}
    except (OSError, ValueError) as e:
        warnings.warn(f"autotune cache {p!r} is corrupt ({e}); "
                      f"regenerating it", RuntimeWarning, stacklevel=3)
        return {}
    if not isinstance(raw, dict):
        warnings.warn(f"autotune cache {p!r} is not a JSON object; "
                      f"regenerating it", RuntimeWarning, stacklevel=3)
        return {}
    schema = raw.pop("__schema__", _AUTOTUNE_SCHEMA)
    if schema != _AUTOTUNE_SCHEMA:
        warnings.warn(f"autotune cache {p!r} has schema {schema!r} != "
                      f"{_AUTOTUNE_SCHEMA!r}; regenerating it",
                      RuntimeWarning, stacklevel=3)
        return {}
    bad = [k for k, v in raw.items()
           if not (isinstance(v, dict) and "block_q" in v
                   and "block_k" in v)]
    if bad:
        warnings.warn(f"autotune cache {p!r}: dropping malformed "
                      f"entries {bad}", RuntimeWarning, stacklevel=3)
    return {k: v for k, v in raw.items() if k not in bad}


def _load_disk_cache(path: Optional[str] = None) -> Dict[str, dict]:
    global _DISK_CACHE, _DISK_CACHE_PATH
    p = path or autotune_cache_path()
    if _DISK_CACHE is None or p != _DISK_CACHE_PATH:
        _DISK_CACHE = _read_disk_cache(p)
        _DISK_CACHE_PATH = p
    return _DISK_CACHE


def _store_disk(key: str, entry: dict, path: Optional[str] = None):
    p = path or autotune_cache_path()
    cache = _load_disk_cache(path)
    cache[key] = entry
    from repro.utils.diskio import atomic_write_text

    atomic_write_text(p, json.dumps(
        {"__schema__": _AUTOTUNE_SCHEMA, **cache}, indent=1, sort_keys=True))


def autotune_key(backend: str, n_bucket: int, d: int, dv: int) -> str:
    # Keyed by platform: block sizes tuned on a CPU interpret run must
    # never steer the TPU kernel (and vice versa).
    return f"{_platform()}:{backend}:n{n_bucket}:d{d}:dv{dv}"


def autotune_attention(q, k, v, *, backend: str = "pallas",
                       candidates: Sequence[Tuple[int, int]] = BLOCK_CANDIDATES,
                       repeats: int = 3, cache_path: Optional[str] = None,
                       force: bool = False,
                       interpret: Optional[bool] = None) -> dict:
    """Time each (block_q, block_k) candidate on *concrete* operands and
    persist the winner keyed by the shape bucket.

    Runs outside any trace (benchmarks, warm-up scripts) — never call it
    from jitted model code; :func:`attention_dispatch` only *reads* the
    cache it writes.  Returns the winning cache entry.

    ``backend`` picks the kernel being tuned: 'pallas' (the ripple
    pair-collapse kernel) or 'sparse' (the block-sparse masked flash
    kernel, timed on an all-full map — the dense-tile inner loop is
    what the block sizes shape; skip tiles cost nothing regardless).
    """
    if backend == "sparse":
        from repro.kernels.sparse.ops import sparse_attention_pallas

        def make(bq, bk):
            return lambda: sparse_attention_pallas(
                q, k, v, block_q=bq, block_k=bk, interpret=interpret)
    else:
        from repro.kernels.ripple.ops import ripple_attention_pallas

        def make(bq, bk):
            return lambda: ripple_attention_pallas(
                q, k, v, block_q=bq, block_k=bk, interpret=interpret)

    key = autotune_key(backend, shape_bucket(q.shape[-2]), q.shape[-1],
                       v.shape[-1])
    cache = _load_disk_cache(cache_path)
    if key in cache and not force:
        return cache[key]

    results = []
    for bq, bk in candidates:
        results.append({"block_q": bq, "block_k": bk,
                        "us": round(time_best(make(bq, bk), repeats) * 1e6,
                                    1)})
    best = min(results, key=lambda r: r["us"])
    entry = {**best, "device": _platform(), "candidates": results}
    _store_disk(key, entry, cache_path)
    _PLAN_CACHE.clear()  # plans may now resolve to the tuned blocks
    return entry


def time_best(fn, repeats: int = 3) -> float:
    """Compile-and-warm once, then min-of-``repeats`` walltime in
    seconds — the one timing idiom shared by the autotuner and the
    kernel benchmarks."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _tuned_blocks(backend: str, n: int, d: int, dv: int):
    entry = _load_disk_cache().get(autotune_key(backend, shape_bucket(n),
                                                d, dv))
    if entry:
        return int(entry["block_q"]), int(entry["block_k"]), True
    return (*_DEFAULT_BLOCKS, False)


# ---------------------------------------------------------------------------
# Plan resolution
# ---------------------------------------------------------------------------


def _platform() -> str:
    return jax.devices()[0].platform


def resolve_backend(cfg: RippleConfig, backend: Optional[str], *,
                    has_bias: bool, n_tokens: int,
                    policy: Optional[ReusePolicy] = None) -> str:
    """Collapse 'auto' onto a concrete backend for this call.

    The policy's declared needs gate the choice without the dispatcher
    knowing the strategy: bias-emitting policies avoid the biasless
    auto-Pallas path, non-snapping policies gain nothing from collapse.
    """
    pol = policy if policy is not None else get_policy(cfg.policy)
    b = backend or cfg.backend or "auto"
    if b not in BACKENDS:
        raise ValueError(f"unknown backend {b!r}; expected one of {BACKENDS}")
    if not cfg.active() or pol.is_dense:
        return "dense"
    emits_bias = pol.will_emit_bias(cfg)
    # The block-sparse backend realizes a policy's mask as skipped
    # tiles, but only when the policy's own bias is the whole story: an
    # external caller bias is dense/arbitrary, and the sparse kernel's
    # full-tile fast path would silently drop it.
    sparse_ok = pol.will_emit_block_map(cfg) and not has_bias
    if b != "auto":
        # A policy-emitted bias rules out backends that can't carry it:
        # the Pallas kernel asserts bias is None, and collapse assumes a
        # window-constant bias (an SVG block mask isn't).  Downgrade the
        # explicit choice to the block-sparse kernel when the policy can
        # tile its mask, else the reference path — never crash inside a
        # jitted sampler, same fall-back-not-error stance as sharding.
        if emits_bias and b in ("pallas", "collapse"):
            return "sparse" if sparse_ok else "reference"
        if b == "sparse" and has_bias and pol.will_emit_block_map(cfg):
            # A map-emitting policy derives FULL tiles from its own keep
            # mask; the kernel's full-tile fast path would then drop the
            # external caller bias.  Same downgrade stance as above.
            return "reference"
        return b
    if sparse_ok:
        # On TPU the sparse kernel skips masked tiles' MXU work; on CPU
        # it runs in interpret mode (correctness-representative, same
        # stance as the other kernels) so mask policies never silently
        # lose their structural savings to a dense fallback.
        return "sparse"
    pallas_ok = (_platform() == "tpu" and not has_bias and not emits_bias
                 and cfg.window == 2 and n_tokens % 2 == 0)
    if pallas_ok:
        return "pallas"
    if not pol.snaps_operands or emits_bias:
        return "reference"
    return "collapse" if cfg.execution == "collapse" else "reference"


def _fused_requested(cfg: RippleConfig) -> bool:
    if cfg.fused_mask == "on":
        return True
    if cfg.fused_mask == "off":
        return False
    # 'auto': the fused kernel wins on TPU; in interpret mode on CPU it
    # is correctness-representative but slower than the fused-by-XLA
    # host path, so it stays off there.
    return _platform() == "tpu"


def _resolve_seq_sharding(mesh: Optional[Mesh], q_shape, resolved: str,
                          cfg: RippleConfig, pol: ReusePolicy,
                          grid, grid_slice) -> Tuple[Optional[str], int]:
    """(seq_axis, seq_shards): is the context-parallel ring eligible
    (DESIGN.md §14)?  Needs a >1 'seq' mesh axis, a ring-capable backend
    (reference or sparse), 4-D operands, a grid covering the whole
    sequence (no text prefix — ``grid_slice`` must be None after the
    dispatcher's full-range normalization), a policy that declares
    ``will_seq_shard``, and T divisible by the seq degree.  Anything
    else replicates the token axis — fall back, never error."""
    if (mesh is None or "seq" not in mesh.axis_names or grid is None
            or grid_slice is not None or len(q_shape) < 4
            or resolved not in ("reference", "sparse")
            or not pol.will_seq_shard(cfg)):
        return None, 1
    s = int(mesh.shape["seq"])
    T = int(grid[0])
    n = math.prod(int(g) for g in grid)
    if s <= 1 or n != q_shape[-2] or T % s != 0:
        return None, 1
    return "seq", s


def resolve_plan(q_shape, v_shape, cfg: RippleConfig,
                 backend: Optional[str] = None,
                 has_bias: bool = False,
                 mesh: Optional[Mesh] = None,
                 policy=None,
                 grid: Optional[Tuple[int, int, int]] = None,
                 grid_slice: Optional[Tuple[int, int]] = None
                 ) -> DispatchPlan:
    """Shape-bucketed, cached plan resolution (trace-safe: shapes only).

    ``mesh`` defaults to the active dispatch mesh; when one is present
    the cache keys on the *exact* leading dims (sharding eligibility is
    a divisibility property, not a bucket property) plus the mesh shape.
    ``policy`` (a registered name or ReusePolicy) defaults to
    ``cfg.policy``; the cache keys on the policy name.  ``grid`` /
    ``grid_slice`` feed seq-axis (ring) eligibility — callers that only
    know shapes simply never get a ring plan.
    """
    if mesh is None:
        mesh = _ACTIVE_MESH
    if grid_slice is not None and grid is not None \
            and tuple(grid_slice) == (0, q_shape[-2]):
        grid_slice = None  # full-range slice is no slice at all
    pol = get_policy(policy if policy is not None else cfg.policy)
    n = q_shape[-2]
    resolved = resolve_backend(cfg, backend, has_bias=has_bias, n_tokens=n,
                               policy=pol)
    # plan_token mixes in external decision state the policy bakes into
    # compiled constants (the pattern artifact's content hash) so an
    # artifact swap can never replay a stale plan; getattr keeps
    # pre-token duck-typed policies working.
    tok = getattr(pol, "plan_token", None)
    key = _bucket_key(q_shape, v_shape, resolved) \
        + (pol.name, cfg.fused_mask, cfg.window, cfg.granularity,
           tok(cfg) if callable(tok) else None)
    if mesh is not None:
        key = key + (_mesh_key(mesh), tuple(q_shape[:-2]),
                     grid, grid_slice is None)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        return plan
    if resolved in ("pallas", "sparse"):
        bq, bk, tuned = _tuned_blocks(resolved, n, q_shape[-1], v_shape[-1])
    else:
        (bq, bk), tuned = _DEFAULT_BLOCKS, False
    b_axes, h_axis, b_shards, h_shards = (
        _resolve_sharding(mesh, q_shape) if resolved != "dense"
        else ((), None, 1, 1))
    seq_axis, seq_shards = _resolve_seq_sharding(
        mesh, q_shape, resolved, cfg, pol, grid, grid_slice)
    plan = DispatchPlan(backend=resolved, policy=pol.name, block_q=bq,
                        block_k=bk, fused_mask=_fused_requested(cfg),
                        bucket=key[1:3], tuned=tuned,
                        batch_axes=b_axes, head_axis=h_axis,
                        batch_shards=b_shards, head_shards=h_shards,
                        seq_axis=seq_axis, seq_shards=seq_shards)
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_CAP:
        _PLAN_CACHE.popitem(last=False)
    return plan


def plan_for_shape(n_tokens: int, head_dim: int, cfg: RippleConfig, *,
                   batch_heads: int = 1, heads: int = 0,
                   backend: Optional[str] = None,
                   mesh: Optional[Mesh] = None,
                   policy=None) -> DispatchPlan:
    """Plan metadata for launchers/engines that only know shapes.

    ``heads`` (when it divides ``batch_heads``) splits the flattened
    leading dim into (batch, heads) so mesh head-sharding is visible in
    the returned plan.
    """
    if heads and batch_heads % heads == 0:
        shape = (batch_heads // heads, heads, n_tokens, head_dim)
    else:
        shape = (batch_heads, n_tokens, head_dim)
    return resolve_plan(shape, shape, cfg, backend=backend, mesh=mesh,
                        policy=policy)


# ---------------------------------------------------------------------------
# The pipeline
# ---------------------------------------------------------------------------


def _decide_extra(plan: DispatchPlan, policy: ReusePolicy,
                  cfg: RippleConfig) -> dict:
    extra = {}
    if plan.backend == "sparse" and policy.will_emit_block_map(cfg):
        # Only sparse plans for map-emitting policies pass block_shape:
        # policies predating the block-sparse backend keep their
        # original decide() signature even under a forced 'sparse'
        # (their mapless decision runs the kernel's all-full path).
        extra["block_shape"] = (plan.block_q, plan.block_k)
    return extra


def _execute_backend(d: ReuseDecision, v, scale, *, plan: DispatchPlan,
                     cfg: RippleConfig):
    """Fig. 6 steps ③-④: run the planned backend on one decision."""
    if plan.backend == "pallas":
        # Deferred import: kernels are optional at module-import time.
        from repro.kernels.ripple.ops import ripple_attention_pallas

        return ripple_attention_pallas(d.q, d.k, v, bias=d.bias,
                                       window=cfg.window,
                                       block_q=plan.block_q,
                                       block_k=plan.block_k)
    if plan.backend == "sparse":
        from repro.kernels.sparse.ops import sparse_attention_pallas

        return sparse_attention_pallas(d.q, d.k, v, bias=d.bias,
                                       block_map=d.block_map,
                                       block_q=plan.block_q,
                                       block_k=plan.block_k)
    if plan.backend == "collapse":
        return collapsed_attention(d.q, d.k, v, bias=d.bias,
                                   window=cfg.window, scale=scale)
    # 'reference': dense attention on the decided operands
    return dense_attention(d.q, d.k, v, scale, d.bias)


def _inject_attn_nan(out, step):
    """Chaos-harness hook (``serving.faults``, DESIGN.md §17.3): when an
    ``attn_nan`` fault is armed at trace time, flip this call's output
    to NaN at the spec'd denoising step.  Only the sparse pipelines call
    this — the dispatcher's dense path never does — so a degraded
    bucket's dense recompile clears the fault, the way a real
    sparse-kernel bug would."""
    if step is None:
        return out
    from repro.serving import faults as fault_lib

    fault = fault_lib.active_faults()
    spec = fault.spec("attn_nan") if fault is not None else None
    if spec is None:
        return out
    fault.note_fired("attn_nan")
    at = jnp.asarray(int(spec.param("step", 0)), jnp.int32)
    return jnp.where(jnp.equal(jnp.asarray(step, jnp.int32), at),
                     jnp.full_like(out, jnp.nan), out)


def _run_pipeline(q, k, v, thetas, scale, bias, *, plan: DispatchPlan,
                  grid, cfg: RippleConfig, grid_slice,
                  policy: ReusePolicy):
    """Fig. 6 steps ①-④ for one resolved plan: the policy's decision
    (snap / mask), then the planned backend on it.  Returns
    (out, ReuseDecision).  Shard-oblivious: runs identically on the full
    operands or on one shard_map shard (decisions only look along t/x/y,
    DESIGN.md §10).
    """
    d = policy.decide(q, k, grid=grid, cfg=cfg, thetas=thetas, bias=bias,
                      grid_slice=grid_slice, fused=plan.fused_mask,
                      **_decide_extra(plan, policy, cfg))
    return _execute_backend(d, v, scale, plan=plan, cfg=cfg), d


def _run_pipeline_cached(q, k, v, thetas, scale, *, plan: DispatchPlan,
                         grid, cfg: RippleConfig, grid_slice,
                         policy: ReusePolicy, step, cached,
                         total_steps=None):
    """The cross-step decision-cache pipeline (DESIGN.md §13): decide
    fresh when the cadence / drift guard says the cached plan is stale,
    otherwise re-apply the carried plan to the fresh operands — both
    arms of one ``lax.cond`` producing structurally identical
    (ReuseDecision, CachedDecision) pairs, so the state is
    scan-carriable.  The backend then executes once on the selected
    decision (the kernels are not duplicated into the branches).
    External bias must be None (the dispatcher gates this).  Returns
    (out, decision, new_cache).
    """
    from repro.core import decision_cache as dc

    extra = _decide_extra(plan, policy, cfg)
    plan_once = getattr(policy, "plan_once", False)
    # The drift statistic is only worth its O(N·c) pass when the guard
    # can act on it; with the guard off — or for plan-once policies,
    # whose decision is a trajectory constant — the carry keeps a zero
    # stat so the pytree structure (and cadence behaviour) is identical.
    if cfg.drift_tol > 0 and not plan_once:
        stat = dc.drift_stat(q, k, cfg)
    else:
        stat = jnp.zeros(q.shape[:-2], jnp.float32)

    def fresh(prev):
        d = policy.decide(q, k, grid=grid, cfg=cfg, thetas=thetas,
                          bias=None, grid_slice=grid_slice,
                          fused=plan.fused_mask, want_plan=True, **extra)
        return d, dc.cache_from_decision(d, stat, prev=prev)

    if cached is None:
        d, new_cache = fresh(None)
    else:
        def reuse(prev):
            d = policy.apply_decision(q, k, prev, grid=grid, cfg=cfg,
                                      thetas=thetas, grid_slice=grid_slice)
            return d, dc.bump_hit(prev)

        if plan_once:
            # Refresh cadence of never: the step-0 plan is replayed for
            # the whole trajectory (no reuse_every, no drift, no
            # final-step re-decide) — DESIGN.md §16.
            refresh = jnp.equal(jnp.asarray(step, jnp.int32), 0)
        else:
            refresh = dc.refresh_due(step, cfg, stat, cached.ref_stat,
                                     total_steps)
        d, new_cache = jax.lax.cond(refresh, fresh, reuse, cached)
    out = _inject_attn_nan(_execute_backend(d, v, scale, plan=plan,
                                            cfg=cfg), step)
    if cfg.sentinel:
        from repro.core import guardrail

        # Sentinel readings ride the cache carry (DESIGN.md §17): the
        # probe compares against the *original* q/k, not the snapped
        # operands — it measures the full approximation error.
        new_cache = guardrail.attach_sentinel(new_cache, out, q, k, v,
                                              scale, step, cfg)
    return out, d, new_cache


def _operand_spec(plan: DispatchPlan, ndim: int) -> P:
    """PartitionSpec for a (..., N, d) attention operand under ``plan``."""
    entries: list = [None] * ndim
    if plan.batch_axes:
        entries[0] = (plan.batch_axes if len(plan.batch_axes) > 1
                      else plan.batch_axes[0])
    if plan.head_axis is not None and ndim >= 4:
        entries[1] = plan.head_axis
    if plan.seq_axis is not None and ndim >= 3:
        entries[ndim - 2] = plan.seq_axis
    return P(*entries)


def _lead_spec(plan: DispatchPlan, ndim: int) -> P:
    """PartitionSpec for a decision-cache leaf: every leaf keeps the
    operands' leading (batch, head) dims (DESIGN.md §13), whatever its
    trailing rank — snap-source maps (..., Ng, d), biases (..., N, N),
    block maps (..., nq, nk), and lead-shaped stats/counters alike.
    ``plan.head_axis`` is only ever set for 4-D operands, so placing it
    at dim 1 is always correct here."""
    entries: list = [None] * ndim
    if plan.batch_axes and ndim >= 1:
        entries[0] = (plan.batch_axes if len(plan.batch_axes) > 1
                      else plan.batch_axes[0])
    if plan.head_axis is not None and ndim >= 2:
        entries[1] = plan.head_axis
    return P(*entries)


def _sharded_pipeline(q, k, v, thetas, scale, *, plan: DispatchPlan,
                      mesh: Mesh, grid, cfg: RippleConfig, grid_slice,
                      policy: ReusePolicy, step=None, cached=None,
                      want_cache: bool = False, total_steps=None):
    """Run :func:`_run_pipeline` under shard_map over the plan's batch /
    head axes.  No collectives: the sharded axes never carry a reuse
    window (the policy contract — decisions look only along t/x/y), so
    each shard's decision is self-contained (zero halo) and the result
    is bitwise-identical to the replicated path.

    With ``want_cache`` the decision cache rides along: every cache
    leaf keeps the operands' leading dims, so each shard carries (and
    refreshes) exactly its own cache slice — drift on one shard
    refreshes that shard alone.  Returns (out, new_cache) then.
    """
    from jax.experimental.shard_map import shard_map

    spec = _operand_spec(plan, q.ndim)
    th_vec = jnp.stack([jnp.asarray(thetas[a], jnp.float32)
                        for a in ("t", "x", "y")])
    scale = jnp.asarray(scale, jnp.float32)

    if plan.seq_axis is not None:
        # Context-parallel ring attention (core.ring, DESIGN.md §14).
        # Deferred import: ring lazily imports dense_attention back.
        from repro.core import ring as ring_lib

        if not want_cache:
            def ring_body(qs, ks, vs, th, sc):
                th_d = {"t": th[0], "x": th[1], "y": th[2]}
                return ring_lib.ring_pipeline(
                    qs, ks, vs, th_d, sc, plan=plan, grid=grid, cfg=cfg,
                    policy=policy)

            fn = shard_map(ring_body, mesh=mesh,
                           in_specs=(spec, spec, spec, P(), P()),
                           out_specs=spec, check_rep=False)
            return fn(q, k, v, th_vec, scale)

        rstep = jnp.asarray(step, jnp.int32)
        # Deterministic spec construction — no eval_shape: the ring body
        # contains collectives, which only abstract-eval inside
        # shard_map, and the leaf structure is fixed by (plan, cfg).
        cache_specs = ring_lib.ring_cache_specs(plan, cfg)

        def ring_cached(qs, ks, vs, th, sc, st, *cache):
            th_d = {"t": th[0], "x": th[1], "y": th[2]}
            return ring_lib.ring_pipeline(
                qs, ks, vs, th_d, sc, plan=plan, grid=grid, cfg=cfg,
                policy=policy, step=st,
                cached=cache[0] if cache else None, want_cache=True,
                total_steps=total_steps)

        in_specs = (spec, spec, spec, P(), P(), P()) + (
            (cache_specs,) if cached is not None else ())
        fn = shard_map(ring_cached, mesh=mesh, in_specs=in_specs,
                       out_specs=(spec, cache_specs), check_rep=False)
        args = (q, k, v, th_vec, scale, rstep) + (
            (cached,) if cached is not None else ())
        return fn(*args)

    if not want_cache:
        def body(qs, ks, vs, th, sc):
            th_d = {"t": th[0], "x": th[1], "y": th[2]}
            out, _ = _run_pipeline(qs, ks, vs, th_d, sc, None, plan=plan,
                                   grid=grid, cfg=cfg, grid_slice=grid_slice,
                                   policy=policy)
            return out

        fn = shard_map(body, mesh=mesh,
                       in_specs=(spec, spec, spec, P(), P()),
                       out_specs=spec, check_rep=False)
        return fn(q, k, v, th_vec, scale)

    step = jnp.asarray(step, jnp.int32)
    # The cache's pytree structure (for the out_specs) without running
    # anything: abstract-eval the cached pipeline.  Identical to the
    # runtime structure by construction — it is the same call.
    tmpl = cached if cached is not None else jax.eval_shape(
        lambda qq, kk, vv, st: _run_pipeline_cached(
            qq, kk, vv, thetas, scale, plan=plan, grid=grid, cfg=cfg,
            grid_slice=grid_slice, policy=policy, step=st, cached=None,
            total_steps=total_steps)[2],
        q, k, v, step)
    cache_specs = jax.tree_util.tree_map(
        lambda a: _lead_spec(plan, len(a.shape)), tmpl)

    def body(qs, ks, vs, th, sc, st, *cache):
        th_d = {"t": th[0], "x": th[1], "y": th[2]}
        out, _, new_cache = _run_pipeline_cached(
            qs, ks, vs, th_d, sc, plan=plan, grid=grid, cfg=cfg,
            grid_slice=grid_slice, policy=policy, step=st,
            cached=cache[0] if cache else None, total_steps=total_steps)
        return out, new_cache

    in_specs = (spec, spec, spec, P(), P(), P()) + (
        (cache_specs,) if cached is not None else ())
    fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                   out_specs=(spec, cache_specs), check_rep=False)
    args = (q, k, v, th_vec, scale, step) + (
        (cached,) if cached is not None else ())
    return fn(*args)


def attention_dispatch(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    grid: Tuple[int, int, int],
    cfg: RippleConfig,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    thetas: Optional[Dict[str, jax.Array]] = None,
    bias: Optional[jax.Array] = None,
    grid_slice: Optional[Tuple[int, int]] = None,
    backend: Optional[str] = None,
    mesh: Optional[Mesh] = None,
    policy=None,
    with_stats: bool = False,
    cached_decision=None,
    return_decision: bool = False,
):
    """Sparse attention behind one dispatch seam.

    q, k, v: (..., N, head_dim), post-RoPE.  ``policy`` (a registered
    name or ReusePolicy) overrides ``cfg.policy`` — it chooses the
    sparsity *strategy* (DESIGN.md §11); ``backend`` overrides
    ``cfg.backend`` for this call ('dense' bypasses the reuse pipeline
    entirely — e.g. cross-attention).  ``thetas`` overrides the policy's
    per-step schedule (otherwise derived from ``step``/``total_steps``).
    ``mesh`` overrides the active dispatch mesh; when the resolved plan
    carries sharding, the pipeline runs under shard_map (DESIGN.md §10).

    Cross-step decision cache (DESIGN.md §13): ``cached_decision`` is a
    :class:`~repro.core.decision_cache.CachedDecision` from an earlier
    call on identically-shaped operands — the decision is then only
    recomputed when ``step % cfg.reuse_every == 0`` or the drift guard
    fires, and otherwise cheaply re-applied to the fresh operands.
    ``return_decision=True`` (implied by passing ``cached_decision``)
    returns the updated cache as the second element so samplers can
    carry it through their scan.  Requires an active cache-capable
    policy, a concrete ``step``, and no external ``bias``.

    Returns ``out``, ``(out, RippleStats)``, ``(out, CachedDecision)``
    or ``(out, CachedDecision, RippleStats)``.
    """
    if mesh is None:
        mesh = _ACTIVE_MESH
    if grid_slice is not None and tuple(grid_slice) == (0, q.shape[-2]):
        grid_slice = None  # full-range slice: the whole sequence is grid
    pol = get_policy(policy if policy is not None else cfg.policy)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    plan = resolve_plan(q.shape, v.shape, cfg, backend=backend,
                        has_bias=bias is not None, mesh=mesh, policy=pol,
                        grid=grid, grid_slice=grid_slice)
    want_cache = return_decision or cached_decision is not None
    if want_cache:
        if plan.backend == "dense" or not pol.will_cache_decisions(cfg):
            raise ValueError(
                f"decision caching requested but policy {pol.name!r} "
                f"under this config resolves to "
                f"{plan.backend!r}/caches_decisions="
                f"{pol.will_cache_decisions(cfg)} — gate on "
                f"decision_cache.supports_cache(cfg, policy) first")
        if bias is not None:
            raise ValueError("decision caching requires bias=None (the "
                             "cached plan could not account for a fresh "
                             "external bias)")
        if step is None:
            raise ValueError("decision caching needs a concrete step for "
                             "the reuse_every cadence")
    if plan.backend == "dense" or not cfg.active():
        out = dense_attention(q, k, v, scale, bias)
        if with_stats:
            zero = jnp.zeros(())
            return out, RippleStats(zero, zero, zero, zero)
        return out

    thetas = pol.thetas_for(cfg, step, total_steps, thetas)

    # Sharded fast path: stats need global reductions and an external
    # bias would need its own spec — both stay on the replicated path.
    if (mesh is not None and plan.sharded and bias is None
            and not with_stats):
        res = _sharded_pipeline(q, k, v, thetas, scale, plan=plan,
                                mesh=mesh, grid=grid, cfg=cfg,
                                grid_slice=grid_slice, policy=pol,
                                step=step, cached=cached_decision,
                                want_cache=want_cache,
                                total_steps=total_steps)
        # The cached body injects faults inside shard_map; the plain
        # sharded path returns the bare output, so inject here.
        return res if want_cache else _inject_attn_nan(res, step)

    if want_cache:
        out, decision, new_cache = _run_pipeline_cached(
            q, k, v, thetas, scale, plan=plan, grid=grid, cfg=cfg,
            grid_slice=grid_slice, policy=pol, step=step,
            cached=cached_decision, total_steps=total_steps)
        if with_stats:
            return out, new_cache, pol.stats(decision)
        return out, new_cache

    out, decision = _run_pipeline(
        q, k, v, thetas, scale, bias, plan=plan, grid=grid, cfg=cfg,
        grid_slice=grid_slice, policy=pol)
    out = _inject_attn_nan(out, step)

    if with_stats:
        return out, pol.stats(decision)
    return out
