"""Pluggable reuse-policy registry — the *strategy* seam of the
attention-dispatch layer (DESIGN.md §11).

TimeRipple's channel-wise spatio-temporal reuse is one way to exploit
latent-space correlation; Sparse VideoGen's spatial/temporal head
classification (arXiv 2502.01776) and Sparse-vDiT's pattern-per-head
sparsity (arXiv 2506.03065) are others.  A :class:`ReusePolicy` owns
every strategy-specific choice:

  * the per-step threshold schedule (:meth:`ReusePolicy.thetas_for`),
  * offline calibration against sample activations
    (:meth:`ReusePolicy.calibrate`),
  * the mask / snap decision itself (:meth:`ReusePolicy.decide`,
    returning one :class:`ReuseDecision`),
  * the expected-savings estimate and stats
    (:meth:`ReusePolicy.stats`).

``core.dispatch.attention_dispatch`` consumes the decision uniformly —
it executes the planned backend on ``decision.q`` / ``decision.k`` with
``decision.bias`` and never inspects which strategy produced them.
Adding a new sparsity idea is therefore a :func:`register_policy` call
(~50 lines), not a fork of the dispatch pipeline; ``--policy NAME`` on
the launchers selects it end-to-end, and the serving engine buckets
per-request on the policy name.

Built-in policies:

  ``ripple``     the paper: windowed Δ-checks snap Q/K entries to their
                 window representative (Eq. 3/4 schedule, ``core.reuse``)
  ``svg``        Sparse VideoGen-style head-classified spatial/temporal
                 block masks (``core.svg_mask``) as a logit bias plus a
                 tiled block map the sparse backend skips (DESIGN.md §12)
  ``equal_mse``  ripple's decision with the Fig. 9 equal-impact
                 per-step schedule (``core.calibrate``) instead of the
                 linear ramp
  ``dense``      no-op baseline; plans resolve straight to the dense
                 backend
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RippleConfig
from repro.core import reuse as reuse_lib
from repro.core import savings as savings_lib
from repro.core.reuse import AXES
from repro.core.schedule import axis_thresholds
from repro.core.svg_mask import svg_logit_bias


@dataclasses.dataclass
class RippleStats:
    savings: jax.Array             # paper accounting (partial-score reuse)
    structural_savings: jax.Array  # realized by the collapse path
    q_snap_frac: jax.Array
    k_snap_frac: jax.Array


@dataclasses.dataclass
class ReuseDecision:
    """What one policy decided for one attention call.

    ``q`` / ``k`` are the operands the backend must execute on (snapped
    by operand-rewriting policies, untouched otherwise); ``bias`` is the
    combined additive logit bias (the caller's bias plus any mask the
    policy emits).  ``q_mask`` / ``k_mask`` are boolean snap masks for
    the savings accounting (None for policies that never snap), and
    ``savings`` is the policy's expected-savings estimate for this call.

    ``block_map`` (DESIGN.md §12) is the per-(q_block, k_block) tile
    state map for the block-sparse backend — int32 skip/full/partial
    states broadcastable over (batch, heads), tiled with the
    ``block_shape`` the dispatcher passed to :meth:`ReusePolicy.decide`.
    Policies derive it from their masks; None means every tile runs.

    ``q_src`` / ``k_src`` (only with ``want_plan=True``, DESIGN.md §13)
    are int32 snap-source token maps over the decided grid segment —
    the cacheable half of an operand-rewriting decision: re-applying it
    to fresh operands is one ``take_along_axis`` gather.

    Registered as a jax pytree (``active_axes`` / ``window`` are static
    metadata) so whole decisions can flow through ``lax.cond`` — the
    decision cache's refresh-vs-reuse branch point.
    """

    q: jax.Array
    k: jax.Array
    thetas: Dict[str, jax.Array]
    active_axes: Tuple[str, ...]
    bias: Optional[jax.Array] = None
    q_mask: Optional[jax.Array] = None
    k_mask: Optional[jax.Array] = None
    savings: Optional[jax.Array] = None
    block_map: Optional[jax.Array] = None
    window: int = 2  # collapse-window size the masks were computed with
    q_src: Optional[jax.Array] = None
    k_src: Optional[jax.Array] = None


jax.tree_util.register_dataclass(
    ReuseDecision,
    data_fields=["q", "k", "thetas", "bias", "q_mask", "k_mask", "savings",
                 "block_map", "q_src", "k_src"],
    meta_fields=["active_axes", "window"])


def zero_inactive_axes(thetas: Dict[str, jax.Array],
                       active_axes: Sequence[str]) -> Dict[str, jax.Array]:
    """Disable the Δ-check on axes outside ``active_axes`` (Δ ≥ 0, so a
    zero threshold can never fire)."""
    out = dict(thetas)
    for a in AXES:
        if a not in active_axes:
            out[a] = jnp.zeros(())
    return out


def _zero_thetas() -> Dict[str, jax.Array]:
    return {a: jnp.zeros(()) for a in AXES}


class ReusePolicy:
    """Base class / protocol for reuse policies.

    Out-of-tree strategies subclass this (or duck-type it), override
    :meth:`decide` (and usually :meth:`thetas_for`), and call
    :func:`register_policy`.  The three class attributes tell plan
    resolution what the policy needs — they gate backend selection
    without the dispatch layer knowing the strategy itself:

      ``emits_bias``       decide() may attach a logit bias (mask
                           policies) → backends that can't take a bias
                           (auto-Pallas, collapse) are avoided
      ``snaps_operands``   decide() may rewrite Q/K entries → the
                           collapse backend is worth choosing
      ``is_dense``         no-op baseline → plans resolve to 'dense'
      ``emits_block_map``  decide() can tile its mask into a sparse
                           block map → the block-sparse backend realizes
                           the mask as skipped tiles (DESIGN.md §12)
      ``caches_decisions`` decide(want_plan=True) emits a reusable plan
                           (snap-source maps / bias / block map) that
                           :meth:`apply_decision` can re-apply to fresh
                           operands — the cross-step decision cache
                           (DESIGN.md §13).  Policies written before the
                           cache existed default to False and keep
                           their original ``decide`` signature.
    """

    name: str = ""
    emits_bias: bool = False
    snaps_operands: bool = True
    is_dense: bool = False
    emits_block_map: bool = False
    caches_decisions: bool = False
    # Cache-capable policies whose decision is a *constant* of the
    # trajectory (offline-searched masks, core/patterns.py): the
    # decision cache refreshes at step 0 only — no drift stat, no
    # reuse_every cadence, no final-step re-decide (DESIGN.md §16).
    plan_once: bool = False

    def will_emit_bias(self, cfg: RippleConfig) -> bool:
        """Will :meth:`decide` attach a logit bias under this config?
        Backend resolution uses this (not ``emits_bias`` directly) so
        config-conditional masks — e.g. ripple's ``cfg.svg_mask`` combo
        — are also kept off the biasless backends."""
        return self.emits_bias

    def will_emit_block_map(self, cfg: RippleConfig) -> bool:
        """Will :meth:`decide` produce a ``ReuseDecision.block_map``
        when given a ``block_shape``?  Plan resolution prefers the
        block-sparse backend for such policies (DESIGN.md §12)."""
        return self.emits_block_map

    def will_cache_decisions(self, cfg: RippleConfig) -> bool:
        """Can this policy's decision be cached across steps under this
        config (DESIGN.md §13)?  The dispatcher passes ``want_plan=True``
        to :meth:`decide` — and calls :meth:`apply_decision` on cache
        hits — only when this returns True, so pre-cache policies keep
        their original signature."""
        return self.caches_decisions

    def will_seq_shard(self, cfg: RippleConfig) -> bool:
        """Does the context-parallel ring path (DESIGN.md §14) know how
        to run this policy's decision shard-locally when the token axis
        is sharded over a ``seq`` mesh axis?  Policies that return False
        fall back to the replicated token axis (batch/head sharding
        still applies) — the ring never guesses."""
        return False

    def plan_token(self, cfg: Optional[RippleConfig] = None):
        """Hashable token identifying external state the decision bakes
        in as compile-time constants (e.g. the pattern artifact's
        content-hash version, DESIGN.md §16).  The dispatch plan cache
        and the serving bucket key mix it in, so swapping the external
        state can never replay a stale compiled plan.  None when the
        policy has no such state."""
        return None

    # -- per-step threshold schedule ------------------------------------

    def thetas_for(self, cfg: RippleConfig, step, total_steps,
                   thetas: Optional[Dict[str, jax.Array]] = None
                   ) -> Dict[str, jax.Array]:
        """Per-axis thresholds for one denoising step.  ``thetas`` is a
        caller override (already-derived values); implementations must
        still apply their axis gating to it.  Must be jittable in
        ``step`` (samplers scan over steps)."""
        return _zero_thetas()

    # -- offline calibration --------------------------------------------

    def calibrate(self, q: jax.Array, k: jax.Array,
                  grid: Tuple[int, int, int], cfg: RippleConfig,
                  target_savings: float) -> Dict[str, object]:
        """Fit strategy parameters on sample Q/K activations.  Returns a
        dict of ``RippleConfig`` field overrides (possibly empty) to
        apply via ``dataclasses.replace`` — how the Tbl. 1
        hyper-parameters were found for the paper's policy."""
        return {}

    # -- the mask / snap decision ---------------------------------------

    def decide(self, q: jax.Array, k: jax.Array, *,
               grid: Tuple[int, int, int], cfg: RippleConfig,
               thetas: Dict[str, jax.Array],
               bias: Optional[jax.Array] = None,
               grid_slice: Optional[Tuple[int, int]] = None,
               fused: bool = False,
               block_shape: Optional[Tuple[int, int]] = None
               ) -> ReuseDecision:
        """The strategy itself.  Shard-oblivious by contract: it must
        produce identical values on the full operands and on one
        shard_map shard (decisions may only look along the t/x/y token
        axes, never across batch or heads — DESIGN.md §10).

        ``block_shape`` is the resolved plan's (block_q, block_k) — the
        dispatcher passes it **only** when the block-sparse backend was
        planned (so policies written before it existed keep working);
        block-map policies tile their masks with it (DESIGN.md §12).

        Cache-capable policies (``caches_decisions``) additionally take
        ``want_plan`` (again passed only when the capability is
        declared) and populate ``ReuseDecision.q_src`` / ``k_src`` when
        it is set, so the dispatcher can carry the decision across
        steps (DESIGN.md §13)."""
        raise NotImplementedError

    # -- cross-step decision reuse (DESIGN.md §13) ----------------------

    def apply_decision(self, q: jax.Array, k: jax.Array, cached, *,
                       grid: Tuple[int, int, int], cfg: RippleConfig,
                       thetas: Dict[str, jax.Array],
                       grid_slice: Optional[Tuple[int, int]] = None
                       ) -> ReuseDecision:
        """Re-apply a cached decision to *fresh* operands — the cheap
        half of the plan/apply split.  ``cached`` is the
        :class:`~repro.core.decision_cache.CachedDecision` an earlier
        ``decide(want_plan=True)`` produced for identically-shaped
        operands: snap-source maps are replayed as one gather each, the
        cached bias / block map are attached verbatim.  The per-step
        math stays exact — only the decision is stale.

        The base implementation covers both built-in shapes (operand
        rewriting via ``q_src``/``k_src``, mask emission via
        ``bias``/``block_map``); override for exotic plans.  Must
        produce a ReuseDecision with the same pytree structure as the
        corresponding ``decide(want_plan=True)`` call — the dispatcher
        selects between the two under ``lax.cond``.
        """
        q_s, q_mask = replay_snap(q, cached.q_idx, grid_slice,
                                  self.snaps_operands)
        k_s, k_mask = replay_snap(k, cached.k_idx, grid_slice,
                                  self.snaps_operands)
        if q_mask is not None and k_mask is not None:
            sav = savings_lib.partial_score_savings(q_mask, k_mask)
        elif cached.bias is not None:
            # mask policies: skippable score fraction = masked density
            sav = 1.0 - jnp.mean((cached.bias >= 0.0).astype(jnp.float32))
        else:
            sav = jnp.zeros(())
        return ReuseDecision(
            q=q_s, k=k_s, thetas=thetas,
            active_axes=tuple(cfg.axes) if self.snaps_operands else (),
            bias=cached.bias, q_mask=q_mask, k_mask=k_mask, savings=sav,
            block_map=cached.block_map,
            window=cfg.window if self.snaps_operands else 2,
            q_src=cached.q_idx, k_src=cached.k_idx)

    # -- savings accounting ---------------------------------------------

    def stats(self, decision: ReuseDecision) -> RippleStats:
        """RippleStats for ``with_stats=True`` callers."""
        zero = jnp.zeros(())
        realized = None
        if decision.block_map is not None:
            # A block map means the sparse backend executed this
            # decision: what's *realized* is its skipped-tile fraction,
            # not the collapse-path accounting (which never ran).
            from repro.kernels.sparse.ops import sparse_block_stats

            realized = sparse_block_stats(decision.block_map)
        if decision.q_mask is None or decision.k_mask is None:
            s = decision.savings if decision.savings is not None else zero
            return RippleStats(
                savings=s,
                structural_savings=realized if realized is not None else s,
                q_snap_frac=zero, k_snap_frac=zero)
        return RippleStats(
            savings=savings_lib.partial_score_savings(
                decision.q_mask, decision.k_mask),
            structural_savings=(
                realized if realized is not None
                else savings_lib.collapse_savings(
                    decision.q_mask, decision.k_mask, decision.window)),
            q_snap_frac=jnp.mean(decision.q_mask.astype(jnp.float32)),
            k_snap_frac=jnp.mean(decision.k_mask.astype(jnp.float32)),
        )


def _keep_block_map(keep: jax.Array,
                    block_shape: Optional[Tuple[int, int]]):
    """Tile a boolean keep-mask into sparse-backend states, or None when
    the dispatcher didn't plan the sparse backend (no ``block_shape``)."""
    if block_shape is None:
        return None
    from repro.kernels.sparse.ops import block_map_from_keep

    return block_map_from_keep(keep, *block_shape)


# ---------------------------------------------------------------------------
# Snap helpers shared by the operand-rewriting policies (the Fig. 6
# step ①-② pipeline, fused on-device or host-side per the plan)
# ---------------------------------------------------------------------------


def _snap_segment(seg, grid, thetas, cfg: RippleConfig, active_axes,
                  use_fused: bool, want_src: bool = False):
    """Step ①-② on one contiguous grid segment: fused kernel when the
    plan asks for it and the shape qualifies, host pipeline otherwise.
    ``want_src`` forces the host pipeline (the fused kernel does not
    expose snap sources) and additionally returns the source map —
    bitwise-equal outputs either way (the fused-mask parity contract)."""
    if use_fused and not want_src:
        from repro.kernels.reuse_mask.ops import (fused_compute_reuse,
                                                  fused_reuse_eligible)
        if fused_reuse_eligible(grid, window=cfg.window,
                                granularity=cfg.granularity,
                                axes=active_axes):
            s, m = fused_compute_reuse(seg, grid, thetas, axes=active_axes,
                                       granularity=cfg.granularity)
            return s, m, None
    r = reuse_lib.compute_reuse(
        seg, grid, thetas, axes=active_axes, window=cfg.window,
        granularity=cfg.granularity, channel_groups=cfg.channel_groups,
        want_src=want_src)
    return r.snapped, r.mask, r.src_idx


def snap_operand(x, do: bool, grid, thetas, cfg: RippleConfig, active_axes,
                 grid_slice, use_fused: bool, want_src: bool = False):
    """Snap one operand (or pass it through with an all-False mask when
    ``do`` is off).  ``grid_slice`` restricts snapping to the grid
    tokens of a mixed text+grid sequence.  Returns ``(snapped, mask,
    src)`` where ``src`` is the segment-scoped snap-source map (None
    unless ``want_src`` and ``do``)."""
    if not do:
        return x, jnp.zeros(x.shape, jnp.bool_), None
    if grid_slice is None:
        return _snap_segment(x, grid, thetas, cfg, active_axes, use_fused,
                             want_src)
    s, n = grid_slice
    seg = jax.lax.slice_in_dim(x, s, s + n, axis=-2)
    snapped_seg, mask_seg, src_seg = _snap_segment(
        seg, grid, thetas, cfg, active_axes, use_fused, want_src)
    snapped = jax.lax.dynamic_update_slice_in_dim(x, snapped_seg, s, axis=-2)
    mask = jnp.zeros(x.shape, jnp.bool_)
    mask = jax.lax.dynamic_update_slice_in_dim(mask, mask_seg, s, axis=-2)
    return snapped, mask, src_seg


def replay_snap(x, src, grid_slice, snaps_operands: bool):
    """Re-apply a cached snap-source map to a fresh operand: one
    ``take_along_axis`` gather over the grid segment (DESIGN.md §13).
    Returns ``(snapped, mask)``; with ``src is None`` the operand passes
    through (all-False mask for snap policies, no mask otherwise, so the
    pytree structure matches the corresponding decide branch)."""
    if src is None:
        return x, (jnp.zeros(x.shape, jnp.bool_) if snaps_operands else None)
    if grid_slice is None:
        snapped = jnp.take_along_axis(x, src, axis=-2)
        mask = src != jnp.arange(x.shape[-2], dtype=src.dtype)[:, None]
        return snapped, mask
    s, n = grid_slice
    seg = jax.lax.slice_in_dim(x, s, s + n, axis=-2)
    snapped_seg = jnp.take_along_axis(seg, src, axis=-2)
    snapped = jax.lax.dynamic_update_slice_in_dim(x, snapped_seg, s, axis=-2)
    mask_seg = src != jnp.arange(n, dtype=src.dtype)[:, None]
    mask = jnp.zeros(x.shape, jnp.bool_)
    mask = jax.lax.dynamic_update_slice_in_dim(mask, mask_seg, s, axis=-2)
    return snapped, mask


# ---------------------------------------------------------------------------
# Built-in policies
# ---------------------------------------------------------------------------


class RipplePolicy(ReusePolicy):
    """The paper's policy: Eq. 4 linear-ramp schedule + windowed Δ-check
    snapping on Q/K (``cfg.svg_mask`` additionally composes the SVG
    block mask on top, the TIMERIPPLE+SVG row of Tbl. 2)."""

    name = "ripple"
    caches_decisions = True

    def will_emit_bias(self, cfg):
        return self.emits_bias or cfg.svg_mask

    def will_emit_block_map(self, cfg):
        # The SVG combo's block mask tiles into skip/full/partial states,
        # so the sparse backend can realize it (snapping still happens;
        # only the pair-collapse structural win is traded away).
        return self.emits_block_map or cfg.svg_mask

    def will_seq_shard(self, cfg):
        # Pure snapping shards cleanly (halo exchange covers the window);
        # the +SVG combo would need the mask *and* snap paths fused on
        # the ring, which the ring driver doesn't implement — fall back.
        return not cfg.svg_mask

    def thetas_for(self, cfg, step, total_steps, thetas=None):
        if thetas is None:
            assert step is not None and total_steps is not None, (
                "attention_dispatch needs explicit thetas or "
                "(step, total_steps)")
            thetas = axis_thresholds(cfg, step, total_steps)
        return zero_inactive_axes(thetas, tuple(cfg.axes))

    def calibrate(self, q, k, grid, cfg, target_savings):
        from repro.core.calibrate import calibrate_threshold

        theta = calibrate_threshold(q, k, grid, cfg, target_savings)
        return {"fixed_threshold": theta}

    def decide(self, q, k, *, grid, cfg, thetas, bias=None, grid_slice=None,
               fused=False, block_shape=None, want_plan=False):
        active_axes = tuple(cfg.axes)
        q_s, q_mask, q_src = snap_operand(q, cfg.snap_q, grid, thetas, cfg,
                                          active_axes, grid_slice, fused,
                                          want_src=want_plan)
        k_s, k_mask, k_src = snap_operand(k, cfg.snap_k, grid, thetas, cfg,
                                          active_axes, grid_slice, fused,
                                          want_src=want_plan)
        block_map = None
        if cfg.svg_mask:
            keep, bias = svg_logit_bias(q_s, k_s, grid, grid_slice, bias)
            block_map = _keep_block_map(keep, block_shape)
        return ReuseDecision(
            q=q_s, k=k_s, thetas=thetas, active_axes=active_axes, bias=bias,
            q_mask=q_mask, k_mask=k_mask,
            savings=savings_lib.partial_score_savings(q_mask, k_mask),
            block_map=block_map, window=cfg.window,
            q_src=q_src, k_src=k_src)


class EqualMSEPolicy(RipplePolicy):
    """Ripple's decision under the Fig. 9 equal-impact schedule.

    The analytical step-sensitivity model (``core.calibrate``): the MSE
    a fixed θ induces decays log-linearly in the denoising step
    (``fit_step_sensitivity``), and at a fixed step MSE grows ~θ², so
    holding the induced MSE constant at its i_min level gives

        θ_i = θ_min · exp(−slope · (i − i_min) / 2)

    clipped to [θ_min, θ_max].  A table calibrated offline by
    ``equal_mse_schedule`` against *measured* MSEs overrides the
    analytic form (:meth:`from_schedule`).
    """

    name = "equal_mse"

    def __init__(self, mse_slope: float = -0.15,
                 theta_table: Optional[np.ndarray] = None,
                 table_i_min: Optional[int] = None):
        self.mse_slope = float(mse_slope)
        self.theta_table = (None if theta_table is None
                            else np.asarray(theta_table, np.float32))
        self.table_i_min = table_i_min

    @classmethod
    def from_schedule(cls, thetas: np.ndarray, i_min: int,
                      name: Optional[str] = None) -> "EqualMSEPolicy":
        """Wrap a per-step θ table from ``calibrate.equal_mse_schedule``."""
        pol = cls(theta_table=thetas, table_i_min=i_min)
        if name is not None:
            pol.name = name
        return pol

    def _shared_theta(self, cfg: RippleConfig, step, total_steps):
        i_min = (self.table_i_min if self.table_i_min is not None
                 else cfg.i_min)
        if self.theta_table is not None:
            tbl = jnp.asarray(self.theta_table, jnp.float32)
            idx = jnp.clip(jnp.asarray(step, jnp.int32) - i_min, 0,
                           tbl.shape[0] - 1)
            theta = tbl[idx]
        else:
            i = jnp.asarray(step, jnp.float32)
            lo = min(cfg.theta_min, cfg.theta_max)
            hi = max(cfg.theta_min, cfg.theta_max)
            ramp = cfg.theta_min * jnp.exp(
                -0.5 * self.mse_slope * (i - i_min))
            theta = jnp.clip(ramp, lo, hi)
        active = jnp.logical_and(
            jnp.asarray(step) >= i_min,
            jnp.asarray(step) < jnp.asarray(total_steps) - 1)
        return jnp.where(active, theta, 0.0)

    def thetas_for(self, cfg, step, total_steps, thetas=None):
        if thetas is None:
            assert step is not None and total_steps is not None, (
                "equal_mse needs explicit thetas or (step, total_steps)")
            shared = self._shared_theta(cfg, step, total_steps)
            thetas = {a: shared for a in AXES}
        return zero_inactive_axes(thetas, tuple(cfg.axes))


class SVGPolicy(ReusePolicy):
    """Sparse VideoGen-style structured masking, promoted from the
    TIMERIPPLE+SVG combination to a standalone strategy: each head is
    classified online as spatial (frame-block-diagonal) or temporal
    (strided-diagonal) and the losing mask's blocks are dropped via a
    −inf logit bias.  Q/K are never rewritten."""

    name = "svg"
    emits_bias = True
    snaps_operands = False
    emits_block_map = True
    caches_decisions = True

    def will_seq_shard(self, cfg):
        # Head classification has a sharded twin (classify_heads_sharded)
        # and the masks are row-separable, so each shard rebuilds its own
        # bias rows exactly.
        return True

    def thetas_for(self, cfg, step, total_steps, thetas=None):
        return _zero_thetas()  # no Δ-thresholds; masks are classified

    def decide(self, q, k, *, grid, cfg, thetas, bias=None, grid_slice=None,
               fused=False, block_shape=None, want_plan=False):
        # The whole decision is the (bias, block_map) pair, which the
        # cache carries verbatim — a cache hit skips the online head
        # classification entirely (no want_plan-specific work needed).
        keep, bias = svg_logit_bias(q, k, grid, grid_slice, bias)
        return ReuseDecision(
            q=q, k=k, thetas=thetas, active_axes=(), bias=bias,
            savings=1.0 - jnp.mean(keep.astype(jnp.float32)),
            block_map=_keep_block_map(keep, block_shape))

    def stats(self, decision):
        zero = jnp.zeros(())
        # savings = skippable score fraction (mask density); structural
        # = the tile fraction the block-sparse backend skips outright —
        # 0 when no block map was planned (reference execution computes
        # the full dense score matrix and only zeroes weights).
        if decision.block_map is not None:
            from repro.kernels.sparse.ops import sparse_block_stats

            structural = sparse_block_stats(decision.block_map)
        else:
            structural = zero
        return RippleStats(savings=decision.savings,
                           structural_savings=structural,
                           q_snap_frac=zero, k_snap_frac=zero)


class DensePolicy(ReusePolicy):
    """No-op baseline: every plan resolves to the dense backend, so
    ``--policy dense`` measures the exact cost of turning reuse off
    without touching the config."""

    name = "dense"
    snaps_operands = False
    is_dense = True

    def decide(self, q, k, *, grid, cfg, thetas, bias=None, grid_slice=None,
               fused=False, block_shape=None):
        return ReuseDecision(q=q, k=k, thetas=thetas, active_axes=(),
                             bias=bias, savings=jnp.zeros(()))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: "OrderedDict[str, ReusePolicy]" = OrderedDict()


def register_policy(policy: ReusePolicy, *, name: Optional[str] = None,
                    override: bool = False) -> ReusePolicy:
    """Register ``policy`` under ``name`` (default ``policy.name``).

    Registration is the whole integration surface: a registered name is
    immediately valid as ``RippleConfig.policy``, as
    ``attention_dispatch(..., policy=...)``, as a per-request
    ``GenRequest.policy``, and as ``--policy`` on the launchers.  Plan
    caches key on the policy *name*, so re-registering (``override``)
    takes effect for new plans without a cache flush.
    """
    n = name or getattr(policy, "name", "")
    if not n or not isinstance(n, str):
        raise ValueError(f"policy {policy!r} needs a non-empty string name")
    if n in _REGISTRY and not override:
        raise ValueError(
            f"policy {n!r} already registered (pass override=True to "
            f"replace it)")
    _REGISTRY[n] = policy
    return policy


def get_policy(name) -> ReusePolicy:
    """Look up a registered policy; ReusePolicy instances pass through."""
    if isinstance(name, ReusePolicy):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown reuse policy {name!r}; registered: "
                       f"{list_policies()}") from None


def list_policies() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register_policy(RipplePolicy())
register_policy(SVGPolicy())
register_policy(EqualMSEPolicy())
register_policy(DensePolicy())

# The pattern-search policies (``static``, ``rainfusion``) live in
# core/patterns.py and register themselves on import; importing here
# makes every registry consumer see them without a separate import.
from repro.core import patterns as _patterns  # noqa: E402,F401
