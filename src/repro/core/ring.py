"""Context-parallel ring attention over the ``seq`` mesh axis (DESIGN.md §14).

The batch/head sharding of §10 cannot split a *single* long/high-res
video — exactly the request shape the paper's savings matter most for.
This module runs the reuse pipeline with the token axis sharded
``S``-way over a third mesh axis, inside the dispatcher's ``shard_map``:

* **Decision path** — shard-local, with an explicit halo.  §10.2's
  zero-halo contract breaks on the t axis when a shard boundary cuts a
  reuse window, so each shard ``ppermute``-exchanges ``window − 1``
  neighbor frames, re-runs the windowed Δ-checks on a window-aligned
  slab of ``L_max`` frames, and keeps its own rows — bitwise equal to
  the single-device decision (``t_valid`` masks the global tail and the
  ring-wrap garbage windows; x/y windows live inside a frame and never
  need halo).  When ``T/S`` is a window multiple (or t is inactive) the
  halo is empty and the slab is the local block itself.

* **Execution path**, two backends:

  - ``reference`` (snap policies: ripple, equal_mse) — the exactness
    path: the snapped K and V are ``all_gather``-ed (tiled) and each
    shard computes its query rows against the full key axis, which is
    *bitwise* identical to single-device.
  - ``sparse`` (mask policies: svg) — the true ring: K/V blocks rotate
    with ``lax.ppermute`` while the block-sparse kernel accumulates
    online-softmax state ``(m, l, acc)`` across hops (the kernel-carry
    convention of ``kernels/sparse``).  Per hop, the shard slices its
    cached bias rows down to the arriving key block, tiles them into a
    block map, and **skips the whole hop** when every tile is SKIP — the
    elided-hop counter rides the decision cache out to engine logs and
    BENCH records.  The rotation itself still runs every hop (downstream
    shards need the blocks), so the communication saving is *modeled*,
    not yet realized in wall-clock; the compute saving is real.  Hop
    order rotates the softmax reduction per shard, so outputs match
    single-device to ~1e-5 relative (documented in §14), not bitwise.

Collectives (halo exchange, sharded head classification, the ring
rotation) always run *outside* the decision cache's refresh
``lax.cond`` — a cond branch must stay pure-local so one shard's
drift-forced refresh can never desync the others (§13 extended to seq).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig
from repro.core import reuse as reuse_lib
from repro.core.decision_cache import CachedDecision
from repro.core.svg_mask import classify_heads_sharded, svg_keep_rows

__all__ = ["SEQ_AXIS", "ring_cache_specs", "ring_pipeline"]

SEQ_AXIS = "seq"


# ---------------------------------------------------------------------------
# Halo geometry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Geometry:
    """Static shard-slab geometry for one (grid, window, S) combination.

    ``left = window − 1`` neighbor frames cover every window a shard
    boundary can cut; the slab is the smallest window-aligned frame
    range containing the local block, so ``lmax = w·⌈(left + tl)/w⌉``
    and ``right = lmax − tl`` (the slab start ``g0 − p`` with
    ``p = g0 mod w`` shifts at most ``w − 1 = left`` frames left, so the
    slice always fits in ``left + tl + right`` exchanged frames)."""

    t_local: int
    hw: int
    window: int
    left: int
    right: int
    lmax: int

    @property
    def fast(self) -> bool:
        return self.left == 0 and self.right == 0


def _geometry(grid, cfg: RippleConfig, shards: int) -> _Geometry:
    T, H, W = grid
    w = max(int(cfg.window), 1)
    tl = T // shards
    if "t" not in cfg.axes or w <= 1 or tl % w == 0:
        return _Geometry(tl, H * W, w, 0, 0, tl)
    left = w - 1
    lmax = w * math.ceil((left + tl) / w)
    return _Geometry(tl, H * W, w, left, lmax - tl, lmax)


def _ppermute(x, shards: int, shift: int):
    """Rotate along the ring: with ``shift=+1`` every shard receives its
    left neighbor's buffer (source ``j`` sends to ``j+1``)."""
    perm = [(j, (j + shift) % shards) for j in range(shards)]
    return jax.lax.ppermute(x, SEQ_AXIS, perm)


def _halo_slab(x, geom: _Geometry, shards: int):
    """(..., N_local, d) tokens -> the window-aligned decision slab.

    Returns ``(slab, o0)`` where ``o0`` is the local block's token
    offset inside the slab (0 on the fast path).  Multi-hop: a window
    larger than a shard pulls whole neighbor blocks (satellite case)."""
    if geom.fast:
        return x, 0
    nl = x.shape[-2]
    left_t = geom.left * geom.hw
    right_t = geom.right * geom.hw

    segs, cur = [], x
    for _ in range(-(-left_t // nl)):
        cur = _ppermute(cur, shards, +1)
        segs.insert(0, cur)
    lbuf = jnp.concatenate(segs, axis=-2)[..., -left_t:, :]

    segs, cur = [], x
    for _ in range(-(-right_t // nl)):
        cur = _ppermute(cur, shards, -1)
        segs.append(cur)
    rbuf = jnp.concatenate(segs, axis=-2)[..., :right_t, :]

    ext = jnp.concatenate([lbuf, x, rbuf], axis=-2)
    p = _phase(geom)
    slab = jax.lax.dynamic_slice_in_dim(
        ext, (geom.left - p) * geom.hw, geom.lmax * geom.hw, axis=-2)
    return slab, p * geom.hw


def _phase(geom: _Geometry):
    """Local block's frame offset inside the window-aligned slab."""
    g0 = jax.lax.axis_index(SEQ_AXIS) * geom.t_local
    return g0 % geom.window


def _t_valid(geom: _Geometry, grid) -> Optional[jax.Array]:
    """(lmax,) bool: slab frames whose t-window lies fully inside
    [0, T).  Gates the global remainder tail (those frames never snap on
    t, matching single-device) and the last shard's ring-wrapped right
    halo.  None on the fast path — every window is then in range."""
    if geom.fast:
        return None
    T = grid[0]
    g0 = jax.lax.axis_index(SEQ_AXIS) * geom.t_local
    j = jnp.arange(geom.lmax)
    win_start = g0 - (g0 % geom.window) + (j // geom.window) * geom.window
    return (win_start + geom.window) <= T


# ---------------------------------------------------------------------------
# Shard-local decisions
# ---------------------------------------------------------------------------


def _decide_src(x, geom: _Geometry, grid, thetas, cfg: RippleConfig,
                o0, t_valid):
    """Windowed Δ-checks on the slab; returns the *slab-coordinate*
    snap-source map for the local rows, (..., N_local, d) int32 — the
    cacheable half of the decision (replay = one gather, §13)."""
    T, H, W = grid
    r = reuse_lib.compute_reuse(
        x, (geom.lmax, H, W), thetas, axes=tuple(cfg.axes),
        window=cfg.window, granularity=cfg.granularity,
        channel_groups=cfg.channel_groups, want_src=True, t_valid=t_valid)
    nl = geom.t_local * geom.hw
    return jax.lax.dynamic_slice_in_dim(r.src_idx, o0, nl, axis=-2)


def _gather_src(slab, src):
    return jnp.take_along_axis(slab, src, axis=-2)


def _pack(stat):
    """(B, H) shard-local statistic -> (B, H, 1) cache leaf, so the seq
    axis has a dim to live on (global shape (B, H, S))."""
    return stat[..., None]


def _counters(prev: Optional[CachedDecision], stat):
    if prev is None or prev.hits is None:
        return jnp.zeros(stat.shape + (1,), jnp.int32), \
            jnp.ones(stat.shape + (1,), jnp.int32)
    return prev.hits, prev.refreshes + 1


def _drift(q, k, cfg: RippleConfig):
    from repro.core import decision_cache as dc

    if cfg.drift_tol > 0:
        return dc.drift_stat(q, k, cfg)
    return jnp.zeros(q.shape[:-2], jnp.float32)


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------


def _reference_ring_execute(q_s, k_s, v, scale):
    """Exactness path for snap policies: gather the (snapped) operands
    and run the *full-shape* dense reference on every shard, keeping the
    local query rows.  Gathering only K/V and computing the local rows
    would be numerically right but not bitwise — XLA's CPU gemm
    partitioning depends on the row count (and thread budget), so a
    shard-shaped matmul can round differently from the single-device
    one.  Identical shapes compile to the identical program, which is
    what the reference backend's bitwise contract demands; the decision
    path (halo Δ-checks, per-shard caches) is what this backend shards,
    and the sparse ring is the execution-scaling path (DESIGN.md §14).
    """
    from repro.core.dispatch import dense_attention

    ax = q_s.ndim - 2
    nl = q_s.shape[-2]
    qb = jax.lax.all_gather(q_s, SEQ_AXIS, axis=ax, tiled=True)
    kb = jax.lax.all_gather(k_s, SEQ_AXIS, axis=ax, tiled=True)
    vb = jax.lax.all_gather(v, SEQ_AXIS, axis=ax, tiled=True)
    full = dense_attention(qb, kb, vb, scale, None)
    off = jax.lax.axis_index(SEQ_AXIS) * nl
    return jax.lax.dynamic_slice_in_dim(full, off, nl, axis=ax)


def _sparse_ring_execute(q, k, v, bias_rows, plan, shards: int):
    """The true ring: rotate K/V blocks, accumulate online-softmax state
    through the block-sparse kernel's carry, and skip a hop outright
    when its block-map slice is all-SKIP.  Returns ``(out, elided)``
    with ``elided`` the number of hops this shard skipped this call."""
    from repro.kernels.sparse.kernel import _M_INIT
    from repro.kernels.sparse.ops import (SKIP, block_map_from_keep,
                                          sparse_attention_pallas)

    B, H, nl, _ = q.shape
    dv = v.shape[-1]
    me = jax.lax.axis_index(SEQ_AXIS)
    m = jnp.full((B, H, nl), _M_INIT, jnp.float32)
    l = jnp.zeros((B, H, nl), jnp.float32)
    acc = jnp.zeros((B, H, nl, dv), jnp.float32)
    elided = jnp.zeros((), jnp.int32)
    k_cur, v_cur = k, v

    for h in range(shards):
        src = (me - h) % shards  # which shard's block arrived this hop
        bias_hop = jax.lax.dynamic_slice_in_dim(
            bias_rows, src * nl, nl, axis=-1)
        bmap = block_map_from_keep(bias_hop >= 0.0, plan.block_q,
                                   plan.block_k)
        elide = jnp.all(bmap == SKIP)

        def run(carry, kk=k_cur, vv=v_cur, bh=bias_hop, bm=bmap):
            _, state = sparse_attention_pallas(
                q, kk, vv, bias=bh, block_map=bm, block_q=plan.block_q,
                block_k=plan.block_k, carry=carry, return_state=True)
            return state

        m, l, acc = jax.lax.cond(elide, lambda c: c, run, (m, l, acc))
        elided = elided + elide.astype(jnp.int32)
        if h < shards - 1:
            # The rotation is never skipped — downstream shards still
            # need the blocks — so elision saves compute, and the comm
            # saving stays modeled (ring_sweep reports both).
            k_cur = _ppermute(k_cur, shards, +1)
            v_cur = _ppermute(v_cur, shards, +1)

    out = (acc / jnp.where(l > 0.0, l, 1.0)[..., None]).astype(q.dtype)
    return out, elided


# ---------------------------------------------------------------------------
# Pipelines (called inside the dispatcher's shard_map, SEQ_AXIS bound)
# ---------------------------------------------------------------------------


def _snap_pipeline(q, k, v, thetas, scale, *, plan, grid, cfg, step,
                   cached, want_cache, total_steps):
    from repro.core import decision_cache as dc

    geom = _geometry(grid, cfg, plan.seq_shards)
    t_valid = _t_valid(geom, grid)
    # Halo exchange runs unconditionally: collectives can never sit
    # inside the refresh cond (per-shard refresh independence, §13/§14).
    q_slab, q_o0 = _halo_slab(q, geom, plan.seq_shards) \
        if cfg.snap_q else (None, 0)
    k_slab, k_o0 = _halo_slab(k, geom, plan.seq_shards) \
        if cfg.snap_k else (None, 0)

    def decide():
        q_src = (None if q_slab is None else
                 _decide_src(q_slab, geom, grid, thetas, cfg, q_o0, t_valid))
        k_src = (None if k_slab is None else
                 _decide_src(k_slab, geom, grid, thetas, cfg, k_o0, t_valid))
        return q_src, k_src

    if not want_cache:
        q_src, k_src = decide()
        q_s = q if q_src is None else _gather_src(q_slab, q_src)
        k_s = k if k_src is None else _gather_src(k_slab, k_src)
        return _reference_ring_execute(q_s, k_s, v, scale)

    stat = _drift(q, k, cfg)

    def fresh(prev):
        q_src, k_src = decide()
        hits, refreshes = _counters(prev, stat)
        return CachedDecision(q_idx=q_src, k_idx=k_src,
                              ref_stat=_pack(stat), hits=hits,
                              refreshes=refreshes)

    if cached is None:
        cache = fresh(None)
    else:
        refresh = dc.refresh_due(step, cfg, stat,
                                 cached.ref_stat[..., 0], total_steps)
        cache = jax.lax.cond(refresh, fresh, dc.bump_hit, cached)

    # The snap itself happens once, outside the cond: both arms agree on
    # the source map, and replaying it is the same gather either way —
    # which is exactly why a cache hit is bitwise.
    q_s = q if cache.q_idx is None else _gather_src(q_slab, cache.q_idx)
    k_s = k if cache.k_idx is None else _gather_src(k_slab, cache.k_idx)
    return _reference_ring_execute(q_s, k_s, v, scale), cache


def _mask_pipeline(q, k, v, scale, *, plan, grid, cfg, step, cached,
                   want_cache, total_steps, policy=None):
    from repro.core import decision_cache as dc

    nl = q.shape[-2]
    off = jax.lax.axis_index(SEQ_AXIS) * nl
    plan_once = getattr(policy, "plan_once", False)
    hook = getattr(policy, "ring_bias_rows", None)
    if hook is not None:
        # Constant-mask policies (core/patterns.py) render their own
        # shard-local rows — position-determined, no collectives, and
        # per-hop all-SKIP elision falls straight out of the constant
        # map in _sparse_ring_execute.
        def bias_rows():
            return hook(q, k, grid=grid, cfg=cfg, row_offset=off,
                        n_rows=nl)
    else:
        # Sharded online head classification (svg) — a collective, so
        # it runs every step regardless of the refresh verdict.
        is_spatial = classify_heads_sharded(q, k, grid, SEQ_AXIS)

        def bias_rows():
            keep = svg_keep_rows(is_spatial, grid, off, nl)
            return jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)

    if not want_cache:
        out, _ = _sparse_ring_execute(q, k, v, bias_rows(), plan,
                                      plan.seq_shards)
        return out

    stat = jnp.zeros(q.shape[:-2], jnp.float32) if plan_once \
        else _drift(q, k, cfg)

    def fresh(prev):
        hits, refreshes = _counters(prev, stat)
        elided = (jnp.zeros((1,), jnp.int32) if prev is None
                  or prev.elided is None else prev.elided)
        return CachedDecision(bias=bias_rows(), ref_stat=_pack(stat),
                              hits=hits, refreshes=refreshes,
                              elided=elided)

    if cached is None:
        cache = fresh(None)
    elif plan_once:
        # Refresh cadence of never (DESIGN.md §16): replay the step-0
        # constant rows for the whole trajectory.
        refresh = jnp.equal(jnp.asarray(step, jnp.int32), 0)
        cache = jax.lax.cond(refresh, fresh, dc.bump_hit, cached)
    else:
        refresh = dc.refresh_due(step, cfg, stat,
                                 cached.ref_stat[..., 0], total_steps)
        cache = jax.lax.cond(refresh, fresh, dc.bump_hit, cached)

    out, elided = _sparse_ring_execute(q, k, v, cache.bias, plan,
                                       plan.seq_shards)
    cache = dataclasses.replace(cache, elided=cache.elided + elided[None])
    return out, cache


def ring_pipeline(q, k, v, thetas, scale, *, plan, grid,
                  cfg: RippleConfig, policy, step=None, cached=None,
                  want_cache: bool = False, total_steps=None):
    """One context-parallel attention call on this shard's (B, H,
    N_local, d) token slice.  Must run inside shard_map with
    ``SEQ_AXIS`` bound.  Returns ``out`` or ``(out, CachedDecision)``.
    """
    if plan.backend == "sparse":
        return _mask_pipeline(q, k, v, scale, plan=plan, grid=grid,
                              cfg=cfg, step=step, cached=cached,
                              want_cache=want_cache,
                              total_steps=total_steps, policy=policy)
    return _snap_pipeline(q, k, v, thetas, scale, plan=plan, grid=grid,
                          cfg=cfg, step=step, cached=cached,
                          want_cache=want_cache, total_steps=total_steps)


def ring_cache_specs(plan, cfg: RippleConfig):
    """PartitionSpecs for the ring cache's leaves, with exactly the
    None-pattern :func:`ring_pipeline` produces — defined next to it so
    the two can never drift.  Token-shaped leaves shard seq at dim 2;
    packed per-shard stats/counters at their trailing dim; the elided
    counter is one i32 per shard."""
    from jax.sharding import PartitionSpec as P

    b = (plan.batch_axes if len(plan.batch_axes) > 1
         else plan.batch_axes[0]) if plan.batch_axes else None
    h = plan.head_axis
    tok = P(b, h, SEQ_AXIS, None)
    stat = P(b, h, SEQ_AXIS)
    if plan.backend == "sparse":
        return CachedDecision(bias=tok, ref_stat=stat, hits=stat,
                              refreshes=stat, elided=P(SEQ_AXIS))
    return CachedDecision(q_idx=tok if cfg.snap_q else None,
                          k_idx=tok if cfg.snap_k else None,
                          ref_stat=stat, hits=stat, refreshes=stat)
