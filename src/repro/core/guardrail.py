"""Runtime quality guardrails (DESIGN.md §17).

TimeRipple's contract — ~85% attention compute saved at <0.06% quality
loss — is enforced *offline* (policy_sweep PSNR rows, pattern-search
scoring).  At serve time nothing used to stand between a sparse-kernel
NaN, a drifted decision cache, or a corrupt pattern artifact and a
broken video shipped to a user.  This module is that missing layer, in
two halves:

**In-graph sentinels** (cheap, traced into the sampler):

  * non-finite detection — an ``isfinite`` reduction over the attention
    output per dispatch call, accumulated into the decision-cache carry
    (:class:`~repro.core.decision_cache.CachedDecision.nonfinite`), and
    over the latents per denoising step (the samplers' ``sentinel``
    flag).  O(N) elementwise passes next to O(N²·d) attention — noise.
  * a sampled drift proxy — every ``cfg.sentinel_probe_every`` steps,
    one (batch, head) slice of the sparse output is re-computed densely
    and the relative error is max-accumulated into
    ``CachedDecision.probe_err``.  One dense (N, d) attention per probe
    step per call: a bounded, scheduled cost, not a per-step one.

**The host-side degradation ladder** (:class:`DegradationLadder`): the
engine reads the sentinels after every batch (plus a host ``isfinite``
over the returned latents, which covers samplers that thread no cache)
and, on a trip, steps the bucket's policy down one rung —
``rainfusion``/``static`` → ``ripple`` → ``dense`` — then re-serves the
batch under the degraded bucket key, so the result that ships is
finite.  Degradation is *sticky with a cool-down*: the bucket family
stays at its rung until ``cooldown_batches`` consecutive clean batches,
then one batch probes the original policy (re-promotion probe); a clean
probe restores the base policy, a tripped one falls back.  The ladder
keys on the bucket *family* (bucket key minus the policy and pattern
token), so a degraded bucket recompiles under its effective policy
instead of replaying the tripped program, and one ladder shared across
router replicas makes the state survive failover.

Everything here is deliberately dependency-light: the dispatch layer
imports it lazily from the cached pipeline, the engine from its serve
loop.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Hashable, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["DEGRADATION_LADDER", "DegradationLadder", "GuardrailConfig",
           "attach_sentinel", "dense_probe_error", "next_policy",
           "nonfinite_count"]


# ---------------------------------------------------------------------------
# In-graph sentinels
# ---------------------------------------------------------------------------


def nonfinite_count(x: jax.Array, lead_ndim: Optional[int] = None):
    """i32 count of non-finite entries of ``x``.  With ``lead_ndim`` the
    count keeps that many leading dims (one cell per (batch, head, ...)
    slice — the decision-cache leaf shape, shard-local under shard_map);
    without it the reduction is total (the samplers' latent sentinel)."""
    bad = ~jnp.isfinite(x)
    if lead_ndim is None:
        return jnp.sum(bad).astype(jnp.int32)
    axes = tuple(range(lead_ndim, x.ndim))
    return jnp.sum(bad, axis=axes).astype(jnp.int32)


def dense_probe_error(q, k, v, out, scale) -> jax.Array:
    """Relative L2 error of one attention slice vs its dense recompute.
    ``q``/``k``/``v``/``out`` are single (N, d) slices.  A NaN anywhere
    propagates into the statistic — the probe doubles as a second
    non-finite sentinel."""
    q32, k32, v32 = (a.astype(jnp.float32) for a in (q, k, v))
    logits = (q32 @ k32.T) * jnp.asarray(scale, jnp.float32)
    ref = jax.nn.softmax(logits, axis=-1) @ v32
    diff = jnp.linalg.norm(ref - out.astype(jnp.float32))
    return diff / (jnp.linalg.norm(ref) + 1e-6)


def attach_sentinel(cache, out, q, k, v, scale, step, cfg):
    """Fold this dispatch call's sentinel readings into the decision
    cache carry: accumulate the non-finite count of ``out`` and, on the
    ``cfg.sentinel_probe_every`` cadence, max-accumulate the dense-probe
    relative error of the leading (batch, head) slice.  Both leaves are
    lead-shaped like ``hits``/``ref_stat``, so shard_map carries each
    shard's own readings (zero halo, DESIGN.md §13) and the sampler aux
    channel reduces them at the end."""
    lead = out.shape[:-2]
    nf = nonfinite_count(out, lead_ndim=len(lead))
    if cache.nonfinite is not None:
        nf = cache.nonfinite + nf
    prev_pe = cache.probe_err if cache.probe_err is not None \
        else jnp.zeros(lead, jnp.float32)
    every = int(cfg.sentinel_probe_every)
    if every > 0 and step is not None:
        idx = (0,) * len(lead)

        def probe(pe):
            err = dense_probe_error(q[idx], k[idx], v[idx], out[idx], scale)
            return pe.at[idx].set(jnp.maximum(pe[idx], err)) if lead \
                else jnp.maximum(pe, err)

        due = jnp.equal(jnp.mod(jnp.asarray(step, jnp.int32), every), 0)
        new_pe = jax.lax.cond(due, probe, lambda pe: pe, prev_pe)
    else:
        new_pe = prev_pe
    return dataclasses.replace(cache, nonfinite=nf, probe_err=new_pe)


# ---------------------------------------------------------------------------
# The degradation ladder
# ---------------------------------------------------------------------------

# One rung down per sentinel trip: the structured / artifact-replaying
# policies fall back to the adaptive per-step Δ-check (ripple), and
# everything sparse bottoms out at dense — the backstop that cannot
# emit a reuse-induced NaN.  Unknown (out-of-tree) policies and the
# engine-default ``None`` jump straight to dense: the ladder cannot
# reason about their failure modes.
DEGRADATION_LADDER: Mapping[str, str] = {
    "rainfusion": "ripple",
    "static": "ripple",
    "svg": "ripple",
    "equal_mse": "ripple",
    "ripple": "dense",
}


def next_policy(policy: Optional[str]) -> Optional[str]:
    """The rung below ``policy`` (``None`` when already at the dense
    floor)."""
    if policy == "dense":
        return None
    return DEGRADATION_LADDER.get(policy, "dense")


def _chain(base: Optional[str]) -> List[Optional[str]]:
    chain: List[Optional[str]] = [base]
    cur = base
    while True:
        nxt = next_policy(cur)
        if nxt is None:
            return chain
        chain.append(nxt)
        cur = nxt


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    """Host-side trip thresholds and stickiness of the ladder."""

    # Non-finite entries tolerated per batch before tripping (across the
    # latents and every attention-output sentinel).  0 = any NaN trips.
    max_nonfinite: int = 0
    # Dense-probe relative-error trip threshold (CachedDecision.probe_err,
    # only populated when cfg.sentinel_probe_every > 0).  0 disables the
    # drift trip — the probe is a diagnostic then.  A non-finite probe
    # statistic always trips regardless.
    drift_tol: float = 0.0
    # Consecutive clean batches at a degraded rung before one batch
    # probes the original policy again (re-promotion).
    cooldown_batches: int = 8


@dataclasses.dataclass
class _Health:
    level: int = 0        # rungs below the base policy
    clean: int = 0        # clean batches at the current rung
    probing: bool = False  # next batch runs the base policy as a probe


class DegradationLadder:
    """Per-bucket-family degradation state (thread-safe; share one
    instance across router replicas so degraded state survives
    failover).  The engine calls :meth:`effective_policy` before each
    batch, :meth:`trip` when a sentinel fires, :meth:`record_clean`
    otherwise."""

    def __init__(self, config: Optional[GuardrailConfig] = None):
        self.config = config or GuardrailConfig()
        self._state: Dict[Hashable, _Health] = {}
        self._lock = threading.Lock()
        self.degraded_count = 0    # rungs stepped down (ladder trips)
        self.dense_fallbacks = 0   # trips that landed on the dense floor
        self.repromotions = 0      # probes that restored the base policy
        self.failed_probes = 0     # probes that tripped again

    def effective_policy(self, family: Hashable, base: Optional[str]
                         ) -> Tuple[Optional[str], bool]:
        """(policy to serve this batch under, is this a re-promotion
        probe).  Sticky: stays at the degraded rung until
        ``cooldown_batches`` clean batches, then probes ``base``."""
        with self._lock:
            h = self._state.get(family)
            if h is None or h.level == 0:
                return base, False
            if h.probing:
                return base, True
            if h.clean >= self.config.cooldown_batches:
                h.probing = True
                return base, True
            return _chain(base)[min(h.level, len(_chain(base)) - 1)], False

    def trip(self, family: Hashable, base: Optional[str]
             ) -> Optional[str]:
        """A sentinel fired for ``family``.  Returns the policy to
        re-serve the batch under, or ``None`` when the ladder is already
        at the dense floor (the engine then errors the batch — a dense
        NaN is a model/params problem, not a reuse one)."""
        chain = _chain(base)
        with self._lock:
            h = self._state.setdefault(family, _Health())
            if h.probing:
                # The base-policy probe tripped: fall back to the rung
                # the family was parked at, cool-down restarts.
                h.probing = False
                h.clean = 0
                self.failed_probes += 1
                return chain[min(h.level, len(chain) - 1)]
            if h.level + 1 >= len(chain):
                return None
            h.level += 1
            h.clean = 0
            self.degraded_count += 1
            pol = chain[h.level]
            if pol == "dense":
                self.dense_fallbacks += 1
            return pol

    def record_clean(self, family: Hashable) -> None:
        """A batch served without tripping: advance the cool-down, or
        restore the base policy if this batch was a re-promotion probe."""
        with self._lock:
            h = self._state.get(family)
            if h is None or h.level == 0:
                return
            if h.probing:
                h.level = 0
                h.probing = False
                h.clean = 0
                self.repromotions += 1
            else:
                h.clean += 1

    def degraded(self, family: Hashable) -> bool:
        with self._lock:
            h = self._state.get(family)
            return h is not None and h.level > 0

    def metrics(self) -> Dict[str, int]:
        with self._lock:
            return {"degraded_count": self.degraded_count,
                    "dense_fallbacks": self.dense_fallbacks,
                    "repromotions": self.repromotions,
                    "failed_probes": self.failed_probes,
                    "degraded_buckets": sum(
                        1 for h in self._state.values() if h.level > 0)}
