"""The drop-in TimeRipple attention module (compatibility wrapper).

``ripple_attention`` runs the paper's pipeline (Fig. 6):

  ① Δ similarity checks on Q and K along the grid axes (``core.reuse``,
     or the fused on-device kernel — ``kernels/reuse_mask``)
  ② OR-aggregation into snap masks
  ③/④ attention with reused partial scores — realized either as the
     dense snapped oracle (`execution='reference'`), the exact
     pair-collapse math (`execution='collapse'`), or the block-skipping
     Pallas kernel (`backend='pallas'`).

Since the dispatch refactor (DESIGN.md §8) the pipeline itself lives in
``core.dispatch``; this module keeps the historical entry point and its
``backend='jnp'|'pallas'`` convention for out-of-tree callers only.
**Deprecated**: call :func:`repro.core.dispatch.attention_dispatch`
instead (a one-time DeprecationWarning says so at first use).

Inputs are post-RoPE Q/K — the RoPE channel groups are what carry the
spatio-temporal structure the checks exploit (paper §3.1-3.2).  When the
sequence mixes text and image/video tokens (MMDiT, vDiT), ``grid_slice``
restricts reuse to the grid tokens; text tokens are never snapped.
"""

from __future__ import annotations

import warnings
from typing import Dict, Optional, Tuple

import jax

from repro.config.base import RippleConfig
from repro.core.dispatch import (RippleStats, attention_dispatch,
                                 dense_attention)

__all__ = ["ripple_attention", "RippleStats"]

_deprecation_warned = False


def _dense_attention(q, k, v, scale, bias=None):
    # Historical alias; the implementation moved to core.dispatch.
    return dense_attention(q, k, v, scale, bias)


def ripple_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    grid: Tuple[int, int, int],
    cfg: RippleConfig,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    thetas: Optional[Dict[str, jax.Array]] = None,
    bias: Optional[jax.Array] = None,
    grid_slice: Optional[Tuple[int, int]] = None,
    backend: str = "jnp",
    with_stats: bool = False,
):
    """TimeRipple attention.  q,k,v: (..., N, head_dim), post-RoPE.

    ``backend='jnp'`` executes per ``cfg.execution``; ``'pallas'`` forces
    the ripple kernel.  thetas overrides the Eq. 4 schedule (otherwise
    derived from ``step``/``total_steps``).  Returns ``out`` or
    ``(out, RippleStats)``.

    .. deprecated:: use :func:`repro.core.dispatch.attention_dispatch`.
    """
    global _deprecation_warned
    if backend == "jnp":
        resolved = "collapse" if cfg.execution == "collapse" else "reference"
    else:
        resolved = backend
    if not _deprecation_warned:
        _deprecation_warned = True
        warnings.warn(
            "repro.core.ripple_attention.ripple_attention is deprecated "
            "and no longer imported anywhere in-repo; replace this call "
            "with repro.core.dispatch.attention_dispatch(q, k, v, "
            "grid=grid, cfg=cfg, step=step, total_steps=total_steps, "
            "thetas=thetas, bias=bias, grid_slice=grid_slice, "
            f"backend={resolved!r}, with_stats=with_stats) — for your "
            f"arguments backend={resolved!r} reproduces the old "
            f"backend={backend!r} behaviour exactly",
            DeprecationWarning, stacklevel=2)
    return attention_dispatch(
        q, k, v, grid=grid, cfg=cfg, step=step, total_steps=total_steps,
        thetas=thetas, bias=bias, grid_slice=grid_slice, backend=resolved,
        with_stats=with_stats)
