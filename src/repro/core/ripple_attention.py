"""The drop-in TimeRipple attention module.

``ripple_attention`` is what model code calls in place of plain scaled
dot-product attention.  It runs the paper's pipeline (Fig. 6):

  ① Δ similarity checks on Q and K along the grid axes (``core.reuse``)
  ② OR-aggregation into snap masks
  ③/④ attention with reused partial scores — realized either as the
     dense snapped oracle (`execution='reference'`), the exact
     pair-collapse math (`execution='collapse'`), or the block-skipping
     Pallas kernel (`backend='pallas'`).

Inputs are post-RoPE Q/K — the RoPE channel groups are what carry the
spatio-temporal structure the checks exploit (paper §3.1-3.2).  When the
sequence mixes text and image/video tokens (MMDiT, vDiT), ``grid_slice``
restricts reuse to the grid tokens; text tokens are never snapped.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig
from repro.core import reuse as reuse_lib
from repro.core import savings as savings_lib
from repro.core.collapse import collapsed_attention, pair_flags
from repro.core.schedule import axis_thresholds
from repro.core.svg_mask import svg_block_mask


@dataclasses.dataclass
class RippleStats:
    savings: jax.Array             # paper accounting (partial-score reuse)
    structural_savings: jax.Array  # realized by the collapse path
    q_snap_frac: jax.Array
    k_snap_frac: jax.Array


def _dense_attention(q, k, v, scale, bias=None):
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * scale
    if bias is not None:
        logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kv->...qv", probs.astype(v.dtype), v)


def ripple_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    grid: Tuple[int, int, int],
    cfg: RippleConfig,
    step: Optional[jax.Array] = None,
    total_steps: Optional[int] = None,
    thetas: Optional[Dict[str, jax.Array]] = None,
    bias: Optional[jax.Array] = None,
    grid_slice: Optional[Tuple[int, int]] = None,
    backend: str = "jnp",
    with_stats: bool = False,
):
    """TimeRipple attention.  q,k,v: (..., N, head_dim), post-RoPE.

    thetas overrides the Eq. 4 schedule (otherwise derived from
    ``step``/``total_steps``).  Returns ``out`` or ``(out, RippleStats)``.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    if not cfg.active():
        out = _dense_attention(q, k, v, scale, bias)
        if with_stats:
            zero = jnp.zeros(())
            return out, RippleStats(zero, zero, zero, zero)
        return out

    if thetas is None:
        assert step is not None and total_steps is not None, (
            "ripple needs either explicit thetas or (step, total_steps)")
        thetas = axis_thresholds(cfg, step, total_steps)
    # Image models have no temporal axis: the t check never fires.
    active_axes = tuple(a for a in cfg.axes)
    for a in ("t", "x", "y"):
        if a not in active_axes:
            thetas = dict(thetas)
            thetas[a] = jnp.zeros(())  # Δ ≥ 0 ⇒ never below 0 ⇒ disabled

    def snap(x, do):
        if not do:
            return x, jnp.zeros(x.shape, jnp.bool_)
        if grid_slice is None:
            r = reuse_lib.compute_reuse(
                x, grid, thetas, axes=active_axes, window=cfg.window,
                granularity=cfg.granularity, channel_groups=cfg.channel_groups)
            return r.snapped, r.mask
        s, n = grid_slice
        seg = jax.lax.slice_in_dim(x, s, s + n, axis=-2)
        r = reuse_lib.compute_reuse(
            seg, grid, thetas, axes=active_axes, window=cfg.window,
            granularity=cfg.granularity, channel_groups=cfg.channel_groups)
        snapped = jax.lax.dynamic_update_slice_in_dim(x, r.snapped, s, axis=-2)
        mask = jnp.zeros(x.shape, jnp.bool_)
        mask = jax.lax.dynamic_update_slice_in_dim(mask, r.mask, s, axis=-2)
        return snapped, mask

    q_s, q_mask = snap(q, cfg.snap_q)
    k_s, k_mask = snap(k, cfg.snap_k)

    if cfg.svg_mask:
        if grid_slice is None:
            keep = svg_block_mask(q_s, k_s, grid)
        else:
            # classify/mask only the grid tokens; text rows/cols stay dense
            s, n = grid_slice
            q_seg = jax.lax.slice_in_dim(q_s, s, s + n, axis=-2)
            k_seg = jax.lax.slice_in_dim(k_s, s, s + n, axis=-2)
            keep_seg = svg_block_mask(q_seg, k_seg, grid)
            N = q.shape[-2]
            keep = jnp.broadcast_to(jnp.ones((N, N), jnp.bool_),
                                    q_s.shape[:-2] + (N, N))
            keep = jax.lax.dynamic_update_slice(
                keep, keep_seg.astype(jnp.bool_),
                (0,) * (q_s.ndim - 2) + (s, s))
        svg_bias = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
        bias = svg_bias if bias is None else bias + svg_bias

    if backend == "pallas":
        # Deferred import: kernels are optional at module-import time.
        from repro.kernels.ripple.ops import ripple_attention_pallas

        out = ripple_attention_pallas(q_s, k_s, v, bias=bias,
                                      window=cfg.window)
    elif cfg.execution == "collapse":
        out = collapsed_attention(q_s, k_s, v, bias=bias, window=cfg.window,
                                  scale=scale)
    else:
        out = _dense_attention(q_s, k_s, v, scale, bias)

    if with_stats:
        stats = RippleStats(
            savings=savings_lib.partial_score_savings(q_mask, k_mask),
            structural_savings=savings_lib.collapse_savings(
                q_mask, k_mask, cfg.window),
            q_snap_frac=jnp.mean(q_mask.astype(jnp.float32)),
            k_snap_frac=jnp.mean(k_mask.astype(jnp.float32)),
        )
        return out, stats
    return out
