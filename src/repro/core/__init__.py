# TimeRipple — the paper's primary contribution, implemented as a
# composable JAX module. See DESIGN.md §1-2 for the semantics and
# the exact snapped-operand identity the implementation is built on.
from repro.core.reuse import (
    window_delta,
    compute_reuse,
    snap_tokens,
    ReuseResult,
)
from repro.core.schedule import threshold_for_step, threshold_schedule
from repro.core.savings import (
    partial_score_savings,
    collapse_savings,
    theoretical_speedup,
    attention_flops,
)
from repro.core.collapse import collapsed_attention, pair_flags
from repro.core.dispatch import (
    attention_dispatch,
    autotune_attention,
    active_dispatch_mesh,
    dispatch_mesh,
    DispatchPlan,
    plan_for_shape,
    resolve_plan,
    set_dispatch_mesh,
    shape_bucket,
)
# The pluggable reuse-policy seam (DESIGN.md §11): register a strategy
# once and it is servable end-to-end via cfg.policy / --policy.
from repro.core.policy import (
    ReuseDecision,
    ReusePolicy,
    RippleStats,
    get_policy,
    list_policies,
    register_policy,
)
# The cross-step decision cache (DESIGN.md §13): amortize decide() over
# the reuse_every cadence.
from repro.core.decision_cache import (
    CachedDecision,
    drift_stat,
    initial_state as initial_decision_state,
    refresh_due,
    supports_cache,
)
from repro.core.calibrate import (calibrate_threshold, equal_mse_schedule,
                                  fit_step_sensitivity)
from repro.core.svg_mask import svg_block_mask, svg_logit_bias
