"""Threshold calibration + the analytical step-sensitivity model.

The paper's knob is a *savings ratio* (TIMERIPPLE_75% / _85%): thresholds
are chosen so reuse skips a target fraction of partial attention scores.
``calibrate_threshold`` bisects the shared θ on sample Q/K activations to
hit that target — this is how the Tbl. 1 hyper-parameters were found.

``fit_step_sensitivity`` reproduces the Fig. 9 analytical model: the MSE a
fixed θ induces decays with the denoising step; fitting a line (in log
space) over [i_min, i_max] and inverting MSE(θ, i) = const yields the
equal-impact linear ramp of Eq. 4.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RippleConfig
from repro.core import reuse as reuse_lib
from repro.core import savings as savings_lib


def savings_at_threshold(q, k, grid, cfg: RippleConfig, theta: float) -> float:
    thetas = {a: jnp.asarray(theta, jnp.float32) for a in ("t", "x", "y")}
    rq = reuse_lib.compute_reuse(q, grid, thetas, axes=cfg.axes,
                                 window=cfg.window, granularity=cfg.granularity,
                                 channel_groups=cfg.channel_groups)
    rk = reuse_lib.compute_reuse(k, grid, thetas, axes=cfg.axes,
                                 window=cfg.window, granularity=cfg.granularity,
                                 channel_groups=cfg.channel_groups)
    return float(savings_lib.partial_score_savings(rq.mask, rk.mask))


def calibrate_threshold(
    q: jax.Array,
    k: jax.Array,
    grid: Tuple[int, int, int],
    cfg: RippleConfig,
    target_savings: float,
    lo: float = 0.0,
    hi: float = 4.0,
    iters: int = 24,
    tol: float = 5e-3,
) -> float:
    """Bisect the shared θ to reach ``target_savings`` on sample Q/K."""
    fn = jax.jit(
        lambda theta: _savings_jit(q, k, grid, cfg, theta)
    )
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        s = float(fn(jnp.asarray(mid)))
        if abs(s - target_savings) < tol:
            return mid
        if s < target_savings:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def _savings_jit(q, k, grid, cfg, theta):
    thetas = {a: theta for a in ("t", "x", "y")}
    rq = reuse_lib.compute_reuse(q, grid, thetas, axes=cfg.axes,
                                 window=cfg.window, granularity=cfg.granularity,
                                 channel_groups=cfg.channel_groups)
    rk = reuse_lib.compute_reuse(k, grid, thetas, axes=cfg.axes,
                                 window=cfg.window, granularity=cfg.granularity,
                                 channel_groups=cfg.channel_groups)
    return savings_lib.partial_score_savings(rq.mask, rk.mask)


def fit_step_sensitivity(steps: np.ndarray, mses: np.ndarray) -> Dict[str, float]:
    """Linear fit of log-MSE vs step (the straight line of Fig. 9)."""
    steps = np.asarray(steps, np.float64)
    logm = np.log(np.maximum(np.asarray(mses, np.float64), 1e-30))
    A = np.stack([steps, np.ones_like(steps)], axis=1)
    coef, *_ = np.linalg.lstsq(A, logm, rcond=None)
    slope, intercept = float(coef[0]), float(coef[1])
    return {"slope": slope, "intercept": intercept}


def equal_mse_schedule(
    fit: Dict[str, float],
    mse_of_theta: Callable[[float, int], float],
    i_min: int,
    i_max: int,
    theta_at_imin: float,
    theta_hi: float = 4.0,
) -> np.ndarray:
    """Per-step θ inducing constant MSE across [i_min, i_max].

    Target MSE = the MSE θ_at_imin induces at i_min (per the fitted
    model); later steps tolerate larger θ. Bisection per step against the
    measured ``mse_of_theta(θ, step)``.
    """
    target = mse_of_theta(theta_at_imin, i_min)
    thetas = []
    for i in range(i_min, i_max + 1):
        lo, hi = 0.0, theta_hi
        for _ in range(20):
            mid = 0.5 * (lo + hi)
            if mse_of_theta(mid, i) < target:
                lo = mid
            else:
                hi = mid
        thetas.append(0.5 * (lo + hi))
    return np.asarray(thetas)
