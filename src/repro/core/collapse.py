"""Exact pair-collapse execution of snapped attention (DESIGN.md §2, §4).

If a window of K tokens is snapped to identical values, its pre-softmax
attention columns are identical, so softmax can fold them into one
representative column with integer multiplicity in the denominator and a
window-summed V row in the numerator::

    softmax([s, s]) · [v0; v1]  ==  (exp(s)·(v0+v1)) / (2·exp(s) + …)

Symmetrically, a window of identically-snapped Q rows needs one computed
output row (the followers copy it).  Both identities are *exact*, which
is what lets the TPU kernel skip real MXU work at block granularity while
``allclose``-matching the dense snapped oracle.

Collapse requires window partners adjacent in token order; use
:func:`pair_major_order` to permute a (t, y, x) grid so partners along a
chosen axis become adjacent.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pair_flags(snapped: jax.Array, window: int = 2) -> jax.Array:
    """True for each window whose members are value-identical.

    snapped: (..., N, d); returns (..., N // window) bool.  Uses value
    equality so it is correct regardless of which axis produced the snap.
    """
    *lead, N, d = snapped.shape
    n = N // window
    w = snapped[..., : n * window, :].reshape(*lead, n, window, d)
    rep = w[..., :1, :]
    return jnp.all(w == rep, axis=(-1, -2))


def pair_major_order(grid: Tuple[int, int, int], axis: str,
                     window: int = 2) -> np.ndarray:
    """Permutation making ``window`` partners along ``axis`` adjacent.

    Token order is (t, y, x) row-major. Returns ``perm`` with
    ``x_pair_major = x[..., perm, :]``; invert with ``argsort(perm)``.
    """
    T, H, W = grid
    idx = np.arange(T * H * W).reshape(T, H, W)
    if axis == "t":
        n = T // window
        head = idx[: n * window].reshape(n, window, H, W)
        head = np.moveaxis(head, 1, -1)  # (n, H, W, window)
        perm = np.concatenate([head.reshape(-1), idx[n * window :].reshape(-1)])
    elif axis == "y":
        n = H // window
        head = idx[:, : n * window].reshape(T, n, window, W)
        head = np.moveaxis(head, 2, -1)
        perm = np.concatenate([head.reshape(-1), idx[:, n * window :].reshape(-1)])
    elif axis == "x":
        perm = idx.reshape(-1)  # x partners are already adjacent
    else:
        raise ValueError(axis)
    return perm


def collapsed_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    k_collapse: Optional[jax.Array] = None,
    q_collapse: Optional[jax.Array] = None,
    scale: Optional[float] = None,
    bias: Optional[jax.Array] = None,
    window: int = 2,
) -> jax.Array:
    """Weighted-softmax attention with window collapse (pure-jnp reference).

    q: (..., Nq, d), k: (..., Nk, d), v: (..., Nk, dv).  ``k_collapse`` /
    ``q_collapse`` are per-window bools (from :func:`pair_flags`); None
    recomputes them from value equality.  ``bias`` is an additive logit
    bias (..., Nq, Nk); collapse assumes bias is window-constant over
    collapsed K windows (true for the padding masks we use).

    This function verifies the *math*; the FLOP savings are realized by
    the Pallas kernel in ``repro/kernels/ripple`` which block-skips.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    if k_collapse is None:
        k_collapse = pair_flags(k, window)
    if q_collapse is None:
        q_collapse = pair_flags(q, window)

    *lead, Nq, d = q.shape
    Nk = k.shape[-2]
    nk = Nk // window
    dv = v.shape[-1]

    logits = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    if bias is not None:
        logits = logits + bias
    logits = logits.astype(jnp.float32)

    # --- K-side collapse: fold member columns into the representative. ---
    head = logits[..., : nk * window].reshape(*lead, Nq, nk, window)
    rep_logit = head[..., 0]
    m_head = jnp.max(head, axis=-1)
    m_tail = (
        jnp.max(logits[..., nk * window :], axis=-1, keepdims=True)
        if Nk > nk * window
        else jnp.full((*lead, Nq, 1), -jnp.inf)
    )
    m = jnp.maximum(jnp.max(m_head, axis=-1, keepdims=True), m_tail)

    v_head = v[..., : nk * window, :].reshape(*lead, nk, window, dv)
    v_sum = jnp.sum(v_head, axis=-2)
    v_rep_path = v_sum  # collapsed: exp(rep) * Σ v
    exp_head = jnp.exp(head - m[..., None])
    kc = k_collapse[..., None, :]  # (..., 1, nk) broadcast over q
    # collapsed window: weight = window·exp(rep); numerator exp(rep)·Σv
    z_win = jnp.where(kc, window * jnp.exp(rep_logit - m), jnp.sum(exp_head, axis=-1))
    num_win = jnp.where(
        kc[..., None],
        jnp.exp(rep_logit - m)[..., None] * v_rep_path[..., None, :, :],
        jnp.einsum("...qkw,...kwv->...qkv", exp_head, v_head),
    )
    z = jnp.sum(z_win, axis=-1)
    num = jnp.sum(num_win, axis=-2)
    if Nk > nk * window:
        tail_logits = logits[..., nk * window :]
        tail_exp = jnp.exp(tail_logits - m)
        z = z + jnp.sum(tail_exp, axis=-1)
        num = num + jnp.einsum("...qk,...kv->...qv", tail_exp, v[..., nk * window :, :])
    out = (num / z[..., None]).astype(v.dtype)

    # --- Q-side collapse: followers copy the representative's output. ---
    nq = Nq // window
    if nq > 0:
        head_out = out[..., : nq * window, :].reshape(*lead, nq, window, dv)
        rep_out = head_out[..., :1, :]
        qc = q_collapse[..., :, None, None]
        head_out = jnp.where(qc, jnp.broadcast_to(rep_out, head_out.shape), head_out)
        out = jnp.concatenate(
            [head_out.reshape(*lead, nq * window, dv), out[..., nq * window :, :]],
            axis=-2,
        )
    return out
