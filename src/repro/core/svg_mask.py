"""SVG-style structured attention masking (Sparse VideoGen, Xi et al. '25).

Implemented as the baseline the paper combines with (TIMERIPPLE+SVG row of
Tbl. 2).  SVG classifies each head online as *spatial* (tokens attend
within their own frame → frame-block-diagonal mask) or *temporal* (tokens
attend to the same spatial location across frames → strided-diagonal
mask) by measuring which mask retains more attention mass on a row
sample, then skips masked blocks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def spatial_mask(grid: Tuple[int, int, int]) -> np.ndarray:
    """Frame-block-diagonal mask: attend within the same frame (+sink frame)."""
    T, H, W = grid
    f = np.repeat(np.arange(T), H * W)
    mask = f[:, None] == f[None, :]
    mask |= f[None, :] == 0  # first-frame attention sink (per SVG)
    return mask


def temporal_mask(grid: Tuple[int, int, int], halo: int = 1) -> np.ndarray:
    """Strided-diagonal mask: same spatial site across frames (± halo)."""
    T, H, W = grid
    s = np.tile(np.arange(H * W), T)
    diff = np.abs(s[:, None] - s[None, :])
    mask = diff <= halo
    return mask


def mask_density(mask: np.ndarray) -> float:
    return float(mask.mean())


def classify_heads(q: jax.Array, k: jax.Array, grid, sample_rows: int = 64,
                   scale=None) -> jax.Array:
    """Per-head bool: True = spatial head, False = temporal head.

    Measures retained softmax mass of each candidate mask on a row
    subsample (SVG's online profiling step).
    """
    *lead, N, d = q.shape
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    rows = np.linspace(0, N - 1, min(sample_rows, N)).astype(np.int32)
    qs = q[..., jnp.asarray(rows), :]
    logits = jnp.einsum("...qd,...kd->...qk", qs, k) * scale
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    sp = jnp.asarray(spatial_mask(grid)[rows])
    tm = jnp.asarray(temporal_mask(grid)[rows])
    mass_sp = jnp.sum(jnp.where(sp, probs, 0.0), axis=(-1, -2))
    mass_tm = jnp.sum(jnp.where(tm, probs, 0.0), axis=(-1, -2))
    return mass_sp >= mass_tm


def svg_block_mask(q: jax.Array, k: jax.Array, grid) -> jax.Array:
    """Boolean keep-mask (..., N, N) per head, SVG spatial/temporal choice."""
    is_spatial = classify_heads(q, k, grid)
    sp = jnp.asarray(spatial_mask(grid))
    tm = jnp.asarray(temporal_mask(grid))
    return jnp.where(is_spatial[..., None, None], sp, tm)


def svg_logit_bias(q: jax.Array, k: jax.Array, grid,
                   grid_slice=None, bias=None):
    """Keep-mask + additive −inf logit bias for the classified block mask.

    ``grid_slice=(start, n)`` restricts classification and masking to the
    grid tokens of a mixed text+grid sequence — text rows/columns stay
    dense.  Returns ``(keep, bias)`` where ``bias`` folds any caller-
    provided bias in.
    """
    if grid_slice is None:
        keep = svg_block_mask(q, k, grid)
    else:
        s, n = grid_slice
        q_seg = jax.lax.slice_in_dim(q, s, s + n, axis=-2)
        k_seg = jax.lax.slice_in_dim(k, s, s + n, axis=-2)
        keep_seg = svg_block_mask(q_seg, k_seg, grid)
        N = q.shape[-2]
        keep = jnp.broadcast_to(jnp.ones((N, N), jnp.bool_),
                                q.shape[:-2] + (N, N))
        keep = jax.lax.dynamic_update_slice(
            keep, keep_seg.astype(jnp.bool_),
            (0,) * (q.ndim - 2) + (s, s))
    svg = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
    return keep, (svg if bias is None else bias + svg)
