"""SVG-style structured attention masking (Sparse VideoGen, Xi et al. '25).

Implemented as the baseline the paper combines with (TIMERIPPLE+SVG row of
Tbl. 2).  SVG classifies each head online as *spatial* (tokens attend
within their own frame → frame-block-diagonal mask) or *temporal* (tokens
attend to the same spatial location across frames → strided-diagonal
mask) by measuring which mask retains more attention mass on a row
sample, then skips masked blocks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def spatial_mask(grid: Tuple[int, int, int]) -> np.ndarray:
    """Frame-block-diagonal mask: attend within the same frame (+sink frame)."""
    T, H, W = grid
    f = np.repeat(np.arange(T), H * W)
    mask = f[:, None] == f[None, :]
    mask |= f[None, :] == 0  # first-frame attention sink (per SVG)
    return mask


def temporal_mask(grid: Tuple[int, int, int], halo: int = 1) -> np.ndarray:
    """Strided-diagonal mask: same spatial site across frames (± halo)."""
    T, H, W = grid
    s = np.tile(np.arange(H * W), T)
    diff = np.abs(s[:, None] - s[None, :])
    mask = diff <= halo
    return mask


def mask_density(mask: np.ndarray) -> float:
    return float(mask.mean())


def classify_heads(q: jax.Array, k: jax.Array, grid, sample_rows: int = 64,
                   scale=None) -> jax.Array:
    """Per-head bool: True = spatial head, False = temporal head.

    Measures retained softmax mass of each candidate mask on a row
    subsample (SVG's online profiling step).
    """
    *lead, N, d = q.shape
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    rows = np.linspace(0, N - 1, min(sample_rows, N)).astype(np.int32)
    qs = q[..., jnp.asarray(rows), :]
    logits = jnp.einsum("...qd,...kd->...qk", qs, k) * scale
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    sp = jnp.asarray(spatial_mask(grid)[rows])
    tm = jnp.asarray(temporal_mask(grid)[rows])
    mass_sp = jnp.sum(jnp.where(sp, probs, 0.0), axis=(-1, -2))
    mass_tm = jnp.sum(jnp.where(tm, probs, 0.0), axis=(-1, -2))
    return mass_sp >= mass_tm


def classify_heads_sharded(q: jax.Array, k: jax.Array, grid, axis_name: str,
                           sample_rows: int = 64, scale=None) -> jax.Array:
    """:func:`classify_heads` when the token axis is sharded over the
    mesh axis ``axis_name`` (the context-parallel ring path, DESIGN.md
    §14).  ``q``/``k`` are one shard's (..., N_loc, d) token slice; the
    sampled rows are gathered and the softmax row statistics reduced
    with ``psum``/``pmax`` collectives, so every shard returns the
    *same* per-head verdict — equal to the single-device one up to
    cross-shard summation order (the retained-mass margins between the
    two candidate masks are orders of magnitude wider than that).

    Must be called from inside ``shard_map`` with ``axis_name`` bound;
    runs unconditionally every step on the ring path (collectives can
    never sit inside the decision cache's refresh ``lax.cond``)."""
    *lead, n_loc, d = q.shape
    T, H, W = grid
    n = T * H * W
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    off = jax.lax.axis_index(axis_name) * n_loc
    rows = np.linspace(0, n - 1, min(sample_rows, n)).astype(np.int32)
    r = jnp.asarray(rows)
    # Assemble the sampled global query rows everywhere: each shard
    # contributes the rows it owns, psum fills in the rest.
    owned = jnp.logical_and(r >= off, r < off + n_loc)
    local = jnp.clip(r - off, 0, n_loc - 1)
    qs = jnp.where(owned[:, None], q[..., local, :], 0.0)
    qs = jax.lax.psum(qs, axis_name)
    logits = (jnp.einsum("...qd,...kd->...qk", qs, k) * scale) \
        .astype(jnp.float32)                      # (..., R, N_loc)
    m = jax.lax.pmax(jnp.max(logits, axis=-1), axis_name)
    p = jnp.exp(logits - m[..., None])
    denom = jax.lax.psum(jnp.sum(p, axis=-1), axis_name)   # (..., R)
    sp = jnp.asarray(spatial_mask(grid)[rows])
    tm = jnp.asarray(temporal_mask(grid)[rows])

    def mass(full_mask):
        cols = jax.lax.dynamic_slice(full_mask, (0, off), (len(rows), n_loc))
        num = jax.lax.psum(jnp.sum(jnp.where(cols, p, 0.0), axis=-1),
                           axis_name)
        return jnp.sum(num / denom, axis=-1)

    return mass(sp) >= mass(tm)


def svg_keep_rows(is_spatial: jax.Array, grid, row_offset,
                  n_rows: int) -> jax.Array:
    """Shard-local slice of the classified keep-mask: the ``n_rows``
    query rows starting at (traced) ``row_offset``, against all N key
    columns — (..., n_rows, N) for per-head verdicts ``is_spatial``."""
    sp = jnp.asarray(spatial_mask(grid))
    tm = jnp.asarray(temporal_mask(grid))
    n = sp.shape[0]
    sp_rows = jax.lax.dynamic_slice(sp, (row_offset, 0), (n_rows, n))
    tm_rows = jax.lax.dynamic_slice(tm, (row_offset, 0), (n_rows, n))
    return jnp.where(is_spatial[..., None, None], sp_rows, tm_rows)


def svg_block_mask(q: jax.Array, k: jax.Array, grid) -> jax.Array:
    """Boolean keep-mask (..., N, N) per head, SVG spatial/temporal choice."""
    is_spatial = classify_heads(q, k, grid)
    sp = jnp.asarray(spatial_mask(grid))
    tm = jnp.asarray(temporal_mask(grid))
    return jnp.where(is_spatial[..., None, None], sp, tm)


def svg_logit_bias(q: jax.Array, k: jax.Array, grid,
                   grid_slice=None, bias=None):
    """Keep-mask + additive −inf logit bias for the classified block mask.

    ``grid_slice=(start, n)`` restricts classification and masking to the
    grid tokens of a mixed text+grid sequence — text rows/columns stay
    dense.  Returns ``(keep, bias)`` where ``bias`` folds any caller-
    provided bias in.
    """
    if grid_slice is None:
        keep = svg_block_mask(q, k, grid)
    else:
        s, n = grid_slice
        q_seg = jax.lax.slice_in_dim(q, s, s + n, axis=-2)
        k_seg = jax.lax.slice_in_dim(k, s, s + n, axis=-2)
        keep_seg = svg_block_mask(q_seg, k_seg, grid)
        N = q.shape[-2]
        keep = jnp.broadcast_to(jnp.ones((N, N), jnp.bool_),
                                q.shape[:-2] + (N, N))
        keep = jax.lax.dynamic_update_slice(
            keep, keep_seg.astype(jnp.bool_),
            (0,) * (q.ndim - 2) + (s, s))
    svg = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
    return keep, (svg if bias is None else bias + svg)
