"""Offline pattern search: static per-(layer, head) attention structure.

TIMERIPPLE's runtime policies pay a real per-step decision cost.
Sparse-vDiT observes that many (layer, head) pairs have *fixed*
sparsity structure — diagonal, multi-diagonal, sliding-window — that an
offline search can discover once, after which the runtime decision cost
drops to zero: the block map becomes a compile-time constant.
RainFusion adds a third "textural" redundancy branch next to the
spatial/temporal split.  This module is that subsystem (DESIGN.md §16):

* a library of parametric **templates** that render a boolean keep-mask
  (and its SKIP/FULL/PARTIAL block map) for *any* (T, H, W) grid and
  block shape — dense, frame-diagonal sliding window, multi-diagonal,
  spatial-local, temporal-stride, global-sink columns;
* an offline **search** (:func:`search_patterns`, driven by
  ``launch/pattern_search.py``) that scores every template per
  (layer, head) on calibration traffic through the dispatch path and
  classifies heads *static* (stable winner within tolerance) vs
  *dynamic*;
* a versioned JSON **artifact** persisted next to the autotune cache
  (same ``REPRO_*`` env-var idiom, same warn-and-regenerate hardening);
* two registered policies: ``static`` (constant maps, plan computed
  once at step 0 and replayed for the whole trajectory) and
  ``rainfusion`` (tri-branch: static heads get their searched
  spatial/temporal/textural mask, dynamic heads fall back to the
  adaptive ripple snap path).
"""

from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import warnings
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import savings as savings_lib
from repro.core.policy import (ReuseDecision, ReusePolicy, RipplePolicy,
                               RippleStats, _keep_block_map, _zero_thetas,
                               register_policy, snap_operand)

__all__ = [
    "TemplateSpec", "template", "render_keep", "render_block_map",
    "block_map_np", "default_bank", "default_template", "branch_of",
    "HeadAssignment", "PatternArtifact", "PATTERN_SCHEMA",
    "pattern_artifact_path", "load_pattern_artifact",
    "save_pattern_artifact", "active_artifact", "set_active_artifact",
    "install_artifact", "use_artifact", "pattern_keep", "search_patterns",
    "StaticPatternPolicy", "RainFusionPolicy",
]

# Tile states, kept in sync with kernels/sparse/kernel.py by the parity
# test in tests/test_patterns.py (importing the kernel here would pull
# Pallas into every artifact load).
_SKIP, _FULL, _PARTIAL = 0, 1, 2


# ---------------------------------------------------------------------------
# Template library
# ---------------------------------------------------------------------------

def _token_coords(grid: Tuple[int, int, int]):
    """Per-token (frame, site, y, x) indices in the raster layout the
    rest of the repo uses: frame-major, then y, then x."""
    t, h, w = grid
    idx = np.arange(t * h * w)
    frame = idx // (h * w)
    site = idx % (h * w)
    return frame, site, site // w, site % w


def _render_dense(grid, **_):
    n = int(np.prod(grid))
    return np.ones((n, n), bool)


def _render_frame_diag(grid, window: int = 1, sink: int = 1):
    """Sliding window over frames (|f_q − f_k| < window) plus optional
    global-sink columns for the first ``sink`` frames."""
    f, _, _, _ = _token_coords(grid)
    keep = np.abs(f[:, None] - f[None, :]) < max(int(window), 1)
    if sink > 0:
        keep |= (f[None, :] < int(sink))
    return keep


def _render_multi_diag(grid, stride: int = 2, sink: int = 0):
    """Multi-diagonal over frames: keep frame pairs whose offset is a
    multiple of ``stride`` (Sparse-vDiT's strided-attention family)."""
    f, _, _, _ = _token_coords(grid)
    df = np.abs(f[:, None] - f[None, :])
    keep = (df % max(int(stride), 1)) == 0
    if sink > 0:
        keep |= (f[None, :] < int(sink))
    return keep


def _render_spatial_local(grid, radius: int = 1, sink_tokens: int = 0):
    """Within-frame Chebyshev neighbourhood: same frame and
    max(|Δx|, |Δy|) ≤ radius — the T=1 (image) family."""
    f, _, y, x = _token_coords(grid)
    r = max(int(radius), 0)
    keep = ((f[:, None] == f[None, :])
            & (np.abs(y[:, None] - y[None, :]) <= r)
            & (np.abs(x[:, None] - x[None, :]) <= r))
    if sink_tokens > 0:
        keep[:, :int(sink_tokens)] = True
    return keep


def _render_temporal_stride(grid, halo: int = 1, stride: int = 1):
    """Same spatial site (± halo in raster distance) across frames,
    optionally only at frame offsets that are multiples of ``stride``."""
    f, s, _, _ = _token_coords(grid)
    keep = np.abs(s[:, None] - s[None, :]) <= max(int(halo), 0)
    if stride > 1:
        keep &= (np.abs(f[:, None] - f[None, :]) % int(stride)) == 0
    return keep


def _render_global_sink(grid, tokens: int = 0):
    """Self-diagonal plus the first ``tokens`` global-sink columns
    (default: one frame's worth) — the textural/global family."""
    t, h, w = grid
    n = t * h * w
    cols = int(tokens) if tokens > 0 else h * w
    keep = np.eye(n, dtype=bool)
    keep[:, :min(cols, n)] = True
    return keep


TEMPLATE_FAMILIES: Dict[str, Callable[..., np.ndarray]] = {
    "dense": _render_dense,
    "frame_diag": _render_frame_diag,
    "multi_diag": _render_multi_diag,
    "spatial_local": _render_spatial_local,
    "temporal_stride": _render_temporal_stride,
    "global_sink": _render_global_sink,
}

# RainFusion's tri-branch routing: which redundancy branch a winning
# family corresponds to.  ``dense`` winners are by definition dynamic.
_BRANCH_OF = {
    "dense": "dynamic",
    "frame_diag": "spatial",
    "spatial_local": "spatial",
    "multi_diag": "temporal",
    "temporal_stride": "temporal",
    "global_sink": "textural",
}


@dataclasses.dataclass(frozen=True)
class TemplateSpec:
    """One parametric template: a family name plus a sorted tuple of
    (param, int-value) pairs — hashable so search can count winners."""

    family: str
    params: Tuple[Tuple[str, int], ...] = ()

    @property
    def label(self) -> str:
        kv = ",".join(f"{k}={v}" for k, v in self.params)
        return f"{self.family}({kv})" if kv else self.family

    def to_json(self) -> dict:
        return {"family": self.family, "params": dict(self.params)}

    @classmethod
    def from_json(cls, obj) -> "TemplateSpec":
        if not isinstance(obj, dict) or "family" not in obj:
            raise ValueError(f"malformed template spec: {obj!r}")
        fam = obj["family"]
        if fam not in TEMPLATE_FAMILIES:
            raise ValueError(f"unknown template family {fam!r}")
        params = obj.get("params", {})
        if not isinstance(params, dict):
            raise ValueError(f"malformed template params: {params!r}")
        return template(fam, **{str(k): int(v) for k, v in params.items()})


def template(family: str, **params: int) -> TemplateSpec:
    if family not in TEMPLATE_FAMILIES:
        raise ValueError(f"unknown template family {family!r}; known: "
                         f"{sorted(TEMPLATE_FAMILIES)}")
    return TemplateSpec(family,
                        tuple(sorted((k, int(v)) for k, v in params.items())))


def branch_of(spec: TemplateSpec) -> str:
    return _BRANCH_OF.get(spec.family, "textural")


def render_keep(spec: TemplateSpec,
                grid: Tuple[int, int, int]) -> np.ndarray:
    """(N, N) boolean keep-mask for ``spec`` on ``grid``.  The identity
    diagonal is always kept — no template may mask a token's own key."""
    keep = TEMPLATE_FAMILIES[spec.family](tuple(grid), **dict(spec.params))
    np.fill_diagonal(keep, True)
    return keep


def block_map_np(keep: np.ndarray, block_q: int, block_k: int) -> np.ndarray:
    """NumPy mirror of ``kernels.sparse.ops.block_map_from_keep`` (edge
    padding, same clamping) so template rendering stays a compile-time
    constant and the PARTIAL-free fast path is a *static* property."""
    n_q, n_k = keep.shape[-2:]
    bq = min(block_q, max(n_q, 1))
    bk = min(block_k, max(n_k, 1))
    nq, nk = -(-n_q // bq), -(-n_k // bk)
    widths = [(0, 0)] * (keep.ndim - 2) + [(0, nq * bq - n_q),
                                           (0, nk * bk - n_k)]
    tiled = np.pad(keep, widths, mode="edge") \
        .reshape(*keep.shape[:-2], nq, bq, nk, bk)
    any_keep = tiled.any(axis=(-3, -1))
    all_keep = tiled.all(axis=(-3, -1))
    return np.where(all_keep, _FULL,
                    np.where(any_keep, _PARTIAL, _SKIP)).astype(np.int32)


def render_block_map(spec: TemplateSpec, grid: Tuple[int, int, int],
                     block_shape: Tuple[int, int]) -> np.ndarray:
    return block_map_np(render_keep(spec, grid), *block_shape)


def template_skip_rate(spec: TemplateSpec, grid: Tuple[int, int, int],
                       block_shape: Tuple[int, int]) -> float:
    bm = render_block_map(spec, grid, block_shape)
    return float((bm == _SKIP).mean())


def default_template(grid: Tuple[int, int, int]) -> TemplateSpec:
    """Conservative fallback when no artifact entry covers a head:
    frame-diagonal + first-frame sink for video grids, a spatial window
    for T=1 image grids (spatial-only reuse)."""
    t, h, w = grid
    if t > 1:
        return template("frame_diag", window=1, sink=1)
    return template("spatial_local", radius=max(1, min(h, w) // 4))


def default_bank(grid: Tuple[int, int, int]) -> List[TemplateSpec]:
    """Candidate templates the search scores on ``grid``.  Video grids
    get the temporal families; T=1 grids get the spatial-only bank."""
    t, h, w = grid
    bank = [template("dense")]
    if t > 1:
        bank += [template("frame_diag", window=1, sink=1),
                 template("frame_diag", window=2, sink=1),
                 template("temporal_stride", halo=1),
                 template("temporal_stride", halo=w)]
        if t >= 4:
            bank.append(template("multi_diag", stride=2, sink=1))
    if min(h, w) >= 4:
        bank.append(template("spatial_local", radius=1))
        if min(h, w) >= 8:
            bank.append(template("spatial_local", radius=min(h, w) // 4))
    bank.append(template("global_sink"))
    return bank


# ---------------------------------------------------------------------------
# The versioned per-(layer, head) assignment artifact
# ---------------------------------------------------------------------------

PATTERN_SCHEMA = "repro-pattern/1"


@dataclasses.dataclass(frozen=True)
class HeadAssignment:
    """Search verdict for one (layer, head): the winning template, the
    static-vs-dynamic classification, and the evidence behind it."""

    spec: TemplateSpec
    static: bool
    branch: str          # spatial | temporal | textural | dynamic
    psnr_db: float       # worst-case PSNR of the winner vs reference
    skip_rate: float     # realized skipped-tile fraction at search block
    stability: float     # fraction of samples that voted for the winner

    def to_json(self) -> dict:
        return {"template": self.spec.to_json(), "static": self.static,
                "branch": self.branch, "psnr_db": round(self.psnr_db, 3),
                "skip_rate": round(self.skip_rate, 4),
                "stability": round(self.stability, 4)}

    @classmethod
    def from_json(cls, obj) -> "HeadAssignment":
        if not isinstance(obj, dict) or "template" not in obj:
            raise ValueError(f"malformed head assignment: {obj!r}")
        return cls(spec=TemplateSpec.from_json(obj["template"]),
                   static=bool(obj.get("static", False)),
                   branch=str(obj.get("branch", "dynamic")),
                   psnr_db=float(obj.get("psnr_db", 0.0)),
                   skip_rate=float(obj.get("skip_rate", 0.0)),
                   stability=float(obj.get("stability", 0.0)))


@dataclasses.dataclass
class PatternArtifact:
    """The searched per-(layer, head) assignment table.

    ``version`` is a content hash over the payload — it keys the plan
    cache and the serving bucket key, so swapping artifacts can never
    replay a stale compiled plan (DESIGN.md §16)."""

    grid: Tuple[int, int, int]
    block_shape: Tuple[int, int]
    tolerance_db: float
    heads: Dict[Tuple[int, int], HeadAssignment]
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.grid = tuple(int(g) for g in self.grid)
        self.block_shape = tuple(int(b) for b in self.block_shape)
        self._keep_cache: Dict[tuple, np.ndarray] = {}

    # -- content-hash version -------------------------------------------

    def _payload(self) -> dict:
        return {
            "schema": PATTERN_SCHEMA,
            "grid": list(self.grid),
            "block_shape": list(self.block_shape),
            "tolerance_db": self.tolerance_db,
            "heads": {f"{l}/{h}": a.to_json()
                      for (l, h), a in sorted(self.heads.items())},
            "meta": self.meta,
        }

    @property
    def version(self) -> str:
        blob = json.dumps(self._payload(), sort_keys=True).encode()
        return hashlib.sha1(blob).hexdigest()[:12]

    # -- lookups ---------------------------------------------------------

    @property
    def num_layers(self) -> int:
        return 1 + max((l for l, _ in self.heads), default=-1)

    @property
    def num_heads(self) -> int:
        return 1 + max((h for _, h in self.heads), default=-1)

    def static_fraction(self) -> float:
        if not self.heads:
            return 0.0
        return sum(a.static for a in self.heads.values()) / len(self.heads)

    def _majority(self, entries: Sequence[HeadAssignment]
                  ) -> Optional[HeadAssignment]:
        """Modal *static* assignment among ``entries`` (dynamic if the
        static votes don't reach half) — the layer-consolidation rule
        used when the caller can't name a layer (DESIGN.md §16)."""
        statics = [a for a in entries if a.static]
        if not entries or 2 * len(statics) < len(entries):
            return None
        counts: Dict[TemplateSpec, List[HeadAssignment]] = {}
        for a in statics:
            counts.setdefault(a.spec, []).append(a)
        spec, votes = max(counts.items(), key=lambda kv: len(kv[1]))
        return min(votes, key=lambda a: a.psnr_db)

    def assignment(self, layer: Optional[int],
                   head: int) -> Optional[HeadAssignment]:
        """Assignment for (layer, head): exact entry, else the majority
        vote over layers for this head, else the global majority.  None
        means dynamic / no stable pattern."""
        if layer is not None and (layer, head) in self.heads:
            a = self.heads[(layer, head)]
            return a if a.static else None
        per_head = [a for (l, h), a in self.heads.items() if h == head]
        got = self._majority(per_head)
        if got is not None or per_head:
            return got
        return self._majority(list(self.heads.values()))

    def keep_for(self, grid: Tuple[int, int, int], n_heads: int,
                 layer: Optional[int] = None) -> np.ndarray:
        """(n_heads, N, N) boolean keep — dynamic heads are all-True.
        Templates are parametric, so any runtime ``grid`` works, not
        just the grid the search ran on."""
        key = (tuple(grid), n_heads, layer)
        hit = self._keep_cache.get(key)
        if hit is not None:
            return hit
        n = int(np.prod(grid))
        keep = np.ones((n_heads, n, n), bool)
        for h in range(n_heads):
            a = self.assignment(layer, h)
            if a is not None:
                keep[h] = render_keep(a.spec, grid)
        self._keep_cache[key] = keep
        return keep

    def branches(self, n_heads: int,
                 layer: Optional[int] = None) -> List[str]:
        out = []
        for h in range(n_heads):
            a = self.assignment(layer, h)
            out.append(a.branch if a is not None else "dynamic")
        return out

    # -- serialization ---------------------------------------------------

    def to_json(self) -> dict:
        obj = self._payload()
        obj["version"] = self.version
        return obj

    @classmethod
    def from_json(cls, obj) -> "PatternArtifact":
        if not isinstance(obj, dict):
            raise ValueError(f"pattern artifact must be an object, got "
                             f"{type(obj).__name__}")
        schema = obj.get("schema")
        if schema != PATTERN_SCHEMA:
            raise ValueError(f"pattern artifact schema {schema!r} != "
                             f"{PATTERN_SCHEMA!r}")
        heads: Dict[Tuple[int, int], HeadAssignment] = {}
        raw = obj.get("heads", {})
        if not isinstance(raw, dict):
            raise ValueError(f"malformed heads table: {raw!r}")
        for key, val in raw.items():
            l, _, h = str(key).partition("/")
            heads[(int(l), int(h))] = HeadAssignment.from_json(val)
        grid = obj.get("grid", ())
        block = obj.get("block_shape", ())
        if len(grid) != 3 or len(block) != 2:
            raise ValueError(f"malformed grid/block_shape: "
                             f"{grid!r}/{block!r}")
        return cls(grid=tuple(grid), block_shape=tuple(block),
                   tolerance_db=float(obj.get("tolerance_db", 0.0)),
                   heads=heads, meta=obj.get("meta", {}) or {})


def pattern_artifact_path() -> str:
    """Resolution order mirrors ``autotune_cache_path``: the
    ``REPRO_PATTERN_ARTIFACT`` env var, else the user cache dir."""
    env = os.environ.get("REPRO_PATTERN_ARTIFACT", "")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "repro_timeripple", "patterns.json")


def load_pattern_artifact(path: Optional[str] = None
                          ) -> Optional[PatternArtifact]:
    """Load the artifact, hardened like the autotune cache: a missing
    file is None (quietly), corrupt/truncated JSON or a mismatched
    schema warns and returns None so callers regenerate instead of
    crashing the launcher (DESIGN.md §16)."""
    p = path or pattern_artifact_path()
    try:
        with open(p, "r", encoding="utf-8") as f:
            obj = json.load(f)
    except FileNotFoundError:
        return None
    except (OSError, ValueError) as e:
        warnings.warn(f"pattern artifact {p!r} is corrupt ({e}); ignoring "
                      f"it — re-run pattern_search to regenerate",
                      RuntimeWarning, stacklevel=2)
        return None
    try:
        return PatternArtifact.from_json(obj)
    except (ValueError, TypeError, KeyError) as e:
        warnings.warn(f"pattern artifact {p!r} does not match schema "
                      f"{PATTERN_SCHEMA!r} ({e}); ignoring it — re-run "
                      f"pattern_search to regenerate",
                      RuntimeWarning, stacklevel=2)
        return None


def save_pattern_artifact(artifact: PatternArtifact,
                          path: Optional[str] = None) -> str:
    """Atomic, durable write (tmp + fsync + rename via
    :func:`repro.utils.diskio.atomic_write_text`), same idiom as the
    autotune cache — an artifact produced just before a crash must be
    either fully present or absent on restart, never torn."""
    from repro.utils.diskio import atomic_write_text

    p = path or pattern_artifact_path()
    atomic_write_text(p, json.dumps(artifact.to_json(), indent=1,
                                    sort_keys=True))
    return p


# -- the process-wide active artifact ---------------------------------------

_ACTIVE: Optional[PatternArtifact] = None      # explicit install
_AUTO: Optional[Tuple[str, Optional[PatternArtifact]]] = None  # lazy load


def active_artifact() -> Optional[PatternArtifact]:
    """The artifact the pattern policies consult: an explicitly
    installed one, else a lazy load from :func:`pattern_artifact_path`
    (cached per resolved path, so flipping the env var takes effect)."""
    global _AUTO
    if _ACTIVE is not None:
        return _ACTIVE
    p = pattern_artifact_path()
    if _AUTO is None or _AUTO[0] != p:
        _AUTO = (p, load_pattern_artifact(p))
    return _AUTO[1]


def set_active_artifact(artifact: Optional[PatternArtifact]
                        ) -> Optional[PatternArtifact]:
    """Install (or with None, uninstall) the active artifact.  Flushes
    the dispatch plan cache — plans key on the artifact version, and a
    swap must never replay a stale compiled plan."""
    global _ACTIVE, _AUTO
    prev = _ACTIVE
    _ACTIVE = artifact
    _AUTO = None
    from repro.core import dispatch

    dispatch.clear_plan_cache()
    return prev


def install_artifact(path: str) -> PatternArtifact:
    """Load ``path`` and install it; raises on a missing or corrupt
    file — an *explicit* ``--pattern-artifact`` must fail loudly rather
    than silently serve the default templates."""
    art = load_pattern_artifact(path)
    if art is None:
        raise ValueError(f"no usable pattern artifact at {path!r}")
    set_active_artifact(art)
    return art


@contextlib.contextmanager
def use_artifact(artifact: Optional[PatternArtifact]):
    prev = set_active_artifact(artifact)
    try:
        yield artifact
    finally:
        set_active_artifact(prev)


def pattern_keep(artifact: Optional[PatternArtifact],
                 grid: Tuple[int, int, int], n_heads: int,
                 layer: Optional[int] = None) -> np.ndarray:
    """(n_heads, N, N) keep for the policies: the artifact's searched
    assignments when one is active, else the per-grid default template
    on every head (so ``--policy static`` stays runnable standalone)."""
    if artifact is not None:
        return artifact.keep_for(grid, n_heads, layer=layer)
    n = int(np.prod(grid))
    keep = render_keep(default_template(tuple(grid)), tuple(grid))
    return np.broadcast_to(keep, (n_heads, n, n))


# ---------------------------------------------------------------------------
# The offline search
# ---------------------------------------------------------------------------

def _psnr_per_head(ref: jax.Array, out: jax.Array) -> np.ndarray:
    """(H,) PSNR in dB of ``out`` vs ``ref`` for (B, H, N, d) outputs."""
    axes = tuple(i for i in range(ref.ndim) if i != 1)
    mse = jnp.mean(jnp.square(ref - out), axis=axes)
    peak = jnp.max(jnp.abs(ref), axis=axes)
    psnr = 10.0 * jnp.log10(jnp.square(peak) / jnp.maximum(mse, 1e-12))
    return np.asarray(jax.device_get(psnr), np.float64)


def search_patterns(samples: Iterable[Tuple[int, jax.Array, jax.Array,
                                            jax.Array]],
                    grid: Tuple[int, int, int], *,
                    block_shape: Tuple[int, int] = (128, 128),
                    tolerance_db: float = 30.0,
                    stability_min: float = 0.6,
                    bank: Optional[Sequence[TemplateSpec]] = None,
                    meta: Optional[Dict[str, object]] = None
                    ) -> PatternArtifact:
    """Score every template per (layer, head) on calibration traffic.

    ``samples`` yields ``(layer, q, k, v)`` with (B, H, N, d) operands —
    one entry per (layer, prompt, step) calibration point.  Every
    sample votes: the winner for a head is the highest-skip template
    whose PSNR vs reference attention stays ≥ ``tolerance_db``.  A head
    is **static** iff the same non-dense template wins on at least
    ``stability_min`` of its samples *and* its worst-case PSNR clears
    the tolerance; everything else is dynamic (DESIGN.md §16).
    """
    from repro.config.base import RippleConfig
    from repro.core.dispatch import attention_dispatch

    grid = tuple(int(g) for g in grid)
    bank = list(bank) if bank is not None else default_bank(grid)
    off = RippleConfig(enabled=False)

    # Pre-render each candidate once; scoring runs through the existing
    # dispatch path (reference backend + external bias) so the search
    # sees exactly the math the runtime will execute.
    biases = {}
    skips = {}
    density = {}  # masked score fraction — tie-breaks equal skip rates
    for spec in bank:
        keep = render_keep(spec, grid)
        biases[spec] = jnp.where(jnp.asarray(keep), 0.0,
                                 -jnp.inf).astype(jnp.float32)
        skips[spec] = template_skip_rate(spec, grid, block_shape)
        density[spec] = 1.0 - float(keep.mean())

    votes: Dict[Tuple[int, int], List[TemplateSpec]] = {}
    worst_psnr: Dict[Tuple[int, int, TemplateSpec], float] = {}
    n_samples = 0
    for layer, q, k, v in samples:
        n_samples += 1
        n_heads = q.shape[1]
        ref = attention_dispatch(q, k, v, grid=grid, cfg=off,
                                 backend="reference")
        scored = []
        for spec in bank:
            if spec.family == "dense":
                psnr = np.full((n_heads,), np.inf)
            else:
                out = attention_dispatch(q, k, v, grid=grid, cfg=off,
                                         backend="reference",
                                         bias=biases[spec])
                psnr = _psnr_per_head(ref, out)
            scored.append((spec, psnr))
            for h in range(n_heads):
                key = (int(layer), h, spec)
                worst_psnr[key] = min(worst_psnr.get(key, np.inf),
                                      float(psnr[h]))
        for h in range(n_heads):
            ok = [(spec, p[h]) for spec, p in scored
                  if p[h] >= tolerance_db]
            # Most skipped tiles wins; masked score fraction tie-breaks
            # (small grids tile coarsely enough that several templates
            # share a skip rate — including dense's zero).
            winner = max(ok, key=lambda sp: (skips[sp[0]],
                                             density[sp[0]]))[0] if ok \
                else template("dense")
            votes.setdefault((int(layer), h), []).append(winner)

    heads: Dict[Tuple[int, int], HeadAssignment] = {}
    for (layer, h), cast in votes.items():
        counts: Dict[TemplateSpec, int] = {}
        for spec in cast:
            counts[spec] = counts.get(spec, 0) + 1
        winner, n_votes = max(counts.items(), key=lambda kv: kv[1])
        stability = n_votes / len(cast)
        wpsnr = worst_psnr.get((layer, h, winner), 0.0)
        static = (winner.family != "dense"
                  and stability >= stability_min
                  and wpsnr >= tolerance_db)
        spec = winner if static else template("dense")
        heads[(layer, h)] = HeadAssignment(
            spec=spec, static=static,
            branch=branch_of(winner) if static else "dynamic",
            psnr_db=min(wpsnr, 1e9), skip_rate=skips[spec],
            stability=stability)

    info = {"samples": n_samples, "stability_min": stability_min,
            "bank": [s.label for s in bank]}
    info.update(meta or {})
    return PatternArtifact(grid=grid, block_shape=tuple(block_shape),
                           tolerance_db=float(tolerance_db), heads=heads,
                           meta=info)


# ---------------------------------------------------------------------------
# The policies
# ---------------------------------------------------------------------------

def _paste_grid_slice(keep: np.ndarray, n_tokens: int,
                      grid_slice: Optional[Tuple[int, int]]) -> np.ndarray:
    """Embed a (H, Ng, Ng) grid-segment keep into the full token range
    (text-prefix layouts): everything outside the video segment stays
    unmasked, same convention as ``svg_logit_bias``."""
    if grid_slice is None:
        return keep
    s, n = grid_slice
    full = np.ones(keep.shape[:-2] + (n_tokens, n_tokens), bool)
    full[..., s:s + n, s:s + n] = keep
    return full


class StaticPatternPolicy(ReusePolicy):
    """Constant searched masks: zero runtime decision cost.

    The keep-mask per head is a compile-time constant from the active
    pattern artifact (or the per-grid default template when none is
    installed), so decide() emits a constant bias/block map that XLA
    folds, and ``plan_once`` tells the decision cache to refresh at
    step 0 only — no Δ-checks, no theta schedule, no drift stat, one
    plan replayed for the whole trajectory (DESIGN.md §16).  When the
    rendered map has no PARTIAL tiles the N×N bias is dropped entirely
    and the block map alone carries the structure.
    """

    name = "static"
    emits_bias = True
    snaps_operands = False
    emits_block_map = True
    caches_decisions = True
    plan_once = True

    def __init__(self, artifact: Optional[PatternArtifact] = None,
                 layer: Optional[int] = None):
        self._artifact = artifact
        self.layer = layer

    def artifact(self) -> Optional[PatternArtifact]:
        return self._artifact if self._artifact is not None \
            else active_artifact()

    def plan_token(self, cfg=None):
        art = self.artifact()
        return art.version if art is not None else None

    def will_seq_shard(self, cfg):
        # Constant masks are row-separable by construction: each shard
        # renders its own bias rows (ring_bias_rows), and all-SKIP ring
        # hops fall straight out of the constant map.
        return True

    def thetas_for(self, cfg, step, total_steps, thetas=None):
        return _zero_thetas()

    def _keep(self, q, grid, grid_slice) -> np.ndarray:
        n_heads = q.shape[1] if q.ndim >= 4 else 1
        keep = pattern_keep(self.artifact(), grid, n_heads,
                            layer=self.layer)
        return _paste_grid_slice(keep, q.shape[-2], grid_slice)

    def decide(self, q, k, *, grid, cfg, thetas, bias=None, grid_slice=None,
               fused=False, block_shape=None, want_plan=False):
        keep_np = self._keep(q, grid, grid_slice)
        savings = jnp.asarray(1.0 - keep_np.mean(), jnp.float32)
        block_map = None
        need_bias = True
        if block_shape is not None:
            bmap_np = block_map_np(keep_np, *block_shape)
            block_map = jnp.asarray(bmap_np)
            # PARTIAL-free maps need no N×N bias at all — FULL tiles
            # ignore it and SKIP tiles never touch it.  This is a
            # static (python) property of the constant mask, so the
            # decision pytree stays stable across steps.
            need_bias = bool((bmap_np == _PARTIAL).any())
        if need_bias:
            pat = jnp.where(jnp.asarray(keep_np), 0.0,
                            -jnp.inf).astype(jnp.float32)
            bias = pat if bias is None else bias + pat
        return ReuseDecision(
            q=q, k=k, thetas=thetas, active_axes=(), bias=bias,
            savings=savings, block_map=block_map)

    def apply_decision(self, q, k, cached, *, grid, cfg, thetas,
                       grid_slice=None):
        # True passthrough: the base implementation re-derives savings
        # from the cached bias (a full pass over an N×N constant every
        # step); here the savings is a trace-time python constant and
        # the replay does zero per-step work — the whole point of
        # plan_once.  Pytree structure matches decide() exactly.
        keep_np = self._keep(q, grid, grid_slice)
        return ReuseDecision(
            q=q, k=k, thetas=thetas, active_axes=(), bias=cached.bias,
            savings=jnp.asarray(1.0 - keep_np.mean(), jnp.float32),
            block_map=cached.block_map)

    def stats(self, decision):
        zero = jnp.zeros(())
        if decision.block_map is not None:
            from repro.kernels.sparse.ops import sparse_block_stats

            structural = sparse_block_stats(decision.block_map)
        else:
            structural = zero
        return RippleStats(savings=decision.savings,
                           structural_savings=structural,
                           q_snap_frac=zero, k_snap_frac=zero)

    # -- ring/seq-shard hook (core/ring.py) -----------------------------

    def ring_bias_rows(self, q, k, *, grid, cfg, row_offset, n_rows):
        """Shard-local bias rows for the sparse ring path: slice the
        constant keep at this shard's row offset.  No collectives — the
        mask is position-determined, unlike svg's head classification."""
        n_heads = q.shape[1] if q.ndim >= 4 else 1
        keep = jnp.asarray(pattern_keep(self.artifact(), grid, n_heads,
                                        layer=self.layer))
        rows = jax.lax.dynamic_slice(
            keep, (0, row_offset, 0), (keep.shape[0], n_rows,
                                       keep.shape[-1]))
        bias = jnp.where(rows, 0.0, -jnp.inf).astype(jnp.float32)
        lead = q.shape[:-2] if q.ndim >= 4 else (q.shape[0],)
        return jnp.broadcast_to(bias, tuple(lead) + bias.shape[-2:])


class RainFusionPolicy(RipplePolicy):
    """Tri-branch routing: each head goes to its searched spatial /
    temporal / textural mask when the artifact classified it static,
    and falls back to the adaptive ripple snap path when dynamic.

    Static heads get the constant keep-mask (bias + block map) and
    *identity* snap sources; dynamic heads get ripple's windowed
    Δ-check snapping.  With no artifact installed every head is
    dynamic and the policy degrades to pure ripple."""

    name = "rainfusion"
    emits_bias = True
    emits_block_map = True

    def __init__(self, artifact: Optional[PatternArtifact] = None,
                 layer: Optional[int] = None):
        self._artifact = artifact
        self.layer = layer

    def artifact(self) -> Optional[PatternArtifact]:
        return self._artifact if self._artifact is not None \
            else active_artifact()

    def plan_token(self, cfg=None):
        art = self.artifact()
        return art.version if art is not None else None

    def will_emit_bias(self, cfg):
        return True

    def will_emit_block_map(self, cfg):
        return True

    def will_seq_shard(self, cfg):
        # Mixing the mask and snap paths on the ring would need both
        # fused shard-locally, which the ring driver doesn't implement.
        return False

    def _routing(self, q, grid, grid_slice):
        """(keep, dyn): the static heads' keep-mask (all-True rows for
        dynamic heads) and the per-head dynamic flag."""
        n_heads = q.shape[1] if q.ndim >= 4 else 1
        art = self.artifact()
        n = int(np.prod(grid))
        keep = np.ones((n_heads, n, n), bool)
        dyn = np.ones((n_heads,), bool)
        if art is not None:
            for h in range(n_heads):
                a = art.assignment(self.layer, h)
                if a is not None:
                    keep[h] = render_keep(a.spec, grid)
                    dyn[h] = False
        return _paste_grid_slice(keep, q.shape[-2], grid_slice), dyn

    def decide(self, q, k, *, grid, cfg, thetas, bias=None, grid_slice=None,
               fused=False, block_shape=None, want_plan=False):
        keep_np, dyn_np = self._routing(q, grid, grid_slice)
        active_axes = tuple(cfg.axes)
        q_s, q_mask, q_src = snap_operand(q, cfg.snap_q, grid, thetas, cfg,
                                          active_axes, grid_slice, fused,
                                          want_src=want_plan)
        k_s, k_mask, k_src = snap_operand(k, cfg.snap_k, grid, thetas, cfg,
                                          active_axes, grid_slice, fused,
                                          want_src=want_plan)
        if not dyn_np.all():
            # Static heads keep their original operands (their mask is
            # the whole decision); snapping applies to dynamic heads
            # only.  dyn aligns with the head axis (dim -3) of 4-D+
            # operands; 3-D operands route as one consolidated head.
            dyn = jnp.asarray(dyn_np)[:, None, None]
            q_s = jnp.where(dyn, q_s, q)
            k_s = jnp.where(dyn, k_s, k)
            if q_mask is not None:
                q_mask = jnp.logical_and(q_mask, dyn)
            if k_mask is not None:
                k_mask = jnp.logical_and(k_mask, dyn)
            if q_src is not None:
                q_src = jnp.where(dyn, q_src, _identity_src(q_src))
            if k_src is not None:
                k_src = jnp.where(dyn, k_src, _identity_src(k_src))
            pat = jnp.where(jnp.asarray(keep_np), 0.0,
                            -jnp.inf).astype(jnp.float32)
            bias = pat if bias is None else bias + pat
            block_map = _keep_block_map(jnp.asarray(keep_np), block_shape)
        else:
            block_map = None
        if q_mask is not None and k_mask is not None:
            savings = savings_lib.partial_score_savings(q_mask, k_mask)
        else:
            savings = jnp.zeros(())
        savings = savings + jnp.asarray(1.0 - keep_np.mean(), jnp.float32)
        return ReuseDecision(
            q=q_s, k=k_s, thetas=thetas, active_axes=active_axes,
            bias=bias, q_mask=q_mask, k_mask=k_mask, savings=savings,
            block_map=block_map, window=cfg.window,
            q_src=q_src, k_src=k_src)


    def stats(self, decision):
        # The base mask-path accounting recomputes savings from the
        # snap masks alone; decide() already folded the static heads'
        # pattern-mask term into decision.savings — keep it.
        s = super().stats(decision)
        return RippleStats(savings=decision.savings,
                           structural_savings=s.structural_savings,
                           q_snap_frac=s.q_snap_frac,
                           k_snap_frac=s.k_snap_frac)


def _identity_src(src: jax.Array) -> jax.Array:
    """Identity gather indices matching a snap-source map's shape: the
    replay becomes a no-op for the masked (static) heads."""
    n = src.shape[-2]
    iota = jnp.arange(n, dtype=src.dtype)[:, None]
    return jnp.broadcast_to(iota, src.shape)


register_policy(StaticPatternPolicy())
register_policy(RainFusionPolicy())
