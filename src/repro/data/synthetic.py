"""Deterministic, shard-aware synthetic data pipelines.

No datasets ship in this offline container, so every family gets a
seeded generator with realistic statistics:

* token streams     — Zipf-distributed ids with short-range repetition
                      structure so LMs have something learnable;
* latent videos     — Gauss-Markov fields with controllable temporal and
                      spatial correlation (matches the redundancy the
                      paper exploits — the knobs set how much TimeRipple
                      can reuse);
* images            — band-limited Gaussian textures per class.

Generators are pure functions of (seed, index), so any shard of any
batch is reproducible from metadata alone — requirement for deterministic
restart after failure (checkpoint stores the cursor).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataSpec:
    seed: int = 0
    shard: int = 0
    num_shards: int = 1


def token_batch(spec: DataSpec, index: int, batch: int, seq_len: int,
                vocab: int) -> dict:
    """Zipf tokens with 8-token motif repetition (next-token learnable)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(spec.seed), index), spec.shard)
    k1, k2, k3 = jax.random.split(key, 3)
    # Zipf via exponential quantization
    u = jax.random.uniform(k1, (batch, seq_len), minval=1e-6)
    ids = jnp.clip((vocab ** u - 1).astype(jnp.int32), 0, vocab - 1)
    # motif: every other 8-window repeats the previous one
    motif = jnp.roll(ids, 8, axis=1)
    gate = (jnp.arange(seq_len) // 8) % 2 == 1
    ids = jnp.where(gate[None, :], motif, ids)
    tokens = ids[:, :-1]
    targets = ids[:, 1:]
    pad = jnp.zeros((batch, 1), jnp.int32)
    return {"tokens": jnp.concatenate([tokens, pad], 1),
            "targets": jnp.concatenate([targets, pad], 1)}


def correlated_video_latents(
    key: jax.Array, batch: int, grid: Tuple[int, int, int], channels: int,
    *, temporal_rho: float = 0.9, spatial_smooth: int = 2,
) -> jax.Array:
    """(B, T, H, W, C) Gauss-Markov latents: AR(1) across frames with
    coefficient ``temporal_rho``; box-smoothed ``spatial_smooth`` times
    spatially.  High rho/smooth => high spatio-temporal redundancy."""
    T, H, W = grid
    k0, k1 = jax.random.split(key)
    base = jax.random.normal(k0, (batch, T, H, W, channels))

    def smooth(x):
        for _ in range(spatial_smooth):
            x = (x + jnp.roll(x, 1, 2) + jnp.roll(x, -1, 2)
                 + jnp.roll(x, 1, 3) + jnp.roll(x, -1, 3)) / 5.0
        return x

    base = smooth(base)

    def ar(carry, z):
        x = temporal_rho * carry + np.sqrt(1 - temporal_rho ** 2) * z
        return x, x

    first = base[:, 0]
    _, frames = jax.lax.scan(ar, first, jnp.moveaxis(base, 1, 0))
    out = jnp.moveaxis(frames, 0, 1)
    return out / (jnp.std(out) + 1e-6)


def latent_video_batch(spec: DataSpec, index: int, batch: int,
                       grid: Tuple[int, int, int], channels: int,
                       txt_tokens: int = 0, txt_dim: int = 0) -> dict:
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(spec.seed + 7), index),
        spec.shard)
    k0, k1 = jax.random.split(key)
    out = {"latents": correlated_video_latents(k0, batch, grid, channels)}
    if txt_tokens:
        out["txt"] = 0.05 * jax.random.normal(k1, (batch, txt_tokens, txt_dim))
    return out


def image_batch(spec: DataSpec, index: int, batch: int, res: int,
                channels: int = 3, num_classes: int = 1000) -> dict:
    """Class-conditional band-limited textures (classifiable)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(spec.seed + 13), index),
        spec.shard)
    k0, k1, k2 = jax.random.split(key, 3)
    labels = jax.random.randint(k0, (batch,), 0, num_classes)
    freq = 1.0 + (labels % 8).astype(jnp.float32)
    xx = jnp.linspace(0, 2 * np.pi, res)
    pattern = jnp.sin(freq[:, None, None] * xx[None, :, None]
                      + freq[:, None, None] * 0.5 * xx[None, None, :])
    noise = 0.3 * jax.random.normal(k2, (batch, res, res, channels))
    images = pattern[..., None] + noise
    return {"images": images, "labels": labels}


def batch_iterator(make_batch, spec: DataSpec, start_index: int = 0) -> Iterator:
    """Infinite deterministic iterator with a resumable cursor."""
    i = start_index
    while True:
        yield make_batch(spec, i)
        i += 1
