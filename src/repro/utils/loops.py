"""Loop helpers with a dry-run unroll override.

XLA's HLO cost analysis counts a ``while`` body ONCE, so any
scan-over-layers (or chunked-attention / chunked-CE / grad-accum loop)
hides its trip count from the roofline.  All internal loops in the
framework go through these helpers; the dry-run sets ``unroll_mode
('full')`` while lowering its *cost probe* so every body instance is
explicit in the HLO and FLOPs/bytes/collective-bytes are exact.  The
production path (default mode) keeps rolled loops — small HLO, fast
compiles.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable

import jax
import jax.numpy as jnp

_UNROLL = contextvars.ContextVar("repro_unroll", default=1)
_COST_PROBE = contextvars.ContextVar("repro_cost_probe", default=False)


@contextlib.contextmanager
def unroll_mode(mode):
    """mode: 1 (rolled, default) | int n | 'full'."""
    tok = _UNROLL.set(mode)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


@contextlib.contextmanager
def cost_probe_mode():
    """Dry-run cost probe: unroll every loop AND take the un-chunked
    (dense) attention / CE paths so HLO FLOPs/bytes/collectives are exact
    totals.  Only ever used for lower()+compile(), never executed."""
    t1 = _UNROLL.set("full")
    t2 = _COST_PROBE.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(t1)
        _COST_PROBE.reset(t2)


def in_cost_probe() -> bool:
    return _COST_PROBE.get()


def _resolve(length: int):
    mode = _UNROLL.get()
    if mode == "full":
        return max(length, 1)
    return max(min(int(mode), length), 1)


def scan_layers(body: Callable, init, xs, length: int | None = None):
    """jax.lax.scan with the dry-run unroll override."""
    if length is None:
        length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, unroll=_resolve(length))


def map_chunks(fn: Callable, n: int):
    """Like ``lax.map(fn, arange(n))`` but honouring the unroll override;
    fn(i) -> pytree, stacked along a new leading axis."""
    unroll = _resolve(n)
    if unroll >= n:
        outs = [fn(i) for i in range(n)]
        return jax.tree_util.tree_map(
            lambda *leaves: jnp.stack(leaves, axis=0), *outs)
    return jax.lax.map(fn, jnp.arange(n))
