"""Pytree helpers used across the framework (no flax/chex in this env)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_size_bytes(tree) -> int:
    """Total size in bytes of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def tree_cast(tree, dtype):
    """Cast every floating leaf of a pytree to ``dtype``."""

    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map(_cast, tree)


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_global_norm(tree):
    """Global L2 norm over all leaves (used for gradient clipping)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)
