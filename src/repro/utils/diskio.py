"""Durable atomic file writes.

The repo's persistent artifacts (autotune cache, pattern artifact,
serving checkpoints, the journal's clean-shutdown marker) all use the
same idiom: write to a sibling ``*.tmp``, then ``os.replace`` onto the
final name, so readers never observe a half-written file.  The rename
alone, however, is only atomic with respect to *other processes* — on a
power loss or kernel crash the data blocks of the tmp file may not have
reached disk yet, and the rename can land while the contents have not,
leaving a **truncated file under the final name** for the
warn-and-regenerate readers to chew on.  These helpers close that hole:

  1. write the payload to ``path + ".tmp"``,
  2. ``flush`` + ``os.fsync`` the tmp file (data durable),
  3. ``os.replace`` onto ``path`` (atomic visibility),
  4. ``fsync`` the containing directory (the rename itself durable).

``fsync=False`` skips steps 2 and 4 for callers that only need the
process-crash atomicity (same behavior as the old idiom).
"""

from __future__ import annotations

import os

__all__ = ["atomic_write_bytes", "atomic_write_text", "fsync_dir"]


def fsync_dir(dirname: str) -> None:
    """fsync a directory so a just-renamed entry survives a power loss.
    Best-effort: some platforms/filesystems refuse O_RDONLY directory
    fds — a failure there degrades to the old (rename-only) guarantee
    instead of breaking the write."""
    try:
        fd = os.open(dirname or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes, *, fsync: bool = True) -> None:
    """Durably write ``data`` to ``path`` via tmp + fsync + replace."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        fsync_dir(os.path.dirname(path))


def atomic_write_text(path: str, text: str, *, fsync: bool = True,
                      encoding: str = "utf-8") -> None:
    atomic_write_bytes(path, text.encode(encoding), fsync=fsync)
