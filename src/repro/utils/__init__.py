from repro.utils.pytree import (
    tree_size_bytes,
    tree_param_count,
    tree_cast,
    tree_zeros_like,
    tree_global_norm,
)
from repro.utils.logging import get_logger
