"""Diffusion noise schedules: DDPM (linear/cosine betas) and rectified flow."""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DDPMSchedule:
    """Discrete-time DDPM. q(x_t | x_0) = N(sqrt(ā_t) x_0, (1-ā_t) I)."""

    num_train_steps: int = 1000
    beta_start: float = 1e-4
    beta_end: float = 0.02
    kind: str = "linear"  # 'linear' | 'cosine'

    def betas(self) -> jax.Array:
        if self.kind == "linear":
            return jnp.linspace(self.beta_start, self.beta_end,
                                self.num_train_steps)
        t = jnp.arange(self.num_train_steps + 1) / self.num_train_steps
        f = jnp.cos((t + 0.008) / 1.008 * jnp.pi / 2) ** 2
        alpha_bar = f / f[0]
        betas = 1 - alpha_bar[1:] / alpha_bar[:-1]
        return jnp.clip(betas, 0, 0.999)

    def alpha_bars(self) -> jax.Array:
        return jnp.cumprod(1.0 - self.betas())

    def add_noise(self, x0, noise, t):
        """t: (B,) int in [0, num_train_steps)."""
        ab = self.alpha_bars()[t]
        shape = (-1,) + (1,) * (x0.ndim - 1)
        return (jnp.sqrt(ab).reshape(shape) * x0
                + jnp.sqrt(1 - ab).reshape(shape) * noise)


@dataclasses.dataclass(frozen=True)
class RectifiedFlowSchedule:
    """Rectified flow / flow matching: x_t = (1-t) x0 + t·noise, target
    velocity v = noise - x0 (Flux-style, t in (0, 1))."""

    timestep_shift: float = 1.0  # resolution-dependent shift, 1 = none

    def interpolate(self, x0, noise, t):
        shape = (-1,) + (1,) * (x0.ndim - 1)
        t = t.reshape(shape)
        return (1.0 - t) * x0 + t * noise

    def velocity_target(self, x0, noise):
        return noise - x0

    def sample_t(self, rng, batch):
        t = jax.random.uniform(rng, (batch,))
        s = self.timestep_shift
        return s * t / (1 + (s - 1) * t)
