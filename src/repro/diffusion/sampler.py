"""Samplers: DDIM (for DDPM-trained DiT/UNet) and Euler rectified flow
(for MMDiT/vDiT).  The denoising loop is where the paper lives: every
step's index feeds the Eq. 4 threshold schedule of TimeRipple, so the
model function receives (x_t, t_cont, step, total_steps).

``denoise_fn(x, t, step) -> eps/velocity`` closes over params, text
conditioning and the RippleConfig; samplers stay model-agnostic.

Cross-step decision cache (DESIGN.md §13): pass ``decision_state`` (the
model's per-layer stacked CachedDecision, e.g. from
``launch.workloads.vdit_decision_state``) and the contract becomes
``denoise_fn(x, t, step, state) -> (eps/velocity, state)`` — the state
rides the denoising scan's carry, so the reuse decision is recomputed
only on the ``reuse_every`` cadence (or drift), and the sampler returns
``(x, final_state)`` so callers can report cache hit rates.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.diffusion.schedule import DDPMSchedule, RectifiedFlowSchedule


def ddim_sample(
    denoise_fn: Callable,
    x_T: jax.Array,
    schedule: DDPMSchedule,
    num_steps: int,
    *,
    eta: float = 0.0,
    rng: Optional[jax.Array] = None,
    decision_state=None,
    step_offset=0,
    total_steps: Optional[int] = None,
    sentinel: bool = False,
):
    """DDIM sampler. denoise_fn(x, t_int (B,), step_idx) -> eps.

    With ``decision_state`` the model's decision cache rides the scan
    carry (``denoise_fn(x, t, step, state) -> (eps, state)``) and the
    sampler returns ``(x, final_state)``.

    With ``sentinel`` (guardrails, DESIGN.md §17) a running i32 count of
    non-finite latent entries rides the carry — one elementwise
    ``isfinite`` per step — and is appended to the return, so the
    serving engine can trip its degradation ladder without a host
    round-trip per step.

    Chunked execution (streaming delivery, DESIGN.md §15.3): pass
    ``total_steps=T`` (the full schedule length) and run the scan in
    slices — ``step_offset`` steps already done, ``num_steps`` to run
    now — feeding each chunk's output ``x`` (and decision state) into
    the next chunk's input.  The per-step math is identical to the
    monolithic call: the timestep table is built from ``total_steps``
    and the body indexes it by absolute step, so chaining chunks
    reproduces the single-scan result exactly.  ``step_offset`` may be
    a traced int32 scalar, letting one compiled chunk serve every
    offset.  The deterministic path (``rng=None``, the serving default)
    carries no cross-chunk RNG; chunked stochastic sampling (``eta >
    0``) needs the caller to split a fresh key per chunk.

    That chunk-chaining exactness is also the crash-recovery contract
    (DESIGN.md §18): a run resumed from a chunk-boundary checkpoint
    ``(x, decision_state, step_offset)`` replays exactly the remaining
    schedule slice, so warm restart and router failover reproduce the
    uninterrupted trajectory bitwise."""
    total = num_steps if total_steps is None else total_steps
    T = schedule.num_train_steps
    ts = jnp.linspace(T - 1, 0, total).astype(jnp.int32)
    alpha_bars = schedule.alpha_bars()
    B = x_T.shape[0]
    bshape = (-1,) + (1,) * (x_T.ndim - 1)

    def body(carry, si):
        x, rng, dstate, nf = carry
        t = ts[si]
        t_prev = jnp.where(si + 1 < total, ts[jnp.minimum(si + 1,
                                                          total - 1)], -1)
        ab_t = alpha_bars[t]
        ab_prev = jnp.where(t_prev >= 0, alpha_bars[jnp.maximum(t_prev, 0)], 1.0)
        if dstate is None:
            eps = denoise_fn(x, jnp.full((B,), t), si)
        else:
            eps, dstate = denoise_fn(x, jnp.full((B,), t), si, dstate)
        x0 = (x - jnp.sqrt(1 - ab_t) * eps) / jnp.sqrt(ab_t)
        sigma = eta * jnp.sqrt((1 - ab_prev) / (1 - ab_t)) * \
            jnp.sqrt(1 - ab_t / ab_prev)
        dir_xt = jnp.sqrt(jnp.maximum(1 - ab_prev - sigma ** 2, 0.0)) * eps
        if rng is not None:
            rng, sub = jax.random.split(rng)
            noise = jax.random.normal(sub, x.shape, x.dtype)
        else:
            noise = jnp.zeros_like(x)
        x = jnp.sqrt(ab_prev) * x0 + dir_xt + sigma * noise
        if sentinel:
            nf = nf + jnp.sum(~jnp.isfinite(x)).astype(jnp.int32)
        return (x, rng, dstate, nf), None

    (x, _, dstate, nf), _ = jax.lax.scan(
        body, (x_T, rng if rng is not None else jax.random.PRNGKey(0),
               decision_state, jnp.zeros((), jnp.int32)),
        jnp.arange(num_steps) + step_offset)
    if decision_state is not None:
        return (x, dstate, nf) if sentinel else (x, dstate)
    return (x, nf) if sentinel else x


def euler_flow_sample(
    denoise_fn: Callable,
    x_T: jax.Array,
    num_steps: int,
    *,
    schedule: Optional[RectifiedFlowSchedule] = None,
    decision_state=None,
    step_offset=0,
    total_steps: Optional[int] = None,
    sentinel: bool = False,
):
    """Euler ODE integration of rectified flow from t=1 (noise) to t=0.
    denoise_fn(x, t_cont (B,), step_idx) -> velocity (noise - x0).

    With ``decision_state`` the model's decision cache rides the scan
    carry (``denoise_fn(x, t, step, state) -> (v, state)``) and the
    sampler returns ``(x, final_state)``.  ``step_offset`` /
    ``total_steps`` slice the integration for chunked streaming exactly
    as in :func:`ddim_sample`; ``sentinel`` appends a running non-finite
    latent count to the return, as there."""
    total = num_steps if total_steps is None else total_steps
    B = x_T.shape[0]
    ts = jnp.linspace(1.0, 0.0, total + 1)

    def body(carry, si):
        x, dstate, nf = carry
        t, t_next = ts[si], ts[si + 1]
        if dstate is None:
            v = denoise_fn(x, jnp.full((B,), t), si)
        else:
            v, dstate = denoise_fn(x, jnp.full((B,), t), si, dstate)
        x = x + (t_next - t) * v
        if sentinel:
            nf = nf + jnp.sum(~jnp.isfinite(x)).astype(jnp.int32)
        return (x, dstate, nf), None

    (x, dstate, nf), _ = jax.lax.scan(
        body, (x_T, decision_state, jnp.zeros((), jnp.int32)),
        jnp.arange(num_steps) + step_offset)
    if decision_state is not None:
        return (x, dstate, nf) if sentinel else (x, dstate)
    return (x, nf) if sentinel else x


def cfg_wrap(denoise_fn: Callable, guidance: float) -> Callable:
    """Classifier-free guidance: denoise_fn must accept ``cond`` batches
    stacked [uncond; cond] and return stacked outputs."""

    def wrapped(x, t, step):
        out = denoise_fn(jnp.concatenate([x, x]), jnp.concatenate([t, t]), step)
        un, co = jnp.split(out, 2, axis=0)
        return un + guidance * (co - un)

    return wrapped
