"""Checkpointing: atomic, CRC-validated, async, retention-managed.

Layout per step::

    <dir>/step_000400/
        arrays.npz      # one entry per pytree leaf, keyed by tree path
        MANIFEST.json   # crc32 per entry + metadata; written LAST

The manifest is the commit record: a checkpoint without a valid manifest
(e.g. the node died mid-save) is invisible to ``restore_latest`` — this
is the crash-consistency property the fault-tolerance tests exercise.
Async mode snapshots arrays to host memory synchronously (cheap) and
writes in a background thread so the step loop never blocks on disk.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("ckpt")
_MANIFEST = "MANIFEST.json"
_ARRAYS = "arrays.npz"


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


def flatten_state(state) -> dict:
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    return {_path_str(path): np.asarray(jax.device_get(x))
            for path, x in leaves}


def unflatten_into(template, arrays: dict):
    """Fill a template pytree (abstract or concrete) from a path->array map."""
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in paths_leaves:
        key = _path_str(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        a = arrays[key]
        want = tuple(leaf.shape)
        if tuple(a.shape) != want:
            raise ValueError(f"{key}: shape {a.shape} != expected {want}")
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, state, *, extra: Optional[dict] = None):
        arrays = flatten_state(state)  # sync device->host snapshot
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, arrays, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, arrays: dict, extra: dict):
        d = os.path.join(self.directory, f"step_{step:08d}")
        tmp = d + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, _ARRAYS), **arrays)
        crcs = {}
        with open(os.path.join(tmp, _ARRAYS), "rb") as f:
            blob_crc = zlib.crc32(f.read())
        for k, v in arrays.items():
            crcs[k] = zlib.crc32(np.ascontiguousarray(v).tobytes())
        manifest = {"step": step, "blob_crc": blob_crc, "leaf_crcs": crcs,
                    "extra": extra}
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)  # atomic commit
        log.info("saved checkpoint step %d (%d leaves)", step, len(arrays))
        self._gc()

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def list_steps(self) -> List[int]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if os.path.exists(os.path.join(self.directory, name, _MANIFEST)):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def validate(self, step: int) -> bool:
        d = os.path.join(self.directory, f"step_{step:08d}")
        try:
            with open(os.path.join(d, _MANIFEST)) as f:
                manifest = json.load(f)
            with open(os.path.join(d, _ARRAYS), "rb") as f:
                if zlib.crc32(f.read()) != manifest["blob_crc"]:
                    return False
            return True
        except (OSError, json.JSONDecodeError, KeyError):
            return False

    def restore(self, step: int, template, *, shardings=None) -> Tuple[Any, dict]:
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, _ARRAYS)) as z:
            arrays = {k: z[k] for k in z.files}
        state = unflatten_into(template, arrays)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest.get("extra", {})

    def restore_latest(self, template, *, shardings=None):
        """Newest checkpoint that passes CRC validation; corrupted tails
        (mid-save crash) fall back to the previous step."""
        for step in reversed(self.list_steps()):
            if self.validate(step):
                state, extra = self.restore(step, template,
                                            shardings=shardings)
                return step, state, extra
            log.warning("checkpoint step %d failed validation; skipping", step)
        return None, None, {}
