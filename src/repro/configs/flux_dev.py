"""flux-dev [diffusion] — img_res=1024 latent_res=128 n_double_blocks=19
n_single_blocks=38 d_model=3072 n_heads=24, 12B params, MMDiT
rectified-flow. [BFL tech report; unverified]

TimeRipple: 2-D mode on the image-token stream of the joint attention
(text tokens never snapped)."""

from repro.config.base import ArchConfig, MMDiTConfig, RippleConfig, TrainConfig
from repro.configs.lm_shapes import DIFFUSION_SHAPES


def make_config() -> ArchConfig:
    model = MMDiTConfig(img_res=1024, latent_res=128, n_double_blocks=19,
                        n_single_blocks=38, d_model=3072, num_heads=24,
                        in_channels=16, patch=2, txt_tokens=512,
                        txt_dim=4096, axes_dim=(16, 56, 56))
    ripple = RippleConfig(enabled=True, axes=("x", "y"),
                          theta_min=0.2, theta_max=0.5, i_min=10, i_max=20)
    return ArchConfig(name="flux-dev", family="mmdit", model=model,
                      shapes=DIFFUSION_SHAPES, ripple=ripple,
                      train=TrainConfig(grad_accum=16),
                      source="BFL tech report; unverified")


def make_smoke_config() -> ArchConfig:
    model = MMDiTConfig(img_res=64, latent_res=8, n_double_blocks=2,
                        n_single_blocks=2, d_model=64, num_heads=4,
                        in_channels=4, patch=2, txt_tokens=8, txt_dim=64,
                        axes_dim=(4, 6, 6))
    cfg = make_config()
    return ArchConfig(name="flux-dev-smoke", family="mmdit", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
