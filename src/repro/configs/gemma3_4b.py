"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

TimeRipple: inapplicable (1-D text tokens; DESIGN.md §6)."""

from repro.config.base import (ArchConfig, LMConfig, RippleConfig,
                               TrainConfig)
from repro.configs.lm_shapes import LM_SHAPES


def make_config() -> ArchConfig:
    model = LMConfig(
        num_layers=34, d_model=2560, num_heads=8, num_kv_heads=4,
        d_ff=10240, vocab_size=262144, head_dim=256, qk_norm=True,
        sliding_window=1024, local_global_pattern=5,
        rope_theta=1_000_000.0,
    )
    return ArchConfig(name="gemma3-4b", family="lm", model=model,
                      shapes=LM_SHAPES, ripple=RippleConfig(enabled=False),
                      train=TrainConfig(grad_accum=8),
                      source="hf:google/gemma-3-1b-pt; unverified")


def make_smoke_config() -> ArchConfig:
    model = LMConfig(
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, qk_norm=True,
        sliding_window=8, local_global_pattern=2,
    )
    cfg = make_config()
    return ArchConfig(name="gemma3-4b-smoke", family="lm", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
