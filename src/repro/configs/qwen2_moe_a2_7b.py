"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Routed experts padded 60 -> 64 for even expert-parallel sharding over the
16-way model axis (router never selects the 4 padding experts — their
router logits exist but training drives them to the same competition as
real ones; at dry-run scale only shapes matter).

TimeRipple: inapplicable (1-D text tokens; DESIGN.md §6)."""

from repro.config.base import (ArchConfig, LMConfig, MoEConfig,
                               RippleConfig, TrainConfig)
from repro.configs.lm_shapes import LM_SHAPES


def make_config() -> ArchConfig:
    model = LMConfig(
        num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=1408, vocab_size=151936, head_dim=128,
        moe=MoEConfig(num_experts=64, num_shared_experts=4, top_k=4,
                      expert_ffw_dim=1408, capacity_factor=1.25),
    )
    return ArchConfig(name="qwen2-moe-a2.7b", family="lm", model=model,
                      shapes=LM_SHAPES, ripple=RippleConfig(enabled=False),
                      train=TrainConfig(grad_accum=8),
                      source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf")


def make_smoke_config() -> ArchConfig:
    model = LMConfig(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=64,
        vocab_size=256, head_dim=16,
        moe=MoEConfig(num_experts=8, num_shared_experts=2, top_k=4,
                      expert_ffw_dim=64, capacity_factor=2.0),
    )
    cfg = make_config()
    return ArchConfig(name="qwen2-moe-smoke", family="lm", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
