"""vdit-paper — the paper's native architecture: a HunyuanVideo-class
3-D video DiT with factorized (t, x, y) RoPE (head split 16/56/56,
paper §3.2) and joint text tokens.

This is the 11th config ("+ paper's own"): not part of the assigned
40-cell table, used by the paper-table benchmarks and examples.
Hyper-parameters for the Eq. 4 schedule come from paper Tbl. 1
(HunyuanVideo row, with the swapped column headers fixed — DESIGN.md §5).
"""

from repro.config.base import ArchConfig, RippleConfig, ShapeSpec, VDiTConfig

VDIT_SHAPES = (
    # 5.33 s @ 24 fps ≈ 128 frames, 544x960 -> latent (32, 68, 120);
    # scaled to a square 512 res for the shape grid here.
    ShapeSpec(name="train_256", kind="train", img_res=256, batch=64,
              steps=1000),
    ShapeSpec(name="gen_512", kind="generate", img_res=512, batch=1,
              steps=50),
)


def make_config() -> ArchConfig:
    model = VDiTConfig(
        frames=128, img_res=512, patch=2, t_patch=1, num_layers=40,
        d_model=3072, num_heads=24, in_channels=16, vae_factor=8,
        t_vae_factor=4, txt_tokens=256, txt_dim=4096,
        axes_dim=(16, 56, 56),
    )
    # Paper Tbl. 1 (HunyuanVideo): theta range [0.2, 0.5], ramp 10..20,
    # 50 denoising steps.
    ripple = RippleConfig(enabled=True, axes=("t", "x", "y"),
                          theta_min=0.2, theta_max=0.5, i_min=10, i_max=20,
                          channel_groups=(16 / 128, 56 / 128, 56 / 128))
    return ArchConfig(name="vdit-paper", family="vdit", model=model,
                      shapes=VDIT_SHAPES, ripple=ripple,
                      source="paper (HunyuanVideo-class)")


def make_smoke_config() -> ArchConfig:
    model = VDiTConfig(
        frames=16, img_res=64, patch=2, t_patch=1, num_layers=2,
        d_model=128, num_heads=2, in_channels=4, vae_factor=8,
        t_vae_factor=4, txt_tokens=8, txt_dim=64, axes_dim=(16, 24, 24),
    )
    cfg = make_config()
    return ArchConfig(name="vdit-paper-smoke", family="vdit", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
