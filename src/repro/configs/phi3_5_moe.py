"""phi3.5-moe-42b-a6.6b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]

TimeRipple: inapplicable (1-D text tokens; DESIGN.md §6)."""

from repro.config.base import (ArchConfig, LMConfig, MoEConfig,
                               RippleConfig, TrainConfig)
from repro.configs.lm_shapes import LM_SHAPES


def make_config() -> ArchConfig:
    model = LMConfig(
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=6400, vocab_size=32064, head_dim=128,
        moe=MoEConfig(num_experts=16, num_shared_experts=0, top_k=2,
                      expert_ffw_dim=6400, capacity_factor=1.25),
    )
    return ArchConfig(name="phi3.5-moe-42b-a6.6b", family="lm", model=model,
                      shapes=LM_SHAPES, ripple=RippleConfig(enabled=False),
                      train=TrainConfig(grad_accum=16),
                      source="hf:microsoft/Phi-3.5-MoE-instruct; hf")


def make_smoke_config() -> ArchConfig:
    model = LMConfig(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, d_ff=96,
        vocab_size=256, head_dim=8,
        moe=MoEConfig(num_experts=4, num_shared_experts=0, top_k=2,
                      expert_ffw_dim=96, capacity_factor=2.0),
    )
    cfg = make_config()
    return ArchConfig(name="phi3.5-moe-smoke", family="lm", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
