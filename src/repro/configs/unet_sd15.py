"""unet-sd15 [diffusion] — img_res=512 latent_res=64 ch=320
ch_mult=1-2-4-4 n_res_blocks=2 attn_res=4-2-1 ctx_dim=768.
[arXiv:2112.10752; paper]

TimeRipple: 2-D mode in the self-attention of the transformer blocks at
each attention resolution; cross-attention untouched."""

from repro.config.base import TrainConfig, ArchConfig, RippleConfig, UNetConfig
from repro.configs.lm_shapes import DIFFUSION_SHAPES


def make_config() -> ArchConfig:
    model = UNetConfig(img_res=512, latent_res=64, ch=320,
                       ch_mult=(1, 2, 4, 4), n_res_blocks=2,
                       attn_res=(4, 2, 1), ctx_dim=768, num_heads=8,
                       ctx_tokens=77)
    ripple = RippleConfig(enabled=True, axes=("x", "y"),
                          theta_min=0.2, theta_max=0.5, i_min=10, i_max=20)
    return ArchConfig(name="unet-sd15", family="unet", model=model,
                      shapes=DIFFUSION_SHAPES, ripple=ripple,
                      train=TrainConfig(grad_accum=8),
                      source="arXiv:2112.10752; paper")


def make_smoke_config() -> ArchConfig:
    model = UNetConfig(img_res=64, latent_res=8, ch=32, ch_mult=(1, 2),
                       n_res_blocks=1, attn_res=(1, 2), ctx_dim=32,
                       num_heads=4, ctx_tokens=5)
    cfg = make_config()
    return ArchConfig(name="unet-sd15-smoke", family="unet", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
