"""efficientnet-b7 [vision] — img_res=600 width_mult=2.0 depth_mult=3.1.
[arXiv:1905.11946; paper]

TimeRipple: inapplicable (attention-free conv net; DESIGN.md §6)."""

from repro.config.base import ArchConfig, EffNetConfig, RippleConfig
from repro.configs.lm_shapes import VISION_SHAPES


def make_config() -> ArchConfig:
    model = EffNetConfig(img_res=600, width_mult=2.0, depth_mult=3.1)
    return ArchConfig(name="efficientnet-b7", family="effnet", model=model,
                      shapes=VISION_SHAPES, ripple=RippleConfig(enabled=False),
                      source="arXiv:1905.11946; paper")


def make_smoke_config() -> ArchConfig:
    model = EffNetConfig(img_res=64, width_mult=0.35, depth_mult=0.35,
                         num_classes=10)
    cfg = make_config()
    return ArchConfig(name="efficientnet-b7-smoke", family="effnet",
                      model=model, shapes=cfg.shapes, ripple=cfg.ripple)
