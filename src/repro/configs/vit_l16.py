"""vit-l16 [vision] — img_res=224 patch=16 n_layers=24 d_model=1024
n_heads=16 d_ff=4096. [arXiv:2010.11929; paper]

TimeRipple: available as a beyond-paper 2-D extension (fixed threshold,
single forward); OFF by default — DESIGN.md §6."""

from repro.config.base import ArchConfig, RippleConfig, ViTConfig
from repro.configs.lm_shapes import VISION_SHAPES


def make_config() -> ArchConfig:
    model = ViTConfig(img_res=224, patch=16, num_layers=24, d_model=1024,
                      num_heads=16, d_ff=4096)
    return ArchConfig(name="vit-l16", family="vit", model=model,
                      shapes=VISION_SHAPES,
                      ripple=RippleConfig(enabled=False, axes=("x", "y")),
                      source="arXiv:2010.11929; paper")


def make_smoke_config() -> ArchConfig:
    model = ViTConfig(img_res=32, patch=8, num_layers=2, d_model=64,
                      num_heads=4, d_ff=128, num_classes=10)
    cfg = make_config()
    return ArchConfig(name="vit-l16-smoke", family="vit", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
