"""Architecture registry: one module per assigned arch (+ the paper's own
vDiT).  ``get_config(name)`` returns the full production ArchConfig;
``get_smoke_config(name)`` returns the reduced same-family config used by
the CPU smoke tests (the full configs are exercised only via the
dry-run's ShapeDtypeStructs)."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config.base import ArchConfig

_MODULES = {
    "qwen3-32b": "repro.configs.qwen3_32b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "dit-xl2": "repro.configs.dit_xl2",
    "dit-b2": "repro.configs.dit_b2",
    "flux-dev": "repro.configs.flux_dev",
    "unet-sd15": "repro.configs.unet_sd15",
    "vit-l16": "repro.configs.vit_l16",
    "efficientnet-b7": "repro.configs.efficientnet_b7",
    "vdit-paper": "repro.configs.vdit_paper",
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "vdit-paper"]
ALL_ARCHS: List[str] = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ALL_ARCHS}")
    return importlib.import_module(_MODULES[name]).make_config()


def get_smoke_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ALL_ARCHS}")
    return importlib.import_module(_MODULES[name]).make_smoke_config()
