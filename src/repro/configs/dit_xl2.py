"""dit-xl2 [diffusion] — img_res=256 patch=2 n_layers=28 d_model=1152
n_heads=16. [arXiv:2212.09748; paper]

TimeRipple: 2-D mode (x/y axes; image DiT has no temporal axis)."""

from repro.config.base import TrainConfig, ArchConfig, DiTConfig, RippleConfig
from repro.configs.lm_shapes import DIFFUSION_SHAPES


def make_config() -> ArchConfig:
    model = DiTConfig(img_res=256, patch=2, num_layers=28, d_model=1152,
                      num_heads=16)
    ripple = RippleConfig(enabled=True, axes=("x", "y"),
                          theta_min=0.2, theta_max=0.5, i_min=10, i_max=20)
    return ArchConfig(name="dit-xl2", family="dit", model=model,
                      shapes=DIFFUSION_SHAPES, ripple=ripple,
                      train=TrainConfig(grad_accum=8),
                      source="arXiv:2212.09748; paper")


def make_smoke_config() -> ArchConfig:
    model = DiTConfig(img_res=32, patch=2, num_layers=2, d_model=64,
                      num_heads=4)
    cfg = make_config()
    return ArchConfig(name="dit-xl2-smoke", family="dit", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
