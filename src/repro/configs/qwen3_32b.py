"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm, head_dim=128. [hf:Qwen/Qwen3-8B; hf]

TimeRipple: inapplicable (1-D text tokens; DESIGN.md §6)."""

from repro.config.base import (ArchConfig, LMConfig, RippleConfig,
                               TrainConfig)
from repro.configs.lm_shapes import LM_SHAPES


def make_config() -> ArchConfig:
    model = LMConfig(
        num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
        d_ff=25600, vocab_size=151936, head_dim=128, qk_norm=True,
        rope_theta=1_000_000.0,
    )
    return ArchConfig(name="qwen3-32b", family="lm", model=model,
                      shapes=LM_SHAPES, ripple=RippleConfig(enabled=False),
                      train=TrainConfig(grad_accum=16),
                      source="hf:Qwen/Qwen3-8B; hf")


def make_smoke_config() -> ArchConfig:
    model = LMConfig(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=8, qk_norm=True,
    )
    cfg = make_config()
    return ArchConfig(name="qwen3-32b-smoke", family="lm", model=model,
                      shapes=cfg.shapes, ripple=cfg.ripple)
