"""The four LM-family workload shapes shared by all assigned LM archs."""

from repro.config.base import ShapeSpec

LM_SHAPES = (
    ShapeSpec(name="train_4k", kind="train", seq_len=4096, global_batch=256),
    ShapeSpec(name="prefill_32k", kind="prefill", seq_len=32768,
              global_batch=32),
    ShapeSpec(name="decode_32k", kind="decode", seq_len=32768,
              global_batch=128),
    ShapeSpec(name="long_500k", kind="decode", seq_len=524288,
              global_batch=1),
)

DIFFUSION_SHAPES = (
    ShapeSpec(name="train_256", kind="train", img_res=256, batch=256,
              steps=1000),
    ShapeSpec(name="gen_1024", kind="generate", img_res=1024, batch=4,
              steps=50),
    ShapeSpec(name="gen_fast", kind="generate", img_res=512, batch=16,
              steps=4),
    ShapeSpec(name="train_1024", kind="train", img_res=1024, batch=32,
              steps=1000),
)

VISION_SHAPES = (
    ShapeSpec(name="cls_224", kind="train", img_res=224, batch=256),
    ShapeSpec(name="cls_384", kind="train", img_res=384, batch=64),
    ShapeSpec(name="serve_b1", kind="classify", img_res=224, batch=1),
    ShapeSpec(name="serve_b128", kind="classify", img_res=224, batch=128),
)
