"""Mixed-shape serving benchmark — continuous batching, SLO scheduling,
streaming TTFF, and the multi-replica router (DESIGN.md §10.4, §15).

Three sections, all on the miniature vDiT over a deterministic
round-robin stream across three (resolution, steps) buckets:

1. **bucketed vs single** — the bucketed continuous-batching engine vs
   the seed-style one-engine-per-shape baseline.  The structural
   headline is the device-utilization proxy (Σ batch compute walltime /
   stream walltime) and that mixed traffic needs no per-shape engines.
2. **scheduler policies** — the same deadline-stamped overload trace
   served under ``hottest`` (pre-SLO drain order) and ``edf``
   (deadline-aware, DESIGN.md §15.1).  Requests stream chunked latents
   (``--stream-every``), so **time-to-first-frame** is measured per
   request next to completion latency; one probe request carries an
   already-expired deadline so admission control provably sheds it at
   the door (§15.2) and the shed path stays exercised.
3. **router** (``--router-replicas N``) — the front-door router over N
   engine replicas (§15.4) on the same deadline-stamped trace.

Both engines in section 1 are warmed with one full pass (compiles
excluded), then timed in steady state; sections 2–3 warm the same way,
which also seeds the admission estimator.  CPU wall time is relative
only (one serial device serves every bucket).

Reported rows (CSV: name,us_per_call,derived):
  serve_mixed[bucketed_p50/p95]  — per-request latency percentiles (us);
                                   derived = utilization proxy
  serve_mixed[single_p50/p95]    — same for the sequential baseline
  serve_mixed[speedup]           — stream walltime ratio (baseline /
                                   bucketed); derived = bucketed stream
                                   walltime in seconds
  serve_mixed[hottest_p50/p95]   — scheduler-policy latency (us);
  serve_mixed[edf_p50/p95]         derived = ttff_ms=..;shed_count=..;
                                   met=..;missed=.. for that policy
  serve_mixed[router_p50/p95]    — router fleet latency (us); derived
                                   adds replicas=..;requeued=..
  serve_mixed[guard_off_p50/p95] — sentinel-off vs sentinel-on latency
  serve_mixed[guard_on_p50/p95]    (us); derived = overhead_pct=..
  serve_mixed[guardrail_overhead]— p50 overhead percent (DESIGN.md §17)
  serve_mixed[chaos_completed]   — chaos drill only (``--inject-faults``
                                   or ``$REPRO_FAULTS``): completions;
                                   derived = degraded/failover counters.
                                   The ``--json`` record then carries a
                                   full ``chaos`` object.

``--json PATH`` additionally writes a BENCH-style record of the rows
(the same schema ``benchmarks/run.py`` emits), so CI can assert the
TTFF and shed fields without scraping stdout.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import build_sampler, make_sampler_factory
from repro.launch.workloads import (mixed_gen_shapes, mixed_request_stream,
                                    model_fns)
from repro.models.params import init_params

REQUESTS = 9


def _drive(engine, traffic):
    """Submit the whole stream to a *started* engine, wait for every
    result; returns (per-request latencies, stream walltime, busy time).
    Run once to warm (compiles) and once to measure steady state — the
    deterministic stream reproduces the same batch shapes, so the timed
    pass never compiles."""
    t0 = time.time()
    submit_t = {}
    for _, req in traffic:
        submit_t[req.request_id] = time.time()
        engine.submit(req)
    lat, busy = [], {}
    for _, req in traffic:
        r = engine.result(req.request_id, timeout=600)
        lat.append(time.time() - submit_t[req.request_id])
        busy[r.batch_index] = r.walltime_s  # one entry per served batch
    wall = time.time() - t0
    return np.asarray(lat), wall, sum(busy.values())


def _drive_slo(front, traffic, deadline_ms, *, shed_probe=None):
    """Deadline-stamped pass: every request gets ``now + deadline_ms``
    at submit; ``shed_probe`` (a spare GenRequest) is submitted with an
    already-expired deadline so admission provably sheds it.  Returns
    (latencies, ttffs, met, missed, shed)."""
    from repro.serving.slo import ShedError

    shed = 0
    if shed_probe is not None:
        shed_probe.deadline_s = time.time() - 1.0
        try:
            front.submit(shed_probe)
        except ShedError:
            shed += 1
    submit_t = {}
    for _, req in traffic:
        req.deadline_s = time.time() + deadline_ms / 1e3
        submit_t[req.request_id] = time.time()
        front.submit(req)
    lat, ttff, met, missed = [], [], 0, 0
    for _, req in traffic:
        r = front.result(req.request_id, timeout=600)
        lat.append(time.time() - submit_t[req.request_id])
        ttff.append(r.ttff_s)
        if r.deadline_met:
            met += 1
        else:
            missed += 1
    return np.asarray(lat), np.asarray(ttff), met, missed, shed


def _policy_rows(tag, lat, ttff, met, missed, shed, extra=""):
    derived = (f"ttff_ms={np.percentile(ttff, 50) * 1e3:.1f};"
               f"shed_count={shed};met={met};missed={missed}{extra}")
    return [f"serve_mixed[{tag}_p50],{np.percentile(lat, 50) * 1e6:.0f},"
            f"{derived}",
            f"serve_mixed[{tag}_p95],{np.percentile(lat, 95) * 1e6:.0f},"
            f"{derived}"]


def _bucketed_vs_single(arch, shapes, params, traffic, rows):
    from repro.serving.engine import DiffusionEngine

    # Bucketed continuous batching: one engine, one queue, all shapes.
    factory, plan_fn = make_sampler_factory(arch, shapes, params)
    eng = DiffusionEngine(sampler_factory=factory, plan_fn=plan_fn,
                          max_batch=4, max_wait_s=0.02)
    eng.start()
    _drive(eng, traffic)  # warm: compiles every bucket's sampler
    b_lat, b_wall, b_busy = _drive(eng, traffic)
    eng.stop()

    # Seed-style baseline: one single-shape engine per bucket, shapes
    # served sequentially (requests still batch within their own shape).
    s_lat_all, s_wall, s_busy = [], 0.0, 0.0
    for sp in shapes:
        fn, lat_shape = build_sampler(arch, sp, params)
        sub = [(s, r) for s, r in traffic if s.name == sp.name]
        single = DiffusionEngine(fn, lat_shape, max_batch=4, max_wait_s=0.02)
        single.start()
        _drive(single, sub)  # warm
        lat, wall, busy = _drive(single, sub)
        single.stop()
        s_lat_all.append(lat)
        s_wall += wall
        s_busy += busy
    s_lat = np.concatenate(s_lat_all)

    b_util = b_busy / max(b_wall, 1e-9)
    s_util = s_busy / max(s_wall, 1e-9)
    rows += [
        f"serve_mixed[bucketed_p50],{np.percentile(b_lat, 50) * 1e6:.0f},"
        f"{b_util:.3f}",
        f"serve_mixed[bucketed_p95],{np.percentile(b_lat, 95) * 1e6:.0f},"
        f"{b_util:.3f}",
        f"serve_mixed[single_p50],{np.percentile(s_lat, 50) * 1e6:.0f},"
        f"{s_util:.3f}",
        f"serve_mixed[single_p95],{np.percentile(s_lat, 95) * 1e6:.0f},"
        f"{s_util:.3f}",
        f"serve_mixed[speedup],{s_wall / max(b_wall, 1e-9):.2f},"
        f"{b_wall:.2f}",
    ]


def _scheduler_section(arch, shapes, params, args, rows):
    from repro.serving.engine import DiffusionEngine

    factory, _ = make_sampler_factory(arch, shapes, params)
    for sched in ("hottest", "edf"):
        traffic = mixed_request_stream(arch, shapes, args.requests,
                                       stream_every=args.stream_every)
        probe = mixed_request_stream(arch, shapes, 1, seed=777,
                                     stream_every=args.stream_every)[0][1]
        probe.request_id = 10_000
        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.02, scheduler=sched)
        eng.start()
        _drive(eng, traffic)  # warm compiles + seeds the estimator
        lat, ttff, met, missed, shed = _drive_slo(
            eng, traffic, args.deadline_ms, shed_probe=probe)
        eng.stop()
        rows += _policy_rows(sched, lat, ttff, met, missed, shed)


def _router_section(arch, shapes, params, args, rows):
    from repro.serving.engine import DiffusionEngine
    from repro.serving.router import Router

    factory, _ = make_sampler_factory(arch, shapes, params)
    router = Router([
        DiffusionEngine(sampler_factory=factory, max_batch=4,
                        max_wait_s=0.02)
        for _ in range(args.router_replicas)])
    router.start()
    traffic = mixed_request_stream(arch, shapes, args.requests,
                                   stream_every=args.stream_every)
    # two warm passes so every replica the balancer touches has
    # compiled samplers before the timed pass
    _drive(router, traffic)
    _drive(router, traffic)
    probe = mixed_request_stream(arch, shapes, 1, seed=778,
                                 stream_every=args.stream_every)[0][1]
    probe.request_id = 10_001
    lat, ttff, met, missed, shed = _drive_slo(
        router, traffic, args.deadline_ms, shed_probe=probe)
    m = router.metrics()
    router.stop()
    # ``shed`` (the probe, counted at submit) already equals the
    # router's fleet-wide shed counter — don't double-count it.
    rows += _policy_rows(
        "router", lat, ttff, met, missed, shed,
        extra=f";replicas={args.router_replicas};"
              f"requeued={m['router_requeued']}")


def _guardrail_section(arch, shapes, params, traffic, rows):
    """Sentinel overhead (DESIGN.md §17): the same steady-state stream
    with the in-graph guardrail sentinels off vs on.  The acceptance bar
    is <2% on p50 — the sentinels are elementwise passes next to the
    attention math."""
    from repro.serving.engine import DiffusionEngine

    stats = {}
    for tag, sent in (("guard_off", False), ("guard_on", True)):
        factory, _ = make_sampler_factory(arch, shapes, params,
                                          sentinel=sent)
        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.02,
                              guardrail=True if sent else None)
        eng.start()
        _drive(eng, traffic)  # warm
        # best-of-2 measured passes: scheduling noise on a serial CPU
        # device dwarfs the sentinels' cost, and the min is the stable
        # statistic for an overhead comparison
        passes = [_drive(eng, traffic)[0] for _ in range(2)]
        eng.stop()
        stats[tag] = min(passes, key=lambda l: np.percentile(l, 50))
    p50_off = np.percentile(stats["guard_off"], 50)
    p50_on = np.percentile(stats["guard_on"], 50)
    overhead = (p50_on - p50_off) / max(p50_off, 1e-9)
    derived = f"overhead_pct={overhead * 100:.2f}"
    for tag in ("guard_off", "guard_on"):
        lat = stats[tag]
        rows += [
            f"serve_mixed[{tag}_p50],{np.percentile(lat, 50) * 1e6:.0f},"
            f"{derived}",
            f"serve_mixed[{tag}_p95],{np.percentile(lat, 95) * 1e6:.0f},"
            f"{derived}",
        ]
    rows += [f"serve_mixed[guardrail_overhead],{overhead * 100:.2f},"
             f"p50_off_us={p50_off * 1e6:.0f};p50_on_us={p50_on * 1e6:.0f}"]


def _chaos_section(arch, shapes, params, args):
    """Chaos drill (DESIGN.md §17.3): serve the stream through a
    2+-replica router with the guardrail ladder shared across replicas
    and the requested faults armed; kill the deepest replica right
    after submit (its first batch is still compiling, so queued
    requests demonstrably fail over).  Every request must still
    complete.  Runs *instead of* the perf sections — armed faults would
    corrupt their numbers."""
    from repro.core.guardrail import DegradationLadder
    from repro.serving import faults as fault_lib
    from repro.serving.engine import DiffusionEngine
    from repro.serving.router import Router

    fault_lib.install_faults(args.inject_faults)
    fault = fault_lib.active_faults()
    ladder = DegradationLadder()
    factory, _ = make_sampler_factory(arch, shapes, params, sentinel=True)
    replicas = max(args.router_replicas, 2)
    router = Router(
        [DiffusionEngine(sampler_factory=factory, max_batch=4,
                         max_wait_s=0.02, guardrail=ladder)
         for _ in range(replicas)],
        probe_interval_s=0.25)
    router.start()
    traffic = mixed_request_stream(arch, shapes, args.requests)
    for _, req in traffic:
        router.submit(req)
    if (fault is not None and fault.spec("kill_replica") is not None
            and fault.take("kill_replica") is not None):
        depths = router.depths()
        idx = max(depths, key=depths.get)
        print(f"# chaos: killing replica {idx} (depth {depths[idx]})",
              file=sys.stderr)
        router.fail_replica(idx)
    completed = degraded = 0
    errors = []
    for _, req in traffic:
        try:
            r = router.result(req.request_id, timeout=600)
            completed += 1
            degraded += int(r.degraded)
        except Exception as e:  # noqa: BLE001 — the drill reports, not raises
            errors.append(f"{req.request_id}: {e!r}")
    m = router.metrics()
    router.stop()
    counters = dict(fault.counters()) if fault is not None else {}
    fault_lib.clear_faults()
    lm = ladder.metrics()
    return {
        "requests": len(traffic),
        "completed": completed,
        "degraded_count": degraded,
        "failover_count": m["router_requeued"],
        "dense_fallbacks": lm["dense_fallbacks"],
        "ladder": lm,
        "fault_counters": counters,
        "errors": errors,
    }


def main(argv=()) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=REQUESTS)
    ap.add_argument("--deadline-ms", type=float, default=2000.0,
                    help="relative SLO stamped on every request at "
                         "submit in the scheduler/router sections")
    ap.add_argument("--stream-every", type=int, default=1, metavar="K",
                    help="chunked streaming cadence for the SLO "
                         "sections (TTFF is measured per chunk)")
    ap.add_argument("--router-replicas", type=int, default=0, metavar="N",
                    help="also run the Router section over N engine "
                         "replicas (0 = skip)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH-style record of the rows")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="fault spec (see repro.serving.faults); also "
                         "read from $REPRO_FAULTS.  When set, the chaos "
                         "drill runs instead of the perf sections")
    args = ap.parse_args(list(argv))
    if args.inject_faults is None:
        args.inject_faults = os.environ.get("REPRO_FAULTS", "").strip() or None

    arch = get_smoke_config("vdit-paper")
    shapes = mixed_gen_shapes(arch, smoke=True)
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    traffic = mixed_request_stream(arch, shapes, args.requests)

    t0 = time.perf_counter()
    rows = []
    chaos = None
    if args.inject_faults:
        chaos = _chaos_section(arch, shapes, params, args)
        rows += [f"serve_mixed[chaos_completed],{chaos['completed']},"
                 f"degraded={chaos['degraded_count']};"
                 f"failover={chaos['failover_count']};"
                 f"requests={chaos['requests']}"]
    else:
        _bucketed_vs_single(arch, shapes, params, traffic, rows)
        _scheduler_section(arch, shapes, params, args, rows)
        _guardrail_section(arch, shapes, params, traffic, rows)
        if args.router_replicas > 0:
            _router_section(arch, shapes, params, args, rows)

    for row in rows:
        print(row)

    if args.json:
        from benchmarks.run import _parse_rows

        record = {
            "schema": "repro-bench/1",
            "created_unix": round(time.time(), 3),
            "args": {"requests": args.requests,
                     "deadline_ms": args.deadline_ms,
                     "stream_every": args.stream_every,
                     "router_replicas": args.router_replicas,
                     "inject_faults": args.inject_faults},
            "walltime_s": round(time.perf_counter() - t0, 3),
            "benchmarks": _parse_rows("\n".join(rows)),
            "failures": [],
        }
        if chaos is not None:
            record["chaos"] = chaos
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json} ({len(rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main(sys.argv[1:])
