"""Mixed-shape serving benchmark — the bucketed continuous-batching
engine vs the seed-style single-bucket engine.

Traffic: a deterministic round-robin stream over three (resolution,
steps) buckets on the miniature vDiT.  The bucketed engine serves the
whole stream from one queue, draining the hottest bucket first; the
baseline mimics the seed engine by standing up one engine per shape and
serving the shapes sequentially (the seed engine could only batch one
(resolution, steps) combination at a time).

Both engines are warmed with one full pass (compiles excluded), then
timed in steady state.  CPU wall time is relative only (one serial
device serves every bucket, so head-of-line blocking across buckets
dominates the shared-queue latency; on a mesh the buckets' sharded
samplers spread over devices) — the structural headline is the
utilization proxy and that mixed traffic needs no per-shape engines.

Reported rows (CSV: name,us_per_call,derived):
  serve_mixed[bucketed_p50/p95]  — per-request latency percentiles (us);
                                   derived = device-utilization proxy
                                   (Σ batch compute walltime / stream
                                   walltime; higher is better)
  serve_mixed[single_p50/p95]    — same for the sequential baseline
  serve_mixed[speedup]           — stream walltime ratio (baseline /
                                   bucketed); derived = bucketed stream
                                   walltime in seconds
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import build_sampler, make_sampler_factory
from repro.launch.workloads import (mixed_gen_shapes, mixed_request_stream,
                                    model_fns)
from repro.models.params import init_params

REQUESTS = 9


def _drive(engine, traffic):
    """Submit the whole stream to a *started* engine, wait for every
    result; returns (per-request latencies, stream walltime, busy time).
    Run once to warm (compiles) and once to measure steady state — the
    deterministic stream reproduces the same batch shapes, so the timed
    pass never compiles."""
    t0 = time.time()
    submit_t = {}
    for _, req in traffic:
        submit_t[req.request_id] = time.time()
        engine.submit(req)
    lat, busy = [], {}
    for _, req in traffic:
        r = engine.result(req.request_id, timeout=600)
        lat.append(time.time() - submit_t[req.request_id])
        busy[r.batch_index] = r.walltime_s  # one entry per served batch
    wall = time.time() - t0
    return np.asarray(lat), wall, sum(busy.values())


def main() -> None:
    arch = get_smoke_config("vdit-paper")
    shapes = mixed_gen_shapes(arch, smoke=True)
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    traffic = mixed_request_stream(arch, shapes, REQUESTS)

    from repro.serving.engine import DiffusionEngine

    # Bucketed continuous batching: one engine, one queue, all shapes.
    factory, plan_fn = make_sampler_factory(arch, shapes, params)
    eng = DiffusionEngine(sampler_factory=factory, plan_fn=plan_fn,
                          max_batch=4, max_wait_s=0.02)
    eng.start()
    _drive(eng, traffic)  # warm: compiles every bucket's sampler
    b_lat, b_wall, b_busy = _drive(eng, traffic)
    eng.stop()

    # Seed-style baseline: one single-shape engine per bucket, shapes
    # served sequentially (requests still batch within their own shape).
    s_lat_all, s_wall, s_busy = [], 0.0, 0.0
    for sp in shapes:
        fn, lat_shape = build_sampler(arch, sp, params)
        sub = [(s, r) for s, r in traffic if s.name == sp.name]
        single = DiffusionEngine(fn, lat_shape, max_batch=4, max_wait_s=0.02)
        single.start()
        _drive(single, sub)  # warm
        lat, wall, busy = _drive(single, sub)
        single.stop()
        s_lat_all.append(lat)
        s_wall += wall
        s_busy += busy
    s_lat = np.concatenate(s_lat_all)

    b_util = b_busy / max(b_wall, 1e-9)
    s_util = s_busy / max(s_wall, 1e-9)
    print(f"serve_mixed[bucketed_p50],{np.percentile(b_lat, 50) * 1e6:.0f},"
          f"{b_util:.3f}")
    print(f"serve_mixed[bucketed_p95],{np.percentile(b_lat, 95) * 1e6:.0f},"
          f"{b_util:.3f}")
    print(f"serve_mixed[single_p50],{np.percentile(s_lat, 50) * 1e6:.0f},"
          f"{s_util:.3f}")
    print(f"serve_mixed[single_p95],{np.percentile(s_lat, 95) * 1e6:.0f},"
          f"{s_util:.3f}")
    print(f"serve_mixed[speedup],{s_wall / max(b_wall, 1e-9):.2f},"
          f"{b_wall:.2f}")


if __name__ == "__main__":
    main()
