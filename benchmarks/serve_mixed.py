"""Mixed-shape serving benchmark — continuous batching, SLO scheduling,
streaming TTFF, and the multi-replica router (DESIGN.md §10.4, §15).

Three sections, all on the miniature vDiT over a deterministic
round-robin stream across three (resolution, steps) buckets:

1. **bucketed vs single** — the bucketed continuous-batching engine vs
   the seed-style one-engine-per-shape baseline.  The structural
   headline is the device-utilization proxy (Σ batch compute walltime /
   stream walltime) and that mixed traffic needs no per-shape engines.
2. **scheduler policies** — the same deadline-stamped overload trace
   served under ``hottest`` (pre-SLO drain order) and ``edf``
   (deadline-aware, DESIGN.md §15.1).  Requests stream chunked latents
   (``--stream-every``), so **time-to-first-frame** is measured per
   request next to completion latency; one probe request carries an
   already-expired deadline so admission control provably sheds it at
   the door (§15.2) and the shed path stays exercised.
3. **router** (``--router-replicas N``) — the front-door router over N
   engine replicas (§15.4) on the same deadline-stamped trace.

Both engines in section 1 are warmed with one full pass (compiles
excluded), then timed in steady state; sections 2–3 warm the same way,
which also seeds the admission estimator.  CPU wall time is relative
only (one serial device serves every bucket).

Reported rows (CSV: name,us_per_call,derived):
  serve_mixed[bucketed_p50/p95]  — per-request latency percentiles (us);
                                   derived = utilization proxy
  serve_mixed[single_p50/p95]    — same for the sequential baseline
  serve_mixed[speedup]           — stream walltime ratio (baseline /
                                   bucketed); derived = bucketed stream
                                   walltime in seconds
  serve_mixed[hottest_p50/p95]   — scheduler-policy latency (us);
  serve_mixed[edf_p50/p95]         derived = ttff_ms=..;shed_count=..;
                                   met=..;missed=.. for that policy
  serve_mixed[router_p50/p95]    — router fleet latency (us); derived
                                   adds replicas=..;requeued=..
  serve_mixed[guard_off_p50/p95] — sentinel-off vs sentinel-on latency
  serve_mixed[guard_on_p50/p95]    (us); derived = overhead_pct=..
  serve_mixed[guardrail_overhead]— p50 overhead percent (DESIGN.md §17)
  serve_mixed[journal_off_p50/95]— durability-off vs durability-on
  serve_mixed[journal_on_p50/95]   latency (us); derived = overhead_pct
  serve_mixed[journal_overhead]  — p50 overhead percent of the request
                                   journal + chunk checkpoints
                                   (DESIGN.md §18; acceptance bar <5%)
  serve_mixed[chaos_completed]   — chaos drill only (``--inject-faults``
                                   or ``$REPRO_FAULTS``): completions;
                                   derived = degraded/failover counters
                                   plus resumed=..;resumed_from_step=..
                                   (checkpointed failover, §18).
                                   The ``--json`` record then carries a
                                   full ``chaos`` object.
  serve_mixed[crash_recovered]   — ``crash`` fault only: the in-process
                                   restart drill (journaled traffic, a
                                   no-drain no-marker teardown once a
                                   chunk checkpoint lands, then recovery
                                   + mid-flight resume into a fresh
                                   engine); derived = resumed_from_step.

``--json PATH`` additionally writes a BENCH-style record of the rows
(the same schema ``benchmarks/run.py`` emits), so CI can assert the
TTFF and shed fields without scraping stdout.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import build_sampler, make_sampler_factory
from repro.launch.workloads import (mixed_gen_shapes, mixed_request_stream,
                                    model_fns)
from repro.models.params import init_params

REQUESTS = 9


def _drive(engine, traffic):
    """Submit the whole stream to a *started* engine, wait for every
    result; returns (per-request latencies, stream walltime, busy time).
    Run once to warm (compiles) and once to measure steady state — the
    deterministic stream reproduces the same batch shapes, so the timed
    pass never compiles."""
    t0 = time.time()
    submit_t = {}
    for _, req in traffic:
        submit_t[req.request_id] = time.time()
        engine.submit(req)
    lat, busy = [], {}
    for _, req in traffic:
        r = engine.result(req.request_id, timeout=600)
        lat.append(time.time() - submit_t[req.request_id])
        busy[r.batch_index] = r.walltime_s  # one entry per served batch
    wall = time.time() - t0
    return np.asarray(lat), wall, sum(busy.values())


def _drive_slo(front, traffic, deadline_ms, *, shed_probe=None):
    """Deadline-stamped pass: every request gets ``now + deadline_ms``
    at submit; ``shed_probe`` (a spare GenRequest) is submitted with an
    already-expired deadline so admission provably sheds it.  Returns
    (latencies, ttffs, met, missed, shed)."""
    from repro.serving.slo import ShedError

    shed = 0
    if shed_probe is not None:
        shed_probe.deadline_s = time.time() - 1.0
        try:
            front.submit(shed_probe)
        except ShedError:
            shed += 1
    submit_t = {}
    for _, req in traffic:
        req.deadline_s = time.time() + deadline_ms / 1e3
        submit_t[req.request_id] = time.time()
        front.submit(req)
    lat, ttff, met, missed = [], [], 0, 0
    for _, req in traffic:
        r = front.result(req.request_id, timeout=600)
        lat.append(time.time() - submit_t[req.request_id])
        ttff.append(r.ttff_s)
        if r.deadline_met:
            met += 1
        else:
            missed += 1
    return np.asarray(lat), np.asarray(ttff), met, missed, shed


def _policy_rows(tag, lat, ttff, met, missed, shed, extra=""):
    derived = (f"ttff_ms={np.percentile(ttff, 50) * 1e3:.1f};"
               f"shed_count={shed};met={met};missed={missed}{extra}")
    return [f"serve_mixed[{tag}_p50],{np.percentile(lat, 50) * 1e6:.0f},"
            f"{derived}",
            f"serve_mixed[{tag}_p95],{np.percentile(lat, 95) * 1e6:.0f},"
            f"{derived}"]


def _bucketed_vs_single(arch, shapes, params, traffic, rows):
    from repro.serving.engine import DiffusionEngine

    # Bucketed continuous batching: one engine, one queue, all shapes.
    factory, plan_fn = make_sampler_factory(arch, shapes, params)
    eng = DiffusionEngine(sampler_factory=factory, plan_fn=plan_fn,
                          max_batch=4, max_wait_s=0.02)
    eng.start()
    _drive(eng, traffic)  # warm: compiles every bucket's sampler
    b_lat, b_wall, b_busy = _drive(eng, traffic)
    eng.stop()

    # Seed-style baseline: one single-shape engine per bucket, shapes
    # served sequentially (requests still batch within their own shape).
    s_lat_all, s_wall, s_busy = [], 0.0, 0.0
    for sp in shapes:
        fn, lat_shape = build_sampler(arch, sp, params)
        sub = [(s, r) for s, r in traffic if s.name == sp.name]
        single = DiffusionEngine(fn, lat_shape, max_batch=4, max_wait_s=0.02)
        single.start()
        _drive(single, sub)  # warm
        lat, wall, busy = _drive(single, sub)
        single.stop()
        s_lat_all.append(lat)
        s_wall += wall
        s_busy += busy
    s_lat = np.concatenate(s_lat_all)

    b_util = b_busy / max(b_wall, 1e-9)
    s_util = s_busy / max(s_wall, 1e-9)
    rows += [
        f"serve_mixed[bucketed_p50],{np.percentile(b_lat, 50) * 1e6:.0f},"
        f"{b_util:.3f}",
        f"serve_mixed[bucketed_p95],{np.percentile(b_lat, 95) * 1e6:.0f},"
        f"{b_util:.3f}",
        f"serve_mixed[single_p50],{np.percentile(s_lat, 50) * 1e6:.0f},"
        f"{s_util:.3f}",
        f"serve_mixed[single_p95],{np.percentile(s_lat, 95) * 1e6:.0f},"
        f"{s_util:.3f}",
        f"serve_mixed[speedup],{s_wall / max(b_wall, 1e-9):.2f},"
        f"{b_wall:.2f}",
    ]


def _scheduler_section(arch, shapes, params, args, rows):
    from repro.serving.engine import DiffusionEngine

    factory, _ = make_sampler_factory(arch, shapes, params)
    for sched in ("hottest", "edf"):
        traffic = mixed_request_stream(arch, shapes, args.requests,
                                       stream_every=args.stream_every)
        probe = mixed_request_stream(arch, shapes, 1, seed=777,
                                     stream_every=args.stream_every)[0][1]
        probe.request_id = 10_000
        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.02, scheduler=sched)
        eng.start()
        _drive(eng, traffic)  # warm compiles + seeds the estimator
        lat, ttff, met, missed, shed = _drive_slo(
            eng, traffic, args.deadline_ms, shed_probe=probe)
        eng.stop()
        rows += _policy_rows(sched, lat, ttff, met, missed, shed)


def _router_section(arch, shapes, params, args, rows):
    from repro.serving.engine import DiffusionEngine
    from repro.serving.router import Router

    factory, _ = make_sampler_factory(arch, shapes, params)
    router = Router([
        DiffusionEngine(sampler_factory=factory, max_batch=4,
                        max_wait_s=0.02)
        for _ in range(args.router_replicas)])
    router.start()
    traffic = mixed_request_stream(arch, shapes, args.requests,
                                   stream_every=args.stream_every)
    # two warm passes so every replica the balancer touches has
    # compiled samplers before the timed pass
    _drive(router, traffic)
    _drive(router, traffic)
    probe = mixed_request_stream(arch, shapes, 1, seed=778,
                                 stream_every=args.stream_every)[0][1]
    probe.request_id = 10_001
    lat, ttff, met, missed, shed = _drive_slo(
        router, traffic, args.deadline_ms, shed_probe=probe)
    m = router.metrics()
    router.stop()
    # ``shed`` (the probe, counted at submit) already equals the
    # router's fleet-wide shed counter — don't double-count it.
    rows += _policy_rows(
        "router", lat, ttff, met, missed, shed,
        extra=f";replicas={args.router_replicas};"
              f"requeued={m['router_requeued']}")


def _guardrail_section(arch, shapes, params, traffic, rows):
    """Sentinel overhead (DESIGN.md §17): the same steady-state stream
    with the in-graph guardrail sentinels off vs on.  The acceptance bar
    is <2% on p50 — the sentinels are elementwise passes next to the
    attention math."""
    from repro.serving.engine import DiffusionEngine

    stats = {}
    for tag, sent in (("guard_off", False), ("guard_on", True)):
        factory, _ = make_sampler_factory(arch, shapes, params,
                                          sentinel=sent)
        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.02,
                              guardrail=True if sent else None)
        eng.start()
        _drive(eng, traffic)  # warm
        # best-of-2 measured passes: scheduling noise on a serial CPU
        # device dwarfs the sentinels' cost, and the min is the stable
        # statistic for an overhead comparison
        passes = [_drive(eng, traffic)[0] for _ in range(2)]
        eng.stop()
        stats[tag] = min(passes, key=lambda l: np.percentile(l, 50))
    p50_off = np.percentile(stats["guard_off"], 50)
    p50_on = np.percentile(stats["guard_on"], 50)
    overhead = (p50_on - p50_off) / max(p50_off, 1e-9)
    derived = f"overhead_pct={overhead * 100:.2f}"
    for tag in ("guard_off", "guard_on"):
        lat = stats[tag]
        rows += [
            f"serve_mixed[{tag}_p50],{np.percentile(lat, 50) * 1e6:.0f},"
            f"{derived}",
            f"serve_mixed[{tag}_p95],{np.percentile(lat, 95) * 1e6:.0f},"
            f"{derived}",
        ]
    rows += [f"serve_mixed[guardrail_overhead],{overhead * 100:.2f},"
             f"p50_off_us={p50_off * 1e6:.0f};p50_on_us={p50_on * 1e6:.0f}"]


def _journal_section(arch, shapes, params, args, rows):
    """Durability overhead (DESIGN.md §18): the same steady-state
    streaming stream with the request journal + chunk-boundary
    checkpoints off vs on.  The acceptance bar is <5% on p50 — one
    framed JSON record plus one bounded checkpoint file per delivered
    chunk, written outside the engine lock."""
    import tempfile

    from repro.serving import journal as journal_lib
    from repro.serving.engine import DiffusionEngine

    factory, _ = make_sampler_factory(arch, shapes, params)
    stats, jm = {}, {}
    with tempfile.TemporaryDirectory(prefix="serve-mixed-journal-") as td:
        for tag in ("journal_off", "journal_on"):
            traffic = mixed_request_stream(arch, shapes, args.requests,
                                           stream_every=args.stream_every)
            journal = None
            kw = {}
            if tag == "journal_on":
                journal = journal_lib.Journal(os.path.join(td, "j"),
                                              fsync="always")
                kw = dict(journal=journal,
                          checkpoint_store=journal_lib.CheckpointStore(
                              os.path.join(td, "j", "ckpt")))
            eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                                  max_wait_s=0.02, **kw)
            eng.start()
            _drive(eng, traffic)  # warm
            # best-of-2, same rationale as the guardrail section: the
            # min is the stable statistic on a noisy serial device
            passes = [_drive(eng, traffic)[0] for _ in range(2)]
            jm[tag] = eng.metrics()
            eng.stop()
            if journal is not None:
                journal.close(clean=True)
            stats[tag] = min(passes, key=lambda l: np.percentile(l, 50))
    p50_off = np.percentile(stats["journal_off"], 50)
    p50_on = np.percentile(stats["journal_on"], 50)
    overhead = (p50_on - p50_off) / max(p50_off, 1e-9)
    derived = f"overhead_pct={overhead * 100:.2f}"
    for tag in ("journal_off", "journal_on"):
        lat = stats[tag]
        rows += [
            f"serve_mixed[{tag}_p50],{np.percentile(lat, 50) * 1e6:.0f},"
            f"{derived}",
            f"serve_mixed[{tag}_p95],{np.percentile(lat, 95) * 1e6:.0f},"
            f"{derived}",
        ]
    on = jm["journal_on"]
    rows += [f"serve_mixed[journal_overhead],{overhead * 100:.2f},"
             f"p50_off_us={p50_off * 1e6:.0f};p50_on_us={p50_on * 1e6:.0f};"
             f"journal_fsync_ms={on.get('journal_fsync_ms', 0)};"
             f"checkpoint_write_ms={on.get('checkpoint_write_ms', 0)};"
             f"checkpoint_bytes={on.get('checkpoint_bytes', 0)}"]


def _restart_drill(arch, shapes, params, args):
    """Crash-restart drill (DESIGN.md §18) — the in-process analogue of
    serve.py's ``crash`` fault (which SIGKILLs the whole process; a
    benchmark cannot survive that, so this drill reproduces the exact
    *disk state* in one process): journaled streaming traffic, a
    snapshot of the journal directory at the instant a chunk checkpoint
    lands (precisely what a SIGKILL mid-generation leaves behind — a
    journal with no clean-shutdown marker, submitted-but-unfinished
    requests, and their chunk checkpoints), then journal recovery +
    mid-flight resume into a fresh engine.  Every journaled request
    must complete and at least one must resume from a step > 0."""
    import shutil
    import tempfile

    from repro.serving import journal as journal_lib
    from repro.serving.engine import DiffusionEngine

    factory, _ = make_sampler_factory(arch, shapes, params)
    with tempfile.TemporaryDirectory(prefix="serve-mixed-crash-") as td:
        live = os.path.join(td, "live")
        journal = journal_lib.Journal(live, fsync="always")
        store = journal_lib.CheckpointStore(os.path.join(live, "ckpt"))
        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.02, journal=journal,
                              checkpoint_store=store)
        eng.start()
        traffic = mixed_request_stream(arch, shapes, args.requests,
                                       stream_every=1)
        for _, req in traffic:
            eng.submit(req)
        # "Mid-generation" made deterministic (faults.py crash spec,
        # wait_ckpt): wait for an in-flight chunk checkpoint — entries
        # are discarded at finish, so count>0 means resumable work.
        deadline = time.time() + 120.0
        while store.count() == 0 and time.time() < deadline:
            time.sleep(0.005)
        ckpts_at_crash = store.count()
        # The "crash": freeze the durable state mid-generation.  A
        # concurrent append may leave a torn final frame in the copy —
        # recovery is specified to tolerate exactly that.
        crashed = os.path.join(td, "crashed")
        shutil.copytree(live, crashed)
        eng.stop(drain=False)
        journal_metrics = eng.metrics()
        journal.close(clean=True)  # the live dir is done; drill uses the copy

        rec = journal_lib.recover(crashed)
        # Restart against the crash snapshot: a fresh journal handle
        # (detects the missing clean marker, truncates any torn tail)
        # + the surviving checkpoint store.
        journal2 = journal_lib.Journal(crashed, fsync="always")
        store2 = journal_lib.CheckpointStore(os.path.join(crashed, "ckpt"))
        eng2 = DiffusionEngine(sampler_factory=factory, max_batch=4,
                               max_wait_s=0.02, journal=journal2,
                               checkpoint_store=store2)
        eng2.start()
        resubmitted = []
        for rid in sorted(rec.pending):
            req = journal_lib.request_from_dict(rec.pending[rid])
            req.deadline_s = None  # absolute deadline predates the crash
            req.recovered = True
            ck = store2.get(rid)
            if (ck and req.stream_every
                    and 0 < ck["step"] < req.steps
                    and ck["step"] % req.stream_every == 0):
                req.resume = {"step": ck["step"], "x": ck["x"],
                              "dstate": ck.get("dstate")}
            eng2.submit(req)
            resubmitted.append(rid)
        completed, errors = 0, []
        for rid in resubmitted:
            try:
                eng2.result(rid, timeout=600)
                completed += 1
            except Exception as e:  # noqa: BLE001 — the drill reports
                errors.append(f"{rid}: {e!r}")
        m = eng2.metrics()
        eng2.stop()
        journal2.close(clean=True)
    return {
        "requests": len(traffic),
        "crash_clean_shutdown": rec.clean,        # must be False
        "checkpoints_at_crash": ckpts_at_crash,
        "journal_pending": len(rec.pending),
        "journal_finished_before_crash": len(rec.finished),
        "recovered_count": int(m.get("recovered_count", 0)),
        "resumed_count": int(m.get("resumed_count", 0)),
        "resumed_from_step": int(m.get("last_resume_step", 0)),
        "completed_after_restart": completed,
        "journal_fsync_ms": journal_metrics.get("journal_fsync_ms", 0),
        "checkpoint_write_ms":
            journal_metrics.get("checkpoint_write_ms", 0),
        "checkpoint_bytes": journal_metrics.get("checkpoint_bytes", 0),
        "errors": errors,
    }


def _chaos_section(arch, shapes, params, args):
    """Chaos drill (DESIGN.md §17.3): serve the stream through a
    2+-replica router with the guardrail ladder and a chunk-boundary
    checkpoint store (§18) shared across replicas, the requested faults
    armed; a ``kill_replica`` fault waits for an in-flight request's
    chunk checkpoint to land, then kills the replica serving it — so
    failover demonstrably *resumes* mid-generation instead of replaying
    from step 0.  Every request must still complete.  Runs *instead of*
    the perf sections — armed faults would corrupt their numbers."""
    import tempfile

    from repro.core.guardrail import DegradationLadder
    from repro.serving import faults as fault_lib
    from repro.serving import journal as journal_lib
    from repro.serving.engine import DiffusionEngine
    from repro.serving.router import Router

    fault_lib.install_faults(args.inject_faults)
    fault = fault_lib.active_faults()
    ladder = DegradationLadder()
    factory, _ = make_sampler_factory(arch, shapes, params, sentinel=True)
    replicas = max(args.router_replicas, 2)
    with tempfile.TemporaryDirectory(prefix="serve-mixed-chaos-") as td:
        store = journal_lib.CheckpointStore(os.path.join(td, "ckpt"))
        router = Router(
            [DiffusionEngine(sampler_factory=factory, max_batch=4,
                             max_wait_s=0.02, guardrail=ladder,
                             checkpoint_store=store)
             for _ in range(replicas)],
            probe_interval_s=0.25, checkpoint_store=store)
        router.start()
        traffic = mixed_request_stream(arch, shapes, args.requests,
                                       stream_every=1)
        for _, req in traffic:
            router.submit(req)
        if (fault is not None and fault.spec("kill_replica") is not None
                and fault.take("kill_replica") is not None):
            # Checkpoint entries are discarded at finish, so any rid in
            # the store is in-flight past >=1 chunk boundary: kill the
            # replica serving one of them so its requeue resumes.
            idx, rid = None, None
            deadline = time.time() + 120.0
            while idx is None and time.time() < deadline:
                for r in store.rids():
                    owner = router._assigned.get(r)
                    if owner is not None:
                        idx, rid = owner, r
                        break
                else:
                    time.sleep(0.005)
            if idx is None:  # no checkpoint landed: old deepest-kill
                depths = router.depths()
                idx = max(depths, key=depths.get)
            print(f"# chaos: killing replica {idx} (checkpointed "
                  f"request {rid})", file=sys.stderr)
            router.fail_replica(idx)
        completed = degraded = 0
        errors = []
        for _, req in traffic:
            try:
                r = router.result(req.request_id, timeout=600)
                completed += 1
                degraded += int(r.degraded)
            except Exception as e:  # noqa: BLE001 — reports, not raises
                errors.append(f"{req.request_id}: {e!r}")
        m = router.metrics()
        router.stop()
    counters = dict(fault.counters()) if fault is not None else {}
    fault_lib.clear_faults()
    lm = ladder.metrics()
    return {
        "requests": len(traffic),
        "completed": completed,
        "degraded_count": degraded,
        "failover_count": m["router_requeued"],
        "resumed_count": m["router_resumed"],
        "resumed_from_step": m["router_resumed_from_step"],
        "dense_fallbacks": lm["dense_fallbacks"],
        "ladder": lm,
        "fault_counters": counters,
        "errors": errors,
    }


def main(argv=()) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=REQUESTS)
    ap.add_argument("--deadline-ms", type=float, default=2000.0,
                    help="relative SLO stamped on every request at "
                         "submit in the scheduler/router sections")
    ap.add_argument("--stream-every", type=int, default=1, metavar="K",
                    help="chunked streaming cadence for the SLO "
                         "sections (TTFF is measured per chunk)")
    ap.add_argument("--router-replicas", type=int, default=0, metavar="N",
                    help="also run the Router section over N engine "
                         "replicas (0 = skip)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a BENCH-style record of the rows")
    ap.add_argument("--inject-faults", default=None, metavar="SPEC",
                    help="fault spec (see repro.serving.faults); also "
                         "read from $REPRO_FAULTS.  When set, the chaos "
                         "drill runs instead of the perf sections")
    args = ap.parse_args(list(argv))
    if args.inject_faults is None:
        args.inject_faults = os.environ.get("REPRO_FAULTS", "").strip() or None

    arch = get_smoke_config("vdit-paper")
    shapes = mixed_gen_shapes(arch, smoke=True)
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    traffic = mixed_request_stream(arch, shapes, args.requests)

    t0 = time.perf_counter()
    rows = []
    chaos = None
    if args.inject_faults:
        from repro.serving import faults as fault_lib

        plan = fault_lib.parse_faults(args.inject_faults)
        if plan.spec("crash") is not None:
            # The crash fault cannot SIGKILL a benchmark that must
            # report afterwards: it selects the in-process restart
            # drill instead (serve.py hosts the real SIGKILL variant).
            chaos = _restart_drill(arch, shapes, params, args)
            rows += [f"serve_mixed[crash_recovered],"
                     f"{chaos['recovered_count']},"
                     f"resumed_from_step={chaos['resumed_from_step']};"
                     f"completed={chaos['completed_after_restart']};"
                     f"pending={chaos['journal_pending']};"
                     f"requests={chaos['requests']}"]
        else:
            chaos = _chaos_section(arch, shapes, params, args)
            rows += [f"serve_mixed[chaos_completed],{chaos['completed']},"
                     f"degraded={chaos['degraded_count']};"
                     f"failover={chaos['failover_count']};"
                     f"resumed={chaos['resumed_count']};"
                     f"resumed_from_step={chaos['resumed_from_step']};"
                     f"requests={chaos['requests']}"]
    else:
        _bucketed_vs_single(arch, shapes, params, traffic, rows)
        _scheduler_section(arch, shapes, params, args, rows)
        _guardrail_section(arch, shapes, params, traffic, rows)
        _journal_section(arch, shapes, params, args, rows)
        if args.router_replicas > 0:
            _router_section(arch, shapes, params, args, rows)

    for row in rows:
        print(row)

    if args.json:
        from benchmarks.run import _parse_rows

        record = {
            "schema": "repro-bench/1",
            "created_unix": round(time.time(), 3),
            "args": {"requests": args.requests,
                     "deadline_ms": args.deadline_ms,
                     "stream_every": args.stream_every,
                     "router_replicas": args.router_replicas,
                     "inject_faults": args.inject_faults},
            "walltime_s": round(time.perf_counter() - t0, 3),
            "benchmarks": _parse_rows("\n".join(rows)),
            "failures": [],
        }
        if chaos is not None:
            record["chaos"] = chaos
        with open(args.json, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.json} ({len(rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main(sys.argv[1:])
