"""Per-policy savings / quality sweep through the dispatch seam.

For every registered reuse policy (DESIGN.md §11) this runs one
attention call on correlated video latents at the paper-style grid and
reports, per policy, the expected-savings estimate from the policy's
own accounting and the output PSNR against the dense baseline — the
apples-to-apples comparison the pluggable-policy API exists for.

Reported rows (CSV: name,us_per_call,derived):
  policy_sweep[<policy>]       — wall time per dispatch call (us);
                                 derived = savings estimate (0..1)
  policy_sweep[<policy>_psnr]  — same wall time; derived = PSNR (dB)
                                 of the policy's output vs dense
  policy_sweep[<policy>_skip]  — only for policies resolved onto the
                                 block-sparse backend (DESIGN.md §12):
                                 derived = realized skipped-tile
                                 fraction (the structural savings the
                                 kernel actually elides)
  decision_overhead[<policy>]  — decide-only µs vs the end-to-end
                                 dispatch µs per call: the share of a
                                 step the decision cache (DESIGN.md
                                 §13) can amortize away
  policy_sweep[<policy>_cache] — with ``reuse_every`` > 1 on a
                                 cache-capable policy: a scan over the
                                 denoising steps carrying the decision
                                 cache; derived = hits / refreshes /
                                 hit rate
  policy_sweep[<policy>_reuse<R>_psnr] — PSNR vs dense of the *cached*
                                 trajectory's mean step output
                                 (compare against <policy>_psnr1, the
                                 same loop at R=1, for the cost of the
                                 stale decisions)

Thresholds are evaluated mid-schedule (the Eq. 4 ramp's active range);
``--steps`` below the active range degenerates every schedule policy to
dense — which is exactly what the CI smoke run
(``benchmarks/run.py --policy dense --steps 2``) wants: a fast path
that still exercises registry → dispatch → stats end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import GRID, correlated_qk, decision_harness, timed
from repro.config.base import RippleConfig
from repro.core import dispatch
from repro.core.dispatch import attention_dispatch
from repro.core.policy import list_policies

D = 32


def _psnr(a, b) -> float:
    mse = float(np.mean((np.asarray(a) - np.asarray(b)) ** 2))
    rng = float(np.asarray(a).max() - np.asarray(a).min())
    return 10 * np.log10(rng ** 2 / max(mse, 1e-12))


def _decide_us(name, q, k, grid, cfg, step, total_steps, plan) -> float:
    """Decide-only walltime minus the measured consumer floor (the
    shared ``benchmarks.common.decision_harness``, also used by
    kernel_bench.decision_amortization — the two report comparable
    decide times).  ``plan`` supplies the block_shape a sparse-planned
    map policy would tile with: that tiling is part of the decide cost
    the cache amortizes."""
    from repro.core.policy import get_policy

    pol = get_policy(name)
    thetas = pol.thetas_for(cfg, step, total_steps)
    block_shape = ((plan.block_q, plan.block_k)
                   if plan.backend == "sparse"
                   and pol.will_emit_block_map(cfg) else None)
    decide, floor, _ = decision_harness(pol, q, k, grid=grid, cfg=cfg,
                                        thetas=thetas,
                                        block_shape=block_shape)
    return max(timed(decide, q, k) - timed(floor), 0.0)


def _cache_loop(name, q, k, v, grid, cfg, total_steps, reuse_every):
    """Scan the denoising steps carrying the decision cache (DESIGN.md
    §13) — the sampler-shaped loop, minus the model around it.  Returns
    (per-step outputs, final CachedDecision, walltime us)."""
    from repro.core import decision_cache

    cfg_r = dataclasses.replace(cfg, policy=name,
                                reuse_every=int(reuse_every))

    @jax.jit
    def loop(q, k, v):
        init = decision_cache.initial_state(q.shape, grid=grid, cfg=cfg_r)

        def body(carry, si):
            out, carry = attention_dispatch(
                q, k, v, grid=grid, cfg=cfg_r, step=si,
                total_steps=total_steps, cached_decision=carry)
            return carry, out

        return jax.lax.scan(body, init, jnp.arange(total_steps))

    us = timed(loop, q, k, v)
    final, outs = loop(q, k, v)
    return outs, final, us


def image_sweep() -> None:
    """Spatial-only reuse on the image-diffusion archs (T=1 grids).

    dit_xl2 at its gen_512 shape has a (1, 32, 32) token grid and
    unet_sd15's finest attention level a (1, 64, 64) one — both big
    enough that the spatial-local static pattern realizes SKIP tiles at
    block 128 (the default policy-sweep grid is a single tile and can
    never skip).  Reported per arch:

      policy_sweep[image@<arch>_static_skip] — realized skipped-tile
        fraction of the static policy's spatial pattern; asserted > the
        dense policy's structural skip (identically 0), the satellite
        check that spatial-only static patterns beat dense on skip rate
      policy_sweep[image@<arch>_static_psnr] — static output vs dense
    """
    from repro.configs.dit_xl2 import make_config as dit_config
    from repro.configs.unet_sd15 import make_config as unet_config
    from repro.core import patterns
    from repro.data.synthetic import correlated_video_latents

    dit = dit_config()
    side = dit.model.latent_res(512) // dit.model.patch
    unet = unet_config()
    targets = (
        ("dit_xl2", (1, side, side), dit.ripple),
        # finest attention level (downsample factor 1): full latent res
        ("unet_sd15", (1, unet.model.latent_res, unet.model.latent_res),
         unet.ripple),
    )
    for arch, grid, ripple in targets:
        n = grid[0] * grid[1] * grid[2]
        lat = correlated_video_latents(jax.random.PRNGKey(5), 1, grid, D,
                                       temporal_rho=0.0, spatial_smooth=3)
        x = 2.0 * lat.reshape(1, 1, n, D)
        q = x
        k = x + 0.05 * jax.random.normal(jax.random.PRNGKey(6), x.shape)
        v = jax.random.normal(jax.random.PRNGKey(7), x.shape)
        cfg = dataclasses.replace(ripple, policy="static")
        dispatch.clear_plan_cache()
        with patterns.use_artifact(None):  # grid-default spatial template
            t0_out, stats = attention_dispatch(
                q, k, v, grid=grid, cfg=cfg, step=0, total_steps=2,
                with_stats=True)
            us = timed(jax.jit(lambda q, k, v: attention_dispatch(
                q, k, v, grid=grid, cfg=cfg, step=0, total_steps=2)),
                q, k, v, warmup=1, iters=2)
        dense = np.asarray(attention_dispatch(
            q, k, v, grid=grid, cfg=cfg, step=0, total_steps=2,
            backend="dense"))
        skip = float(stats.structural_savings)
        # dense policy never skips tiles; spatial-only static must
        assert skip > 0.0, \
            f"{arch}: spatial static pattern realized no tile skips"
        print(f"policy_sweep[image@{arch}_static_skip],{us:.0f},"
              f"{skip:.3f}")
        print(f"policy_sweep[image@{arch}_static_psnr],{us:.0f},"
              f"{_psnr(dense, t0_out):.1f}")


def main(policies: Optional[Sequence[str]] = None,
         steps: Optional[int] = None,
         grid: Optional[Tuple[int, int, int]] = None,
         reuse_every: Optional[int] = None) -> None:
    from repro.core import decision_cache

    grid = grid or GRID
    total_steps = steps or 10
    q, k = correlated_qk(grid=grid, d=D)
    v = jax.random.normal(jax.random.PRNGKey(7), q.shape)
    # mid-schedule step: inside [i_min, i_max] when the schedule fits,
    # otherwise whatever the tiny smoke step count allows
    cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                       i_min=min(2, max(total_steps - 2, 0)),
                       i_max=max(total_steps - 2, 1))
    step = jnp.asarray(max(total_steps // 2, cfg.i_min))

    dense = np.asarray(attention_dispatch(
        q, k, v, grid=grid, cfg=cfg, step=step, total_steps=total_steps,
        backend="dense"))

    for name in policies or list_policies():
        cfg_p = dataclasses.replace(cfg, policy=name)
        dispatch.clear_plan_cache()

        def run(cfg_p=cfg_p):
            return attention_dispatch(q, k, v, grid=grid, cfg=cfg_p,
                                      step=step, total_steps=total_steps)

        out, stats = attention_dispatch(
            q, k, v, grid=grid, cfg=cfg_p, step=step,
            total_steps=total_steps, with_stats=True)
        us = timed(jax.jit(run))
        sav = float(stats.savings)
        print(f"policy_sweep[{name}],{us:.0f},{sav:.3f}")
        print(f"policy_sweep[{name}_psnr],{us:.0f},"
              f"{_psnr(dense, out):.1f}")
        plan = dispatch.resolve_plan(q.shape, v.shape, cfg_p)
        if plan.backend == "sparse":
            print(f"policy_sweep[{name}_skip],{us:.0f},"
                  f"{float(stats.structural_savings):.3f}")
        if plan.backend != "dense":
            dus = _decide_us(name, q, k, grid, cfg_p, step, total_steps,
                             plan)
            print(f"decision_overhead[{name}],{dus:.0f},"
                  f"decide_us={dus:.0f};end_to_end_us={us:.0f};"
                  f"decide_frac={dus / max(us, 1e-9):.3f}")
        # plan_once policies (static patterns, DESIGN.md §16) always get
        # the cache loop: their whole value proposition is the one
        # refresh at step 0 replayed across the trajectory, so report
        # the hit counters even when no cadence was asked for.
        from repro.core.policy import get_policy
        eff_reuse = reuse_every if reuse_every and reuse_every > 1 else (
            2 if getattr(get_policy(name), "plan_once", False) else None)
        if eff_reuse and decision_cache.supports_cache(cfg_p):
            outs_r, final, cus = _cache_loop(name, q, k, v, grid, cfg,
                                             total_steps, eff_reuse)
            outs_1, _, _ = _cache_loop(name, q, k, v, grid, cfg,
                                       total_steps, 1)
            hits = int(np.asarray(final.hits).sum())
            refr = int(np.asarray(final.refreshes).sum())
            print(f"policy_sweep[{name}_cache],{cus:.0f},"
                  f"hits={hits};refreshes={refr};"
                  f"hit_rate={hits / max(hits + refr, 1):.3f}")
            mean_r = np.asarray(outs_r).mean(axis=0)
            mean_1 = np.asarray(outs_1).mean(axis=0)
            p_r, p_1 = _psnr(dense, mean_r), _psnr(dense, mean_1)
            # degradation = how much *worse* than the per-step baseline
            # the cached trajectory is; stale decisions carry an older
            # (smaller) θ, so the cached path is usually conservative
            # and the degradation clamps at 0.
            print(f"policy_sweep[{name}_reuse{eff_reuse}_psnr],{cus:.0f},"
                  f"{p_r:.1f}")
            print(f"policy_sweep[{name}_psnr1],{cus:.0f},{p_1:.1f}")
            print(f"policy_sweep[{name}_reuse{eff_reuse}_degradation_db],"
                  f"{cus:.0f},{max(p_1 - p_r, 0.0):.2f}")

    if policies is None:
        # full-suite mode only: the image archs' grids are big (up to
        # 4096 tokens), too slow for the per-policy CI smoke path
        image_sweep()


if __name__ == "__main__":
    main()
