"""Per-policy savings / quality sweep through the dispatch seam.

For every registered reuse policy (DESIGN.md §11) this runs one
attention call on correlated video latents at the paper-style grid and
reports, per policy, the expected-savings estimate from the policy's
own accounting and the output PSNR against the dense baseline — the
apples-to-apples comparison the pluggable-policy API exists for.

Reported rows (CSV: name,us_per_call,derived):
  policy_sweep[<policy>]       — wall time per dispatch call (us);
                                 derived = savings estimate (0..1)
  policy_sweep[<policy>_psnr]  — same wall time; derived = PSNR (dB)
                                 of the policy's output vs dense
  policy_sweep[<policy>_skip]  — only for policies resolved onto the
                                 block-sparse backend (DESIGN.md §12):
                                 derived = realized skipped-tile
                                 fraction (the structural savings the
                                 kernel actually elides)

Thresholds are evaluated mid-schedule (the Eq. 4 ramp's active range);
``--steps`` below the active range degenerates every schedule policy to
dense — which is exactly what the CI smoke run
(``benchmarks/run.py --policy dense --steps 2``) wants: a fast path
that still exercises registry → dispatch → stats end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import GRID, correlated_qk, timed
from repro.config.base import RippleConfig
from repro.core import dispatch
from repro.core.dispatch import attention_dispatch
from repro.core.policy import list_policies

D = 32


def _psnr(a, b) -> float:
    mse = float(np.mean((np.asarray(a) - np.asarray(b)) ** 2))
    rng = float(np.asarray(a).max() - np.asarray(a).min())
    return 10 * np.log10(rng ** 2 / max(mse, 1e-12))


def main(policies: Optional[Sequence[str]] = None,
         steps: Optional[int] = None,
         grid: Optional[Tuple[int, int, int]] = None) -> None:
    grid = grid or GRID
    total_steps = steps or 10
    q, k = correlated_qk(grid=grid, d=D)
    v = jax.random.normal(jax.random.PRNGKey(7), q.shape)
    # mid-schedule step: inside [i_min, i_max] when the schedule fits,
    # otherwise whatever the tiny smoke step count allows
    cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                       i_min=min(2, max(total_steps - 2, 0)),
                       i_max=max(total_steps - 2, 1))
    step = jnp.asarray(max(total_steps // 2, cfg.i_min))

    dense = np.asarray(attention_dispatch(
        q, k, v, grid=grid, cfg=cfg, step=step, total_steps=total_steps,
        backend="dense"))

    for name in policies or list_policies():
        cfg_p = dataclasses.replace(cfg, policy=name)
        dispatch.clear_plan_cache()

        def run(cfg_p=cfg_p):
            return attention_dispatch(q, k, v, grid=grid, cfg=cfg_p,
                                      step=step, total_steps=total_steps)

        out, stats = attention_dispatch(
            q, k, v, grid=grid, cfg=cfg_p, step=step,
            total_steps=total_steps, with_stats=True)
        us = timed(jax.jit(run))
        sav = float(stats.savings)
        print(f"policy_sweep[{name}],{us:.0f},{sav:.3f}")
        print(f"policy_sweep[{name}_psnr],{us:.0f},"
              f"{_psnr(dense, out):.1f}")
        plan = dispatch.resolve_plan(q.shape, v.shape, cfg_p)
        if plan.backend == "sparse":
            print(f"policy_sweep[{name}_skip],{us:.0f},"
                  f"{float(stats.structural_savings):.3f}")


if __name__ == "__main__":
    main()
