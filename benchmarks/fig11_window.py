"""Paper Fig. 11 — sensitivity to the reuse window size.

At a fixed threshold (the window-2 setting, exactly as the paper does),
larger windows require all K members to agree, so fewer tokens qualify
(savings drop) while each reuse is more aggressive (error rises when it
fires).  Window 2 is the savings/quality sweet spot.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import (GRID, attention_out, correlated_qk,
                               savings_at, theta_for_savings)


def run():
    q, k = correlated_qk(0)
    v = jax.random.normal(jax.random.PRNGKey(7), q.shape)
    base = attention_out(q, k, v)
    theta = theta_for_savings(q, k, 0.85, window=2)  # the W=2 threshold
    rows = []
    for window in (2, 4, 8):
        s, rq, rk = savings_at(q, k, theta, window=window)
        out = attention_out(rq.snapped, rk.snapped, v)
        rows.append({
            "window": window,
            "savings": round(s, 4),
            "mse": float(jnp.mean((out - base) ** 2)),
        })
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"fig11_window[w={r['window']}],{us:.0f},"
              f"savings={r['savings']};mse={r['mse']:.3e}")
    return rows


if __name__ == "__main__":
    main()
