"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the
wall time of the whole benchmark computation on this CPU container
(relative only); ``derived`` is the headline metric reproduced from the
paper.  Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``.

``--policy NAME[,NAME...] [--steps N]`` runs only the reuse-policy
sweep (benchmarks/policy_sweep.py) for those registered policies at a
tiny grid — the CI smoke invocations are ``--policy dense --steps 2``
and ``--policy svg --steps 2`` (the latter keeps the svg→sparse backend
path compiling).  ``--grid TxHxW`` overrides the sweep's token grid —
the default (2, 4, 4) is a single 128-block tile, so structural tile
skips need a bigger grid (the static-pattern CI smoke runs
``--policy static --grid 4x8x8``).  ``--reuse-every R`` additionally scans the steps
carrying the cross-step decision cache (DESIGN.md §13) and reports its
hit counters and reuse-PSNR rows.  ``--mesh DxMxS`` installs a dispatch
mesh first; with a seq degree > 1 the run becomes the context-parallel
ring sweep (benchmarks/kernel_bench.py ``ring_sweep``, DESIGN.md §14)
and the record's derived fields carry ``elided_hops`` — the CI ring
smoke is ``--mesh 1x1x2 --policy svg --steps 2`` under
``XLA_FLAGS=--xla_force_host_platform_device_count=2``.

Every run writes a machine-readable ``BENCH_*.json`` record (per-
benchmark ``us_per_call`` plus the derived metrics — including the
sparse backend's skip rate and the decision-cache hit counts) so the
perf trajectory is tracked across PRs; CI uploads it as an artifact.
``--json PATH`` overrides the default ``BENCH_<policy|full>[_rR].json``
name; ``--json ''`` disables the record.

``--baseline PATH`` compares the fresh record against a committed one
(``benchmarks/baselines/BENCH_seed.json``) and prints ``#``-prefixed
per-benchmark walltime/derived deltas — parser-safe, so the comparison
rides along any invocation without perturbing the CSV contract.
"""

from __future__ import annotations

import argparse
import contextlib
import io
import json
import sys
import time
import traceback


class _Tee(io.TextIOBase):
    """Duplicate stdout into a buffer so the CSV rows can be parsed
    into the --json record without changing what every benchmark
    module prints."""

    def __init__(self, inner):
        self.inner = inner
        self.chunks = []

    def write(self, s):
        self.inner.write(s)
        self.chunks.append(s)
        return len(s)

    def flush(self):
        self.inner.flush()


def _parse_rows(text: str):
    """``name,us_per_call,derived`` rows -> JSON-ready dicts.  ``derived``
    may itself contain commas/semicolons; only the first two fields are
    structural."""
    rows = []
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) != 3 or parts[0] in ("", "name"):
            continue
        if parts[0].count("(") != parts[0].count(")"):
            continue  # a comma inside the name field, not a CSV row
        try:
            us = float(parts[1])
        except ValueError:
            continue
        derived: object = parts[2]
        try:
            derived = float(parts[2])
        except ValueError:
            pass  # keep the raw key=value string
        rows.append({"name": parts[0], "us_per_call": us,
                     "derived": derived})
    return rows


def _write_record(path: str, args, rows, failures, walltime_s: float):
    record = {
        "schema": "repro-bench/1",
        "created_unix": round(time.time(), 3),
        "args": {"quick": args.quick, "policy": args.policy,
                 "steps": args.steps, "reuse_every": args.reuse_every,
                 "mesh": args.mesh, "grid": getattr(args, "grid", None)},
        "walltime_s": round(walltime_s, 3),
        "benchmarks": rows,
        "failures": [{"module": m, "error": e} for m, e in failures],
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({len(rows)} benchmark rows)", file=sys.stderr)


def _default_json_path(args, ring: bool = False) -> str:
    name = (args.policy or "full").replace(",", "-")
    if args.reuse_every and args.reuse_every > 1:
        name += f"_r{args.reuse_every}"
    if ring:
        name += "_ring"
    return f"BENCH_{name}.json"


def _print_baseline_deltas(path: str, rows) -> None:
    """``#``-prefixed walltime/derived deltas vs a committed baseline
    record.  Tolerant of missing/renamed benchmarks — CPU-container
    walltimes are relative, so the deltas inform, they don't gate."""
    try:
        with open(path) as f:
            base = json.load(f)
        base_rows = {r["name"]: r for r in base.get("benchmarks", [])}
    except (OSError, ValueError, TypeError, KeyError) as e:
        print(f"# baseline {path}: unreadable ({e!r})", file=sys.stderr)
        return
    if not base_rows:
        print(f"# baseline {path}: no benchmark rows", file=sys.stderr)
        return
    matched = 0
    for r in rows:
        b = base_rows.get(r["name"])
        if b is None:
            continue
        matched += 1
        b_us, us = float(b["us_per_call"]), r["us_per_call"]
        # a sub-µs baseline (rounds to 0 in the record) has no
        # meaningful relative delta
        pct = (f"{100.0 * (us - b_us) / b_us:+.0f}%" if b_us >= 1.0
               else "n/a")
        line = f"# delta[{r['name']}]: us {b_us:.0f} -> {us:.0f} ({pct})"
        if isinstance(r["derived"], float) \
                and isinstance(b.get("derived"), float):
            line += f"; derived {b['derived']:g} -> {r['derived']:g}"
        print(line)
    print(f"# baseline {path}: {matched}/{len(rows)} rows matched "
          f"({len(base_rows)} in baseline)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow Tbl. 2 savings benchmark")
    ap.add_argument("--policy", default=None,
                    help="run only the policy sweep, for these comma-"
                         "separated registered reuse policies, at a tiny "
                         "smoke grid")
    ap.add_argument("--grid", default=None, metavar="TxHxW",
                    help="token grid for the --policy sweep (default "
                         "2x4x4; tile skips need a bigger grid, e.g. "
                         "4x8x8)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="compare the fresh record against this committed "
                         "BENCH_*.json and print #-prefixed deltas")
    ap.add_argument("--steps", type=int, default=None,
                    help="denoising-step count for the policy sweep")
    ap.add_argument("--reuse-every", type=int, default=None, metavar="R",
                    help="decision-cache cadence for the policy sweep "
                         "(DESIGN.md §13): scan the steps carrying the "
                         "cache and report hit counters + reuse-PSNR")
    ap.add_argument("--mesh", default=None, metavar="DxMxS",
                    help="install a (data, model[, seq]) dispatch mesh; "
                         "a seq degree > 1 (e.g. 1x1x2) runs the context-"
                         "parallel ring sweep (DESIGN.md §14) instead of "
                         "the policy sweep — on CPU prefix with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable BENCH_*.json record "
                         "to PATH (default: BENCH_<policy|full>[_rR].json "
                         "in the working directory; '' disables)")
    args = ap.parse_args()

    ring = False
    if args.mesh:
        from repro.core import dispatch as dispatch_lib
        from repro.launch.mesh import parse_mesh_spec

        mesh = parse_mesh_spec(args.mesh)
        dispatch_lib.set_dispatch_mesh(mesh)
        ring = "seq" in mesh.axis_names and int(mesh.shape["seq"]) > 1
    json_path = args.json if args.json is not None \
        else _default_json_path(args, ring)

    t0 = time.perf_counter()
    tee = _Tee(sys.stdout)
    failures = []
    with contextlib.redirect_stdout(tee):
        print("name,us_per_call,derived")
        if ring:
            from benchmarks import kernel_bench

            r = kernel_bench.ring_main(policy=args.policy or "svg",
                                       steps=args.steps or 2)
            if r is None:
                failures.append(("benchmarks.kernel_bench",
                                 "ring_sweep could not build a ring mesh"))
        elif args.policy is not None:
            from benchmarks import policy_sweep

            grid = (2, 4, 4)
            if args.grid:
                parts = args.grid.lower().split("x")
                if len(parts) != 3 or not all(p.isdigit() for p in parts):
                    raise SystemExit(f"--grid wants TxHxW, got {args.grid!r}")
                grid = tuple(int(p) for p in parts)
            policy_sweep.main(policies=args.policy.split(","),
                              steps=args.steps or 2, grid=grid,
                              reuse_every=args.reuse_every)
        else:
            from benchmarks import (fig7_mse, fig9_steps, fig11_window,
                                    kernel_bench, policy_sweep, serve_mixed,
                                    tbl3_ablation, tbl4_channelwise)
            mods = [fig7_mse, fig9_steps, fig11_window, tbl3_ablation,
                    tbl4_channelwise, policy_sweep, kernel_bench,
                    serve_mixed]
            if not args.quick:
                from benchmarks import tbl2_savings
                mods.insert(0, tbl2_savings)
            for mod in mods:
                try:
                    if mod is policy_sweep:
                        # the one module that honours the cadence flag —
                        # never stamp a cadence into the record that no
                        # benchmark actually ran with
                        mod.main(reuse_every=args.reuse_every)
                    else:
                        mod.main()
                except Exception as e:  # noqa: BLE001 — keep suite running
                    traceback.print_exc()
                    failures.append((mod.__name__, repr(e)))

    rows = _parse_rows("".join(tee.chunks))
    if json_path:
        _write_record(json_path, args, rows, failures,
                      time.perf_counter() - t0)
    if args.baseline:
        _print_baseline_deltas(args.baseline, rows)
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
