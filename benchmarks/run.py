"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the
wall time of the whole benchmark computation on this CPU container
(relative only); ``derived`` is the headline metric reproduced from the
paper.  Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")
    from benchmarks import (fig7_mse, fig9_steps, fig11_window,
                            kernel_bench, serve_mixed, tbl3_ablation,
                            tbl4_channelwise)
    mods = [fig7_mse, fig9_steps, fig11_window, tbl3_ablation,
            tbl4_channelwise, kernel_bench, serve_mixed]
    if not quick:
        from benchmarks import tbl2_savings
        mods.insert(0, tbl2_savings)
    failures = []
    for mod in mods:
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            traceback.print_exc()
            failures.append((mod.__name__, repr(e)))
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
