"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the
wall time of the whole benchmark computation on this CPU container
(relative only); ``derived`` is the headline metric reproduced from the
paper.  Run: ``PYTHONPATH=src python -m benchmarks.run [--quick]``.

``--policy NAME [--steps N]`` runs only the reuse-policy sweep
(benchmarks/policy_sweep.py) for that registered policy at a tiny grid —
the CI smoke invocation is ``--policy dense --steps 2``.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow Tbl. 2 savings benchmark")
    ap.add_argument("--policy", default=None,
                    help="run only the policy sweep, for this registered "
                         "reuse policy, at a tiny smoke grid")
    ap.add_argument("--steps", type=int, default=None,
                    help="denoising-step count for the policy sweep")
    args = ap.parse_args()
    print("name,us_per_call,derived")

    if args.policy is not None:
        from benchmarks import policy_sweep

        policy_sweep.main(policies=[args.policy],
                          steps=args.steps or 2, grid=(2, 4, 4))
        return

    from benchmarks import (fig7_mse, fig9_steps, fig11_window,
                            kernel_bench, policy_sweep, serve_mixed,
                            tbl3_ablation, tbl4_channelwise)
    mods = [fig7_mse, fig9_steps, fig11_window, tbl3_ablation,
            tbl4_channelwise, policy_sweep, kernel_bench, serve_mixed]
    if not args.quick:
        from benchmarks import tbl2_savings
        mods.insert(0, tbl2_savings)
    failures = []
    for mod in mods:
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            traceback.print_exc()
            failures.append((mod.__name__, repr(e)))
    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
