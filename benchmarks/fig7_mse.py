"""Paper Fig. 7 — why reuse beats masking.

At matched token-saving ratios, compares attention-output MSE of:
  * TIMERIPPLE reuse (snap to window representative),
  * mask-lowest (zero the lowest-|value| entries, baseline 1),
  * skip-same-selection (zero exactly the entries reuse would reuse,
    baseline 2).
The paper reports ~an order of magnitude advantage for reuse.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (GRID, attention_out, correlated_qk,
                               savings_at, theta_for_savings, timed)


def run():
    q, k = correlated_qk(0)
    v = jax.random.normal(jax.random.PRNGKey(99), q.shape)
    base = attention_out(q, k, v)
    rows = []
    for target in (0.5, 0.75, 0.85):
        theta = theta_for_savings(q, k, target)
        s, rq, rk = savings_at(q, k, theta)
        out_reuse = attention_out(rq.snapped, rk.snapped, v)
        mse_reuse = float(jnp.mean((out_reuse - base) ** 2))

        q_skip = jnp.where(rq.mask, 0.0, q)
        k_skip = jnp.where(rk.mask, 0.0, k)
        mse_skip = float(jnp.mean((attention_out(q_skip, k_skip, v)
                                   - base) ** 2))

        def low(x, frac):
            thr = jnp.quantile(jnp.abs(x), frac)
            return jnp.where(jnp.abs(x) < thr, 0.0, x)

        q_m = low(q, float(rq.mask.mean()))
        k_m = low(k, float(rk.mask.mean()))
        mse_mask = float(jnp.mean((attention_out(q_m, k_m, v) - base) ** 2))

        rows.append({
            "ratio": round(s, 3), "theta": round(theta, 4),
            "mse_reuse": mse_reuse, "mse_mask_lowest": mse_mask,
            "mse_skip_selected": mse_skip,
            "advantage_vs_mask": mse_mask / max(mse_reuse, 1e-12),
            "advantage_vs_skip": mse_skip / max(mse_reuse, 1e-12),
        })
    return rows


def main():
    import time
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"fig7_mse[ratio={r['ratio']}],{us:.0f},"
              f"reuse={r['mse_reuse']:.3e};mask={r['mse_mask_lowest']:.3e};"
              f"skip={r['mse_skip_selected']:.3e};"
              f"adv_mask={r['advantage_vs_mask']:.1f}x;"
              f"adv_skip={r['advantage_vs_skip']:.1f}x")
    return rows


if __name__ == "__main__":
    main()
