"""Paper Tbl. 2 — quality + performance of TIMERIPPLE variants on a
(miniature, briefly-trained) vDiT.

Reproduced columns, scaled to this container:
  * savings ratio (the TIMERIPPLE_xx% knob, calibrated like the paper),
  * PSNR / SSIM / MSE of ripple generation vs the dense generation of
    the SAME model (the paper compares against the original model's
    output frame by frame),
  * theoretical speedup at the paper's measured 78% attention fraction,
  * structural (TPU collapse) savings — our beyond-paper realized skip,
  * extra serving memory (bytes) — zero by construction, as in Tbl. 2.

VBench needs the full 950-prompt suite + pretrained models — out of
scope offline; PSNR/SSIM/MSE carry the comparison here.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import metrics
from benchmarks.common import trained_mini_vdit
from repro.core import savings as savings_lib
from repro.data.synthetic import DataSpec, latent_video_batch
from repro.diffusion.sampler import ddim_sample
from repro.diffusion.schedule import DDPMSchedule
from repro.models.vdit import vdit_apply

ATTN_FRACTION = 0.78  # paper Fig. 4 average


def _generate(arch, params, ripple_cfg, seed=0, steps=20):
    m = arch.model
    g = m.grid(img_res=32)
    key = jax.random.PRNGKey(seed)
    noise = jax.random.normal(
        key, (1, g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch,
              m.in_channels))
    txt = 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                   (1, m.txt_tokens, m.txt_dim))
    sch = DDPMSchedule()

    def denoise(x, t, step):
        return vdit_apply(params, x, t, txt, m, ripple=ripple_cfg,
                          step=step, total_steps=steps,
                          compute_dtype=jnp.float32).astype(x.dtype)

    return jax.jit(lambda n: ddim_sample(denoise, n, sch, steps))(noise)


def _measure_savings(arch, params, ripple_cfg, steps=20):
    """Mean partial-score savings over the active steps, measured on the
    patchified latent tokens (operand proxy for the block inputs)."""
    from repro.core.reuse import compute_reuse
    from repro.core.schedule import axis_thresholds
    from repro.data.synthetic import correlated_video_latents
    from repro.models.vdit import patchify_3d
    m = arch.model
    g = m.grid(img_res=32)
    key = jax.random.PRNGKey(5)
    lat = correlated_video_latents(
        key, 1, (g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch),
        m.in_channels, temporal_rho=0.9)
    tokens = patchify_3d(lat, m.t_patch, m.patch)  # (1, N, in_dim)
    x = tokens[None]  # (1, 1, N, d) — grid = g
    vals = []
    for step in range(steps):
        th = axis_thresholds(ripple_cfg, step, steps)
        if float(th["t"]) == 0.0:
            vals.append(0.0)
            continue
        r = compute_reuse(x, g, th, axes=ripple_cfg.axes,
                          window=ripple_cfg.window,
                          granularity=ripple_cfg.granularity)
        vals.append(float(savings_lib.partial_score_savings(r.mask, r.mask)))
    active = [v for v in vals if v > 0]
    return float(np.mean(active)) if active else 0.0


def run(steps=20):
    arch, params = trained_mini_vdit()
    dense = _generate(arch, params,
                      dataclasses.replace(arch.ripple, enabled=False),
                      steps=steps)
    rows = []
    variants = {
        # thresholds calibrated against the generation trajectory so the
        # subscript matches the realized savings (paper §4.2 protocol)
        "timeripple_75": dataclasses.replace(
            arch.ripple, theta_min=0.25, theta_max=0.55,
            i_min=int(0.2 * steps), i_max=int(0.4 * steps)),
        "timeripple_85": dataclasses.replace(
            arch.ripple, theta_min=0.45, theta_max=0.9,
            i_min=int(0.2 * steps), i_max=int(0.4 * steps)),
        "timeripple_75+svg": dataclasses.replace(
            arch.ripple, theta_min=0.25, theta_max=0.55,
            i_min=int(0.2 * steps), i_max=int(0.4 * steps), svg_mask=True),
    }
    for name, cfg in variants.items():
        out = _generate(arch, params, cfg, steps=steps)
        d = np.asarray(dense, np.float32)
        o = np.asarray(out, np.float32)
        # per-frame metrics averaged (as the paper does frame-by-frame)
        ps = np.mean([metrics.psnr(d[0, i], o[0, i])
                      for i in range(d.shape[1])])
        ss = np.mean([metrics.ssim(d[0, i, ..., 0], o[0, i, ..., 0])
                      for i in range(d.shape[1])])
        sv = _measure_savings(arch, params, cfg, steps=steps)
        rows.append({
            "variant": name,
            "savings": round(sv, 3),
            "psnr_db": round(float(ps), 2),
            "ssim": round(float(ss), 4),
            "mse": metrics.mse(d, o),
            "theoretical_speedup": round(float(
                savings_lib.theoretical_speedup(ATTN_FRACTION, sv)), 2),
            "extra_serving_mem_bytes": 0,
        })
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"tbl2[{r['variant']}],{us:.0f},"
              f"savings={r['savings']};psnr={r['psnr_db']}dB;"
              f"ssim={r['ssim']};speedup={r['theoretical_speedup']}x;"
              f"mem=+{r['extra_serving_mem_bytes']}B")
    return rows


if __name__ == "__main__":
    main()
