"""Paper Tbl. 3 — ablation: fixed threshold vs adaptive schedule, and
temporal-only vs spatial+temporal reuse, **at matched savings** ("for a
fair comparison, all variants are configured to achieve roughly the same
level of computational savings").

Each variant's threshold schedule is scaled by a calibrated global
factor until its mean savings over the trajectory hits the target; the
reported number is then the final-output-relevant trajectory MSE.
Expected ordering (paper): spat+temp adaptive ≤ fixed < temporal-only.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import GRID, attention_out, savings_at
from repro.config.base import RippleConfig
from repro.core.schedule import threshold_for_step
from repro.data.synthetic import correlated_video_latents
from repro.diffusion.schedule import DDPMSchedule

D = 32
TOTAL = 50
TARGET = 0.75


def _step_qkv(step):
    sch = DDPMSchedule()
    t = int((1 - step / TOTAL) * (sch.num_train_steps - 1))
    key = jax.random.PRNGKey(0)
    x0 = correlated_video_latents(key, 1, GRID, D, temporal_rho=0.95)
    noise = jax.random.normal(jax.random.fold_in(key, 1), x0.shape)
    xt = sch.add_noise(x0, noise, jnp.asarray([t])).reshape(1, 1, -1, D)
    wq = 0.5 * jax.random.normal(jax.random.PRNGKey(100), (D, D))
    wk = 0.5 * jax.random.normal(jax.random.PRNGKey(200), (D, D))
    q = jnp.einsum("bhnd,df->bhnf", xt, wq)
    k = jnp.einsum("bhnd,df->bhnf", xt, wk)
    v = jax.random.normal(jax.random.fold_in(key, 3), q.shape)
    return q, k, v


def _traj(cfg, axes, scale, steps):
    """(mean savings, mean MSE) over active steps with θ·scale."""
    tot_s, tot_m, n = 0.0, 0.0, 0
    for step in steps:
        theta = float(threshold_for_step(cfg, step, TOTAL)) * scale
        if theta == 0:
            continue
        q, k, v = _step_qkv(step)
        s, rq, rk = savings_at(q, k, theta, axes=axes)
        base = attention_out(q, k, v)
        out = attention_out(rq.snapped, rk.snapped, v)
        tot_s += s
        tot_m += float(jnp.mean((out - base) ** 2))
        n += 1
    return tot_s / max(n, 1), tot_m / max(n, 1)


def _calibrate(cfg, axes, steps):
    lo, hi = 0.0, 12.0
    for _ in range(16):
        mid = 0.5 * (lo + hi)
        s, _ = _traj(cfg, axes, mid, steps)
        if s < TARGET:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def run():
    steps = list(range(10, TOTAL, 8))
    variants = {
        "fixed": (RippleConfig(enabled=True, fixed_threshold=1.0,
                               i_min=10, i_max=20), ("t", "x", "y")),
        "adaptive_temporal_only": (RippleConfig(
            enabled=True, theta_min=1.0, theta_max=2.5, i_min=10,
            i_max=20), ("t",)),
        "adaptive_spat+temp": (RippleConfig(
            enabled=True, theta_min=1.0, theta_max=2.5, i_min=10,
            i_max=20), ("t", "x", "y")),
    }
    rows = []
    for name, (cfg, axes) in variants.items():
        scale = _calibrate(cfg, axes, steps)
        s, m = _traj(cfg, axes, scale, steps)
        rows.append({"variant": name, "savings": round(s, 3),
                     "traj_mse": m})
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"tbl3[{r['variant']}],{us:.0f},savings={r['savings']};"
              f"traj_mse={r['traj_mse']:.3e}")
    return rows


if __name__ == "__main__":
    main()
