"""Render the dry-run JSON-lines output as the EXPERIMENTS.md roofline
table.  Usage: PYTHONPATH=src python -m benchmarks.roofline_report
dryrun_singlepod.jsonl"""

from __future__ import annotations

import json
import sys


def load(path):
    rows = []
    seen = set()
    for line in open(path):
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"])
        if key in seen:           # keep the latest entry per cell
            rows = [x for x in rows if (x["arch"], x["shape"], x["mesh"]) != key]
        seen.add(key)
        rows.append(r)
    return rows


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def render(rows):
    hdr = ("| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
           "dominant | MODEL_FLOPs/HLO | peak mem (GB) | note |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for r in rows:
        note = ""
        if r.get("steps_multiplier", 1) > 1:
            note = f"x{r['steps_multiplier']} sampler steps"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['compute_s'])} | "
            f"{fmt_ms(r['memory_s'])} | {fmt_ms(r['collective_s'])} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r.get('peak_mem_gb', 0):.1f} | {note} |")
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_singlepod.jsonl"
    rows = load(path)
    print(render(rows))
    print(f"\n{len(rows)} cells.")
    worst = sorted(rows, key=lambda r: r["useful_ratio"])[:3]
    coll = sorted(rows, key=lambda r: -r["collective_s"])[:3]
    print("\nworst useful-ratio:",
          [(r["arch"], r["shape"], round(r["useful_ratio"], 2)) for r in worst])
    print("most collective-bound:",
          [(r["arch"], r["shape"], fmt_ms(r["collective_s"])) for r in coll])


if __name__ == "__main__":
    main()
