"""Image/video quality metrics (PSNR / SSIM / MSE) in pure numpy/jnp."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mse(a, b) -> float:
    return float(jnp.mean(jnp.square(jnp.asarray(a, jnp.float32)
                                     - jnp.asarray(b, jnp.float32))))


def psnr(a, b, data_range: float | None = None) -> float:
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    if data_range is None:
        data_range = float(max(a.max() - a.min(), 1e-6))
    m = np.mean((a - b) ** 2)
    if m == 0:
        return float("inf")
    return float(10.0 * np.log10(data_range ** 2 / m))


def ssim(a, b, data_range: float | None = None, win: int = 7) -> float:
    """Mean SSIM with a uniform window over the last two spatial dims."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    win = min(win, a.shape[-1], a.shape[-2])
    if data_range is None:
        data_range = float(max(a.max() - a.min(), 1e-6))
    c1 = (0.01 * data_range) ** 2
    c2 = (0.03 * data_range) ** 2

    def box(x):
        from numpy.lib.stride_tricks import sliding_window_view
        w = sliding_window_view(x, (win, win), axis=(-2, -1))
        return w.mean(axis=(-2, -1))

    mu_a, mu_b = box(a), box(b)
    var_a = box(a * a) - mu_a ** 2
    var_b = box(b * b) - mu_b ** 2
    cov = box(a * b) - mu_a * mu_b
    s = ((2 * mu_a * mu_b + c1) * (2 * cov + c2)) / (
        (mu_a ** 2 + mu_b ** 2 + c1) * (var_a + var_b + c2))
    return float(s.mean())
