"""Kernel micro-bench: structural MXU savings of the ripple kernel and
relative CPU timings (interpret mode — correctness-representative only;
the MXU skip fraction is the TPU-meaningful number).

Reports, on token-granularity reuse over correlated latents at the
paper's 75%/85% operating points:
  * the paper-accounting savings (partial scores),
  * the pair-collapse fraction,
  * the block-level MXU skip the Pallas kernel realizes (block 128),
  * the same after pair-major reordering along the dominant axis
    (the layout trick from DESIGN.md §4).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import theta_for_savings
from repro.core import reuse, savings as savings_lib
from repro.core.collapse import pair_major_order
from repro.data.synthetic import correlated_video_latents
from repro.kernels.ripple.ops import ripple_block_stats

GRID = (8, 16, 16)
N = GRID[0] * GRID[1] * GRID[2]
D = 64


def _qk(seed=0):
    lat = correlated_video_latents(jax.random.PRNGKey(seed), 1, GRID, D,
                                   temporal_rho=0.97, spatial_smooth=3)
    x = lat.reshape(1, 1, N, D)
    wq = 0.4 * jax.random.normal(jax.random.PRNGKey(seed + 1), (D, D))
    wk = 0.4 * jax.random.normal(jax.random.PRNGKey(seed + 2), (D, D))
    return (jnp.einsum("bhnd,df->bhnf", x, wq),
            jnp.einsum("bhnd,df->bhnf", x, wk))


def run():
    q, k = _qk()
    rows = []
    for target in (0.75, 0.85):
        theta = theta_for_savings(q, k, target, grid=GRID,
                                  granularity="token")
        th = {a: jnp.asarray(theta) for a in ("t", "x", "y")}
        rq = reuse.compute_reuse(q, GRID, th, granularity="token")
        rk = reuse.compute_reuse(k, GRID, th, granularity="token")
        paper = float(savings_lib.partial_score_savings(rq.mask, rk.mask))
        pq, pk = savings_lib.pair_collapse_fractions(rq.mask, rk.mask)
        skip_raw = float(ripple_block_stats(rq.snapped, rk.snapped,
                                            block_q=128, block_k=128))
        # pair-major reorder along x (already adjacent) vs t
        perm = jnp.asarray(pair_major_order(GRID, "t"))
        q_t = rq.snapped[..., perm, :]
        k_t = rk.snapped[..., perm, :]
        skip_tmajor = float(ripple_block_stats(q_t, k_t, block_q=128,
                                               block_k=128))
        # collapse-aware scheduling: protect t-representatives from x/y
        # snaps so the pair structure survives high thresholds
        rq_p = reuse.compute_reuse(q, GRID, th, granularity="token",
                                   protect_axis="t")
        rk_p = reuse.compute_reuse(k, GRID, th, granularity="token",
                                   protect_axis="t")
        paper_p = float(savings_lib.partial_score_savings(rq_p.mask,
                                                          rk_p.mask))
        skip_prot = float(ripple_block_stats(
            rq_p.snapped[..., perm, :], rk_p.snapped[..., perm, :],
            block_q=128, block_k=128))
        rows.append({
            "target": target, "theta": round(theta, 4),
            "paper_savings": round(paper, 3),
            "pair_collapse_q": round(float(pq), 3),
            "pair_collapse_k": round(float(pk), 3),
            "mxu_block_skip_xmajor": round(skip_raw, 3),
            "mxu_block_skip_tmajor": round(skip_tmajor, 3),
            "paper_savings_protected": round(paper_p, 3),
            "mxu_block_skip_protected": round(skip_prot, 3),
        })
    return rows


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"kernel_bench[{int(r['target']*100)}%],{us:.0f},"
              f"paper={r['paper_savings']};"
              f"collapse_q={r['pair_collapse_q']};"
              f"collapse_k={r['pair_collapse_k']};"
              f"mxu_skip_x={r['mxu_block_skip_xmajor']};"
              f"mxu_skip_t={r['mxu_block_skip_tmajor']};"
              f"protected:paper={r['paper_savings_protected']},"
              f"mxu_skip={r['mxu_block_skip_protected']}")
    return rows


if __name__ == "__main__":
    main()
