"""Kernel micro-bench: structural MXU savings of the ripple kernel and
relative CPU timings (interpret mode — correctness-representative only;
the MXU skip fraction is the TPU-meaningful number).

Reports, on token-granularity reuse over correlated latents at the
paper's 75%/85% operating points:
  * the paper-accounting savings (partial scores),
  * the pair-collapse fraction,
  * the block-level MXU skip the Pallas kernel realizes (block 128),
  * the same after pair-major reordering along the dominant axis
    (the layout trick from DESIGN.md §4).

Four dispatch-layer sections (DESIGN.md §8, §12, §13):
  * ``autotune_sweep`` — drives ``core.dispatch.autotune_attention``
    over the block-size candidates and persists the winner in the
    on-disk cache the dispatcher reads;
  * ``mask_pipeline_overhead`` — fused on-device reuse-mask kernel vs
    the unfused host-side ``compute_reuse`` at the paper's
    ``vdit_paper`` latent-grid shape, as modeled HBM traffic plus
    measured walltime;
  * ``sparse_backend_sweep`` — the block-sparse masked flash backend on
    the svg policy's head-classified block map at a vdit_paper-style
    grid: realized skipped-tile fraction, modeled attention speedup,
    and measured sparse-vs-dense walltime (both kernels in the same
    interpret harness, so the ratio tracks the skip rate);
  * ``decision_amortization`` — the cross-step decision cache
    (DESIGN.md §13) at the same grid: measured decide-vs-apply µs per
    policy and the resulting per-step decision overhead at cadence
    R ∈ {1, 2, 4, 8};
  * ``static_pattern_sweep`` — searched static patterns (DESIGN.md §16)
    vs adaptive ripple at the same grid: per-step replay cost ratio
    (the static plan's ``apply_decision`` is a pure passthrough, bar
    ≤ 0.1× ripple's), bitwise block-map stability across the schedule,
    and output PSNR at matched savings (bar: within 0.5 dB);
  * ``ring_sweep`` — context-parallel ring attention (DESIGN.md §14)
    at the same grid: drives ``attention_dispatch`` under a
    (data, model, seq) mesh and reports the elided-hop fraction — the
    ring hops whose block-map slice is all-SKIP, so the shard skips the
    whole hop's kernel launch.  Needs >1 local device (on CPU prefix
    with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``);
    skipped silently otherwise.  ``benchmarks/run.py --mesh 1x1xS``
    routes here.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (decision_harness, decision_tensors,
                               theta_for_savings)
from repro.core import dispatch as dispatch_lib
from repro.core import reuse, savings as savings_lib
from repro.core.collapse import pair_major_order
from repro.data.synthetic import correlated_video_latents
from repro.kernels.reuse_mask.ops import fused_compute_reuse
from repro.kernels.ripple.ops import ripple_block_stats

GRID = (8, 16, 16)
N = GRID[0] * GRID[1] * GRID[2]
D = 64


def _qk(seed=0):
    lat = correlated_video_latents(jax.random.PRNGKey(seed), 1, GRID, D,
                                   temporal_rho=0.97, spatial_smooth=3)
    x = lat.reshape(1, 1, N, D)
    wq = 0.4 * jax.random.normal(jax.random.PRNGKey(seed + 1), (D, D))
    wk = 0.4 * jax.random.normal(jax.random.PRNGKey(seed + 2), (D, D))
    return (jnp.einsum("bhnd,df->bhnf", x, wq),
            jnp.einsum("bhnd,df->bhnf", x, wk))


def run():
    q, k = _qk()
    rows = []
    for target in (0.75, 0.85):
        theta = theta_for_savings(q, k, target, grid=GRID,
                                  granularity="token")
        th = {a: jnp.asarray(theta) for a in ("t", "x", "y")}
        rq = reuse.compute_reuse(q, GRID, th, granularity="token")
        rk = reuse.compute_reuse(k, GRID, th, granularity="token")
        paper = float(savings_lib.partial_score_savings(rq.mask, rk.mask))
        pq, pk = savings_lib.pair_collapse_fractions(rq.mask, rk.mask)
        skip_raw = float(ripple_block_stats(rq.snapped, rk.snapped,
                                            block_q=128, block_k=128))
        # pair-major reorder along x (already adjacent) vs t
        perm = jnp.asarray(pair_major_order(GRID, "t"))
        q_t = rq.snapped[..., perm, :]
        k_t = rk.snapped[..., perm, :]
        skip_tmajor = float(ripple_block_stats(q_t, k_t, block_q=128,
                                               block_k=128))
        # collapse-aware scheduling: protect t-representatives from x/y
        # snaps so the pair structure survives high thresholds
        rq_p = reuse.compute_reuse(q, GRID, th, granularity="token",
                                   protect_axis="t")
        rk_p = reuse.compute_reuse(k, GRID, th, granularity="token",
                                   protect_axis="t")
        paper_p = float(savings_lib.partial_score_savings(rq_p.mask,
                                                          rk_p.mask))
        skip_prot = float(ripple_block_stats(
            rq_p.snapped[..., perm, :], rk_p.snapped[..., perm, :],
            block_q=128, block_k=128))
        rows.append({
            "target": target, "theta": round(theta, 4),
            "paper_savings": round(paper, 3),
            "pair_collapse_q": round(float(pq), 3),
            "pair_collapse_k": round(float(pk), 3),
            "mxu_block_skip_xmajor": round(skip_raw, 3),
            "mxu_block_skip_tmajor": round(skip_tmajor, 3),
            "paper_savings_protected": round(paper_p, 3),
            "mxu_block_skip_protected": round(skip_prot, 3),
        })
    return rows


def mask_pipeline_overhead(grid=None, d=128, theta=0.35):
    """Fused reuse-mask kernel vs the unfused host path at the paper's
    ``vdit_paper`` shape (one head; both scale linearly in batch·heads).

    HBM-traffic model: the fused kernel touches the operand once — one
    read plus the snapped/mask writes.  The host path runs one windowed
    pass per grid axis (read x, write per-token rep + mask each; the
    axis-wise window reshapes defeat single-kernel fusion on TPU) and a
    combine pass that re-reads x and the three (rep, mask) pairs to
    emit snapped + mask.
    """
    if grid is None:
        from repro.configs.vdit_paper import make_config
        grid = make_config().model.grid()  # (32, 32, 32) at 512 res
    n = grid[0] * grid[1] * grid[2]
    lat = correlated_video_latents(jax.random.PRNGKey(0), 1, grid, d,
                                   temporal_rho=0.95, spatial_smooth=2)
    x = lat.reshape(1, 1, n, d)
    th = {a: jnp.asarray(theta, jnp.float32) for a in ("t", "x", "y")}

    # Operands must be *arguments* of the jitted functions — a nullary
    # closure bakes them in as constants and XLA folds the whole host
    # pipeline at compile time, timing nothing but dispatch overhead.
    @jax.jit
    def host(x):
        r = reuse.compute_reuse(x, grid, th)
        return r.snapped, r.mask

    @jax.jit
    def fused(x):
        return fused_compute_reuse(x, grid, th)

    host_us = dispatch_lib.time_best(lambda: host(x), repeats=5) * 1e6
    fused_us = dispatch_lib.time_best(lambda: fused(x), repeats=5) * 1e6

    e = x.dtype.itemsize
    elems = x.size
    fused_bytes = elems * (e + e + 1)               # read x, write snap+mask
    axis_pass = elems * (e + e + 1)                 # read x, write rep+mask
    combine = elems * (e + 3 * (e + 1) + e + 1)     # read x+3(rep,mask); write
    host_bytes = 3 * axis_pass + combine
    return {
        "grid": grid, "d": d,
        "fused_mask_bytes": fused_bytes,
        "host_mask_bytes": host_bytes,
        "bytes_ratio": round(fused_bytes / host_bytes, 3),
        "fused_mask_us": round(fused_us, 1),
        "host_mask_us": round(host_us, 1),
        "walltime_ratio": round(fused_us / max(host_us, 1e-9), 3),
        "fused_le_host": fused_bytes <= host_bytes,
    }


def sparse_backend_sweep(grid=None, d=64, heads=2, block=128):
    """The svg policy's block map through the block-sparse backend
    (DESIGN.md §12) at a vdit_paper-style latent grid.

    The grid defaults to the paper architecture's own latent geometry
    (``configs/vdit_paper``) at reduced frames/resolution so the CPU
    interpret run stays in seconds: same (t, x, y) structure, 2048
    tokens.  Reported numbers:

      * ``skip_rate``   — fraction of (q, k) tiles the kernel skips
        outright, i.e. SVG's *realized* structural savings;
      * ``modeled_attn_speedup`` — 1 / (1 − skip_rate): both the score
        and AV matmuls of a skipped tile are elided;
      * ``walltime_speedup`` — the same kernel on an all-dense map vs
        the real map (identical harness, so the ratio isolates the tile
        skips; per-step interpret overhead mutes it on CPU — the skip
        rate is the TPU-meaningful number);
      * ``dense_flash_us`` — the plain flash kernel as an anchor (its
        interpret emulation is lighter than the scalar-prefetched
        sparse one, so compare it across PRs, not against sparse_us).
    """
    from repro.configs.vdit_paper import make_config
    from repro.core.policy import get_policy
    from repro.kernels.flash.ops import flash_attention
    from repro.kernels.sparse.ops import (sparse_attention_pallas,
                                          sparse_block_stats)

    if grid is None:
        grid = make_config().model.grid(frames=32, img_res=256)  # (8,16,16)
    n = grid[0] * grid[1] * grid[2]
    lat = correlated_video_latents(jax.random.PRNGKey(11), heads, grid, d,
                                   temporal_rho=0.95, spatial_smooth=2)
    x = lat.reshape(1, heads, n, d)
    wq = 0.4 * jax.random.normal(jax.random.PRNGKey(12), (d, d))
    wk = 0.4 * jax.random.normal(jax.random.PRNGKey(13), (d, d))
    q = jnp.einsum("bhnd,df->bhnf", x, wq)
    k = jnp.einsum("bhnd,df->bhnf", x, wk)
    v = jax.random.normal(jax.random.PRNGKey(14), (1, heads, n, d))

    pol = get_policy("svg")
    from repro.config.base import RippleConfig
    from repro.kernels.sparse.ops import PARTIAL
    cfg = RippleConfig(enabled=True)
    dec = pol.decide(q, k, grid=grid, cfg=cfg,
                     thetas=pol.thetas_for(cfg, 0, 1),
                     block_shape=(block, block))
    skip = float(sparse_block_stats(dec.block_map))

    @jax.jit
    def sparse(q, k, v, bias, bmap):
        return sparse_attention_pallas(q, k, v, bias=bias, block_map=bmap,
                                       block_q=block, block_k=block)

    @jax.jit
    def dense(q, k, v):
        return flash_attention(q, k, v, block_q=block, block_k=block)

    dense_map = jnp.full(dec.block_map.shape, PARTIAL, jnp.int32)
    sparse_us = dispatch_lib.time_best(
        lambda: sparse(q, k, v, dec.bias, dec.block_map), repeats=2) * 1e6
    dense_map_us = dispatch_lib.time_best(
        lambda: sparse(q, k, v, dec.bias, dense_map), repeats=2) * 1e6
    flash_us = dispatch_lib.time_best(lambda: dense(q, k, v),
                                      repeats=2) * 1e6
    return {
        "grid": grid, "d": d, "heads": heads, "block": block,
        "mask_savings": round(float(dec.savings), 3),
        "skip_rate": round(skip, 3),
        "modeled_attn_speedup": round(1.0 / max(1.0 - skip, 1e-9), 2),
        "sparse_us": round(sparse_us, 1),
        "dense_map_us": round(dense_map_us, 1),
        "dense_flash_us": round(flash_us, 1),
        "walltime_speedup": round(dense_map_us / max(sparse_us, 1e-9), 2),
    }


def decision_amortization(grid=None, d=64, heads=2,
                          cadences=(1, 2, 4, 8)):
    """Per-step decision overhead of the cross-step decision cache
    (DESIGN.md §13) at a vdit_paper-style latent grid.

    For each cache-capable policy this times, in the same jit harness,

      * ``decide_us`` — one full ``decide(want_plan=True)``: the
        windowed Δ-stats / head classification plus the plan build
        (what every step of every layer used to pay), and
      * ``apply_us`` — one ``apply_decision``: re-applying the cached
        plan to fresh operands (a gather for ripple, a pure
        bias/block-map passthrough for svg),

    each consuming every tensor the backend would read (q, k, bias,
    block map) through a scalar reduction — so XLA cannot fold the
    decision away (masks and savings dead-code-eliminate, as in a
    stats-less dispatch), while the standalone-harness *output copies*
    are excluded (in the real pipeline those tensors feed the kernel
    inside one program).  A measured consumer floor — the same
    reductions on precomputed decision outputs — is subtracted from
    both, so the numbers isolate pure decision work.  The per-step
    decision overhead at cadence R is then
    ``(decide + (R-1)·apply) / R`` — what the sampler's refresh cond
    amortizes — and ``reduction_R`` its improvement over R=1.
    """
    from repro.config.base import RippleConfig
    from repro.configs.vdit_paper import make_config
    from repro.core import decision_cache as dc
    from repro.core.policy import get_policy

    if grid is None:
        grid = make_config().model.grid(frames=32, img_res=256)  # (8,16,16)
    n = grid[0] * grid[1] * grid[2]
    lat = correlated_video_latents(jax.random.PRNGKey(21), heads, grid, d,
                                   temporal_rho=0.95, spatial_smooth=2)
    x = lat.reshape(1, heads, n, d)
    wq = 0.4 * jax.random.normal(jax.random.PRNGKey(22), (d, d))
    wk = 0.4 * jax.random.normal(jax.random.PRNGKey(23), (d, d))
    q = jnp.einsum("bhnd,df->bhnf", x, wq)
    k = jnp.einsum("bhnd,df->bhnf", x, wk)

    rows = []
    for name in ("ripple", "svg"):
        pol = get_policy(name)
        cfg = RippleConfig(enabled=True, policy=name, theta_min=0.2,
                           theta_max=0.5, i_min=2, i_max=8)
        thetas = pol.thetas_for(cfg, jnp.asarray(5), 10)
        decide, floor, d0 = decision_harness(
            pol, q, k, grid=grid, cfg=cfg, thetas=thetas,
            block_shape=(128, 128) if name == "svg" else None,
            want_plan=True)
        cache = dc.cache_from_decision(d0, dc.drift_stat(q, k, cfg))

        @jax.jit
        def apply(q, k, cache):
            return tuple(t.sum() for t in decision_tensors(
                pol.apply_decision(q, k, cache, grid=grid, cfg=cfg,
                                   thetas=thetas)))

        floor_us = dispatch_lib.time_best(floor, repeats=5) * 1e6
        decide_us = max(dispatch_lib.time_best(
            lambda: decide(q, k), repeats=5) * 1e6 - floor_us, 0.0)
        apply_us = max(dispatch_lib.time_best(
            lambda: apply(q, k, cache), repeats=5) * 1e6 - floor_us, 0.0)
        per_step = {R: (decide_us + (R - 1) * apply_us) / R
                    for R in cadences}
        rows.append({
            "policy": name, "grid": grid, "d": d, "heads": heads,
            "decide_us": round(decide_us, 1),
            "apply_us": round(apply_us, 1),
            "per_step_us": {R: round(us, 1) for R, us in per_step.items()},
            "reduction": {R: round(per_step[1] / max(us, 1e-9), 2)
                          for R, us in per_step.items()},
        })
    return rows


def ring_sweep(grid=None, d=64, heads=2, policy="svg", steps=2,
               seq=None):
    """Context-parallel ring attention (DESIGN.md §14) at a vdit_paper-
    style latent grid.

    Runs ``steps`` cached dispatch calls under a ``1x1xS`` mesh and
    reads the ring telemetry off the threaded decision state:

      * ``elided_hops`` — ring hops whose block-map slice was all-SKIP
        (the shard skipped the hop's kernel launch entirely),
      * ``hops`` — total hops executed (steps × S shards × S hops),
      * ``elided_frac`` — the realized structural savings of the ring
        schedule; the K/V rotation itself still runs every hop, so the
        matching communication savings are modeled, not realized
        (DESIGN.md §14).

    Returns ``None`` when no ring mesh can be built (single device, or
    the seq degree does not divide the frame axis).
    """
    from repro.config.base import RippleConfig
    from repro.configs.vdit_paper import make_config
    from repro.core import decision_cache as dc
    from repro.launch.mesh import parse_mesh_spec

    if grid is None:
        grid = make_config().model.grid(frames=32, img_res=256)  # (8,16,16)
    mesh = dispatch_lib.active_dispatch_mesh()
    if mesh is None or "seq" not in mesh.axis_names \
            or int(mesh.shape["seq"]) < 2:
        if seq is None:
            n_dev = jax.device_count()
            seq = max((s for s in (8, 4, 2)
                       if s <= n_dev and grid[0] % s == 0), default=1)
        if seq < 2:
            return None
        mesh = parse_mesh_spec(f"1x1x{seq}")
    S = int(mesh.shape["seq"])
    if grid[0] % S:
        return None

    n = grid[0] * grid[1] * grid[2]
    # Random operands: with uncorrelated data every head classifies
    # spatial (the 2/T-vs-3/HW margin, DESIGN.md §12), whose local+sink
    # mask is what makes whole ring hops elidable.
    q = jax.random.normal(jax.random.PRNGKey(31), (1, heads, n, d))
    k = jax.random.normal(jax.random.PRNGKey(32), (1, heads, n, d))
    v = jax.random.normal(jax.random.PRNGKey(33), (1, heads, n, d))
    cfg = RippleConfig(enabled=True, policy=policy, reuse_every=2)

    with dispatch_lib.dispatch_mesh(mesh):
        plan = dispatch_lib.resolve_plan(q.shape, v.shape, cfg,
                                         backend="sparse", policy=policy,
                                         grid=grid)
        state = dc.initial_state(q.shape, grid=grid, cfg=cfg,
                                 policy=policy, backend="sparse")

        @jax.jit
        def step_fn(q, k, v, step, state):
            return dispatch_lib.attention_dispatch(
                q, k, v, grid=grid, cfg=cfg, step=step,
                total_steps=steps + 1, backend="sparse", policy=policy,
                cached_decision=state, return_decision=True)

        # Compile outside the timed loop; the warm-up call's state is
        # discarded so the elided counters cover the timed steps only.
        warm, _ = step_fn(q, k, v, jnp.asarray(0, jnp.int32), state)
        jax.block_until_ready(warm)
        t0 = time.perf_counter()
        for s in range(steps):
            out, state = step_fn(q, k, v, jnp.asarray(s, jnp.int32),
                                 state)
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) * 1e6 / steps

    elided = (0 if state.elided is None
              else int(jax.device_get(state.elided).sum()))
    hops = steps * S * S
    frac = elided / hops
    return {
        "grid": grid, "d": d, "heads": heads, "policy": policy,
        "seq": S, "steps": steps, "ring": plan.seq_shards == S,
        "elided_hops": elided, "hops": hops,
        "elided_frac": round(frac, 3),
        "modeled_attn_speedup": round(1.0 / max(1.0 - frac, 1e-9), 2),
        "us_per_step": round(us, 1),
    }


def ring_main(policy="svg", steps=2):
    """Print the ring_sweep CSV row (the ``--mesh`` path of
    ``benchmarks/run.py`` lands here)."""
    r = ring_sweep(policy=policy, steps=steps)
    if r is None:
        print("# ring_sweep skipped: needs >1 device and seq | frames "
              "(prefix XLA_FLAGS=--xla_force_host_platform_device_count=N)")
        return None

    def gname(g):
        return "x".join(str(v) for v in g)

    print(f"kernel_bench[ring@{r['policy']}x{r['seq']}seq"
          f"_vdit_paper{gname(r['grid'])}xd{r['d']}],"
          f"{r['us_per_step']:.0f},"
          f"elided_hops={r['elided_hops']};hops={r['hops']};"
          f"elided_frac={r['elided_frac']};"
          f"modeled_attn_speedup={r['modeled_attn_speedup']};"
          f"ring={r['ring']};steps={r['steps']}")
    return r


def static_pattern_sweep(grid=None, d=64, heads=2, steps=4):
    """Searched static patterns (DESIGN.md §16) vs adaptive ripple at a
    vdit_paper-style latent grid.

    Runs the offline pattern search in-process on small head-diverse
    calibration traffic, installs the artifact, and reports:

      * ``apply_ratio`` — the static policy's per-step ``apply_decision``
        cost over ripple's, each floor-subtracted against a jitted floor
        with the *same* (q, k, cache) argument structure so call
        overhead cancels.  Static replay is a pure bias/block-map
        passthrough (no snap gather), so the acceptance bar is ≤ 0.1×;
      * ``map_stable`` — the block map decided at step 0 vs the last
        step, bitwise (a static plan must not drift with the schedule);
      * ``psnr_static`` / ``psnr_ripple`` / ``psnr_delta_db`` — output
        PSNR vs dense of the static pattern and of adaptive ripple at a
        θ matched to the same savings level (the apples-to-apples
        quality comparison; the acceptance bar is within 0.5 dB);
      * ``skip_rate`` — the realized skipped-tile fraction of the
        searched patterns' block map.
    """
    from repro.config.base import RippleConfig
    from repro.configs.vdit_paper import make_config
    from repro.core import patterns
    from repro.core.policy import get_policy
    from repro.kernels.sparse.ops import sparse_block_stats
    from repro.launch.pattern_search import calibration_traffic

    if grid is None:
        grid = make_config().model.grid(frames=32, img_res=256)  # (8,16,16)
    n = grid[0] * grid[1] * grid[2]

    # one layer of head-diverse calibration traffic, searched in-process
    samples = calibration_traffic(grid=grid, layers=1, heads=heads,
                                  steps=2, prompts=1, d=d,
                                  characters=("temporal", "spatial"))
    art = patterns.search_patterns(samples, grid, block_shape=(128, 128),
                                   tolerance_db=20.0,
                                   meta={"traffic": "bench"})

    # held-out eval traffic: same head characters the patterns were
    # searched for, different seed — quality is meaningful only on the
    # distribution the calibration covered
    _, q, k, v = next(iter(calibration_traffic(
        grid=grid, layers=1, heads=heads, steps=1, prompts=1, d=d,
        seed=123, characters=("temporal", "spatial"))))

    with patterns.use_artifact(art):
        # --- per-step replay cost, static vs ripple -------------------
        from repro.core import decision_cache as dc

        apply_us = {}
        for name in ("static", "ripple"):
            pol = get_policy(name)
            cfg = RippleConfig(enabled=True, policy=name, theta_min=0.2,
                               theta_max=0.5, i_min=1, i_max=steps - 1)
            thetas = pol.thetas_for(cfg, jnp.asarray(1), steps)
            _, _, d0 = decision_harness(
                pol, q, k, grid=grid, cfg=cfg, thetas=thetas,
                block_shape=(128, 128) if name == "static" else None,
                want_plan=True)
            cache = dc.cache_from_decision(d0, dc.drift_stat(q, k, cfg))

            @jax.jit
            def apply(q, k, cache, pol=pol, cfg=cfg, thetas=thetas):
                return tuple(t.sum() for t in decision_tensors(
                    pol.apply_decision(q, k, cache, grid=grid, cfg=cfg,
                                       thetas=thetas)))

            # The floor must share apply's argument structure — same
            # (q, k, cache-pytree) signature, same-shape scalar sums —
            # so jit-call and pytree-flatten overhead cancels in the
            # subtraction and the difference isolates apply_decision's
            # real per-step work (the snap gather for ripple; nothing
            # for static's passthrough).
            @jax.jit
            def floor_fn(q, k, cache):
                vals = [q.sum(), k.sum()]
                for t in (cache.bias, cache.block_map):
                    if t is not None:
                        vals.append(t.sum())
                return tuple(vals)

            # Both sides sum the same multi-MB constant bias, so each
            # timing is ms-scale and a one-shot subtraction inherits
            # machine-load drift between the two measurements.
            # Interleave floor/apply rounds and keep the smallest
            # difference — drift common to a round cancels.
            diffs = []
            for _ in range(5):
                f = dispatch_lib.time_best(
                    lambda: floor_fn(q, k, cache), repeats=10)
                a = dispatch_lib.time_best(
                    lambda: apply(q, k, cache), repeats=10)
                diffs.append(a - f)
            apply_us[name] = max(min(diffs) * 1e6, 0.0)

        # --- block-map stability across the schedule ------------------
        pol = get_policy("static")
        cfg_s = RippleConfig(enabled=True, policy="static", theta_min=0.2,
                             theta_max=0.5, i_min=1, i_max=steps - 1)
        maps = [pol.decide(q, k, grid=grid, cfg=cfg_s,
                           thetas=pol.thetas_for(cfg_s, jnp.asarray(s),
                                                 steps),
                           block_shape=(128, 128)).block_map
                for s in (0, steps - 1)]
        stable = bool(np.array_equal(np.asarray(maps[0]),
                                     np.asarray(maps[1])))
        skip = float(sparse_block_stats(maps[0]))

        # --- quality at matched savings -------------------------------
        dense = np.asarray(dispatch_lib.attention_dispatch(
            q, k, v, grid=grid, cfg=RippleConfig(enabled=False),
            backend="dense"))
        out_s, stats_s = dispatch_lib.attention_dispatch(
            q, k, v, grid=grid, cfg=cfg_s, step=1, total_steps=steps,
            with_stats=True)
        target = float(stats_s.savings)
        theta = theta_for_savings(q, k, target, grid=grid)
        cfg_r = RippleConfig(enabled=True, policy="ripple",
                             theta_min=theta, theta_max=theta,
                             i_min=1, i_max=steps - 1)
        out_r = dispatch_lib.attention_dispatch(
            q, k, v, grid=grid, cfg=cfg_r, step=1, total_steps=steps)

    def psnr(ref, out):
        mse = float(np.mean((ref - np.asarray(out)) ** 2))
        rng = float(ref.max() - ref.min())
        return 10 * np.log10(rng ** 2 / max(mse, 1e-12))

    p_s, p_r = psnr(dense, out_s), psnr(dense, out_r)
    return {
        "grid": grid, "d": d, "heads": heads,
        "static_frac": round(art.static_fraction(), 3),
        "skip_rate": round(skip, 3),
        "matched_savings": round(target, 3),
        "static_apply_us": round(apply_us["static"], 1),
        "ripple_apply_us": round(apply_us["ripple"], 1),
        "apply_ratio": round(apply_us["static"]
                             / max(apply_us["ripple"], 1e-9), 3),
        "map_stable": stable,
        "psnr_static": round(p_s, 1),
        "psnr_ripple": round(p_r, 1),
        "psnr_delta_db": round(p_r - p_s, 2),
    }


def autotune_sweep(n=1024, d=64):
    """Sweep the dispatch autotuner's block candidates and persist the
    winner in the on-disk cache ``attention_dispatch`` reads."""
    q = correlated_video_latents(jax.random.PRNGKey(1), 1, (4, 16, 16), d,
                                 temporal_rho=0.95).reshape(1, 1, n, d)
    k = correlated_video_latents(jax.random.PRNGKey(2), 1, (4, 16, 16), d,
                                 temporal_rho=0.95).reshape(1, 1, n, d)
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 1, n, d))
    entry = dispatch_lib.autotune_attention(
        q, k, v, candidates=((64, 64), (128, 128), (256, 256)),
        repeats=3, force=True)
    return {"cache": dispatch_lib.autotune_cache_path(), **entry}


def main():
    t0 = time.perf_counter()
    rows = run()
    us = (time.perf_counter() - t0) * 1e6
    for r in rows:
        print(f"kernel_bench[{int(r['target']*100)}%],{us:.0f},"
              f"paper={r['paper_savings']};"
              f"collapse_q={r['pair_collapse_q']};"
              f"collapse_k={r['pair_collapse_k']};"
              f"mxu_skip_x={r['mxu_block_skip_xmajor']};"
              f"mxu_skip_t={r['mxu_block_skip_tmajor']};"
              f"protected:paper={r['paper_savings_protected']},"
              f"mxu_skip={r['mxu_block_skip_protected']}")

    def gname(g):
        # no commas: the CSV rows' first two fields must stay structural
        # for the --json parser in benchmarks/run.py
        return "x".join(str(v) for v in g)

    m = mask_pipeline_overhead()
    print(f"kernel_bench[mask_fusion@vdit_paper{gname(m['grid'])}xd{m['d']}],"
          f"{m['fused_mask_us']:.0f},"
          f"fused_bytes={m['fused_mask_bytes']};"
          f"host_bytes={m['host_mask_bytes']};"
          f"bytes_ratio={m['bytes_ratio']};"
          f"fused_us={m['fused_mask_us']};host_us={m['host_mask_us']};"
          f"walltime_ratio={m['walltime_ratio']};"
          f"fused_le_host={m['fused_le_host']}")

    s = sparse_backend_sweep()
    print(f"kernel_bench[sparse@vdit_paper{gname(s['grid'])}xd{s['d']}],"
          f"{s['sparse_us']:.0f},"
          f"skip_rate={s['skip_rate']};"
          f"mask_savings={s['mask_savings']};"
          f"modeled_attn_speedup={s['modeled_attn_speedup']};"
          f"sparse_us={s['sparse_us']};dense_map_us={s['dense_map_us']};"
          f"dense_flash_us={s['dense_flash_us']};"
          f"walltime_speedup={s['walltime_speedup']}")

    amort = decision_amortization()
    for r in amort:
        per = ";".join(f"R{R}={us}" for R, us in r["per_step_us"].items())
        red = ";".join(f"red_R{R}={x}" for R, x in r["reduction"].items())
        print(f"kernel_bench[decision_amortization@vdit_paper"
              f"{gname(r['grid'])}xd{r['d']}/{r['policy']}],"
              f"{r['decide_us']:.0f},"
              f"decide_us={r['decide_us']};apply_us={r['apply_us']};"
              f"{per};{red}")

    sp = static_pattern_sweep()
    print(f"kernel_bench[static_pattern@vdit_paper"
          f"{gname(sp['grid'])}xd{sp['d']}],"
          f"{sp['static_apply_us']:.0f},"
          f"apply_ratio={sp['apply_ratio']};"
          f"static_apply_us={sp['static_apply_us']};"
          f"ripple_apply_us={sp['ripple_apply_us']};"
          f"skip_rate={sp['skip_rate']};"
          f"static_frac={sp['static_frac']};"
          f"map_stable={sp['map_stable']};"
          f"matched_savings={sp['matched_savings']};"
          f"psnr_static={sp['psnr_static']};"
          f"psnr_ripple={sp['psnr_ripple']};"
          f"psnr_delta_db={sp['psnr_delta_db']}")

    a = autotune_sweep()
    cand = ";".join(f"{c['block_q']}x{c['block_k']}={c['us']}us"
                    for c in a["candidates"])
    print(f"kernel_bench[autotune],{a['us']:.0f},"
          f"best={a['block_q']}x{a['block_k']};device={a['device']};"
          f"{cand};cache={a['cache']}")

    ring = ring_main()  # no-op on a single device
    return rows + [m, s, sp, a] + amort + ([ring] if ring else [])


if __name__ == "__main__":
    main()
