"""Paper Figs. 8-9 — sensitivity of generation quality to the denoising
step (strong, decaying) and to the prompt (weak).

Protocol (matches the paper's): take a (miniature, trained) vDiT, apply
reuse at ONE denoising step only (fixed θ), and measure the MSE of the
*final* generated video against the dense generation.  Early-step errors
shape global structure and propagate; late-step errors stay local — so
the injected-step MSE decays with the step index, which is exactly what
licenses Eq. 4's rising threshold ramp.  Fig. 8's claim = the decay
curve is stable across prompts (var over prompts ≪ var over steps).

Also reported: the operand-level mechanism (at fixed θ on a DDPM forward
trajectory, later/less-noisy steps have MORE reuse fire — the adaptive
ramp exploits exactly this growing headroom).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import GRID, savings_at, trained_mini_vdit
from repro.core.calibrate import fit_step_sensitivity
from repro.data.synthetic import correlated_video_latents
from repro.diffusion.sampler import ddim_sample
from repro.diffusion.schedule import DDPMSchedule
from repro.models.vdit import vdit_apply

D = 32
TOTAL = 20     # sampler steps for the injection study
PROMPTS = 3


def _generate_with_injection(arch, params, inject_step, theta, seed):
    """Generate; apply reuse ONLY at ``inject_step`` (None = dense)."""
    m = arch.model
    g = m.grid(img_res=32)
    key = jax.random.PRNGKey(seed)
    noise = jax.random.normal(
        key, (1, g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch,
              m.in_channels))
    txt = 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                   (1, m.txt_tokens, m.txt_dim))
    sch = DDPMSchedule()
    rip_on = dataclasses.replace(arch.ripple, fixed_threshold=theta,
                                 i_min=0, i_max=TOTAL)
    rip_off = dataclasses.replace(arch.ripple, enabled=False)

    def denoise(x, t, step):
        use = (inject_step is not None) and (step == inject_step)
        # both branches traced; `where` on the scalar picks at runtime —
        # cheap at this size and keeps one jitted callable for all steps
        out_on = vdit_apply(params, x, t, txt, m, ripple=rip_on,
                            step=jnp.asarray(step), total_steps=TOTAL,
                            compute_dtype=jnp.float32)
        out_off = vdit_apply(params, x, t, txt, m, ripple=rip_off,
                             compute_dtype=jnp.float32)
        return jnp.where(use, out_on, out_off).astype(x.dtype)

    if inject_step is None:
        def denoise(x, t, step):  # noqa: F811 — dense-only fast path
            return vdit_apply(params, x, t, txt, m, ripple=rip_off,
                              compute_dtype=jnp.float32).astype(x.dtype)

    return ddim_sample(denoise, noise, sch, TOTAL)


def run():
    arch, params = trained_mini_vdit()
    theta = 0.35
    inject_steps = [2, 5, 8, 11, 14, 17]
    table = np.zeros((PROMPTS, len(inject_steps)))
    for p in range(PROMPTS):
        dense = _generate_with_injection(arch, params, None, theta, seed=p)
        for j, s in enumerate(inject_steps):
            out = _generate_with_injection(arch, params, s, theta, seed=p)
            table[p, j] = float(jnp.mean((out - dense) ** 2))
    mean_mse = table.mean(axis=0)
    fit = fit_step_sensitivity(np.asarray(inject_steps), mean_mse)
    var_step = float(np.var(table.mean(axis=0)))
    var_prompt = float(np.var(table.mean(axis=1)))

    # operand-level mechanism: reuse fires more as noise decays
    sch = DDPMSchedule()
    fire = []
    for s in inject_steps:
        t = int((1 - s / TOTAL) * (sch.num_train_steps - 1))
        key = jax.random.PRNGKey(0)
        x0 = correlated_video_latents(key, 1, GRID, D, temporal_rho=0.95)
        noise = jax.random.normal(jax.random.fold_in(key, 1), x0.shape)
        xt = sch.add_noise(x0, noise, jnp.asarray([t])).reshape(1, 1, -1, D)
        sv, _, _ = savings_at(xt, xt, theta)
        fire.append(sv)

    return {
        "inject_steps": inject_steps,
        "final_mse_per_step": mean_mse.tolist(),
        "slope": fit["slope"],
        "monotone_decay": bool(mean_mse[0] > mean_mse[-1]),
        "step_over_prompt_var": var_step / max(var_prompt, 1e-18),
        "savings_headroom_per_step": [round(f, 3) for f in fire],
    }


def main():
    t0 = time.perf_counter()
    r = run()
    us = (time.perf_counter() - t0) * 1e6
    print(f"fig9_steps,{us:.0f},slope={r['slope']:.4f};"
          f"decaying={r['monotone_decay']};"
          f"mse_step{r['inject_steps'][0]}={r['final_mse_per_step'][0]:.3e};"
          f"mse_step{r['inject_steps'][-1]}={r['final_mse_per_step'][-1]:.3e};"
          f"step_var/prompt_var={r['step_over_prompt_var']:.1f};"
          f"reuse_headroom={r['savings_headroom_per_step']}")
    return r


if __name__ == "__main__":
    main()
