"""Paper Tbl. 4 — uniform vs channel-wise thresholds.

Channel-wise: τ_c = α · mean_c'|Δ_c'| scaled per channel by its own mean
absolute variation (the paper's adaptive formulation).  The paper finds
uniform slightly better because the attention score sums all channels'
partial results; we reproduce the comparison at matched savings.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (GRID, attention_out, correlated_qk,
                               savings_at, theta_for_savings)
from repro.core import reuse, savings as savings_lib

D = 32


def _channelwise_masks(x, alpha, grid):
    """Per-channel τ_c = α · mean|Δ_c| (relative thresholds)."""
    *lead, N, d = x.shape
    xg = x.reshape(*lead, *grid, d)
    masks = []
    snapped = xg
    claimed = jnp.zeros(xg.shape, bool)
    for axis in ("t", "x", "y"):
        dim = {"t": -4, "y": -3, "x": -2}[axis] % xg.ndim
        delta, rep = reuse.window_delta(xg, dim, 2)
        tau = alpha * jnp.mean(jnp.abs(delta), axis=tuple(
            range(delta.ndim - 1)), keepdims=True)
        ok = delta < tau
        mask = reuse._expand_window(ok, dim, 2, xg.shape[dim],
                                    first_is_rep=True)
        rep_full = reuse._expand_window(rep, dim, 2, xg.shape[dim],
                                        first_is_rep=False)
        take = jnp.logical_and(mask, ~claimed)
        snapped = jnp.where(take, rep_full, snapped)
        claimed = jnp.logical_or(claimed, mask)
    return snapped.reshape(*lead, N, d), claimed.reshape(*lead, N, d)


def run():
    q, k = correlated_qk(0)
    v = jax.random.normal(jax.random.PRNGKey(3), q.shape)
    base = attention_out(q, k, v)

    # uniform at 85% savings
    theta = theta_for_savings(q, k, 0.85)
    s_u, rq, rk = savings_at(q, k, theta)
    mse_u = float(jnp.mean((attention_out(rq.snapped, rk.snapped, v)
                            - base) ** 2))

    # channel-wise α calibrated to the same savings
    lo, hi = 0.0, 16.0
    for _ in range(24):
        alpha = 0.5 * (lo + hi)
        qs, qm = _channelwise_masks(q, alpha, GRID)
        ks, km = _channelwise_masks(k, alpha, GRID)
        s_c = float(savings_lib.partial_score_savings(qm, km))
        if s_c < s_u:
            lo = alpha
        else:
            hi = alpha
    mse_c = float(jnp.mean((attention_out(qs, ks, v) - base) ** 2))
    return {"savings": round(s_u, 3), "mse_uniform": mse_u,
            "mse_channelwise": mse_c,
            "uniform_better": bool(mse_u <= mse_c)}


def main():
    t0 = time.perf_counter()
    r = run()
    us = (time.perf_counter() - t0) * 1e6
    print(f"tbl4_channelwise,{us:.0f},savings={r['savings']};"
          f"mse_uniform={r['mse_uniform']:.3e};"
          f"mse_channelwise={r['mse_channelwise']:.3e};"
          f"uniform_better={r['uniform_better']}")
    return r


if __name__ == "__main__":
    main()
