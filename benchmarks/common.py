"""Shared benchmark fixtures: correlated latents, matched-savings
threshold search, timing, and a briefly-trained miniature vDiT."""

from __future__ import annotations

import functools
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RippleConfig
from repro.core import reuse, savings
from repro.data.synthetic import correlated_video_latents

GRID = (8, 8, 8)
N = GRID[0] * GRID[1] * GRID[2]
D = 32


def correlated_qk(seed=0, grid=GRID, d=D, rho=0.95, smooth=2):
    lat = correlated_video_latents(jax.random.PRNGKey(seed), 1, grid, d,
                                   temporal_rho=rho, spatial_smooth=smooth)
    x = lat.reshape(1, 1, -1, d)
    wq = 0.5 * jax.random.normal(jax.random.PRNGKey(seed + 1), (d, d))
    wk = 0.5 * jax.random.normal(jax.random.PRNGKey(seed + 2), (d, d))
    return (jnp.einsum("bhnd,df->bhnf", x, wq),
            jnp.einsum("bhnd,df->bhnf", x, wk))


def savings_at(q, k, theta, grid=GRID, axes=("t", "x", "y"), window=2,
               granularity="channel"):
    th = {a: jnp.asarray(theta, jnp.float32) for a in ("t", "x", "y")}
    rq = reuse.compute_reuse(q, grid, th, axes=axes, window=window,
                             granularity=granularity)
    rk = reuse.compute_reuse(k, grid, th, axes=axes, window=window,
                             granularity=granularity)
    return float(savings.partial_score_savings(rq.mask, rk.mask)), rq, rk


def theta_for_savings(q, k, target, grid=GRID, axes=("t", "x", "y"),
                      window=2, granularity="channel"):
    lo, hi = 0.0, 8.0
    for _ in range(28):
        mid = 0.5 * (lo + hi)
        s, _, _ = savings_at(q, k, mid, grid, axes, window, granularity)
        if s < target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def decision_tensors(d):
    """The tensors of a ReuseDecision an attention backend would read."""
    return tuple(t for t in (d.q, d.k, d.bias, d.block_map)
                 if t is not None)


def decision_harness(pol, q, k, *, grid, cfg, thetas, block_shape=None,
                     want_plan=False):
    """Shared decide-timing harness (DESIGN.md §13), used by both
    ``kernel_bench.decision_amortization`` and ``policy_sweep``'s
    decision_overhead rows so the two report comparable decide times.

    Returns ``(decide, floor, d0)``: ``decide(q, k)`` is a jitted
    decide() reduced to scalar sums of every consumed tensor — XLA
    cannot fold the decision away, while standalone output copies are
    excluded; ``floor()`` runs the same reductions on the precomputed
    decision ``d0`` — the measured consumer floor to subtract so the
    number isolates decision work.  ``block_shape`` must mirror what
    the dispatch plan would pass (sparse-planned map policies tile
    their masks, and that tiling is part of the decide cost)."""
    extra = {}
    if block_shape is not None:
        extra["block_shape"] = block_shape
    if want_plan:
        extra["want_plan"] = True

    @jax.jit
    def decide(q, k):
        return tuple(t.sum() for t in decision_tensors(
            pol.decide(q, k, grid=grid, cfg=cfg, thetas=thetas, **extra)))

    d0 = pol.decide(q, k, grid=grid, cfg=cfg, thetas=thetas, **extra)
    d0_tensors = decision_tensors(d0)

    @jax.jit
    def consume(*ts):
        return tuple(t.sum() for t in ts)

    return decide, (lambda: consume(*d0_tensors)), d0


def attention_out(q, k, v):
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def timed(fn, *args, warmup=2, iters=5) -> float:
    """Median wall time per call in microseconds (CPU; relative only)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


@functools.lru_cache(maxsize=1)
def trained_mini_vdit():
    """A miniature vDiT trained ~30 steps on correlated latents so its
    attention distributions are meaningful (cached per process)."""
    import dataclasses
    from repro.config.base import ShapeSpec
    from repro.configs import get_smoke_config
    from repro.data.synthetic import DataSpec, latent_video_batch
    from repro.launch.workloads import build_workload, model_fns
    from repro.models.params import init_params
    from repro.training import train_loop

    arch = get_smoke_config("vdit-paper")
    shape = ShapeSpec(name="mini", kind="train", img_res=32, batch=4,
                      steps=10)
    arch = dataclasses.replace(
        arch, shapes=(shape,),
        train=dataclasses.replace(arch.train, remat=False,
                                  learning_rate=3e-3, warmup_steps=5))
    wl = build_workload(arch, "mini", mesh=None)
    step = wl.jitted()
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    state = train_loop.train_state_init(params, arch.train)
    m = arch.model
    g = m.grid(img_res=32)
    spec = DataSpec(seed=0)
    for i in range(30):
        b = latent_video_batch(spec, i, 4,
                               (g[0] * m.t_patch, g[1] * m.patch,
                                g[2] * m.patch), m.in_channels,
                               txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)
        state, _ = step(state, b, jax.random.PRNGKey(i))
    return arch, state.params
