"""Train a small (~35M) video DiT for a few hundred steps on the
synthetic correlated-latent pipeline, with checkpointing + auto-resume.

    PYTHONPATH=src python examples/train_vdit.py --steps 300

(Re-run the same command after interrupting it — it resumes from the
newest valid checkpoint.)
"""

import argparse
import dataclasses

import jax

from repro.config.base import ShapeSpec, VDiTConfig
from repro.configs.vdit_paper import make_config
from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_vdit_example")
    args = ap.parse_args()

    # ~35M-param video DiT (depth 6, width 384) — big enough to be a real
    # model, small enough for a CPU example run.
    model = VDiTConfig(frames=16, img_res=64, patch=2, t_patch=1,
                       num_layers=6, d_model=384, num_heads=6,
                       in_channels=8, vae_factor=8, t_vae_factor=4,
                       txt_tokens=16, txt_dim=256, axes_dim=(16, 24, 24))
    base = make_config()
    arch = dataclasses.replace(
        base, name="vdit-example", model=model,
        shapes=(ShapeSpec(name="train_64", kind="train", img_res=64,
                          batch=4, steps=1000),),
        train=dataclasses.replace(base.train, learning_rate=1e-3,
                                  warmup_steps=20, total_steps=args.steps,
                                  remat=False))

    import repro.configs as cfgs
    # register on the fly so the launcher resolves it
    cfgs._MODULES["vdit-example"] = "examples.train_vdit"
    global make_config_example

    def make_config_example():
        return arch

    # call the launcher internals directly (no CLI indirection needed)
    from repro.data import synthetic
    from repro.launch.workloads import build_workload, model_fns
    from repro.models.params import init_params, param_count
    from repro.training import train_loop
    from repro.checkpoint.checkpointer import Checkpointer

    defs = model_fns(arch)
    print(f"model parameters: {param_count(defs)/1e6:.1f}M")
    wl = build_workload(arch, "train_64", mesh=None)
    step = wl.jitted()
    params = init_params(defs, jax.random.PRNGKey(0))
    state = train_loop.train_state_init(params, arch.train)

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    found, restored, extra = ckpt.restore_latest(state)
    start = 0
    if found is not None:
        state, start = restored, found
        print(f"resumed from step {start}")

    m = arch.model
    g = m.grid(img_res=64)
    spec = synthetic.DataSpec(seed=0)

    def batch_fn(spec_, i):
        return synthetic.latent_video_batch(
            spec_, i, 4, (g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch),
            m.in_channels, txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)

    it = synthetic.batch_iterator(batch_fn, spec, start_index=start)
    state, history = train_loop.run_train_loop(
        step, state, it, args.steps, rng=jax.random.PRNGKey(1),
        checkpointer=ckpt, checkpoint_every=50, log_every=20,
        start_step=start)
    ckpt.wait()
    print("loss trajectory:", [round(h["loss"], 4) for h in history])


if __name__ == "__main__":
    main()
