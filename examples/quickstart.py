"""Quickstart: TimeRipple in 60 seconds.

Builds correlated video latents, runs the paper's reuse pipeline on an
attention call, and prints the savings/quality numbers that summarize
the whole idea:

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.config.base import RippleConfig
from repro.core.dispatch import attention_dispatch, dense_attention
from repro.data.synthetic import correlated_video_latents

# 1. A video-shaped token grid: 8 frames of 16x16 latent tokens.
GRID = (8, 16, 16)
D = 64
lat = correlated_video_latents(jax.random.PRNGKey(0), 1, GRID, D,
                               temporal_rho=0.95, spatial_smooth=2)
x = lat.reshape(1, 1, -1, D)          # (batch, heads, tokens, channels)

# 2. Q/K/V as a model would produce them.
wq, wk, wv = (0.4 * jax.random.normal(jax.random.PRNGKey(i), (D, D))
              for i in (1, 2, 3))
q = jnp.einsum("bhnd,df->bhnf", x, wq)
k = jnp.einsum("bhnd,df->bhnf", x, wk)
v = jnp.einsum("bhnd,df->bhnf", x, wv)

# 3. TimeRipple: Eq. 3 similarity checks along (t, x, y), Eq. 4 adaptive
#    threshold for denoising step 25 of 50, partial-score reuse.
cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                   i_min=10, i_max=20)
out, stats = attention_dispatch(q, k, v, grid=GRID, cfg=cfg,
                                step=jnp.asarray(25), total_steps=50,
                                with_stats=True)

# 4. Compare against dense attention — and against masking at the SAME
#    savings ratio (paper Fig. 7: that comparison is the whole point).
dense = dense_attention(q, k, v, 1.0 / jnp.sqrt(D))
rel_err = float(jnp.linalg.norm(out - dense) / jnp.linalg.norm(dense))

from repro.core.reuse import compute_reuse           # noqa: E402
from repro.core.schedule import axis_thresholds      # noqa: E402
th = axis_thresholds(cfg, 25, 50)
rq = compute_reuse(q, GRID, th)
rk = compute_reuse(k, GRID, th)
q_skip = jnp.where(rq.mask, 0.0, q)   # skip-instead-of-reuse baseline
k_skip = jnp.where(rk.mask, 0.0, k)
skip_out = dense_attention(q_skip, k_skip, v, 1.0 / jnp.sqrt(D))
rel_err_skip = float(jnp.linalg.norm(skip_out - dense)
                     / jnp.linalg.norm(dense))

print(f"attention computations skipped (paper accounting): "
      f"{float(stats.savings):.1%}")
print(f"structural (TPU pair-collapse) savings:            "
      f"{float(stats.structural_savings):.1%}")
print(f"Q tokens snapped: {float(stats.q_snap_frac):.1%}   "
      f"K tokens snapped: {float(stats.k_snap_frac):.1%}")
print(f"relative output error — REUSE (this paper):        {rel_err:.2%}")
print(f"relative output error — SKIP at same savings:      "
      f"{rel_err_skip:.2%}  ({rel_err_skip / max(rel_err, 1e-9):.1f}x worse)")
