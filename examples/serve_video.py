"""End-to-end driver (the paper's kind is inference acceleration):
serve a small video-DiT with batched requests, TimeRipple ON vs OFF.

Trains a miniature vDiT briefly on correlated synthetic latents so its
attention is meaningful, then runs the bucketed serving engine both ways
and reports per-request latency, realized reuse savings per denoising
step, and dense-vs-ripple output PSNR.  ``--mesh DxM`` (with enough
devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8) runs
the attention dispatch sharded under shard_map (DESIGN.md §10).

    PYTHONPATH=src python examples/serve_video.py [--steps 20] [--requests 4]

``--deadline-ms`` stamps a per-request SLO (admission control may shed),
``--stream-every K`` streams intermediate latents and reports TTFF, and
``--no-guardrail`` turns off the §17 sentinels + degradation ladder.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ShapeSpec
from repro.configs import get_smoke_config
from repro.core import dispatch as dispatch_lib
from repro.data.synthetic import DataSpec, latent_video_batch
from repro.launch.mesh import parse_mesh_spec
from repro.launch.serve import make_sampler_factory
from repro.launch.workloads import (build_workload, latent_shape_for,
                                    model_fns)
from repro.models.params import init_params
from repro.serving.engine import DiffusionEngine, GenRequest
from repro.serving.slo import ShedError
from repro.training import train_loop


def train_briefly(arch, steps=30):
    wl = build_workload(arch, "mini", mesh=None)
    step = wl.jitted()
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    state = train_loop.train_state_init(params, arch.train)
    m = arch.model
    g = m.grid(img_res=32)
    spec = DataSpec(seed=0)
    for i in range(steps):
        b = latent_video_batch(spec, i, 4,
                               (g[0] * m.t_patch, g[1] * m.patch,
                                g[2] * m.patch), m.in_channels,
                               txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)
        state, metrics = step(state, b, jax.random.PRNGKey(i))
    print(f"trained {steps} steps; final denoising MSE "
          f"{float(metrics['loss']):.4f}")
    return state.params


def psnr(a, b):
    m = float(np.mean((np.asarray(a) - np.asarray(b)) ** 2))
    rng = float(np.asarray(a).max() - np.asarray(a).min())
    return 10 * np.log10(rng ** 2 / max(m, 1e-12))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="(data, model) mesh for sharded attention "
                         "dispatch, e.g. 2x1")
    ap.add_argument("--policy", default="ripple",
                    help="reuse policy for the accelerated pass "
                         "(core.policy registry: ripple, svg, equal_mse, "
                         "dense, or anything registered out-of-tree)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO: stamp deadline_s = now + this "
                         "and report deadline_met / admission sheds")
    ap.add_argument("--stream-every", type=int, default=None, metavar="K",
                    help="stream intermediate latents every K denoising "
                         "steps and report time-to-first-frame")
    ap.add_argument("--no-guardrail", action="store_true",
                    help="disable the runtime quality guardrails "
                         "(DESIGN.md §17): in-graph sentinels plus the "
                         "per-bucket degradation ladder.  On by default")
    args = ap.parse_args()

    if args.mesh:
        dispatch_lib.set_dispatch_mesh(parse_mesh_spec(args.mesh))

    arch = get_smoke_config("vdit-paper")
    shape = ShapeSpec(name="mini", kind="train", img_res=32, batch=4,
                      steps=args.steps)
    arch = dataclasses.replace(
        arch, shapes=(shape,),
        train=dataclasses.replace(arch.train, remat=False,
                                  learning_rate=3e-3, warmup_steps=5))
    params = train_briefly(arch)
    gen_shape = ShapeSpec(name="gen", kind="generate", img_res=32,
                          batch=1, steps=args.steps)
    arch = dataclasses.replace(arch, shapes=(gen_shape,))

    guardrail = not args.no_guardrail
    ladder = None
    if guardrail:
        from repro.core.guardrail import DegradationLadder

        ladder = DegradationLadder()

    results = {}
    # --policy dense must not overwrite the baseline's results slot
    accel = args.policy if args.policy != "dense" else "dense_policy"
    lat_shape = tuple(latent_shape_for(arch, gen_shape))
    for label, ripple in (("dense", False), (accel, True)):
        # Factory mode (not a prebuilt sample_fn): streaming buckets and
        # guardrail degradation both need the engine to compile per
        # (policy, stream_every) bucket identity.
        factory, plan_fn = make_sampler_factory(arch, (gen_shape,), params,
                                                use_ripple=ripple,
                                                sentinel=guardrail)
        engine = DiffusionEngine(sampler_factory=factory, plan_fn=plan_fn,
                                 max_batch=2,
                                 default_policy=args.policy if ripple
                                 else None,
                                 guardrail=ladder)
        engine.start()
        m = arch.model
        t0 = time.time()
        submitted = []
        for i in range(args.requests):
            txt = 0.05 * np.random.default_rng(i).standard_normal(
                (m.txt_tokens, m.txt_dim)).astype(np.float32)
            req = GenRequest(request_id=i, txt=txt, seed=i,
                             steps=args.steps, latent_shape=lat_shape,
                             stream_every=args.stream_every)
            if args.deadline_ms is not None:
                req.deadline_s = time.time() + args.deadline_ms / 1e3
            try:
                engine.submit(req)
                submitted.append(i)
            except ShedError as e:
                print(f"[{label}] request {i} shed at admission: {e}")
        if args.stream_every:
            for i in submitted:
                chunks = sum(1 for _ in engine.stream(i, timeout=600))
                print(f"[{label}] request {i}: {chunks} streamed chunks")
        outs = [engine.result(i, timeout=600) for i in submitted]
        engine.stop()
        wall = time.time() - t0
        results[label] = {i: o for i, o in zip(submitted, outs)}
        extra = ""
        if args.stream_every:
            extra += (f", mean TTFF "
                      f"{np.mean([o.ttff_s for o in outs]):.2f}s")
        if args.deadline_ms is not None:
            met = sum(1 for o in outs if o.deadline_met)
            extra += f", {met}/{len(outs)} deadlines met"
        print(f"[{label}] {len(outs)} requests in {wall:.2f}s "
              f"(mean/request {np.mean([o.walltime_s for o in outs]):.2f}s"
              f"{extra})")

    for i in sorted(set(results["dense"]) & set(results[accel])):
        p = psnr(results["dense"][i].latents, results[accel][i].latents)
        print(f"request {i}: {accel}-vs-dense PSNR {p:.1f} dB")
    print("NOTE: CPU wall time does not reflect TPU speedup; the realized "
          "MXU skip is reported by benchmarks/kernel_bench.py and the "
          "roofline deltas in EXPERIMENTS.md §Perf.")


if __name__ == "__main__":
    main()
