"""Crash-safety tests (DESIGN.md §18): WAL framing + torn-tail
recovery at every byte offset, clean-shutdown-marker semantics under a
frozen clock, checkpoint-store round-trips (including bfloat16 leaves)
and corruption tolerance, decision-state slice/merge inverses, engine
mid-flight resume (bitwise vs the uninterrupted run), and the router's
checkpointed-failover snapshot."""

import json
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade property tests to fixed-example tests
    from _hypothesis_compat import given, settings, st

from repro.core import decision_cache
from repro.serving import journal as journal_lib
from repro.serving.engine import DiffusionEngine, GenRequest
from repro.serving.journal import (CheckpointStore, Journal, recover,
                                   request_from_dict, request_to_dict,
                                   scan_records)
from repro.serving.router import Router


def _txt(val, tokens=2, dim=3):
    return np.full((tokens, dim), float(val), np.float32)


def _req(rid, **kw):
    kw.setdefault("txt", _txt(rid))
    kw.setdefault("latent_shape", (4,))
    return GenRequest(request_id=rid, **kw)


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------


class TestFraming:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(1, 12), rid0=st.integers(0, 999))
    def test_append_scan_round_trip(self, tmp_path, n, rid0):
        """Property: any sequence of lifecycle appends scans back
        intact, in order, with contiguous sequence numbers."""
        d = str(tmp_path / f"j{n}_{rid0}")
        j = Journal(d, fsync="never")
        events = []
        for i in range(n):
            ev = ("submitted", "chunk", "finished", "shed")[i % 4]
            j.append(ev, rid0 + i, i=i)
            events.append((ev, rid0 + i))
        j.close(clean=False)
        records, torn = scan_records(os.path.join(d, "journal.log"))
        assert not torn
        assert [(r["ev"], r["rid"]) for r in records] == events
        assert [r["seq"] for r in records] == list(range(1, n + 1))

    def test_fsync_policies(self, tmp_path):
        for policy in ("always", "interval", "never"):
            d = str(tmp_path / policy)
            j = Journal(d, fsync=policy, fsync_interval=2)
            for i in range(5):
                j.append("chunk", i)
            m = j.metrics()
            j.close(clean=False)
            if policy == "always":
                assert m["journal_fsyncs"] == 5
            elif policy == "interval":
                assert m["journal_fsyncs"] == 2  # after appends 2 and 4
            else:
                assert m["journal_fsyncs"] == 0
        with pytest.raises(ValueError):
            Journal(str(tmp_path / "bad"), fsync="sometimes")

    def test_torn_tail_at_every_byte_offset(self, tmp_path):
        """Truncating anywhere inside the final frame loses exactly
        that record: every prior record survives, torn is flagged."""
        d = str(tmp_path / "torn")
        j = Journal(d, fsync="never")
        for i in range(3):
            j.append("chunk", i, pad="x" * (10 + 7 * i))
        j.close(clean=False)
        path = os.path.join(d, "journal.log")
        with open(path, "rb") as f:
            data = f.read()
        # Frame offsets from the headers themselves.
        offs, off = [], 0
        while off < len(data):
            (length,) = np.frombuffer(data[off:off + 4], np.uint32)
            offs.append(off)
            off += 8 + int(length)
        last = offs[-1]
        for cut in range(last, len(data)):
            with open(path, "wb") as f:
                f.write(data[:cut])
            records, torn = scan_records(path)
            assert len(records) == 2
            assert torn == (cut > last)
        with open(path, "wb") as f:
            f.write(data)
        records, torn = scan_records(path)
        assert len(records) == 3 and not torn

    def test_corrupt_middle_record_stops_scan(self, tmp_path):
        d = str(tmp_path / "mid")
        j = Journal(d, fsync="never")
        for i in range(3):
            j.append("chunk", i)
        j.close(clean=False)
        path = os.path.join(d, "journal.log")
        with open(path, "rb") as f:
            data = bytearray(f.read())
        data[len(data) // 2] ^= 0xFF  # flip a bit mid-file
        with open(path, "wb") as f:
            f.write(bytes(data))
        records, torn = scan_records(path)
        assert torn and len(records) < 3

    def test_reopen_truncates_torn_tail_and_continues_seq(self, tmp_path):
        d = str(tmp_path / "reopen")
        j = Journal(d, fsync="never")
        j.append("submitted", 1)
        j.append("chunk", 1)
        j.close(clean=False)
        path = os.path.join(d, "journal.log")
        with open(path, "ab") as f:
            f.write(b"\x99\x00\x00\x00garbage")  # torn partial frame
        j2 = Journal(d, fsync="never")
        seq = j2.append("finished", 1)
        j2.close(clean=False)
        assert seq == 3
        records, torn = scan_records(path)
        assert not torn
        assert [r["ev"] for r in records] == ["submitted", "chunk",
                                              "finished"]


# ---------------------------------------------------------------------------
# Clean-shutdown marker (frozen clock)
# ---------------------------------------------------------------------------


class TestCleanMarker:
    def test_clean_close_vs_crash(self, tmp_path):
        clock = [1234.5]
        d = str(tmp_path / "clean")
        j = Journal(d, time_fn=lambda: clock[0])
        j.append("submitted", 7)
        j.append("finished", 7)
        j.close(clean=True)
        with open(os.path.join(d, "CLEAN"), encoding="utf-8") as f:
            marker = json.load(f)
        assert marker == {"last_seq": 2, "time": 1234.5}
        assert recover(d).clean

        # Opening removes the marker: a running process is not a clean
        # snapshot.  A crash (no close) must then scan as unclean.
        j2 = Journal(d, time_fn=lambda: clock[0])
        assert not os.path.exists(os.path.join(d, "CLEAN"))
        j2.append("submitted", 8)
        del j2  # crash: no close, no marker
        rec = recover(d)
        assert not rec.clean
        assert list(rec.pending) == [8]

    def test_stale_marker_is_a_crash(self, tmp_path):
        """A marker from an older clean run followed by more journal
        records must not mask the later crash."""
        d = str(tmp_path / "stale")
        j = Journal(d)
        j.append("submitted", 1)
        j.close(clean=True)
        # Re-plant the stale marker after more records land.
        with open(os.path.join(d, "CLEAN"), encoding="utf-8") as f:
            stale = f.read()
        j2 = Journal(d)
        j2.append("submitted", 2)
        j2._f.close()  # simulate crash without close()
        with open(os.path.join(d, "CLEAN"), "w", encoding="utf-8") as f:
            f.write(stale)
        rec = recover(d)
        assert not rec.clean

    def test_empty_directory_is_clean(self, tmp_path):
        rec = recover(str(tmp_path / "nothing"))
        assert rec.clean and not rec.pending and rec.events == 0


# ---------------------------------------------------------------------------
# Recovery fold + request round-trip
# ---------------------------------------------------------------------------


class TestRecovery:
    def test_event_order_fold(self, tmp_path):
        d = str(tmp_path / "fold")
        j = Journal(d)
        reqs = {i: _req(i, steps=4, stream_every=2, policy="ripple",
                        seed=i) for i in range(4)}
        for r in reqs.values():
            j.record_submitted(r)
        j.record_chunk(0, 0, step=2)
        j.record_chunk(1, 0, step=2)
        j.record_chunk(1, 1, step=4)
        j.record_finished(1)
        j.record_finished(2, error="poisoned")
        j.record_shed(3, "deadline passed")
        j.close(clean=False)
        rec = recover(d)
        assert sorted(rec.pending) == [0]
        assert rec.finished == {1: None, 2: "poisoned"}
        assert rec.shed == {3: "deadline passed"}
        assert rec.chunks[0] == {"chunk": 0, "step": 2}
        assert rec.chunks[1] == {"chunk": 1, "step": 4}
        back = request_from_dict(rec.pending[0])
        assert back.request_id == 0 and back.steps == 4
        assert back.stream_every == 2 and back.policy == "ripple"
        np.testing.assert_array_equal(back.txt, reqs[0].txt)

    def test_request_round_trip_excludes_runtime_fields(self):
        r = _req(5, steps=6, seed=9, guidance=2.5, reuse_every=3,
                 deadline_s=123.4, stream_every=2)
        r.resume = {"step": 2, "x": np.zeros(4)}
        r.recovered = True
        d = request_to_dict(r)
        assert "resume" not in json.dumps({k: v for k, v in d.items()
                                           if k != "txt"})
        back = request_from_dict(json.loads(json.dumps(d)))
        assert back.resume is None and not back.recovered
        for field in ("request_id", "steps", "seed", "guidance",
                      "latent_shape", "reuse_every", "deadline_s",
                      "stream_every"):
            assert getattr(back, field) == getattr(r, field), field
        np.testing.assert_array_equal(back.txt, r.txt)


# ---------------------------------------------------------------------------
# Checkpoint store
# ---------------------------------------------------------------------------


class TestCheckpointStore:
    def test_round_trip_with_bfloat16_dstate(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        x = np.arange(24, dtype=np.float32).reshape(2, 3, 4) / 7.0
        dstate = {
            "hits": np.ones((3, 1, 2), np.int32),
            "bias": np.asarray(jnp.full((3, 1, 2, 2), 0.5,
                                        jnp.bfloat16)),
            "block_map": None,
        }
        store.put(3, step=2, x=x, seed=11, bucket=((4,), 4, None),
                  dstate=dstate)
        ck = store.get(3)
        assert ck["step"] == 2 and ck["seed"] == 11
        assert ck["bucket"] == ((4,), 4, None)
        np.testing.assert_array_equal(ck["x"], x)
        assert ck["dstate"]["block_map"] is None
        np.testing.assert_array_equal(ck["dstate"]["hits"],
                                      dstate["hits"])
        assert ck["dstate"]["bias"].dtype == dstate["bias"].dtype
        np.testing.assert_array_equal(
            np.asarray(ck["dstate"]["bias"], np.float32),
            np.asarray(dstate["bias"], np.float32))

    def test_corrupt_checkpoint_degrades_to_none(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        store.put(1, step=1, x=np.zeros(4, np.float32), seed=0)
        path = store._path(1)
        with open(path, "r+b") as f:
            f.seek(-3, os.SEEK_END)
            f.write(b"\xff\xff\xff")
        assert store.get(1) is None  # body CRC mismatch
        with open(path, "wb") as f:
            f.write(b"\x01")
        assert store.get(1) is None  # truncated header
        assert store.get(99) is None  # absent

    def test_bounded_eviction_and_discard(self, tmp_path):
        store = CheckpointStore(str(tmp_path), max_entries=2)
        for rid in range(4):
            store.put(rid, step=1, x=np.zeros(2, np.float32), seed=rid)
        assert store.count() == 2
        assert store.rids() == [2, 3]  # least-recently-written evicted
        assert store.get(0) is None
        assert not os.path.exists(store._path(0))
        store.discard(3)
        store.discard(3)  # idempotent
        assert store.rids() == [2]
        # Overwrite moves a rid to most-recently-written.
        store.put(4, step=1, x=np.zeros(2, np.float32), seed=4)
        store.put(2, step=2, x=np.zeros(2, np.float32), seed=2)
        store.put(5, step=1, x=np.zeros(2, np.float32), seed=5)
        assert store.rids() == [2, 5]

    def test_restart_re_adopts_existing_files(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        for rid in (10, 20):
            store.put(rid, step=3, x=np.full(2, rid, np.float32), seed=0)
        again = CheckpointStore(str(tmp_path), max_entries=8)
        assert sorted(again.rids()) == [10, 20]
        assert again.get(20)["x"][0] == 20.0


# ---------------------------------------------------------------------------
# Decision-state (de)serialization
# ---------------------------------------------------------------------------


class TestDecisionState:
    def _batched_state(self, batch=3):
        # layer-stacked (L, B, ...) leaves, the engine's checkpoint shape
        return decision_cache.CachedDecision(
            hits=jnp.arange(2 * batch, dtype=jnp.int32).reshape(2, batch),
            refreshes=jnp.ones((2, batch), jnp.int32),
            bias=jnp.full((2, batch, 2, 2), 0.25, jnp.bfloat16),
            ref_stat=jnp.zeros((2, batch), jnp.float32))

    def test_slice_merge_inverse(self):
        state = self._batched_state(3)
        parts = [decision_cache.slice_state(state, i) for i in range(3)]
        back = decision_cache.merge_states(parts)
        for name in ("hits", "refreshes", "bias", "ref_stat"):
            np.testing.assert_array_equal(np.asarray(getattr(back, name)),
                                          np.asarray(getattr(state, name)))
        assert back.block_map is None

    def test_arrays_round_trip(self):
        state = self._batched_state(2)
        arrays = decision_cache.state_to_arrays(state)
        assert arrays["block_map"] is None
        back = decision_cache.state_from_arrays(arrays)
        np.testing.assert_array_equal(np.asarray(back.bias),
                                      np.asarray(state.bias))
        with pytest.raises(ValueError):
            decision_cache.state_from_arrays({"not_a_field": None})

    def test_mixed_none_merge_rejected(self):
        a = decision_cache.CachedDecision(hits=jnp.ones((1, 1), jnp.int32))
        b = decision_cache.CachedDecision()
        with pytest.raises(ValueError):
            decision_cache.merge_states([a, b])

    def test_sharded_state_not_sliceable(self):
        state = decision_cache.CachedDecision(
            hits=jnp.ones((1, 2), jnp.int32),
            elided=jnp.zeros((1,), jnp.int32))
        with pytest.raises(ValueError):
            decision_cache.slice_state(state, 0)


# ---------------------------------------------------------------------------
# Engine resume (fake resume-capable streaming sampler)
# ---------------------------------------------------------------------------

STEPS = 4


def _counting_factory(delay_s=0.0):
    """Sampler factory honouring the §18 resume contract: x gains +1
    per step from the checkpointed offset, so any trajectory is
    predictable and resume-vs-monolithic is exactly comparable."""

    def factory(latent_shape, steps, policy=None, reuse_every=None,
                stream_every=None):
        def fn(noise, txt, rngs, resume=None):
            start = 0 if resume is None else int(resume["step"])

            def gen():
                cur = jnp.asarray(noise)
                for s in range(start, steps):
                    if delay_s:
                        time.sleep(delay_s)
                    cur = cur + 1.0
                    yield cur, {"__ckpt__": {"step": s + 1,
                                             "dstate": None}}
            return gen()
        return fn
    return factory


class TestEngineResume:
    def _engine(self, tmp_path, name, delay_s=0.0):
        journal = Journal(str(tmp_path / name))
        store = CheckpointStore(str(tmp_path / name))
        eng = DiffusionEngine(sampler_factory=_counting_factory(delay_s),
                              latent_shape=(4,), max_batch=2,
                              max_wait_s=0.05, journal=journal,
                              checkpoint_store=store)
        return eng, journal, store

    def test_resume_bitwise_equals_uninterrupted(self, tmp_path):
        eng, journal, store = self._engine(tmp_path, "bitwise")
        eng.start()
        eng.submit(_req(0, steps=STEPS, stream_every=1, seed=3))
        chunks = list(eng.stream(0, timeout=30))
        full = eng.result(0, timeout=30)
        assert len(chunks) == STEPS
        # Resume a twin from the step-2 state, as a restart would.
        eng.submit(_req(1, steps=STEPS, stream_every=1, seed=3,
                        resume={"step": 2, "x": chunks[1],
                                "dstate": None}))
        resumed = eng.result(1, timeout=30)
        m = eng.metrics()
        eng.stop()
        journal.close()
        np.testing.assert_array_equal(resumed.latents, full.latents)
        assert m["resumed_count"] == 1
        assert m["last_resume_step"] == 2

    def test_journal_and_checkpoint_lifecycle(self, tmp_path):
        eng, journal, store = self._engine(tmp_path, "lifecycle")
        eng.start()
        eng.submit(_req(0, steps=STEPS, stream_every=1, seed=0))
        eng.result(0, timeout=30)
        eng.stop()
        journal.close(clean=True)
        rec = recover(str(tmp_path / "lifecycle"))
        assert rec.clean and not rec.pending
        assert rec.finished == {0: None}
        assert rec.chunks[0]["step"] == STEPS
        assert store.count() == 0  # discarded at finish
        assert store.metrics()["checkpoint_writes"] == STEPS - 1

    def test_recovered_request_counts(self, tmp_path):
        eng, journal, _ = self._engine(tmp_path, "recovered")
        eng.start()
        req = _req(0, steps=STEPS, stream_every=1)
        req.recovered = True
        eng.submit(req)
        eng.result(0, timeout=30)
        m = eng.metrics()
        eng.stop()
        journal.close()
        assert m["recovered_count"] == 1

    def test_invalid_resume_payload_rejected(self, tmp_path):
        eng, journal, _ = self._engine(tmp_path, "invalid")
        eng.start()
        for resume in ({"step": 1},                       # missing x
                       {"step": -1, "x": np.zeros(4)},    # bad step
                       {"step": STEPS, "x": np.zeros(4)},  # >= steps
                       {"step": 1, "x": np.zeros(4)}):    # off-boundary
            with pytest.raises(ValueError):
                eng.submit(_req(9, steps=STEPS, stream_every=2,
                                resume=resume))
        eng.stop()
        journal.close()

    def test_replay_fallback_without_resume_support(self, tmp_path):
        """A factory sampler without a resume kwarg still serves a
        checkpointed request — by deterministic replay from step 0."""
        def factory(latent_shape, steps, policy=None, reuse_every=None,
                    stream_every=None):
            def fn(noise, txt, rngs):
                return jnp.asarray(noise) + float(steps)
            return fn

        eng = DiffusionEngine(sampler_factory=factory, latent_shape=(4,),
                              max_batch=1, max_wait_s=0.01)
        eng.start()
        x = np.full((4,), 5.0, np.float32)
        eng.submit(_req(0, steps=STEPS, stream_every=2,
                        resume={"step": 2, "x": x, "dstate": None}))
        res = eng.result(0, timeout=30)
        eng.stop()
        assert res.error is None and res.latents.shape[-1] == 4


# ---------------------------------------------------------------------------
# Real vdit sampler: resume is bitwise-equal to the monolithic run
# ---------------------------------------------------------------------------


class TestRealSamplerResume:
    def test_build_sampler_resume_bitwise(self):
        """The §18 claim on the real model: restarting the streaming
        vdit sampler from a chunk-boundary checkpoint ``(x, dstate,
        step)`` reproduces the uninterrupted final latents bitwise —
        the PR 7 chunk-chaining exactness carries over to resume."""
        import dataclasses

        from repro.configs import get_smoke_config
        from repro.launch.serve import build_sampler
        from repro.launch.workloads import model_fns
        from repro.models.params import init_params

        arch = get_smoke_config("vdit-paper")
        sp = dataclasses.replace(
            [s for s in arch.shapes if s.kind == "generate"][0],
            img_res=32, steps=4)
        params = init_params(model_fns(arch), jax.random.PRNGKey(0))
        fn, lshape = build_sampler(arch, sp, params, stream_every=2,
                                   reuse_every=2)
        m = arch.model
        noise = jax.random.normal(jax.random.PRNGKey(3), (1, *lshape))
        txt = 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                       (1, m.txt_tokens, m.txt_dim))
        rngs = jnp.stack([jax.random.PRNGKey(7)])

        chunks = []
        for lat, aux in fn(noise, txt, rngs):
            chunks.append((np.asarray(lat), aux.pop("__ckpt__", None)))
        assert len(chunks) == 2  # 4 steps at K=2
        full = chunks[-1][0]
        mid_lat, mid_ck = chunks[0]
        assert mid_ck is not None and mid_ck["step"] == 2

        resumed = list(fn(jnp.asarray(mid_lat), txt, rngs,
                          resume={"step": 2,
                                  "dstate": mid_ck["dstate"]}))
        assert len(resumed) == 1  # only the remaining chunk
        np.testing.assert_array_equal(np.asarray(resumed[-1][0]), full)


# ---------------------------------------------------------------------------
# Router checkpointed failover
# ---------------------------------------------------------------------------


class TestRouterCheckpointedFailover:
    def test_with_checkpoint_snapshot_rules(self, tmp_path):
        store = CheckpointStore(str(tmp_path))
        eng = DiffusionEngine(sampler_factory=_counting_factory(),
                              latent_shape=(4,))
        router = Router([eng], checkpoint_store=store)
        x = np.zeros(4, np.float32)

        req = _req(1, steps=8, stream_every=2)
        assert router._with_checkpoint(req) is req  # no checkpoint yet
        store.put(1, step=4, x=x, seed=0)
        out = router._with_checkpoint(req)
        assert out is not req and out.resume["step"] == 4

        store.put(2, step=3, x=x, seed=0)   # not a chunk boundary
        assert router._with_checkpoint(
            _req(2, steps=8, stream_every=2)).resume is None
        store.put(3, step=8, x=x, seed=0)   # final step: nothing left
        assert router._with_checkpoint(
            _req(3, steps=8, stream_every=2)).resume is None
        store.put(4, step=2, x=x, seed=0)   # older than current resume
        stale = _req(4, steps=8, stream_every=2,
                     resume={"step": 4, "x": x, "dstate": None})
        assert router._with_checkpoint(stale).resume["step"] == 4
        assert router._with_checkpoint(
            _req(5, steps=8)) .resume is None  # no streaming cadence

    def test_failover_resumes_from_checkpoint(self, tmp_path):
        """Lose the replica serving a checkpointed request to its hang
        watchdog (the §17.4 path that really strands mid-flight work —
        an in-process ``stop`` lets the batch finish): the survivor
        must resume past the checkpoint (not replay from 0), the stream
        must stay one contiguous chunk sequence, and the final latents
        must match the uninterrupted trajectory."""
        store = CheckpointStore(str(tmp_path))
        # Replica 0 checkpoints two chunks (0.2s apart) and then hangs
        # past its 0.5s watchdog budget; replica 1 is instant.
        slow = DiffusionEngine(sampler_factory=_counting_factory(0.2),
                               latent_shape=(4,), max_batch=1,
                               max_wait_s=0.01, checkpoint_store=store,
                               batch_timeout_s=0.5)
        fast = DiffusionEngine(sampler_factory=_counting_factory(),
                               latent_shape=(4,), max_batch=1,
                               max_wait_s=0.01, checkpoint_store=store)
        router = Router([slow, fast], checkpoint_store=store)
        router.start()
        rid = 0
        router.submit(_req(rid, steps=STEPS, stream_every=1, seed=1))
        chunks = [np.asarray(c)
                  for c in router.stream(rid, timeout=30)]
        res = router.result(rid, timeout=30)
        # Uninterrupted twin, same seed, on the healthy replica: the
        # resumed trajectory applies the identical op sequence, so the
        # final latents must match bitwise.
        router.submit(_req(1, steps=STEPS, stream_every=1, seed=1))
        twin = router.result(1, timeout=30)
        m = router.metrics()
        router.stop()
        assert res.error is None
        assert m["router_requeued"] >= 1
        assert m["router_resumed"] >= 1
        assert m["router_resumed_from_step"] >= 1
        np.testing.assert_array_equal(res.latents, twin.latents)
        # Contiguous chunk trajectory across the failover: chunk i is
        # the step-(i+1) state (float32 rounding aside), the last one
        # is the final latents.
        assert len(chunks) == STEPS
        for a, b in zip(chunks, chunks[1:]):
            np.testing.assert_allclose(b - a, np.ones(4, np.float32),
                                       rtol=1e-6)
        np.testing.assert_array_equal(chunks[-1], res.latents)
