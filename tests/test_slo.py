"""SLO serving tests (DESIGN.md §15): admission-control proofs,
deadline-aware bucket choice, EDF vs hottest under overload, and the
multi-replica router's balancing / shed propagation / failover.

The scheduler comparison is the PR's acceptance gate: on a crafted
overload trace EDF must meet *strictly more* deadlines than the legacy
hottest-first drain."""

import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade property tests to fixed-example tests
    from _hypothesis_compat import given, settings, st

from repro.serving.engine import DiffusionEngine, GenRequest
from repro.serving.router import Router
from repro.serving.slo import (ServiceEstimator, ShedError,
                               _batches_needed, admission_decision,
                               choose_bucket)


def _txt(val, tokens=1, dim=1):
    return np.full((tokens, dim), float(val), np.float32)


class TestServiceEstimator:
    def test_unknown_bucket_has_no_estimate(self):
        est = ServiceEstimator()
        assert est.lower_bound("k") is None
        assert est.expected("k") is None

    def test_lower_bound_is_min_expected_is_ewma(self):
        est = ServiceEstimator(alpha=0.5)
        est.observe("k", 2.0)
        est.observe("k", 1.0)
        est.observe("k", 3.0)
        assert est.lower_bound("k") == 1.0
        # EWMA: 2.0 -> 1.5 -> 2.25
        assert est.expected("k") == pytest.approx(2.25)

    def test_buckets_are_independent(self):
        est = ServiceEstimator()
        est.observe("a", 1.0)
        assert est.lower_bound("b") is None

    def test_timeout_hint_scales_with_ewma_above_floor(self):
        """Watchdog budget (§17.4): the caller's floor until the bucket
        has observations, then mult x the EWMA — never below the floor."""
        est = ServiceEstimator()
        assert est.timeout_hint("k", 5.0) == 5.0
        est.observe("k", 2.0)
        assert est.timeout_hint("k", 5.0) == pytest.approx(16.0)
        assert est.timeout_hint("k", 60.0) == 60.0  # floor still wins
        assert est.timeout_hint("k", 5.0, mult=2.0) == pytest.approx(5.0)


class TestAdmissionDecision:
    NOW = 1000.0

    def test_no_deadline_always_admits(self):
        assert admission_decision(None, self.NOW, 50, 1, 10.0) is None

    def test_expired_deadline_sheds_without_estimate(self):
        """The one proof that needs no service-time observation: the
        deadline already passed at submit."""
        reason = admission_decision(self.NOW - 0.5, self.NOW, 0, 8, None)
        assert reason is not None and "passed" in reason

    def test_unknown_bucket_never_sheds_a_live_deadline(self):
        assert admission_decision(self.NOW + 1e-6, self.NOW, 10 ** 6, 1,
                                  None) is None

    @settings(max_examples=200, deadline=None)
    @given(budget=st.floats(1e-3, 10.0), queued=st.integers(0, 64),
           mb=st.integers(1, 8), lb=st.floats(1e-4, 5.0))
    def test_shed_iff_provably_infeasible(self, budget, queued, mb, lb):
        """Oracle property: with a known lower bound, shed exactly when
        even the fastest-ever batch cadence cannot drain the FIFO ahead
        plus the request itself inside the budget."""
        need = _batches_needed(queued, mb) * lb
        reason = admission_decision(self.NOW + budget, self.NOW, queued,
                                    mb, lb)
        if need > budget:
            assert reason is not None
        else:
            assert reason is None

    @settings(max_examples=100, deadline=None)
    @given(budget=st.floats(1e-3, 10.0), queued=st.integers(0, 64),
           mb=st.integers(1, 8))
    def test_feasible_never_shed_without_proof(self, budget, queued, mb):
        """A live deadline with no observation is always admitted — the
        engine never sheds on a guess."""
        assert admission_decision(self.NOW + budget, self.NOW, queued,
                                  mb, None) is None


class TestChooseBucket:
    NOW = 1000.0

    def test_empty_heads(self):
        assert choose_bucket({}, self.NOW) is None

    def test_aging_beats_deadlines(self):
        """A head older than starve_after_s wins even against a tighter
        deadline elsewhere — the pre-SLO starvation guard survives."""
        heads = {"old": (self.NOW - 5.0, self.NOW + 100.0, 1),
                 "tight": (self.NOW - 0.1, self.NOW + 0.2, 9)}
        assert choose_bucket(heads, self.NOW, starve_after_s=2.0) == "old"

    def test_edf_picks_earliest_deadline(self):
        heads = {"late": (self.NOW, self.NOW + 9.0, 9),
                 "soon": (self.NOW, self.NOW + 1.0, 1)}
        assert choose_bucket(heads, self.NOW) == "soon"

    def test_edf_prefers_feasible_over_earlier_infeasible(self):
        """An earlier-but-already-doomed deadline must not pre-empt a
        feasible one; serving the doomed head first would miss both."""
        est = ServiceEstimator()
        est.observe("doomed", 5.0)   # expected 5s >> its 1s budget
        est.observe("savable", 0.1)
        heads = {"doomed": (self.NOW, self.NOW + 1.0, 1),
                 "savable": (self.NOW, self.NOW + 2.0, 1)}
        assert choose_bucket(heads, self.NOW, estimator=est) == "savable"

    def test_edf_all_infeasible_earliest_goes_first(self):
        est = ServiceEstimator()
        est.observe("a", 50.0)
        est.observe("b", 50.0)
        heads = {"a": (self.NOW, self.NOW + 2.0, 1),
                 "b": (self.NOW, self.NOW + 1.0, 1)}
        assert choose_bucket(heads, self.NOW, estimator=est) == "b"

    def test_deadline_less_traffic_drains_deepest(self):
        heads = {"shallow": (self.NOW, None, 1),
                 "deep": (self.NOW, None, 7)}
        assert choose_bucket(heads, self.NOW) == "deep"

    def test_hottest_scheduler_ignores_deadlines(self):
        heads = {"tight": (self.NOW, self.NOW + 0.1, 1),
                 "deep": (self.NOW, None, 7)}
        assert choose_bucket(heads, self.NOW,
                             scheduler="hottest") == "deep"


class TestAdmissionInEngine:
    def test_expired_deadline_shed_before_any_compute(self):
        calls = []

        def sample_fn(noise, txt, rngs):
            calls.append(1)
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01)
        eng.start()
        with pytest.raises(ShedError):
            eng.submit(GenRequest(request_id=0, txt=_txt(0),
                                  deadline_s=time.time() - 1.0))
        time.sleep(0.05)  # had it been queued, the batcher would serve it
        eng.stop()
        assert calls == []  # shed at the door: zero sampler invocations
        assert eng.metrics()["shed_count"] == 1
        with pytest.raises(TimeoutError):  # and no result record exists
            eng.result(0, timeout=0.01)

    def test_provably_infeasible_shed_via_lower_bound(self):
        def sample_fn(noise, txt, rngs):
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01)
        req = GenRequest(request_id=0, txt=_txt(0),
                         deadline_s=time.time() + 0.5)
        # fastest-ever batch for this bucket takes 10s: a 0.5s budget is
        # provably unmeetable even with an empty queue
        eng.estimator.observe(eng._bucket_key(req), 10.0)
        eng.start()
        with pytest.raises(ShedError):
            eng.submit(req)
        eng.stop()

    def test_feasible_request_admitted_and_served(self):
        def sample_fn(noise, txt, rngs):
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01)
        req = GenRequest(request_id=0, txt=_txt(0),
                         deadline_s=time.time() + 30.0)
        eng.estimator.observe(eng._bucket_key(req), 0.001)
        eng.start()
        eng.submit(req)
        r = eng.result(0, timeout=30)
        eng.stop()
        assert r.deadline_met is True
        assert eng.metrics()["deadlines_met"] == 1

    def test_admission_control_off_never_sheds(self):
        def sample_fn(noise, txt, rngs):
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01, admission_control=False)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0),
                              deadline_s=time.time() - 1.0))
        r = eng.result(0, timeout=30)
        eng.stop()
        assert r.deadline_met is False  # served, late, counted as missed
        assert eng.metrics()["shed_count"] == 0


class TestEDFBeatsHottest:
    """Acceptance gate: on an overload trace with one tight-SLO request
    stuck behind a deep relaxed-SLO bucket, EDF meets strictly more
    deadlines than the legacy hottest-first drain."""

    SERVICE_S = 0.15

    def _run(self, scheduler):
        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                time.sleep(self.SERVICE_S)
                return noise
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=1,
                              max_wait_s=0.0, scheduler=scheduler,
                              starve_after_s=60.0)
        now = time.time()
        # deep hot bucket, relaxed SLOs — feasible under either policy
        for i in range(4):
            eng.submit(GenRequest(request_id=i, txt=_txt(i), steps=2,
                                  latent_shape=(4, 4),
                                  deadline_s=now + 30.0))
        # one tight-SLO request in a shallow bucket: its budget covers
        # ~2 batches, not the 5 it waits behind under hottest-first
        eng.submit(GenRequest(request_id=99, txt=_txt(99), steps=2,
                              latent_shape=(2, 2),
                              deadline_s=now + 2.5 * self.SERVICE_S))
        eng.start()  # backlog drains under the scheduler's order
        for rid in (0, 1, 2, 3, 99):
            eng.result(rid, timeout=60)
        m = eng.metrics()
        eng.stop()
        return m

    def test_edf_meets_strictly_more_deadlines(self):
        hot = self._run("hottest")
        edf = self._run("edf")
        # hottest drains the deep bucket first: the tight request misses
        assert hot["deadlines_missed"] >= 1
        # EDF serves the earliest deadline first: everything lands
        assert edf["deadlines_missed"] == 0
        assert edf["deadlines_met"] > hot["deadlines_met"]


class TestRouter:
    @staticmethod
    def _replica(service_s=0.0, max_batch=1):
        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                if service_s:
                    time.sleep(service_s)
                return noise
            return fn

        return DiffusionEngine(sampler_factory=factory,
                               max_batch=max_batch, max_wait_s=0.0)

    def test_needs_a_replica(self):
        with pytest.raises(ValueError):
            Router([])

    def test_balances_across_replicas_by_depth(self):
        router = Router([self._replica(service_s=0.1) for _ in range(2)])
        router.start()
        placed = [router.submit(GenRequest(request_id=i, txt=_txt(i),
                                           latent_shape=(2,)))
                  for i in range(4)]
        for i in range(4):
            router.result(i, timeout=30)
        router.stop()
        # the in-flight ledger spreads a burst over both replicas
        assert set(placed) == {0, 1}

    def test_fleet_wide_shed_only_when_all_refuse(self):
        router = Router([self._replica() for _ in range(2)])
        router.start()
        with pytest.raises(ShedError):
            router.submit(GenRequest(request_id=0, txt=_txt(0),
                                     latent_shape=(2,),
                                     deadline_s=time.time() - 1.0))
        router.stop()
        m = router.metrics()
        assert m["router_shed_count"] == 1
        # both replicas were tried before the fleet-wide shed
        assert m["replica0_shed_count"] + m["replica1_shed_count"] == 2

    def test_failover_requeues_unserved_requests(self):
        """Two replicas, kill one mid-trace: every request still
        resolves (replay on the survivor), at least one was requeued,
        and the dead replica leaves the rotation."""
        router = Router([self._replica(service_s=0.1) for _ in range(2)])
        router.start()
        for i in range(8):
            router.submit(GenRequest(request_id=i, txt=_txt(i),
                                     latent_shape=(2,), seed=i))
        time.sleep(0.05)  # let replica 0 start chewing its share
        router.fail_replica(0)
        results = {i: router.result(i, timeout=60) for i in range(8)}
        assert router.healthy_replicas() == [1]
        m = router.metrics()
        router.stop()
        assert all(r.latents.shape == (2,) for r in results.values())
        assert m["router_requeued"] >= 1

    def test_result_follows_failover_when_waiting(self):
        """A result() call already blocked on the dying replica follows
        the request to the survivor instead of surfacing the dead
        engine's error."""
        router = Router([self._replica(service_s=0.2) for _ in range(2)])
        router.start()
        placed = [router.submit(GenRequest(request_id=i, txt=_txt(i),
                                           latent_shape=(2,)))
                  for i in range(4)]
        victim = placed[-1]
        got = {}

        def waiter():
            got["res"] = router.result(3, timeout=60)

        t = threading.Thread(target=waiter)
        t.start()
        time.sleep(0.05)
        router.fail_replica(victim)
        t.join(timeout=60)
        for i in range(3):
            router.result(i, timeout=60)
        router.stop()
        assert not t.is_alive()
        assert got["res"].latents.shape == (2,)

    def test_stream_passes_through_router(self):
        def factory(latent_shape, steps, policy=None, reuse_every=None,
                    stream_every=None):
            if stream_every is None:
                return lambda noise, txt, rngs: noise

            def gen_fn(noise, txt, rngs):
                for k in range(2):
                    yield noise + k, None

            return gen_fn

        router = Router([DiffusionEngine(sampler_factory=factory,
                                         latent_shape=(2,), max_batch=1,
                                         max_wait_s=0.0)])
        router.start()
        router.submit(GenRequest(request_id=0, txt=_txt(0),
                                 stream_every=1))
        chunks = list(router.stream(0, timeout=30))
        r = router.result(0, timeout=30)
        router.stop()
        assert len(chunks) == 2
        np.testing.assert_allclose(chunks[-1], r.latents)

    def test_stream_follows_failover(self):
        """REVIEW regression: a stream used to bind to the submit-time
        replica forever, so a consumer blocked on the dying replica
        never saw the chunks the survivor produced.  The consumer must
        follow the request and still receive every chunk."""
        def factory(latent_shape, steps, policy=None, reuse_every=None,
                    stream_every=None):
            if stream_every is None:
                def fn(noise, txt, rngs):
                    time.sleep(0.25)
                    return noise

                return fn

            def gen_fn(noise, txt, rngs):
                for k in range(1, 4):
                    time.sleep(0.02)
                    yield noise + k, None

            return gen_fn

        router = Router([DiffusionEngine(sampler_factory=factory,
                                         latent_shape=(2,), max_batch=1,
                                         max_wait_s=0.0)
                         for _ in range(2)])
        router.start()
        # one slow blocker per replica so the streaming request sits
        # *queued* on its replica when that replica dies
        router.submit(GenRequest(request_id=0, txt=_txt(0)))
        router.submit(GenRequest(request_id=1, txt=_txt(1)))
        victim = router.submit(GenRequest(request_id=2, txt=_txt(2),
                                          stream_every=1))
        got = []

        def consume():
            for c in router.stream(2, timeout=30):
                got.append(c)

        t = threading.Thread(target=consume)
        t.start()
        time.sleep(0.05)  # consumer is now blocked on the victim
        router.fail_replica(victim)
        t.join(timeout=60)
        res = router.result(2, timeout=60)
        for rid in (0, 1):
            router.result(rid, timeout=60)
        router.stop()
        assert not t.is_alive()
        assert len(got) == 3
        np.testing.assert_allclose(got[-1], res.latents)

    def test_forget_releases_ledger_after_timeout(self):
        """REVIEW: a caller that gives up on a result() timeout keeps
        its ledger entry (so a retry still works) and must release it
        with forget() — otherwise the in-flight count stays inflated
        and skews least-loaded routing."""
        router = Router([self._replica(service_s=0.5)])
        router.start()
        idx = router.submit(GenRequest(request_id=0, txt=_txt(0),
                                       latent_shape=(2,)))
        with pytest.raises(TimeoutError):
            router.result(0, timeout=0.01)
        # the entry survives the timeout: result() is retryable
        assert router.depths()[idx] >= 1
        router.forget(0)
        router.forget(0)  # idempotent
        assert router.depths()[idx] == 0
        with pytest.raises(KeyError):
            router.result(0, timeout=1)
        router.stop()

    def test_stop_claims_each_replica_exactly_once(self):
        """REVIEW: stop() used to read _healthy outside the lock, so a
        concurrent fail_replica could stop the same engine twice (or a
        just-downed replica got stopped again with drain=True).  Both
        paths now claim the replica under the lock first."""
        router = Router([self._replica() for _ in range(2)])
        router.start()
        stops = []
        for i, eng in enumerate(router._replicas):
            orig = eng.stop

            def spy(drain=True, _i=i, _orig=orig):
                stops.append((_i, drain))
                _orig(drain=drain)

            eng.stop = spy
        router.stop()
        router.fail_replica(0)  # already claimed: must not stop again
        router.stop()           # idempotent
        assert stops == [(0, True), (1, True)]

    def test_restart_restores_replica_health(self):
        router = Router([self._replica()])
        router.start()
        router.stop()
        assert router.healthy_replicas() == []
        router.start()
        assert router.healthy_replicas() == [0]
        router.submit(GenRequest(request_id=0, txt=_txt(0),
                                 latent_shape=(2,)))
        assert router.result(0, timeout=30).latents.shape == (2,)
        router.stop()


class TestRouterHealthProbes:
    @staticmethod
    def _replica():
        def factory(latent_shape, steps):
            return lambda noise, txt, rngs: noise

        return DiffusionEngine(sampler_factory=factory, max_batch=1,
                               max_wait_s=0.0)

    def test_probe_health_readmits_restarted_replica(self):
        """§17: a downed replica whose engine is healthy again (ops
        restarted it) rejoins the rotation on the next health probe —
        and only then; a still-dead engine stays out."""
        router = Router([self._replica() for _ in range(2)])
        router.start()
        router.fail_replica(0)
        assert router.healthy_replicas() == [1]
        assert router.probe_health() == []  # engine still stopped
        router._replicas[0].start()         # the restart
        assert router.probe_health() == [0]
        assert router.healthy_replicas() == [0, 1]
        assert router.metrics()["router_readmitted"] == 1
        # traffic spreads over the re-admitted replica again
        placed = [router.submit(GenRequest(request_id=i, txt=_txt(i),
                                           latent_shape=(2,)))
                  for i in range(4)]
        for i in range(4):
            router.result(i, timeout=30)
        router.stop()
        assert 0 in placed

    def test_probe_thread_readmits_on_interval(self):
        router = Router([self._replica() for _ in range(2)],
                        probe_interval_s=0.05)
        router.start()
        router.fail_replica(0)
        router._replicas[0].start()
        deadline = time.time() + 5.0
        while (router.healthy_replicas() != [0, 1]
               and time.time() < deadline):
            time.sleep(0.02)
        healthy = router.healthy_replicas()
        router.stop()
        assert healthy == [0, 1]  # the background probe re-admitted it


class TestGuardrailFailover:
    def test_degraded_state_survives_replica_failover(self):
        """§17.2: router replicas share one DegradationLadder, so a
        bucket family degraded on the dying replica is served at its
        degraded rung by the survivor — no second trip, no second NaN
        batch shipped while the survivor rediscovers the bug."""
        import jax.numpy as jnp

        from repro.core.guardrail import DegradationLadder

        ladder = DegradationLadder()

        def factory(latent_shape, steps, policy=None):
            def fn(noise, txt, rngs):
                if policy != "dense":
                    return jnp.full_like(noise, jnp.nan)
                return jnp.zeros_like(noise)
            return fn

        def replica():
            return DiffusionEngine(sampler_factory=factory, max_batch=1,
                                   max_wait_s=0.0, guardrail=ladder)

        router = Router([replica() for _ in range(2)])
        router.start()
        victim = router.submit(GenRequest(request_id=0, txt=_txt(0),
                                          latent_shape=(2,), steps=2))
        r0 = router.result(0, timeout=30)
        assert r0.degraded and np.all(np.isfinite(r0.latents))
        assert ladder.metrics()["degraded_count"] == 1
        router.fail_replica(victim)
        router.submit(GenRequest(request_id=1, txt=_txt(1),
                                 latent_shape=(2,), steps=2))
        r1 = router.result(1, timeout=30)
        router.stop()
        assert r1.degraded and np.all(np.isfinite(r1.latents))
        # the survivor served straight from the shared degraded rung:
        # no new trip was charged
        assert ladder.metrics()["degraded_count"] == 1
