import os

# Multi-device test tier (DESIGN.md §10): when REPRO_MULTIDEVICE is set
# (CI's second job exports it), force 8 virtual CPU devices.  This must
# happen before jax initializes its backend, hence the early env guard
# here rather than a late fixture; tests that need a *guaranteed*
# multi-device backend regardless of the parent process use subprocesses
# (tests/test_sharded_dispatch.py, tests/test_distributed.py).
if os.environ.get("REPRO_MULTIDEVICE", "") not in ("", "0"):
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

# Tests otherwise run single-device (the dry-run sets its own 512-device
# override in a separate process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)

# The multi-device tier must fail loudly, not silently skip: if the
# forced-device-count guard above did not engage (an XLA_FLAGS collision
# already pinned a smaller count, or the flag was ignored), every
# require_devices() test would skip and CI's tier1-multidevice job would
# go green while testing nothing.
if os.environ.get("REPRO_MULTIDEVICE", "") not in ("", "0") \
        and len(jax.devices()) < 8:
    raise RuntimeError(
        f"REPRO_MULTIDEVICE is set but jax sees only "
        f"{len(jax.devices())} device(s) — the 8-virtual-device guard "
        f"did not engage (XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r})")


@pytest.fixture
def multidevice_env():
    """Environment for subprocess tests that need the forced 8-virtual-
    device CPU backend (jax locks the device count at init, so a fresh
    process is the only reliable way from a single-device parent)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = "src"
    return env


def require_devices(n: int):
    """Skip unless the current backend exposes >= n devices (run the
    suite with REPRO_MULTIDEVICE=1 to force 8 virtual CPU devices)."""
    have = len(jax.devices())
    if have < n:
        pytest.skip(f"needs {n} devices, have {have} "
                    f"(set REPRO_MULTIDEVICE=1)")
