import os

# Tests run single-device (the dry-run sets its own 512-device override
# in a separate process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
