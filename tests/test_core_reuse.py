"""Unit + property tests for the TimeRipple core (paper §3.3 semantics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade property tests to fixed-example tests
    from _hypothesis_compat import given, settings, st

from repro.config.base import RippleConfig
from repro.core import reuse, savings
from repro.core.collapse import (collapsed_attention, pair_flags,
                                 pair_major_order)
from repro.core.dispatch import attention_dispatch, dense_attention
from repro.core.schedule import axis_thresholds, threshold_for_step

GRID = (4, 4, 6)
N = GRID[0] * GRID[1] * GRID[2]
D = 16


def _qk(seed=0, shape=(2, 3, N, D)):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def thetas(v):
    return {a: jnp.asarray(v, jnp.float32) for a in ("t", "x", "y")}


class TestEq3Delta:
    def test_window2_matches_halved_absdiff(self):
        x = _qk(1)
        delta, rep = reuse.window_delta(x.reshape(2, 3, *GRID, D), -4, 2)
        xg = np.asarray(x).reshape(2, 3, *GRID, D)
        expect = np.abs(xg[..., 1::2, :, :, :] - xg[..., 0::2, :, :, :]) / 2
        np.testing.assert_allclose(np.asarray(delta), expect, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(rep), xg[..., 0::2, :, :, :])

    def test_window4_population_std(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (8, 5))
        delta, rep = reuse.window_delta(x, 0, 4)
        xg = np.asarray(x).reshape(2, 4, 5)
        np.testing.assert_allclose(np.asarray(delta), xg.std(axis=1),
                                   rtol=1e-5)

    def test_remainder_excluded(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (7, 5))
        delta, rep = reuse.window_delta(x, 0, 2)
        assert delta.shape == (3, 5)  # 7 // 2 windows


class TestReuseMasks:
    def test_zero_threshold_never_snaps(self):
        r = reuse.compute_reuse(_qk(), GRID, thetas(0.0))
        assert not bool(r.mask.any())
        np.testing.assert_array_equal(np.asarray(r.snapped),
                                      np.asarray(_qk()))

    def test_infinite_threshold_snaps_all_followers(self):
        r = reuse.compute_reuse(_qk(), GRID, thetas(1e9))
        # OR over 3 axes with window 2: follower fraction 1 - (1/2)^3
        assert abs(float(r.mask.mean()) - (1 - 0.5 ** 3)) < 1e-6

    def test_representative_never_snapped(self):
        r = reuse.compute_reuse(_qk(), GRID, thetas(1e9), axes=("x",))
        m = np.asarray(r.mask).reshape(2, 3, *GRID, D)
        assert not m[..., 0::2, :].any()
        assert m[..., 1::2, :].all()

    def test_snapped_values_equal_representative(self):
        r = reuse.compute_reuse(_qk(5), GRID, thetas(0.7))
        x = np.asarray(_qk(5)).reshape(2, 3, *GRID, D)
        s = np.asarray(r.snapped).reshape(2, 3, *GRID, D)
        m = np.asarray(r.mask).reshape(2, 3, *GRID, D)
        # wherever not snapped, value unchanged
        np.testing.assert_array_equal(s[~m], x[~m])
        # x-axis followers snapped by the x test copy their x-neighbor
        rx = reuse.compute_reuse(_qk(5), GRID, thetas(0.7), axes=("x",))
        sx = np.asarray(rx.snapped).reshape(2, 3, *GRID, D)
        mx = np.asarray(rx.mask).reshape(2, 3, *GRID, D)
        rep = np.repeat(x[..., 0::2, :], 2, axis=-2)
        np.testing.assert_array_equal(sx[mx], rep[mx])

    @settings(max_examples=20, deadline=None)
    @given(lo=st.floats(0.0, 0.5), hi=st.floats(0.5, 2.0))
    def test_mask_monotone_in_threshold(self, lo, hi):
        x = _qk(7, (1, 1, N, D))
        m_lo = reuse.compute_reuse(x, GRID, thetas(lo)).mask
        m_hi = reuse.compute_reuse(x, GRID, thetas(hi)).mask
        assert bool(jnp.all(jnp.logical_or(~m_lo, m_hi)))  # lo ⊆ hi

    def test_token_granularity_gates_whole_tokens(self):
        r = reuse.compute_reuse(_qk(9), GRID, thetas(0.8),
                                granularity="token")
        m = np.asarray(r.mask)
        per_tok = m.all(axis=-1) | (~m.any(axis=-1))
        assert per_tok.all()

    def test_grid_mismatch_raises(self):
        with pytest.raises(ValueError):
            reuse.compute_reuse(_qk(), (3, 3, 3), thetas(1.0))


class TestSavings:
    def test_matches_bruteforce(self):
        rng = np.random.default_rng(0)
        qm = rng.random((1, 1, 12, 5)) < 0.4
        km = rng.random((1, 1, 12, 5)) < 0.2
        got = float(savings.partial_score_savings(jnp.asarray(qm),
                                                  jnp.asarray(km)))
        # brute force: product (i,j,c) computed iff neither snapped
        computed = 0
        for c in range(5):
            fq = qm[0, 0, :, c].mean()
            fk = km[0, 0, :, c].mean()
            computed += (1 - fq) * (1 - fk)
        expect = 1 - computed / 5
        assert abs(got - expect) < 1e-6

    def test_theoretical_speedup_formula(self):
        s = savings.theoretical_speedup(0.78, jnp.asarray(0.85))
        assert abs(float(s) - 1 / (1 - 0.78 * 0.85)) < 1e-6


class TestSchedule:
    CFG = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                       i_min=10, i_max=20)

    def test_dense_before_imin_and_last_step(self):
        assert float(threshold_for_step(self.CFG, 0, 50)) == 0.0
        assert float(threshold_for_step(self.CFG, 9, 50)) == 0.0
        assert float(threshold_for_step(self.CFG, 49, 50)) == 0.0

    def test_linear_ramp_and_plateau(self):
        t10 = float(threshold_for_step(self.CFG, 10, 50))
        t15 = float(threshold_for_step(self.CFG, 15, 50))
        t20 = float(threshold_for_step(self.CFG, 20, 50))
        t40 = float(threshold_for_step(self.CFG, 40, 50))
        assert abs(t10 - 0.2) < 1e-6
        assert abs(t15 - 0.35) < 1e-6
        assert abs(t20 - 0.5) < 1e-6
        assert abs(t40 - 0.5) < 1e-6  # plateau at theta_max

    def test_axis_override(self):
        cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                           i_min=0, i_max=10, theta_t=0.9)
        th = axis_thresholds(cfg, 5, 50)
        assert abs(float(th["t"]) - 0.9) < 1e-6
        assert float(th["x"]) == float(th["y"])

    def test_fixed_threshold_mode(self):
        cfg = RippleConfig(enabled=True, fixed_threshold=0.33, i_min=0,
                           i_max=10)
        assert abs(float(threshold_for_step(cfg, 5, 50)) - 0.33) < 1e-6


class TestCollapse:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), frac=st.floats(0.0, 1.0))
    def test_collapse_equals_dense_snapped(self, seed, frac):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (1, 2, 32, 8))
        e, o = x[..., 0::2, :], x[..., 1::2, :]
        coll = jax.random.uniform(jax.random.fold_in(key, 1),
                                  (1, 2, 16, 1)) < frac
        o = jnp.where(coll, e, o)
        snapped = jnp.stack([e, o], axis=3).reshape(1, 2, 32, 8)
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 32, 8))
        scale = 1 / np.sqrt(8)
        dense = dense_attention(snapped, snapped, v, scale)
        col = collapsed_attention(snapped, snapped, v, scale=scale)
        np.testing.assert_allclose(np.asarray(col), np.asarray(dense),
                                   atol=2e-5)

    def test_pair_flags_value_equality(self):
        x = jnp.asarray([[1., 2.], [1., 2.], [3., 4.], [5., 6.]])[None]
        f = pair_flags(x)
        np.testing.assert_array_equal(np.asarray(f[0]), [True, False])

    def test_pair_major_order_permutation_and_adjacency(self):
        for axis in ("t", "x", "y"):
            perm = pair_major_order(GRID, axis)
            assert sorted(perm.tolist()) == list(range(N))
        # after t-pair-major reorder, positions 2j and 2j+1 are t-partners
        perm = pair_major_order(GRID, "t")
        T, H, W = GRID
        coords = np.unravel_index(perm, GRID)
        t, y, x = coords
        assert ((t[0::2] + 1 == t[1::2]) & (y[0::2] == y[1::2])
                & (x[0::2] == x[1::2])).all()


class TestRippleAttention:
    CFG = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                       i_min=2, i_max=6)

    def test_dense_when_disabled(self):
        q, k, v = _qk(1), _qk(2), _qk(3)
        out = attention_dispatch(q, k, v, grid=GRID, cfg=RippleConfig())
        ref = dense_attention(q, k, v, 1 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)

    def test_dense_at_early_steps(self):
        q, k, v = _qk(1), _qk(2), _qk(3)
        out = attention_dispatch(q, k, v, grid=GRID, cfg=self.CFG,
                               step=jnp.asarray(0), total_steps=10)
        ref = dense_attention(q, k, v, 1 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)

    def test_collapse_execution_matches_reference(self):
        import dataclasses
        q, k, v = _qk(1), _qk(2), _qk(3)
        cfg_ref = dataclasses.replace(self.CFG, execution="reference")
        cfg_col = dataclasses.replace(self.CFG, execution="collapse")
        o1 = attention_dispatch(q, k, v, grid=GRID, cfg=cfg_ref,
                              step=jnp.asarray(5), total_steps=10)
        o2 = attention_dispatch(q, k, v, grid=GRID, cfg=cfg_col,
                              step=jnp.asarray(5), total_steps=10)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5)

    def test_grid_slice_protects_text_tokens(self):
        L = 8
        q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, L + N, D))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, L + N, D))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, L + N, D))
        out, stats = attention_dispatch(
            q, k, v, grid=GRID, cfg=self.CFG, step=jnp.asarray(5),
            total_steps=10, grid_slice=(L, N), with_stats=True)
        assert out.shape == q.shape
        assert float(stats.savings) > 0

    def test_stats_savings_match_calibration(self):
        q, k, v = _qk(1), _qk(2), _qk(3)
        _, stats = attention_dispatch(q, k, v, grid=GRID, cfg=self.CFG,
                                    step=jnp.asarray(6), total_steps=10,
                                    with_stats=True)
        assert 0.0 < float(stats.savings) < 1.0
