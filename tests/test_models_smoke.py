"""Per-architecture smoke tests: instantiate the REDUCED same-family
config, run one forward / train step on CPU, assert output shapes and
no NaNs.  One test per assigned arch (+ the paper's vDiT)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ShapeSpec
from repro.configs import ALL_ARCHS, get_config, get_smoke_config
from repro.launch.workloads import build_workload, model_fns
from repro.models.params import init_params
from repro.training import train_loop

SMOKE_SHAPES = {
    "lm": ShapeSpec(name="smoke", kind="train", seq_len=32, global_batch=2),
    "dit": ShapeSpec(name="smoke", kind="train", img_res=32, batch=2,
                     steps=10),
    "mmdit": ShapeSpec(name="smoke", kind="train", img_res=64, batch=2,
                       steps=10),
    "unet": ShapeSpec(name="smoke", kind="train", img_res=64, batch=2,
                      steps=10),
    "vdit": ShapeSpec(name="smoke", kind="train", img_res=32, batch=2,
                      steps=10),
    "vit": ShapeSpec(name="smoke", kind="train", img_res=32, batch=2),
    "effnet": ShapeSpec(name="smoke", kind="train", img_res=64, batch=2),
}


def _smoke_batch(arch, shape):
    m = arch.model
    rng = np.random.default_rng(0)
    if arch.family == "lm":
        toks = rng.integers(0, m.vocab_size,
                            (shape.global_batch, shape.seq_len))
        return {"tokens": jnp.asarray(toks, jnp.int32),
                "targets": jnp.asarray(toks, jnp.int32)}
    if arch.family == "dit":
        lr = m.latent_res(shape.img_res)
        return {"latents": jnp.asarray(
            rng.standard_normal((shape.batch, lr, lr, m.in_channels)),
            jnp.float32),
            "labels": jnp.zeros((shape.batch,), jnp.int32)}
    if arch.family == "mmdit":
        lr = shape.img_res // 8
        return {"latents": jnp.asarray(
            rng.standard_normal((shape.batch, lr, lr, m.in_channels)),
            jnp.float32),
            "txt": jnp.asarray(rng.standard_normal(
                (shape.batch, m.txt_tokens, m.txt_dim)), jnp.float32),
            "vec": jnp.zeros((shape.batch, 768), jnp.float32)}
    if arch.family == "unet":
        lr = shape.img_res // 8
        return {"latents": jnp.asarray(
            rng.standard_normal((shape.batch, lr, lr, m.in_channels)),
            jnp.float32),
            "ctx": jnp.asarray(rng.standard_normal(
                (shape.batch, m.ctx_tokens, m.ctx_dim)), jnp.float32)}
    if arch.family == "vdit":
        g = m.grid(img_res=shape.img_res)
        return {"latents": jnp.asarray(rng.standard_normal(
            (shape.batch, g[0] * m.t_patch, g[1] * m.patch,
             g[2] * m.patch, m.in_channels)), jnp.float32),
            "txt": jnp.asarray(rng.standard_normal(
                (shape.batch, m.txt_tokens, m.txt_dim)), jnp.float32)}
    # vision
    return {"images": jnp.asarray(rng.standard_normal(
        (shape.batch, shape.img_res, shape.img_res, 3)), jnp.float32),
        "labels": jnp.zeros((shape.batch,), jnp.int32)}


@pytest.mark.parametrize("arch_name", ALL_ARCHS)
def test_arch_smoke_train_step(arch_name):
    arch = get_smoke_config(arch_name)
    shape = SMOKE_SHAPES[arch.family]
    arch = dataclasses.replace(
        arch, shapes=(shape,),
        train=dataclasses.replace(arch.train, remat=False))
    wl = build_workload(arch, "smoke", mesh=None)
    step = wl.jitted()
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    state = train_loop.train_state_init(params, arch.train)
    batch = _smoke_batch(arch, shape)
    rng = jax.random.PRNGKey(1)
    state, metrics = step(state, batch, rng)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch_name}: loss {loss}"
    # one more step must run cleanly (optimizer actually applied); the
    # input state is donated, so only the returned state is readable.
    state2, metrics2 = step(state, batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics2["loss"]))
    for leaf in jax.tree_util.tree_leaves(state2.params):
        assert np.isfinite(np.asarray(leaf)).all(), f"{arch_name}: NaN params"


@pytest.mark.parametrize("arch_name", ALL_ARCHS)
def test_full_configs_have_exact_assigned_hparams(arch_name):
    """The FULL configs carry the exact assignment numbers (they are only
    ever lowered abstractly; this guards against drift)."""
    arch = get_config(arch_name)
    m = arch.model
    expect = {
        "qwen3-32b": ("num_layers", 64, "d_model", 5120, "num_heads", 64,
                      "num_kv_heads", 8, "d_ff", 25600, "vocab_size", 151936),
        "gemma3-4b": ("num_layers", 34, "d_model", 2560, "num_heads", 8,
                      "num_kv_heads", 4, "d_ff", 10240, "vocab_size", 262144),
        "qwen2-moe-a2.7b": ("num_layers", 24, "d_model", 2048, "num_heads",
                            16, "num_kv_heads", 16, "vocab_size", 151936),
        "phi3.5-moe-42b-a6.6b": ("num_layers", 32, "d_model", 4096,
                                 "num_heads", 32, "num_kv_heads", 8,
                                 "d_ff", 6400, "vocab_size", 32064),
        "dit-xl2": ("img_res", 256, "patch", 2, "num_layers", 28,
                    "d_model", 1152, "num_heads", 16),
        "dit-b2": ("img_res", 256, "patch", 2, "num_layers", 12,
                   "d_model", 768, "num_heads", 12),
        "flux-dev": ("img_res", 1024, "latent_res", 128, "n_double_blocks",
                     19, "n_single_blocks", 38, "d_model", 3072,
                     "num_heads", 24),
        "unet-sd15": ("img_res", 512, "latent_res", 64, "ch", 320,
                      "ctx_dim", 768),
        "vit-l16": ("img_res", 224, "patch", 16, "num_layers", 24,
                    "d_model", 1024, "num_heads", 16, "d_ff", 4096),
        "efficientnet-b7": ("img_res", 600, "width_mult", 2.0,
                            "depth_mult", 3.1),
        "vdit-paper": ("d_model", 3072, "num_heads", 24),
    }[arch_name]
    for field, value in zip(expect[::2], expect[1::2]):
        assert getattr(m, field) == value, (arch_name, field)
    if arch_name == "qwen2-moe-a2.7b":
        assert m.moe.top_k == 4 and m.moe.num_shared_experts == 4
        assert m.moe.num_experts == 64  # 60 padded to 64 (see config note)
    if arch_name == "phi3.5-moe-42b-a6.6b":
        assert m.moe.num_experts == 16 and m.moe.top_k == 2
    if arch_name == "gemma3-4b":
        assert m.local_global_pattern == 5 and m.sliding_window > 0


def test_all_archs_have_their_assigned_shapes():
    lm_names = {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    diff_names = {"train_256", "gen_1024", "gen_fast", "train_1024"}
    vis_names = {"cls_224", "cls_384", "serve_b1", "serve_b128"}
    for name in ALL_ARCHS:
        if name == "vdit-paper":
            continue
        arch = get_config(name)
        have = {s.name for s in arch.shapes}
        if arch.family == "lm":
            assert have == lm_names, name
        elif arch.family in ("dit", "mmdit", "unet"):
            assert have == diff_names, name
        else:
            assert have == vis_names, name
