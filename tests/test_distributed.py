"""Distribution substrate tests: sharding rules, checkpoint fault
tolerance, elastic resharding, straggler policy, gradient compression.
Multi-device cases run in subprocesses (jax locks device count at init)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.config.base import TrainConfig
from repro.distributed.collectives import (compress_grads, compression_init,
                                           quantize_int8, dequantize_int8)
from repro.distributed.sharding import (param_rules, spec_from_axes,
                                        train_act_rules, decode_act_rules)
from repro.distributed.straggler import StragglerPolicy
from repro.training import train_loop
from repro.training.optimizer import adamw_init, adamw_update


def _run_sub(code: str, devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


class TestShardingRules:
    def test_indivisible_dims_fall_back_to_replicated(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        rules = {"kv": "model", "embed": ("data",)}
        spec = spec_from_axes(("embed", "kv"), rules, (64, 8), FakeMesh())
        # kv=8 doesn't divide model=16 -> replicated
        assert spec == jax.sharding.PartitionSpec(("data",))

    def test_no_mesh_axis_used_twice(self):
        rules = {"a": "model", "b": "model"}
        spec = spec_from_axes(("a", "b"), rules)
        assert spec == jax.sharding.PartitionSpec("model")

    def test_decode_rules_long_context(self):
        rules = decode_act_rules(None, long_context=True)
        assert rules["batch"] == ()


class TestCheckpointFaultTolerance:
    def _state(self, seed=0):
        params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (4, 4)),
                  "b": jnp.zeros((4,))}
        return train_loop.train_state_init(params, TrainConfig())

    def test_save_restore_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
        state = self._state()
        ck.save(10, state, extra={"cursor": 123})
        step, restored, extra = ck.restore_latest(state)
        assert step == 10 and extra["cursor"] == 123
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corrupted_checkpoint_falls_back(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=5, async_save=False)
        state = self._state()
        ck.save(1, state)
        ck.save(2, state)
        # corrupt the newest arrays blob (simulated disk fault)
        blob = tmp_path / "step_00000002" / "arrays.npz"
        data = bytearray(blob.read_bytes())
        data[len(data) // 2] ^= 0xFF
        blob.write_bytes(bytes(data))
        step, restored, _ = ck.restore_latest(state)
        assert step == 1  # newest invalid -> previous wins

    def test_mid_save_crash_invisible(self, tmp_path):
        """A checkpoint dir without a manifest (simulated crash before
        commit) must not be considered."""
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = self._state()
        ck.save(1, state)
        partial = tmp_path / "step_00000002"
        partial.mkdir()
        (partial / "arrays.npz").write_bytes(b"garbage")
        assert ck.list_steps() == [1]

    def test_async_save_equivalent(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=True)
        state = self._state(3)
        ck.save(7, state)
        ck.wait()
        step, restored, _ = ck.restore_latest(state)
        assert step == 7

    def test_retention_policy(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
        for s in (1, 2, 3, 4):
            ck.save(s, self._state())
        assert ck.list_steps() == [3, 4]


class TestElasticAndEP:
    @pytest.mark.slow
    def test_elastic_reshard_1_to_4_to_2(self):
        _run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed.elastic import reshard_state
            params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
            axes = {"w": ("embed", "mlp")}
            m4 = jax.make_mesh((2, 2), ("data", "model"))
            s4 = reshard_state(params, axes, m4)
            m2 = jax.make_mesh((1, 2), ("data", "model"))
            s2 = reshard_state(jax.device_get(s4), axes, m2)
            np.testing.assert_array_equal(np.asarray(s2["w"]),
                                          np.asarray(params["w"]))
            print("elastic OK")
        """, devices=4)

    @pytest.mark.slow
    def test_ep_moe_matches_dense_on_mesh(self):
        _run_sub("""
            import jax, jax.numpy as jnp
            from repro.models import moe as moe_lib
            from repro.models.params import init_params
            from repro.config.base import MoEConfig
            from repro.distributed.sharding import ShardCtx, train_act_rules
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            cfg = MoEConfig(num_experts=8, top_k=2, expert_ffw_dim=32,
                            capacity_factor=16.0)
            params = init_params(moe_lib.moe_defs(16, cfg),
                                 jax.random.PRNGKey(0))
            x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
            dense, _ = moe_lib.moe_ffn(params, x, cfg, impl="dense")
            ctx = ShardCtx(mesh, train_act_rules(mesh))
            ep, _ = jax.jit(lambda p, x: moe_lib.moe_ffn(
                p, x, cfg, ctx=ctx, impl="ep"))(params, x)
            err = float(jnp.max(jnp.abs(dense - ep)))
            assert err < 1e-4, err
            print("EP OK", err)
        """, devices=8)


class TestStragglerPolicy:
    def test_skips_slow_hosts_bounded(self):
        p = StragglerPolicy(deadline_factor=2.0, max_skip_fraction=0.1)
        times = [1.0] * 98 + [10.0, 50.0]
        skipped, evicted = p.decide(times)
        assert set(skipped) == {98, 99}
        assert evicted == []

    def test_never_skips_more_than_fraction(self):
        p = StragglerPolicy(deadline_factor=1.5, max_skip_fraction=0.05)
        times = [1.0] * 80 + [100.0] * 20
        skipped, _ = p.decide(times)
        assert len(skipped) == 5  # bounded despite 20 stragglers
        # slowest-first tie-break keeps the worst offenders out
        assert all(times[i] == 100.0 for i in skipped)

    def test_eviction_after_streak(self):
        p = StragglerPolicy(deadline_factor=2.0, max_skip_fraction=0.5,
                            evict_after=3)
        evicted_total = []
        for _ in range(3):
            _, ev = p.decide([1.0, 1.0, 1.0, 9.0])
            evicted_total += ev
        assert evicted_total == [3]

    def test_gradient_rescale_unbiased(self):
        assert StragglerPolicy.gradient_rescale(100, [1, 2]) == 100 / 98


class TestGradientCompression:
    def test_int8_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s = quantize_int8(x)
        err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
        assert float(err) <= float(s) * 0.5 + 1e-7

    def test_error_feedback_preserves_sum(self):
        """Σ_t decompressed_t ≈ Σ_t g_t — EF makes quantization noise
        telescoping, the property that preserves SGD convergence."""
        grads = [jax.random.normal(jax.random.PRNGKey(i), (64,)) * 0.01
                 for i in range(30)]
        state = compression_init({"g": grads[0]})
        acc_true = jnp.zeros((64,))
        acc_sent = jnp.zeros((64,))
        for g in grads:
            sent, state = compress_grads({"g": g}, state)
            acc_true += g
            acc_sent += sent["g"]
        resid = float(jnp.max(jnp.abs(acc_true - acc_sent)))
        # residual bounded by ONE step's quantization error, not 30
        one_step = float(jnp.max(jnp.abs(grads[0]))) / 127
        assert resid < 5 * one_step

    def test_compressed_training_converges(self):
        """Linear regression: int8+EF compressed grads reach the same
        loss ballpark as exact grads (the EF convergence guarantee)."""
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (128, 8))
        w_true = jnp.arange(1.0, 9.0)
        y = X @ w_true

        def loss_fn(params, batch, rng):
            pred = batch["x"] @ params["w"]
            l = jnp.mean((pred - batch["y"]) ** 2)
            return l, {}

        def run(compress):
            cfg = TrainConfig(learning_rate=0.05, warmup_steps=1,
                              total_steps=200, weight_decay=0.0,
                              schedule="constant",
                              grad_compression=compress)
            step = train_loop.make_train_step(loss_fn, cfg)
            state = train_loop.train_state_init({"w": jnp.zeros((8,))}, cfg)
            batch = {"x": X, "y": y}
            for i in range(150):
                state, metrics = step(state, batch, jax.random.PRNGKey(i))
            return float(metrics["loss"])

        exact, compressed = run(False), run(True)
        start = float(jnp.mean(y ** 2))
        assert compressed < start * 1e-2          # converged 100x+
        assert compressed < max(exact, 1e-3) * 10  # within 10x of exact


class TestDistributedInit:
    """REVIEW regression: the init guard used to probe
    jax.process_count(), which initializes the local XLA backend, after
    which jax.distributed.initialize() unconditionally raises — every
    ``serve.py --distributed`` launch crashed at startup."""

    def test_real_init_succeeds_in_fresh_process(self):
        """End-to-end: a fresh process must be able to bring up the
        single-process distributed runtime through init_distributed
        and see the guard stay idempotent afterwards."""
        _run_sub("""
            import jax
            from repro.launch.mesh import init_distributed
            assert init_distributed(
                coordinator_address="localhost:12421",
                num_processes=1, process_id=0) is True
            assert jax.process_count() == 1
            assert init_distributed() is False  # idempotent re-entry
            print("OK")
        """, devices=1)

    def test_active_client_short_circuits_without_initialize(self,
                                                             monkeypatch):
        from repro.launch import mesh

        monkeypatch.setattr(mesh, "_distributed_initialized", False)
        monkeypatch.setattr(mesh, "_distributed_client_active",
                            lambda: True)

        def boom(**kw):
            raise AssertionError("initialize() must not be called when "
                                 "a client is already active")

        monkeypatch.setattr(jax.distributed, "initialize", boom)
        assert mesh.init_distributed() is False

    def test_double_init_error_is_treated_as_idempotent(self, monkeypatch):
        from repro.launch import mesh

        monkeypatch.setattr(mesh, "_distributed_initialized", False)
        monkeypatch.setattr(mesh, "_distributed_client_active",
                            lambda: False)

        def already(**kw):
            raise RuntimeError(
                "distributed.initialize should only be called once.")

        monkeypatch.setattr(jax.distributed, "initialize", already)
        assert mesh.init_distributed() is False
        assert mesh._distributed_initialized is True

    def test_backend_already_up_still_raises(self, monkeypatch):
        """The 'must be called before any JAX computations' error is a
        genuine misuse (caller ran jax work first) — it must surface,
        not be swallowed as idempotency."""
        from repro.launch import mesh

        monkeypatch.setattr(mesh, "_distributed_initialized", False)
        monkeypatch.setattr(mesh, "_distributed_client_active",
                            lambda: False)

        def too_late(**kw):
            raise RuntimeError(
                "jax.distributed.initialize() must be called before "
                "any JAX computations are executed.")

        monkeypatch.setattr(jax.distributed, "initialize", too_late)
        with pytest.raises(RuntimeError, match="must be called before"):
            mesh.init_distributed()
        assert mesh._distributed_initialized is False
