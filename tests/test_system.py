"""End-to-end system behaviour tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RippleConfig, ShapeSpec, TrainConfig
from repro.configs import get_smoke_config
from repro.launch.workloads import build_workload, model_fns
from repro.models.params import init_params
from repro.training import train_loop


def _mini_arch(name, **shape_kw):
    arch = get_smoke_config(name)
    shape = ShapeSpec(name="mini", **shape_kw)
    return dataclasses.replace(
        arch, shapes=(shape,),
        train=dataclasses.replace(arch.train, remat=False,
                                  learning_rate=3e-3, warmup_steps=5,
                                  total_steps=60)), shape


def test_lm_training_reduces_loss():
    """A tiny LM must fit the synthetic motif structure in ~50 steps."""
    from repro.data.synthetic import DataSpec, token_batch
    arch, shape = _mini_arch("qwen3-32b", kind="train", seq_len=64,
                             global_batch=8)
    wl = build_workload(arch, "mini", mesh=None)
    step = wl.jitted()
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    state = train_loop.train_state_init(params, arch.train)
    spec = DataSpec(seed=0)
    first = last = None
    for i in range(50):
        batch = token_batch(spec, i, 8, 64, arch.model.vocab_size)
        state, metrics = step(state, batch, jax.random.PRNGKey(i))
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.5, (first, last)


def test_diffusion_training_reduces_loss():
    from repro.data.synthetic import DataSpec, latent_video_batch
    arch, shape = _mini_arch("vdit-paper", kind="train", img_res=32,
                             batch=4, steps=10)
    wl = build_workload(arch, "mini", mesh=None)
    step = wl.jitted()
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    state = train_loop.train_state_init(params, arch.train)
    m = arch.model
    g = m.grid(img_res=32)
    spec = DataSpec(seed=0)
    losses = []
    for i in range(30):
        b = latent_video_batch(spec, i, 4,
                               (g[0] * m.t_patch, g[1] * m.patch,
                                g[2] * m.patch), m.in_channels,
                               txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)
        state, metrics = step(state, b, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


def test_ripple_preserves_trained_vdit_output():
    """After brief training, generation with TimeRipple at a mid-range
    threshold stays close to dense generation (the paper's quality
    claim, miniature edition) while achieving real savings."""
    from repro.data.synthetic import DataSpec, latent_video_batch
    from repro.models.vdit import vdit_apply

    arch, shape = _mini_arch("vdit-paper", kind="train", img_res=32,
                             batch=4, steps=10)
    wl = build_workload(arch, "mini", mesh=None)
    step = wl.jitted()
    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    state = train_loop.train_state_init(params, arch.train)
    m = arch.model
    g = m.grid(img_res=32)
    spec = DataSpec(seed=0)
    for i in range(20):
        b = latent_video_batch(spec, i, 4,
                               (g[0] * m.t_patch, g[1] * m.patch,
                                g[2] * m.patch), m.in_channels,
                               txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)
        state, _ = step(state, b, jax.random.PRNGKey(i))

    b = latent_video_batch(spec, 999, 2,
                           (g[0] * m.t_patch, g[1] * m.patch,
                            g[2] * m.patch), m.in_channels,
                           txt_tokens=m.txt_tokens, txt_dim=m.txt_dim)
    t = jnp.asarray([400.0, 400.0])
    dense = vdit_apply(state.params, b["latents"], t, b["txt"], m,
                       compute_dtype=jnp.float32)
    rip = dataclasses.replace(arch.ripple, fixed_threshold=0.3, i_min=0)
    out = vdit_apply(state.params, b["latents"], t, b["txt"], m,
                     ripple=rip, step=jnp.asarray(25), total_steps=50,
                     compute_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(out - dense) / (jnp.linalg.norm(dense) + 1e-9))
    assert rel < 0.15, rel  # near-identical output


def test_checkpoint_restart_bitexact():
    """Crash-restart must reproduce the exact same training trajectory
    (deterministic data + saved cursor)."""
    import tempfile
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.data.synthetic import DataSpec, token_batch

    arch, shape = _mini_arch("qwen3-32b", kind="train", seq_len=32,
                             global_batch=4)
    wl = build_workload(arch, "mini", mesh=None)
    step = wl.jitted()
    spec = DataSpec(seed=0)

    def run(n, state):
        for i in range(state[1], n):
            batch = token_batch(spec, i, 4, 32, arch.model.vocab_size)
            s, _ = step(state[0], batch, jax.random.PRNGKey(i))
            state = (s, i + 1)
        return state

    params = init_params(model_fns(arch), jax.random.PRNGKey(0))
    s0 = train_loop.train_state_init(params, arch.train)
    # uninterrupted run to step 6
    full = run(6, (s0, 0))
    # interrupted at 3, checkpointed, restored, continued
    params2 = init_params(model_fns(arch), jax.random.PRNGKey(0))
    s1 = train_loop.train_state_init(params2, arch.train)
    mid = run(3, (s1, 0))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, async_save=False)
        ck.save(3, mid[0], extra={"cursor": 3})
        template = train_loop.train_state_init(
            init_params(model_fns(arch), jax.random.PRNGKey(0)), arch.train)
        step_found, restored, extra = ck.restore_latest(template)
    resumed = run(6, (restored, extra["cursor"]))
    for a, b in zip(jax.tree_util.tree_leaves(full[0].params),
                    jax.tree_util.tree_leaves(resumed[0].params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
