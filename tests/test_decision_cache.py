"""Cross-step decision-cache tests (DESIGN.md §13).

The contract under test:

  * ``reuse_every=1`` is bitwise-identical to the per-step path — the
    cache only wraps the decision in a refresh cond, it never changes
    the math (single-device here; the 8-device subprocess check at the
    bottom guarantees the sharded variant on every run of the suite);
  * ``reuse_every>1`` with *unchanged* operands and a step-invariant
    schedule equals the ``reuse_every=1`` trajectory bitwise — re-
    applying a cached plan to the same operands reproduces the fresh
    decision exactly;
  * a drift past ``drift_tol`` forces an early refresh, and the final
    denoising step always refreshes (the schedule's dense-last-step
    contract);
  * the state is scan-carriable (samplers) and threads end-to-end
    through vdit's scan-over-layers.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import RippleConfig
from repro.core import decision_cache as dc
from repro.core import dispatch
from repro.core.dispatch import attention_dispatch
from repro.core.policy import ReusePolicy

GRID = (4, 4, 6)
N = GRID[0] * GRID[1] * GRID[2]
D = 16

CFG = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                   i_min=2, i_max=6)
# Step-invariant schedule for the R>1 == R=1 bitwise comparisons: a
# fixed θ inside an all-active range makes decide() independent of the
# step, so the only difference between cadences is *which branch* of
# the refresh cond produced the operands.
CFG_CONST = dataclasses.replace(CFG, fixed_threshold=0.35, i_min=0,
                                i_max=1, theta_min=0.35, theta_max=0.35)


def _qkv(seed=0, shape=(2, 3, N, D)):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, shape) for k in ks)


def _scan(q, k, v, cfg, policy=None, steps=8, total_steps=None):
    """Denoising-shaped scan carrying the cache; returns (outs, final)."""
    total = total_steps if total_steps is not None else steps + 2

    def body(carry, si):
        out, carry = attention_dispatch(
            q, k, v, grid=GRID, cfg=cfg, step=si, total_steps=total,
            cached_decision=carry, policy=policy)
        return carry, out

    init = dc.initial_state(q.shape, grid=GRID, cfg=cfg, policy=policy)
    final, outs = jax.lax.scan(body, init, jnp.arange(steps))
    return np.asarray(outs), final


class TestRefreshEveryStep:
    """R=1: the cache is a pass-through — bitwise equal to today."""

    @pytest.mark.parametrize("policy", ["ripple", "svg", "equal_mse"])
    def test_bitwise_identical_to_per_step_path(self, policy):
        q, k, v = _qkv(0)
        cfg = dataclasses.replace(CFG, reuse_every=1)
        outs, final = _scan(q, k, v, cfg, policy=policy, steps=6)
        for si in range(6):
            ref = attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                                     step=jnp.asarray(si), total_steps=8,
                                     policy=policy)
            np.testing.assert_array_equal(outs[si], np.asarray(ref))
        assert int(np.asarray(final.refreshes).max()) == 6
        assert int(np.asarray(final.hits).max()) == 0

    def test_single_call_return_decision_matches_plain(self):
        q, k, v = _qkv(1)
        ref = attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                                 step=jnp.asarray(5), total_steps=10)
        out, cache = attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                                        step=jnp.asarray(5), total_steps=10,
                                        return_decision=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert cache.q_idx.dtype == jnp.int32
        assert cache.q_idx.shape == q.shape


class TestCadence:
    """R>1 with unchanged operands reproduces the R=1 trajectory."""

    @pytest.mark.parametrize("policy", ["ripple", "svg"])
    @pytest.mark.parametrize("every", [2, 4])
    def test_hits_bitwise_equal_to_refreshes(self, policy, every):
        q, k, v = _qkv(2)
        cfg1 = dataclasses.replace(CFG_CONST, reuse_every=1)
        cfgR = dataclasses.replace(CFG_CONST, reuse_every=every)
        outs1, fin1 = _scan(q, k, v, cfg1, policy=policy, steps=6)
        outsR, finR = _scan(q, k, v, cfgR, policy=policy, steps=6)
        np.testing.assert_array_equal(outsR, outs1)
        # and the cadence really did skip decide() on the hit steps
        # (the final scan step always refreshes — dense-last contract)
        expected = len([s for s in range(6)
                        if s % every == 0 or s == 8 - 1])
        assert int(np.asarray(finR.refreshes).max()) == expected
        assert int(np.asarray(finR.hits).max()) == 6 - expected

    def test_hit_counters_per_cell(self):
        q, k, v = _qkv(3)
        cfg = dataclasses.replace(CFG_CONST, reuse_every=4)
        _, fin = _scan(q, k, v, cfg, steps=4, total_steps=10)
        # steps 0..3 at R=4: one refresh (step 0), three hits — per cell
        assert np.asarray(fin.refreshes).tolist() == [[1, 1, 1]] * 2
        assert np.asarray(fin.hits).tolist() == [[3, 3, 3]] * 2

    def test_final_step_always_refreshes(self):
        q, k, v = _qkv(4)
        cfg = dataclasses.replace(CFG, reuse_every=8)
        # 6 steps of a 6-step schedule: refresh at 0 and at the final
        # step (5), which the Eq. 4 schedule forces dense
        _, fin = _scan(q, k, v, cfg, steps=6, total_steps=6)
        assert int(np.asarray(fin.refreshes).max()) == 2
        out_last = attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                                      step=jnp.asarray(5), total_steps=6)
        outs, _ = _scan(q, k, v, cfg, steps=6, total_steps=6)
        np.testing.assert_array_equal(outs[5], np.asarray(out_last))


class TestDrift:
    def test_perturbation_past_bound_forces_refresh(self):
        q, k, v = _qkv(5)
        cfg = dataclasses.replace(CFG, reuse_every=8, drift_tol=0.05)
        _, c0 = attention_dispatch(q, k, v, grid=GRID, cfg=cfg,
                                   step=jnp.asarray(0), total_steps=10,
                                   return_decision=True)
        # unchanged operands at an off-cadence step: hit
        _, c1 = attention_dispatch(q, k, v, grid=GRID, cfg=cfg,
                                   step=jnp.asarray(1), total_steps=10,
                                   cached_decision=c0)
        assert int(np.asarray(c1.hits).sum()) > 0
        assert np.array_equal(np.asarray(c1.refreshes),
                              np.asarray(c0.refreshes))
        # perturbed well past the bound: early refresh, and the output
        # equals a fresh decision on the perturbed operands
        qp = 3.0 * q
        out, c2 = attention_dispatch(qp, k, v, grid=GRID, cfg=cfg,
                                     step=jnp.asarray(2), total_steps=10,
                                     cached_decision=c1)
        assert (np.asarray(c2.refreshes) == np.asarray(c1.refreshes) + 1).all()
        ref, _ = attention_dispatch(qp, k, v, grid=GRID, cfg=cfg,
                                    step=jnp.asarray(2), total_steps=10,
                                    return_decision=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_drift_off_never_early_refreshes(self):
        q, k, v = _qkv(6)
        cfg = dataclasses.replace(CFG, reuse_every=8, drift_tol=0.0)
        _, c0 = attention_dispatch(q, k, v, grid=GRID, cfg=cfg,
                                   step=jnp.asarray(0), total_steps=10,
                                   return_decision=True)
        _, c1 = attention_dispatch(3.0 * q, k, v, grid=GRID, cfg=cfg,
                                   step=jnp.asarray(1), total_steps=10,
                                   cached_decision=c0)
        assert np.array_equal(np.asarray(c1.refreshes),
                              np.asarray(c0.refreshes))


class TestGating:
    def test_dense_policy_rejects_cache(self):
        q, k, v = _qkv(7)
        with pytest.raises(ValueError, match="decision caching"):
            attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                               step=jnp.asarray(0), total_steps=10,
                               policy="dense", return_decision=True)

    def test_external_bias_rejected(self):
        q, k, v = _qkv(7)
        bias = jnp.zeros((1, 1, N, N))
        with pytest.raises(ValueError, match="bias"):
            attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                               step=jnp.asarray(0), total_steps=10,
                               bias=bias, return_decision=True)

    def test_legacy_policy_without_capability_rejected(self):
        class _Legacy(ReusePolicy):
            name = "legacy_nocache_test"

            def decide(self, q, k, *, grid, cfg, thetas, bias=None,
                       grid_slice=None, fused=False):
                from repro.core.policy import ReuseDecision
                return ReuseDecision(q=q, k=k, thetas=thetas,
                                     active_axes=(), savings=jnp.zeros(()))

        assert not dc.supports_cache(CFG, _Legacy())
        q, k, v = _qkv(7)
        with pytest.raises(ValueError, match="decision caching"):
            attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                               step=jnp.asarray(0), total_steps=10,
                               policy=_Legacy(), return_decision=True)
        # ...but the plain path still serves it untouched
        out = attention_dispatch(q, k, v, grid=GRID, cfg=CFG,
                                 step=jnp.asarray(0), total_steps=10,
                                 policy=_Legacy())
        assert np.isfinite(np.asarray(out)).all()

    def test_supports_cache_matrix(self):
        assert dc.supports_cache(CFG, "ripple")
        assert dc.supports_cache(CFG, "svg")
        assert dc.supports_cache(CFG, "equal_mse")
        assert not dc.supports_cache(CFG, "dense")
        assert not dc.supports_cache(RippleConfig(), "ripple")  # inactive


class TestModelAndSampler:
    """End-to-end threading: vdit scan-over-layers + sampler carry."""

    @pytest.fixture(scope="class")
    def vdit_setup(self):
        from repro.configs import get_smoke_config
        from repro.launch.workloads import model_fns
        from repro.models.params import init_params

        arch = get_smoke_config("vdit-paper")
        arch = dataclasses.replace(arch, ripple=dataclasses.replace(
            arch.ripple, i_min=1, i_max=3))
        params = init_params(model_fns(arch), jax.random.PRNGKey(0))
        m = arch.model
        g = m.grid(img_res=64)
        B = 2
        lat = jax.random.normal(
            jax.random.PRNGKey(1),
            (B, g[0] * m.t_patch, g[1] * m.patch, g[2] * m.patch,
             m.in_channels))
        txt = 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                       (B, m.txt_tokens, m.txt_dim))
        return arch, params, lat, txt

    def test_vdit_refresh_step_matches_plain(self, vdit_setup):
        from repro.launch.workloads import vdit_decision_state
        from repro.models import vdit as vdit_lib

        arch, params, lat, txt = vdit_setup
        rip = dataclasses.replace(arch.ripple, reuse_every=2)
        t = jnp.full((lat.shape[0],), 500.0)
        plain = vdit_lib.vdit_apply(params, lat, t, txt, arch.model,
                                    ripple=rip, step=jnp.asarray(2),
                                    total_steps=4)
        st = vdit_decision_state(arch, 64, lat.shape[0])
        assert st is not None
        out, st2 = vdit_lib.vdit_apply(params, lat, t, txt, arch.model,
                                       ripple=rip, step=jnp.asarray(2),
                                       total_steps=4, decision_state=st)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))
        assert int(np.asarray(st2.refreshes).sum()) > 0

    def test_sampler_threads_state_and_counts(self, vdit_setup):
        from repro.launch.serve import build_sampler

        arch, params, _, txt = vdit_setup
        sp = dataclasses.replace(
            [s for s in arch.shapes if s.kind == "generate"][0],
            img_res=64, steps=4)
        fn, lshape = build_sampler(arch, sp, params, reuse_every=2)
        B = 2
        noise = jax.random.normal(jax.random.PRNGKey(3), (B, *lshape))
        rngs = jnp.stack([jax.random.PRNGKey(i) for i in range(B)])
        lat_out, aux = fn(noise, txt, rngs)
        assert lat_out.shape == (B, *lshape)
        hits = int(np.asarray(aux["cache_hits"]))
        refr = int(np.asarray(aux["cache_refreshes"]))
        # 4 steps at R=2: refresh at 0, 2 and the final step; hit at 1 —
        # per layer per (batch, head) cell
        m = arch.model
        cells = m.num_layers * B * m.num_heads
        assert (hits, refr) == (1 * cells, 3 * cells)

    def test_engine_buckets_on_reuse_every(self):
        from repro.serving.engine import DiffusionEngine, GenRequest

        built = []

        def factory(shape, steps, policy=None, reuse_every=None):
            built.append((policy, reuse_every))
            return lambda n, t, r: n

        eng = DiffusionEngine(sampler_factory=factory, max_batch=2,
                              max_wait_s=0.01)
        eng.start()
        for rid, r in enumerate((None, 4, 4, 1)):
            eng.submit(GenRequest(request_id=rid,
                                  txt=np.zeros((1, 1), np.float32),
                                  steps=2, latent_shape=(4, D),
                                  reuse_every=r))
        for rid in range(4):
            eng.result(rid, timeout=60)
        eng.stop()
        assert len(built) == 3
        assert set(built) == {(None, None), (None, 1), (None, 4)}

    def test_engine_refuses_cadence_it_cannot_honour(self):
        from repro.serving.engine import DiffusionEngine, GenRequest

        eng = DiffusionEngine(
            sampler_factory=lambda shape, steps: (lambda n, t, r: n))
        with pytest.raises(ValueError, match="reuse_every"):
            eng.submit(GenRequest(request_id=0,
                                  txt=np.zeros((1, 1), np.float32),
                                  latent_shape=(2,), reuse_every=4))
        with pytest.raises(ValueError, match="default_reuse_every"):
            DiffusionEngine(
                sampler_factory=lambda shape, steps: (lambda n, t, r: n),
                default_reuse_every=4)


def test_forced_8_device_cache_parity_subprocess(multidevice_env):
    """Always-on multi-device guarantee: the cache-carrying scan under a
    forced 8-virtual-device backend is bitwise-equal to the single-device
    trajectory on 1/2/8-way batch meshes and a 4x2 batch-and-heads mesh —
    R=1 against the plain path, R=3 against the single-device R=3 run —
    for both cache-capable built-in policies."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.config.base import RippleConfig
        from repro.core import decision_cache as dc, dispatch
        from repro.core.dispatch import attention_dispatch, dispatch_mesh

        GRID, N, D = (4, 4, 4), 64, 16
        cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                           i_min=2, i_max=6, reuse_every=3)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (8, 2, N, D)) for kk in ks)

        def scan(pol, c):
            def body(carry, si):
                out, carry = attention_dispatch(
                    q, k, v, grid=GRID, cfg=c, step=si, total_steps=8,
                    cached_decision=carry, policy=pol)
                return carry, out
            init = dc.initial_state(q.shape, grid=GRID, cfg=c, policy=pol)
            fin, outs = jax.lax.scan(body, init, jnp.arange(6))
            return np.asarray(outs), fin

        for pol in ("ripple", "svg"):
            dispatch.clear_plan_cache()
            ref_outs, ref_fin = scan(pol, cfg)
            plain = np.stack([np.asarray(attention_dispatch(
                q, k, v, grid=GRID, cfg=dataclasses.replace(
                    cfg, reuse_every=1),
                step=jnp.asarray(si), total_steps=8, policy=pol))
                for si in range(6)])
            for shape in ((1, 1), (2, 1), (8, 1), (4, 2)):
                mesh = jax.make_mesh(shape, ("data", "model"))
                with dispatch_mesh(mesh):
                    dispatch.clear_plan_cache()
                    outs, fin = scan(pol, cfg)
                    np.testing.assert_array_equal(outs, ref_outs)
                    np.testing.assert_array_equal(
                        np.asarray(fin.hits), np.asarray(ref_fin.hits))
                    c1 = dataclasses.replace(cfg, reuse_every=1)
                    outs1, _ = scan(pol, c1)
                    np.testing.assert_array_equal(outs1, plain)
        print("cache sharded parity OK on", len(jax.devices()), "devices")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=multidevice_env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "cache sharded parity OK on 8 devices" in r.stdout
