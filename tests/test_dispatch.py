"""Tests for the unified attention-dispatch layer (DESIGN.md §8):
backend equivalence, fused-mask parity, shape bucketing, plan-cache LRU
bounds, and the autotune-cache round trip."""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-example property checks
    from _hypothesis_compat import given, settings, st

from repro.config.base import RippleConfig
from repro.core import dispatch
from repro.core.collapse import collapsed_attention
from repro.core.dispatch import (attention_dispatch, autotune_attention,
                                 dense_attention, get_policy, resolve_plan,
                                 shape_bucket)
from repro.core.reuse import compute_reuse
from repro.kernels.reuse_mask.ops import (fused_compute_reuse,
                                          fused_reuse_eligible)

GRID = (4, 4, 6)
N = GRID[0] * GRID[1] * GRID[2]
D = 16

CFG = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                   i_min=2, i_max=6)


def _qkv(seed=0, shape=(2, 3, N, D)):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, shape) for k in ks)


class TestBackendEquivalence:
    """Dispatch output matches the paper pipeline built from first
    principles (compute_reuse snap → backend math) — no shim."""

    STEP = jnp.asarray(5)

    def _dispatch(self, backend, cfg=CFG, **kw):
        q, k, v = _qkv(1)
        return attention_dispatch(q, k, v, grid=GRID, cfg=cfg,
                                  step=self.STEP, total_steps=10,
                                  backend=backend, **kw)

    def _snapped(self, q, k, cfg=CFG):
        thetas = get_policy("ripple").thetas_for(cfg, self.STEP, 10)
        rq = compute_reuse(q, GRID, thetas, window=cfg.window)
        rk = compute_reuse(k, GRID, thetas, window=cfg.window)
        return rq.snapped, rk.snapped

    def test_reference_matches_manual_snapped_dense(self):
        q, k, v = _qkv(1)
        q_s, k_s = self._snapped(q, k)
        direct = dense_attention(q_s, k_s, v, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(self._dispatch("reference")),
                                   np.asarray(direct), atol=1e-6)

    def test_collapse_matches_manual_collapsed(self):
        q, k, v = _qkv(1)
        q_s, k_s = self._snapped(q, k)
        direct = collapsed_attention(q_s, k_s, v, window=CFG.window,
                                     scale=1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(self._dispatch("collapse")),
                                   np.asarray(direct), atol=3e-5)

    def test_pallas_matches_reference(self):
        ref = self._dispatch("reference")
        np.testing.assert_allclose(np.asarray(self._dispatch("pallas")),
                                   np.asarray(ref), atol=3e-5)

    def test_backends_agree_with_each_other(self):
        ref = self._dispatch("reference")
        for b in ("collapse", "pallas"):
            np.testing.assert_allclose(np.asarray(self._dispatch(b)),
                                       np.asarray(ref), atol=3e-5)

    def test_dense_backend_bypasses_pipeline(self):
        q, k, v = _qkv(1)
        out = self._dispatch("dense")
        ref = dense_attention(q, k, v, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)

    def test_inactive_cfg_is_dense(self):
        q, k, v = _qkv(2)
        out = attention_dispatch(q, k, v, grid=GRID, cfg=RippleConfig())
        ref = dense_attention(q, k, v, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)

    def test_unknown_backend_raises(self):
        with pytest.raises(ValueError):
            self._dispatch("cudnn")

    def test_grid_slice_and_stats(self):
        L = 8
        q, k, v = _qkv(3, (1, 2, L + N, D))
        out, stats = attention_dispatch(
            q, k, v, grid=GRID, cfg=CFG, step=self.STEP, total_steps=10,
            grid_slice=(L, N), with_stats=True)
        # manual reference: snap only the grid segment, dense attention
        thetas = get_policy("ripple").thetas_for(CFG, self.STEP, 10)

        def snap_seg(x):
            seg = x[..., L:, :]
            r = compute_reuse(seg, GRID, thetas, window=CFG.window)
            return jnp.concatenate([x[..., :L, :], r.snapped], axis=-2), \
                r.mask
        q_s, q_mask = snap_seg(q)
        k_s, k_mask = snap_seg(k)
        ref = dense_attention(q_s, k_s, v, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)
        from repro.core.savings import partial_score_savings
        pad_q = jnp.concatenate(
            [jnp.zeros((*q.shape[:-2], L, D), jnp.bool_), q_mask], axis=-2)
        pad_k = jnp.concatenate(
            [jnp.zeros((*k.shape[:-2], L, D), jnp.bool_), k_mask], axis=-2)
        assert float(stats.savings) == pytest.approx(
            float(partial_score_savings(pad_q, pad_k)))


class TestFusedMask:
    """The fused Pallas Δ-check/snap kernel is bit-exact vs the host."""

    @pytest.mark.parametrize("grid,lead", [
        ((4, 4, 6), (2, 3)),
        ((1, 4, 8), (1, 2)),   # single frame: t check never fires
        ((2, 2, 2), ()),
    ])
    @pytest.mark.parametrize("granularity", ["channel", "token"])
    def test_matches_host_pipeline(self, grid, lead, granularity):
        n = grid[0] * grid[1] * grid[2]
        x = jax.random.normal(jax.random.PRNGKey(0), (*lead, n, D))
        th = {a: jnp.asarray(0.6, jnp.float32) for a in ("t", "x", "y")}
        assert fused_reuse_eligible(grid, granularity=granularity)
        r = compute_reuse(x, grid, th, granularity=granularity)
        s, m = fused_compute_reuse(x, grid, th, granularity=granularity)
        np.testing.assert_array_equal(np.asarray(m), np.asarray(r.mask))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(r.snapped))

    def test_axis_priority_matches_host(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, N, D))
        th = {a: jnp.asarray(0.9, jnp.float32) for a in ("t", "x", "y")}
        for axes in (("t", "x", "y"), ("y", "t", "x"), ("x",)):
            r = compute_reuse(x, GRID, th, axes=axes)
            s, m = fused_compute_reuse(x, GRID, th, axes=axes)
            np.testing.assert_array_equal(np.asarray(s), np.asarray(r.snapped))

    def test_ineligible_shapes_fall_back(self):
        # odd spatial dims / odd frame counts / group granularity
        assert not fused_reuse_eligible((4, 3, 4))
        assert not fused_reuse_eligible((3, 4, 4))      # odd T with t check
        assert fused_reuse_eligible((3, 4, 4), axes=("x", "y"))
        assert not fused_reuse_eligible((4, 4, 4), granularity="group")
        assert not fused_reuse_eligible((4, 4, 4), window=4)

    def test_dispatch_fused_on_equals_host_path(self):
        q, k, v = _qkv(4)
        kw = dict(grid=GRID, step=jnp.asarray(5), total_steps=10)
        host = attention_dispatch(
            q, k, v, cfg=dataclasses.replace(CFG, fused_mask="off"), **kw)
        fused = attention_dispatch(
            q, k, v, cfg=dataclasses.replace(CFG, fused_mask="on"), **kw)
        np.testing.assert_array_equal(np.asarray(host), np.asarray(fused))


class TestPlansAndBuckets:
    def test_shape_bucket_powers_of_two(self):
        assert shape_bucket(1) == 64
        assert shape_bucket(96) == 128
        assert shape_bucket(128) == 128
        assert shape_bucket(129) == 256
        assert shape_bucket(32768) == 32768

    def test_nearby_shapes_share_plan(self):
        p1 = resolve_plan((2, 3, 96, D), (2, 3, 96, D), CFG)
        p2 = resolve_plan((2, 3, 100, D), (2, 3, 100, D), CFG)
        assert p1 is p2  # same bucket -> same cached plan object

    def test_auto_backend_on_cpu_follows_execution(self):
        p = resolve_plan((1, 1, N, D), (1, 1, N, D), CFG)
        assert p.backend == "reference"
        cfg = dataclasses.replace(CFG, execution="collapse")
        p = resolve_plan((1, 1, N, D), (1, 1, N, D), cfg)
        assert p.backend == "collapse"

    def test_inactive_resolves_dense(self):
        p = resolve_plan((1, 1, N, D), (1, 1, N, D), RippleConfig())
        assert p.backend == "dense"

    def test_plan_summary_prints(self):
        s = resolve_plan((1, 1, N, D), (1, 1, N, D), CFG).summary()
        assert "reference" in s


class TestSparseBackendResolution:
    """Plan resolution for the block-sparse masked flash backend
    (DESIGN.md §12): block-map policies land on it, explicit 'sparse'
    is honoured, and its block sizes come from the autotune cache."""

    def test_svg_auto_resolves_sparse(self):
        dispatch.clear_plan_cache()
        try:
            p = resolve_plan((1, 1, N, D), (1, 1, N, D), CFG, policy="svg")
            assert p.backend == "sparse"
            assert "sparse" in p.summary()
        finally:
            dispatch.clear_plan_cache()

    def test_explicit_sparse_honoured_for_any_policy(self):
        dispatch.clear_plan_cache()
        try:
            p = resolve_plan((1, 1, N, D), (1, 1, N, D), CFG,
                             backend="sparse")
            assert p.backend == "sparse" and p.policy == "ripple"
        finally:
            dispatch.clear_plan_cache()

    def test_sparse_dispatch_matches_reference(self):
        q, k, v = _qkv(9)
        kw = dict(grid=GRID, cfg=CFG, step=jnp.asarray(5), total_steps=10)
        out = attention_dispatch(q, k, v, backend="sparse", **kw)
        ref = attention_dispatch(q, k, v, backend="reference", **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)

    def test_sparse_blocks_come_from_autotune_cache(self, tmp_path,
                                                    monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                           str(tmp_path / "autotune.json"))
        dispatch.clear_plan_cache()
        try:
            n, d = 64, 8
            q, k, v = _qkv(0, (1, 1, n, d))
            entry = autotune_attention(q, k, v, backend="sparse",
                                       candidates=((16, 16), (32, 32)),
                                       repeats=1)
            plan = resolve_plan((1, 1, n, d), (1, 1, n, d), CFG,
                                backend="sparse")
            assert plan.tuned
            assert (plan.block_q, plan.block_k) == (entry["block_q"],
                                                    entry["block_k"])
            # ripple's pallas kernel never reads the sparse entry
            plan_p = resolve_plan((1, 1, n, d), (1, 1, n, d), CFG,
                                  backend="pallas")
            assert not plan_p.tuned
        finally:
            dispatch.clear_plan_cache()


class TestBucketProperties:
    """Property coverage for the shape-bucket map (fixed examples when
    hypothesis is absent, randomized search otherwise)."""

    @settings(deadline=None, max_examples=60)
    @given(n=st.integers(1, 1 << 16))
    def test_bucket_covers_and_is_power_of_two(self, n):
        b = shape_bucket(n)
        assert b >= n and b >= 64
        assert b & (b - 1) == 0          # power of two
        assert b < 2 * max(n, 64)        # tight: never over-doubles

    @settings(deadline=None, max_examples=60)
    @given(n1=st.integers(1, 1 << 16), n2=st.integers(1, 1 << 16))
    def test_bucket_monotonic(self, n1, n2):
        if n1 > n2:
            n1, n2 = n2, n1
        assert shape_bucket(n1) <= shape_bucket(n2)

    @settings(deadline=None, max_examples=25)
    @given(n=st.integers(65, 128), m=st.integers(65, 128))
    def test_shapes_in_one_bucket_share_one_plan(self, n, m):
        dispatch.clear_plan_cache()
        try:
            p1 = resolve_plan((1, 1, n, D), (1, 1, n, D), CFG)
            p2 = resolve_plan((1, 1, m, D), (1, 1, m, D), CFG)
            assert p1 is p2  # same (64, 128] bucket -> same cached plan
        finally:
            dispatch.clear_plan_cache()


class TestPlanCacheLRU:
    """The plan cache is a bounded LRU: it never exceeds its cap and
    eviction discards the coldest entry, keeping the hottest."""

    def _with_cap(self, cap):
        old = dispatch._PLAN_CACHE_CAP
        dispatch._PLAN_CACHE_CAP = cap
        dispatch.clear_plan_cache()
        return old

    @settings(deadline=None, max_examples=10)
    @given(cap=st.integers(2, 8), extra=st.integers(1, 24))
    def test_bounded_and_keeps_hottest(self, cap, extra):
        old = self._with_cap(cap)
        try:
            hot_shape = (1, 1, 64, D)
            hot = resolve_plan(hot_shape, hot_shape, CFG)
            for i in range(extra):
                # distinct buckets: distinct n buckets per iteration
                n = 64 * (i + 2)
                resolve_plan((1, 1, n, D), (1, 1, n, D), CFG)
                # re-touch the hot entry so it stays MRU
                assert resolve_plan(hot_shape, hot_shape, CFG) is hot
                assert len(dispatch._PLAN_CACHE) <= cap
            # the hottest entry survived every eviction
            assert resolve_plan(hot_shape, hot_shape, CFG) is hot
        finally:
            dispatch._PLAN_CACHE_CAP = old
            dispatch.clear_plan_cache()

    def test_cold_entries_are_evicted(self):
        old = self._with_cap(2)
        try:
            cold = resolve_plan((1, 1, 64, D), (1, 1, 64, D), CFG)
            resolve_plan((1, 1, 256, D), (1, 1, 256, D), CFG)
            resolve_plan((1, 1, 1024, D), (1, 1, 1024, D), CFG)
            assert len(dispatch._PLAN_CACHE) == 2
            # the first (coldest) entry was evicted -> fresh object now
            assert resolve_plan((1, 1, 64, D), (1, 1, 64, D), CFG) is not cold
        finally:
            dispatch._PLAN_CACHE_CAP = old
            dispatch.clear_plan_cache()


class TestAutotuneCache:
    def test_round_trip(self, tmp_path, monkeypatch):
        path = str(tmp_path / "autotune.json")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
        dispatch.clear_plan_cache()
        try:
            n, d = 64, 8
            q, k, v = _qkv(0, (1, 1, n, d))
            entry = autotune_attention(
                q, k, v, candidates=((16, 16), (32, 32)), repeats=1)
            assert (entry["block_q"], entry["block_k"]) in ((16, 16), (32, 32))
            assert len(entry["candidates"]) == 2

            # persisted on disk, keyed by the shape bucket
            disk = json.load(open(path))
            key = dispatch.autotune_key("pallas", shape_bucket(n), d, d)
            assert disk[key]["block_q"] == entry["block_q"]

            # a fresh in-memory cache resolves the tuned plan from disk
            dispatch.clear_plan_cache()
            plan = resolve_plan((1, 1, n, d), (1, 1, n, d), CFG,
                                backend="pallas")
            assert plan.tuned
            assert (plan.block_q, plan.block_k) == (entry["block_q"],
                                                    entry["block_k"])

            # second autotune call is a cache hit (no re-timing)
            again = autotune_attention(q, k, v,
                                       candidates=((16, 16), (32, 32)))
            assert again == disk[key]
        finally:
            dispatch.clear_plan_cache()  # drop tmp-path state for other tests

    def test_untuned_shapes_use_defaults(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                           str(tmp_path / "empty.json"))
        dispatch.clear_plan_cache()
        try:
            plan = resolve_plan((1, 1, 512, 32), (1, 1, 512, 32), CFG,
                                backend="pallas")
            assert not plan.tuned
            assert (plan.block_q, plan.block_k) == (128, 128)
        finally:
            dispatch.clear_plan_cache()
