"""Quality-claim tests: the paper's core comparative claims, validated on
correlated synthetic latents (DESIGN.md §9.3).

These mirror the benchmarks but as pass/fail invariants:
  * Fig. 7 — reuse beats masking AND beats skip-same-selection by a wide
    MSE margin at matched savings;
  * calibration hits a target savings ratio;
  * the savings the stats report are what the masks actually imply.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RippleConfig
from repro.core import reuse, savings
from repro.core.calibrate import calibrate_threshold
from repro.core.dispatch import attention_dispatch, dense_attention
from repro.data.synthetic import correlated_video_latents

GRID = (8, 8, 8)
N = 8 * 8 * 8
D = 32


def _correlated_qk(seed=0):
    lat = correlated_video_latents(jax.random.PRNGKey(seed), 1, GRID, D,
                                   temporal_rho=0.95, spatial_smooth=2)
    x = lat.reshape(1, 1, N, D)
    wq = 0.5 * jax.random.normal(jax.random.PRNGKey(seed + 1), (D, D))
    wk = 0.5 * jax.random.normal(jax.random.PRNGKey(seed + 2), (D, D))
    return jnp.einsum("bhnd,df->bhnf", x, wq), \
        jnp.einsum("bhnd,df->bhnf", x, wk)


def _attn_mse(q1, k1, q2, k2, v):
    scale = 1 / np.sqrt(D)
    a = dense_attention(q1, k1, v, scale)
    b = dense_attention(q2, k2, v, scale)
    return float(jnp.mean(jnp.square(a - b)))


def test_reuse_beats_masking_at_matched_savings():
    """Paper Fig. 7: at the same token-saving ratio, reuse has (much)
    lower output MSE than (a) masking the lowest-magnitude entries and
    (b) skipping exactly the entries reuse would have reused."""
    q, k = _correlated_qk()
    v = jax.random.normal(jax.random.PRNGKey(9), (1, 1, N, D))
    th = {a: jnp.asarray(0.35) for a in ("t", "x", "y")}
    rq = reuse.compute_reuse(q, GRID, th)
    rk = reuse.compute_reuse(k, GRID, th)
    ratio = float(savings.partial_score_savings(rq.mask, rk.mask))
    assert 0.2 < ratio < 0.99

    mse_reuse = _attn_mse(rq.snapped, rk.snapped, q, k, v)

    # baseline 2 (same selection, zeroed instead of reused)
    q_skip = jnp.where(rq.mask, 0.0, q)
    k_skip = jnp.where(rk.mask, 0.0, k)
    mse_skip = _attn_mse(q_skip, k_skip, q, k, v)

    # baseline 1 (mask lowest-|value| entries at the same per-operand rate)
    def low_mask(x, frac):
        thr = jnp.quantile(jnp.abs(x), frac)
        return jnp.where(jnp.abs(x) < thr, 0.0, x)

    q_mask = low_mask(q, float(rq.mask.mean()))
    k_mask = low_mask(k, float(rk.mask.mean()))
    mse_mask = _attn_mse(q_mask, k_mask, q, k, v)

    # order-of-magnitude vs skip-same-selection (the paper's headline);
    # clearly better (>2x) vs magnitude masking on synthetic latents.
    assert mse_reuse < mse_skip / 5, (mse_reuse, mse_skip)
    assert mse_reuse < mse_mask / 2, (mse_reuse, mse_mask)


def test_calibration_hits_target_savings():
    q, k = _correlated_qk(3)
    cfg = RippleConfig(enabled=True)
    for target in (0.5, 0.75):
        theta = calibrate_threshold(q, k, GRID, cfg, target, tol=0.02)
        got = _savings_at(q, k, theta, cfg)
        assert abs(got - target) < 0.05, (target, theta, got)


def _savings_at(q, k, theta, cfg):
    th = {a: jnp.asarray(theta) for a in ("t", "x", "y")}
    rq = reuse.compute_reuse(q, GRID, th, axes=cfg.axes, window=cfg.window)
    rk = reuse.compute_reuse(k, GRID, th, axes=cfg.axes, window=cfg.window)
    return float(savings.partial_score_savings(rq.mask, rk.mask))


def test_error_monotone_in_savings():
    """More reuse ⇒ more error (sanity for the threshold/quality dial)."""
    q, k = _correlated_qk(5)
    v = jax.random.normal(jax.random.PRNGKey(6), (1, 1, N, D))
    last_mse = -1.0
    for theta in (0.1, 0.4, 1.0):
        th = {a: jnp.asarray(theta) for a in ("t", "x", "y")}
        rq = reuse.compute_reuse(q, GRID, th)
        rk = reuse.compute_reuse(k, GRID, th)
        mse = _attn_mse(rq.snapped, rk.snapped, q, k, v)
        assert mse >= last_mse
        last_mse = mse


def test_window2_saves_more_than_window4_on_moderate_correlation():
    """Paper Fig. 11: larger windows reduce eligible tokens (all K members
    must agree), so savings drop — window 2 is the sweet spot."""
    q, k = _correlated_qk(7)
    cfg2 = RippleConfig(enabled=True, window=2)
    cfg4 = RippleConfig(enabled=True, window=4)
    s2 = _savings_at(q, k, 0.3, cfg2)
    s4_cfg = RippleConfig(enabled=True, window=4)
    th = {a: jnp.asarray(0.3) for a in ("t", "x", "y")}
    rq = reuse.compute_reuse(q, GRID, th, window=4)
    rk = reuse.compute_reuse(k, GRID, th, window=4)
    s4 = float(savings.partial_score_savings(rq.mask, rk.mask))
    assert s2 > s4


def test_structural_savings_materialize_on_redundant_data():
    """On highly-redundant latents the collapse path actually skips
    blocks (token-granularity snapping makes full pairs)."""
    q, k = _correlated_qk(11)
    cfg = RippleConfig(enabled=True, granularity="token",
                       fixed_threshold=0.5, i_min=0, i_max=1)
    out, stats = attention_dispatch(
        q, k, jax.random.normal(jax.random.PRNGKey(12), (1, 1, N, D)),
        grid=GRID, cfg=cfg, step=jnp.asarray(0), total_steps=10,
        with_stats=True)
    assert float(stats.structural_savings) > 0.1
