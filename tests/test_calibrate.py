"""Tests for core/calibrate.py — threshold bisection, the Fig. 9
step-sensitivity fit, the equal-MSE schedule — and the calibration hooks
the reuse policies expose over them (DESIGN.md §11)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.config.base import RippleConfig
from repro.core.calibrate import (calibrate_threshold, equal_mse_schedule,
                                  fit_step_sensitivity, savings_at_threshold)
from repro.core.policy import EqualMSEPolicy, get_policy
from repro.data.synthetic import correlated_video_latents

GRID = (8, 8, 8)
D = 32
CFG = RippleConfig(enabled=True)


def _correlated_qk(seed=0):
    lat = correlated_video_latents(jax.random.PRNGKey(seed), 1, GRID, D,
                                   temporal_rho=0.95, spatial_smooth=2)
    x = lat.reshape(1, 1, -1, D)
    wq = 0.5 * jax.random.normal(jax.random.PRNGKey(seed + 1), (D, D))
    wk = 0.5 * jax.random.normal(jax.random.PRNGKey(seed + 2), (D, D))
    return x @ wq, x @ wk


class TestCalibrateThreshold:
    def test_hits_target_savings(self):
        q, k = _correlated_qk()
        theta = calibrate_threshold(q, k, GRID, CFG, target_savings=0.5)
        s = savings_at_threshold(q, k, GRID, CFG, theta)
        assert s == pytest.approx(0.5, abs=0.05)

    def test_monotone_in_target(self):
        q, k = _correlated_qk(1)
        t_lo = calibrate_threshold(q, k, GRID, CFG, target_savings=0.3)
        t_hi = calibrate_threshold(q, k, GRID, CFG, target_savings=0.7)
        assert t_lo < t_hi

    def test_savings_monotone_in_theta(self):
        q, k = _correlated_qk(2)
        s = [savings_at_threshold(q, k, GRID, CFG, t)
             for t in (0.0, 0.3, 1.0, 4.0)]
        assert s[0] == 0.0
        assert all(b >= a for a, b in zip(s, s[1:]))

    def test_ripple_policy_calibrate_returns_override(self):
        q, k = _correlated_qk(3)
        out = get_policy("ripple").calibrate(q, k, GRID, CFG, 0.5)
        assert set(out) == {"fixed_threshold"}
        cfg = dataclasses.replace(CFG, **out)
        s = savings_at_threshold(q, k, GRID, CFG, cfg.fixed_threshold)
        assert s == pytest.approx(0.5, abs=0.05)


class TestFitStepSensitivity:
    def test_recovers_known_line(self):
        steps = np.arange(10, 31)
        slope, intercept = -0.2, 1.5
        mses = np.exp(slope * steps + intercept)
        fit = fit_step_sensitivity(steps, mses)
        assert fit["slope"] == pytest.approx(slope, abs=1e-6)
        assert fit["intercept"] == pytest.approx(intercept, abs=1e-6)

    def test_robust_to_zero_mse(self):
        steps = np.asarray([1.0, 2.0, 3.0])
        fit = fit_step_sensitivity(steps, np.asarray([1e-3, 0.0, 1e-5]))
        assert np.isfinite(fit["slope"]) and np.isfinite(fit["intercept"])


class TestEqualMSESchedule:
    # Synthetic sensitivity model: MSE(θ, i) = θ² · exp(slope·i) — MSE
    # quadratic in the threshold, log-linearly decaying in the step
    # (exactly the Fig. 9 structure the schedule inverts).
    SLOPE = -0.2

    def _mse(self, theta, i):
        return theta ** 2 * np.exp(self.SLOPE * i)

    def test_constant_induced_mse(self):
        fit = {"slope": self.SLOPE, "intercept": 0.0}
        thetas = equal_mse_schedule(fit, self._mse, i_min=10, i_max=20,
                                    theta_at_imin=0.2)
        target = self._mse(0.2, 10)
        induced = [self._mse(t, i) for t, i in zip(thetas, range(10, 21))]
        np.testing.assert_allclose(induced, target, rtol=1e-3)

    def test_schedule_is_increasing(self):
        fit = {"slope": self.SLOPE, "intercept": 0.0}
        thetas = equal_mse_schedule(fit, self._mse, i_min=5, i_max=15,
                                    theta_at_imin=0.3)
        assert len(thetas) == 11
        assert thetas[0] == pytest.approx(0.3, abs=1e-3)
        assert all(b > a for a, b in zip(thetas, thetas[1:]))

    def test_feeds_equal_mse_policy(self):
        """The full caller path calibrate.py was missing: fit → schedule
        → a servable policy instance."""
        fit = fit_step_sensitivity(
            np.arange(4, 12),
            np.asarray([self._mse(0.25, i) for i in range(4, 12)]))
        thetas = equal_mse_schedule(fit, self._mse, i_min=4, i_max=11,
                                    theta_at_imin=0.25)
        pol = EqualMSEPolicy.from_schedule(thetas, i_min=4)
        got = [float(pol.thetas_for(CFG, np.int32(i), 20)["t"])
               for i in range(4, 12)]
        np.testing.assert_allclose(got, thetas, rtol=1e-5)
        # analytic fallback tracks the fitted slope's growth rate
        analytic = EqualMSEPolicy(mse_slope=fit["slope"])
        a = [float(analytic.thetas_for(
            dataclasses.replace(CFG, theta_min=0.25, theta_max=10.0,
                                i_min=4),
            np.int32(i), 20)["t"]) for i in range(4, 12)]
        np.testing.assert_allclose(a, thetas, rtol=0.05)
