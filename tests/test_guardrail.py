"""Runtime guardrail + chaos-harness tests (DESIGN.md §17): the
in-graph sentinels, the degradation ladder's trip / cool-down /
re-promotion state machine, the fault-spec grammar, and the engine's
escalation chain end to end — sentinel trip -> degrade-and-re-serve,
hang -> watchdog, transient error -> retry, poison -> bisection
quarantine — driven by deterministic fault injection."""

import time
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decision_cache import CachedDecision
from repro.core.guardrail import (DegradationLadder, GuardrailConfig,
                                  attach_sentinel, dense_probe_error,
                                  next_policy, nonfinite_count)
from repro.serving import faults as fault_lib
from repro.serving.engine import (DiffusionEngine, GenRequest,
                                  is_failover_error)
from repro.serving.faults import FaultPlan, parse_faults


def _txt(val, tokens=1, dim=1):
    return np.full((tokens, dim), float(val), np.float32)


@pytest.fixture(autouse=True)
def _no_leftover_faults():
    """Every test starts and ends with no fault plan installed — an
    armed plan leaking across tests would corrupt unrelated suites."""
    fault_lib.clear_faults()
    yield
    fault_lib.clear_faults()


class TestSentinels:
    def test_nonfinite_count_total_and_lead_shaped(self):
        x = jnp.ones((2, 3, 4))
        x = x.at[0, 1, 2].set(jnp.nan).at[1, 0, 0].set(jnp.inf)
        assert int(nonfinite_count(x)) == 2
        per = nonfinite_count(x, lead_ndim=2)
        assert per.shape == (2, 3)
        assert int(per[0, 1]) == 1 and int(per[1, 0]) == 1
        assert int(per.sum()) == 2

    def test_dense_probe_error_zero_on_dense_output(self):
        k0 = jax.random.PRNGKey(0)
        q, k, v = (jax.random.normal(kk, (4, 8))
                   for kk in jax.random.split(k0, 3))
        scale = 8 ** -0.5
        ref = jax.nn.softmax((q @ k.T) * scale, axis=-1) @ v
        assert float(dense_probe_error(q, k, v, ref, scale)) < 1e-5
        # a wildly wrong output has O(1) relative error
        assert float(dense_probe_error(q, k, v, jnp.zeros_like(ref),
                                       scale)) > 0.5

    def test_attach_sentinel_accumulates_nonfinite(self):
        cfg = types.SimpleNamespace(sentinel_probe_every=0)
        k0 = jax.random.PRNGKey(1)
        q, k, v = (jax.random.normal(kk, (2, 3, 4, 8))
                   for kk in jax.random.split(k0, 3))
        out = jnp.ones((2, 3, 4, 8)).at[0, 0, 1, :].set(jnp.nan)
        cache = attach_sentinel(CachedDecision(), out, q, k, v,
                                8 ** -0.5, step=0, cfg=cfg)
        assert cache.nonfinite.shape == (2, 3)
        assert int(cache.nonfinite.sum()) == 8
        # second call accumulates into the carry
        cache = attach_sentinel(cache, out, q, k, v, 8 ** -0.5,
                                step=1, cfg=cfg)
        assert int(cache.nonfinite.sum()) == 16
        np.testing.assert_allclose(np.asarray(cache.probe_err), 0.0)

    def test_attach_sentinel_probe_measures_drift(self):
        cfg = types.SimpleNamespace(sentinel_probe_every=1)
        k0 = jax.random.PRNGKey(2)
        q, k, v = (jax.random.normal(kk, (2, 4, 8))
                   for kk in jax.random.split(k0, 3))
        scale = 8 ** -0.5
        dense = jax.vmap(
            lambda qq, kk, vv: jax.nn.softmax(
                (qq @ kk.T) * scale, axis=-1) @ vv)(q, k, v)
        clean = attach_sentinel(CachedDecision(), dense, q, k, v, scale,
                                step=0, cfg=cfg)
        assert float(clean.probe_err.max()) < 1e-5
        drifted = attach_sentinel(CachedDecision(), jnp.zeros_like(dense),
                                  q, k, v, scale, step=0, cfg=cfg)
        assert float(drifted.probe_err.max()) > 0.5


class TestLadderStateMachine:
    def test_next_policy_rungs(self):
        assert next_policy("rainfusion") == "ripple"
        assert next_policy("static") == "ripple"
        assert next_policy("ripple") == "dense"
        assert next_policy("dense") is None
        # unknown / default policies jump straight to the backstop
        assert next_policy("mystery") == "dense"
        assert next_policy(None) == "dense"

    def test_trip_steps_down_and_dead_ends_at_dense(self):
        lad = DegradationLadder()
        assert lad.effective_policy("f", "rainfusion") == ("rainfusion",
                                                           False)
        assert lad.trip("f", "rainfusion") == "ripple"
        assert lad.effective_policy("f", "rainfusion") == ("ripple", False)
        assert lad.trip("f", "rainfusion") == "dense"
        assert lad.trip("f", "rainfusion") is None  # floor: engine errors
        m = lad.metrics()
        assert m["degraded_count"] == 2
        assert m["dense_fallbacks"] == 1
        assert m["degraded_buckets"] == 1
        assert lad.degraded("f") and not lad.degraded("other")

    def test_cooldown_probe_and_repromotion(self):
        lad = DegradationLadder(GuardrailConfig(cooldown_batches=2))
        lad.trip("f", "ripple")
        assert lad.effective_policy("f", "ripple") == ("dense", False)
        lad.record_clean("f")
        lad.record_clean("f")  # cool-down met: next batch probes base
        assert lad.effective_policy("f", "ripple") == ("ripple", True)
        lad.record_clean("f")  # clean probe restores the base policy
        assert lad.metrics()["repromotions"] == 1
        assert not lad.degraded("f")
        assert lad.effective_policy("f", "ripple") == ("ripple", False)

    def test_failed_probe_falls_back_and_restarts_cooldown(self):
        lad = DegradationLadder(GuardrailConfig(cooldown_batches=1))
        lad.trip("f", "ripple")
        lad.record_clean("f")
        assert lad.effective_policy("f", "ripple") == ("ripple", True)
        assert lad.trip("f", "ripple") == "dense"  # probe tripped
        m = lad.metrics()
        assert m["failed_probes"] == 1 and m["repromotions"] == 0
        # parked back at dense, cool-down restarted
        assert lad.effective_policy("f", "ripple") == ("dense", False)


class TestFaultSpecGrammar:
    def test_parse_kinds_params_counts_seed(self):
        plan = parse_faults("seed=7;attn_nan:step=2;"
                            "raise:count=3,msg=transient;poison:rid=5")
        assert plan.seed == 7
        assert plan.spec("attn_nan").param("step") == 2
        s = plan.spec("raise")
        assert s.count == 3 and s.param("msg") == "transient"
        assert plan.spec("poison").count == -1  # unlimited by default
        assert plan.spec("kill_replica") is None

    def test_unknown_kind_and_malformed_param_fail_loudly(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            parse_faults("attn_nam:step=1")
        with pytest.raises(ValueError, match="malformed fault param"):
            parse_faults("hang:seconds")

    def test_take_respects_counts(self):
        plan = parse_faults("raise:count=2")
        assert plan.take("raise") is not None
        assert plan.take("raise") is not None
        assert plan.take("raise") is None  # exhausted
        assert plan.counters() == {"fault_raise": 2}
        unlimited = FaultPlan(parse_faults("poison:rid=1").specs)
        for _ in range(5):
            assert unlimited.take("poison") is not None

    def test_install_and_clear(self):
        fault_lib.install_faults("hang:seconds=1")
        assert fault_lib.active_faults().spec("hang") is not None
        fault_lib.clear_faults()
        assert fault_lib.active_faults() is None


class TestAttnNanInjection:
    def test_traced_flip_fires_only_at_armed_step(self):
        from repro.core.dispatch import _inject_attn_nan

        out = jnp.ones((2, 8))
        assert bool(jnp.isfinite(_inject_attn_nan(out, 1)).all())  # unarmed
        fault_lib.install_faults("attn_nan:step=1")
        assert not bool(jnp.isfinite(_inject_attn_nan(out, 1)).any())
        assert bool(jnp.isfinite(_inject_attn_nan(out, 0)).all())
        assert fault_lib.active_faults().counters()["fault_attn_nan"] >= 1


def _nan_under_sparse_factory(healthy=None):
    """Policy-aware toy factory: the base (sparse) policy emits NaNs —
    unless ``healthy`` says the 'kernel bug' is fixed — while the dense
    rung is always clean.  The exact shape of a real sparse-backend NaN
    as the ladder sees it."""
    def factory(latent_shape, steps, policy=None):
        def fn(noise, txt, rngs):
            if policy != "dense" and not (healthy or {}).get("fixed"):
                return jnp.full_like(noise, jnp.nan)
            return jnp.zeros_like(noise)
        return fn
    return factory


class TestEngineEscalation:
    def test_sentinel_trip_degrades_to_dense_and_completes(self):
        eng = DiffusionEngine(sampler_factory=_nan_under_sparse_factory(),
                              max_batch=2, max_wait_s=0.01, guardrail=True)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), steps=2,
                              latent_shape=(4,)))
        r = eng.result(0, timeout=30)
        eng.stop()
        assert np.all(np.isfinite(r.latents))
        assert r.degraded is True
        m = eng.metrics()
        assert m["degraded_count"] == 1 and m["dense_fallbacks"] == 1

    def test_degradation_is_sticky_then_repromotes(self):
        healthy = {}
        eng = DiffusionEngine(
            sampler_factory=_nan_under_sparse_factory(healthy),
            max_batch=1, max_wait_s=0.01,
            guardrail=GuardrailConfig(cooldown_batches=2))
        eng.start()
        # rid 0 trips (one rung charged), 1 rides the sticky dense rung,
        # 2 is the cool-down probe — still broken, so it falls back, and
        # 3 rides dense again while the new cool-down runs
        for rid in range(4):
            eng.submit(GenRequest(request_id=rid, txt=_txt(rid), steps=2,
                                  latent_shape=(4,)))
            r = eng.result(rid, timeout=30)
            assert np.all(np.isfinite(r.latents)) and r.degraded
        healthy["fixed"] = True  # the 'kernel bug' goes away
        eng.submit(GenRequest(request_id=4, txt=_txt(4), steps=2,
                              latent_shape=(4,)))
        assert eng.result(4, timeout=30).degraded is False  # clean probe
        eng.submit(GenRequest(request_id=5, txt=_txt(5), steps=2,
                              latent_shape=(4,)))
        r = eng.result(5, timeout=30)
        eng.stop()
        assert r.degraded is False  # back on the base policy for good
        m = eng.metrics()
        assert m["degraded_count"] == 1  # exactly one rung ever charged
        assert m["repromotions"] == 1 and m["failed_probes"] == 1

    def test_dense_floor_failure_errors_not_loops(self):
        def factory(latent_shape, steps, policy=None):
            return lambda noise, txt, rngs: jnp.full_like(noise, jnp.nan)

        eng = DiffusionEngine(sampler_factory=factory, max_batch=1,
                              max_wait_s=0.01, guardrail=True)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), steps=2,
                              latent_shape=(4,)))
        with pytest.raises(RuntimeError, match="dense floor"):
            eng.result(0, timeout=30)
        eng.stop()

    def test_guardrail_requires_policy_aware_factory(self):
        with pytest.raises(ValueError, match="policy"):
            DiffusionEngine(lambda n, t, r: n, latent_shape=(2,),
                            guardrail=True)

    def test_hang_fault_trips_watchdog_and_marks_unhealthy(self):
        fault_lib.install_faults("hang:seconds=2")

        def sample_fn(noise, txt, rngs):
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01, batch_timeout_s=0.2)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        with pytest.raises(RuntimeError, match="watchdog") as ei:
            eng.result(0, timeout=30)
        assert is_failover_error(ei.value)  # the router would requeue it
        assert eng.healthy() is False
        assert eng.metrics()["watchdog_trips"] == 1
        eng.stop()

    def test_transient_raise_fault_is_retried(self):
        fault_lib.install_faults("raise:count=1,msg=flaky-driver")

        def sample_fn(noise, txt, rngs):
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01, max_retries=1,
                              retry_backoff_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        r = eng.result(0, timeout=30)
        eng.stop()
        assert r.latents.shape == (2,)
        assert eng.metrics()["batch_retries"] == 1

    def test_poison_request_quarantined_alone_by_bisection(self):
        fault_lib.install_faults("poison:rid=2")

        def sample_fn(noise, txt, rngs):
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=4,
                              max_wait_s=0.05, max_retries=0,
                              retry_backoff_s=0.01)
        for rid in range(3):  # queue before start: one 3-request batch
            eng.submit(GenRequest(request_id=rid, txt=_txt(rid)))
        eng.start()
        for rid in (0, 1):  # batchmates survive the bisection
            assert eng.result(rid, timeout=30).latents.shape == (2,)
        with pytest.raises(RuntimeError, match="poison"):
            eng.result(2, timeout=30)
        eng.stop()
        assert eng.metrics()["quarantined"] == 1
